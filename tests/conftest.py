"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the 1 real CPU
device (the 512-device setting is exclusively the dry-run entry point)."""
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "hyp: property-based tests (need the optional hypothesis dep; "
        "run with -m hyp, excluded from tier-1 via -m 'not hyp')")
    config.addinivalue_line(
        "markers", "slow: long-running tests, excluded from quick loops")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
