"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the 1 real CPU
device (the 512-device setting is exclusively the dry-run entry point)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
