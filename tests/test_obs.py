"""Observability layer: span tracer (exact timings via injected clocks,
ring bounds, Chrome-trace schema), mergeable metrics registry (snapshot
isolation, associative merge), the scheduler's schema-driven telemetry
contract, and the zero-new-device-syncs guarantee of tracing the serving
hot path."""
import json
import time

import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.obs.clock import ManualClock
from repro.obs.metrics import (DEFAULT_BOUNDS, MetricsRegistry,
                               merge_snapshots)
from repro.obs.trace import (NULL_TRACER, SpanTracer, validate_chrome_trace)
from repro.launch.obs_report import summarize
from repro.launch.obs_report import main as obs_report_main
from repro.models.transformer import init_params
from repro.serve.scheduler import TELEMETRY_SCHEMA, ServeScheduler
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, init_train_state

from test_serve import _cfg, _request_material


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_tracer_manual_clock_exact_timings():
    """Injected clock -> exact ts/dur in microseconds, no tolerances."""
    clk = ManualClock()
    tr = SpanTracer(clock=clk)
    with tr.span("outer"):
        clk.advance(1.0)
        with tr.span("inner", row=3) as sp:
            clk.advance(0.5)
            sp.set(bucket=16)
        clk.advance(0.25)
    inner, outer = tr.events()               # inner exits first
    assert inner["name"] == "inner" and outer["name"] == "outer"
    assert inner["ts"] == pytest.approx(1.0e6)
    assert inner["dur"] == pytest.approx(0.5e6)
    assert inner["args"] == {"row": 3, "bucket": 16}
    assert outer["ts"] == pytest.approx(0.0)
    assert outer["dur"] == pytest.approx(1.75e6)
    # positional nesting: inner's range sits inside outer's on one tid
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert inner["tid"] == outer["tid"]


def test_tracer_instant_counter_and_clear():
    clk = ManualClock()
    tr = SpanTracer(clock=clk)
    clk.advance(2.0)
    tr.instant("admission", rid=1)
    tr.counter("queue_depth", 4)
    ev_i, ev_c = tr.events()
    assert ev_i["ph"] == "i" and ev_i["s"] == "t"
    assert ev_i["ts"] == pytest.approx(2.0e6)
    assert ev_c["ph"] == "C" and ev_c["args"] == {"value": 4}
    # clear re-anchors the epoch: new events start at ts 0 again
    tr.clear()
    assert len(tr) == 0
    tr.instant("after")
    assert tr.events()[0]["ts"] == pytest.approx(0.0)


def test_tracer_ring_bounds_and_drop_count():
    tr = SpanTracer(clock=ManualClock(), capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr) == 4
    assert tr.dropped == 6
    assert [e["name"] for e in tr.events()] == ["e6", "e7", "e8", "e9"]
    assert tr.to_chrome_trace()["otherData"]["dropped_events"] == 6


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    assert len(NULL_TRACER) == 0
    sp = NULL_TRACER.span("x", a=1)
    assert NULL_TRACER.span("y") is sp       # shared instance, no alloc
    with sp:
        sp.set(b=2)
    NULL_TRACER.instant("i")
    NULL_TRACER.counter("c", 1)
    NULL_TRACER.clear()
    assert len(NULL_TRACER) == 0


def test_validate_chrome_trace_accepts_tracer_output(tmp_path):
    clk = ManualClock()
    tr = SpanTracer(clock=clk)
    with tr.span("step"):
        clk.advance(0.1)
    tr.instant("finish", rid=0)
    tr.counter("queue_depth", 0)
    doc = tr.to_chrome_trace()
    assert validate_chrome_trace(doc) == []
    # and the round-trip through save() stays valid JSON + schema
    path = tmp_path / "t.json"
    tr.save(str(path))
    assert validate_chrome_trace(json.loads(path.read_text())) == []


def test_validate_chrome_trace_rejects_malformed():
    assert validate_chrome_trace([]) != []               # root not object
    assert validate_chrome_trace({}) != []               # no traceEvents
    assert validate_chrome_trace({"traceEvents": {}}) != []
    good = {"name": "x", "ph": "i", "ts": 0.0, "pid": 1, "tid": 1}
    for mutation, frag in (
            (dict(good, ph="Z"), "bad ph"),
            (dict(good, ts=-1.0), "bad ts"),
            (dict(good, name=""), "name"),
            (dict(good, pid="1"), "pid"),
            ({"name": "x", "ph": "X", "ts": 0.0, "pid": 1, "tid": 1},
             "dur"),                                     # X without dur
            (dict(good, ph="C"), "args"),                # C without args
            (dict(good, args=[1]), "args"),
    ):
        problems = validate_chrome_trace({"traceEvents": [mutation]})
        assert any(frag in p for p in problems), (mutation, problems)
    # metadata-only trace is "valid but empty" -> flagged by default,
    # accepted when emptiness is expected
    meta_only = {"traceEvents": [
        {"name": "process_name", "ph": "M", "ts": 0, "pid": 1, "tid": 0}]}
    assert validate_chrome_trace(meta_only) != []
    assert validate_chrome_trace(meta_only, require_nonempty=False) == []


def test_span_overhead_bounded():
    """Tracing must stay a clock read + append: the per-span cost bound
    here is what makes --trace safe on the serving hot path."""
    tr = SpanTracer()
    n = 2000
    t0 = time.perf_counter()
    for i in range(n):
        with tr.span("step", i=i):
            pass
    per_span = (time.perf_counter() - t0) / n
    assert len(tr) == n
    assert per_span < 200e-6, f"span overhead {per_span*1e6:.1f}us/span"


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_basics():
    reg = MetricsRegistry()
    c = reg.counter("serve.steps")
    c.inc()
    c.inc(4)
    assert reg.counter("serve.steps") is c and c.value == 5
    g = reg.gauge("jit.compile_s")
    g.set(1.5)
    g.set(2.5)
    assert g.value == 2.5 and g.seq == 2
    h = reg.histogram("serve.queue_depth")
    for v in (0, 1, 3, 700):
        h.observe(v)
    assert h.count == 4 and h.total == 704
    assert h.vmin == 0 and h.vmax == 700
    assert h.mean == pytest.approx(176.0)
    assert sum(h.counts) == 4
    assert reg.names("serve.") == ["serve.queue_depth", "serve.steps"]
    reg.reset(prefix="serve.")
    assert c.value == 0 and h.count == 0
    assert g.value == 2.5                    # outside the reset prefix


def test_registry_type_and_bounds_conflicts():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    reg.histogram("h", bounds=(1, 2))
    with pytest.raises(ValueError):
        reg.histogram("h", bounds=(1, 2, 3))
    with pytest.raises(ValueError):
        reg.histogram("bad", bounds=(2, 1))  # not strictly increasing


def test_snapshot_is_deep_and_non_aliasing():
    reg = MetricsRegistry()
    reg.counter("c").inc(3)
    reg.histogram("h").observe(2)
    s1 = reg.snapshot()
    s2 = reg.snapshot()
    # mutating a snapshot never perturbs the registry or other snapshots
    s1["c"]["value"] = 999
    s1["h"]["counts"][0] = 999
    s1["h"]["bounds"][0] = -1
    assert reg.counter("c").value == 3
    assert reg.histogram("h").counts[0] == 0
    assert s2["c"]["value"] == 3
    assert s2["h"]["counts"] is not s1["h"]["counts"]
    assert s2["h"]["bounds"][0] == DEFAULT_BOUNDS[0]


def _apply(ops):
    """Replay (kind, value) ops into a fresh registry, return snapshot."""
    reg = MetricsRegistry()
    for kind, v in ops:
        if kind == 0:
            reg.counter("c").inc(v)
        elif kind == 1:
            reg.gauge("g").set(v)
        else:
            reg.histogram("h").observe(v)
    return reg.snapshot()


def test_merge_deterministic_properties():
    a = _apply([(0, 3), (2, 5), (2, 5000)])
    b = _apply([(0, 4), (1, 7.0)])
    c = _apply([(2, 1)])
    # identity: merging one snapshot copies it (non-aliasing)
    m = merge_snapshots(a)
    assert m == a
    m["h"]["counts"][0] = 77
    assert a["h"]["counts"][0] != 77
    # commutative + associative over a mixed group
    ab_c = merge_snapshots(merge_snapshots(a, b), c)
    a_bc = merge_snapshots(a, merge_snapshots(b, c))
    cba = merge_snapshots(c, b, a)
    assert ab_c == a_bc == cba
    assert ab_c["c"]["value"] == 7
    assert ab_c["h"]["count"] == 3
    assert ab_c["h"]["min"] == 1 and ab_c["h"]["max"] == 5000
    # gauge: larger (seq, value) wins regardless of order
    g1 = _apply([(1, 5.0), (1, 2.0)])        # seq 2, value 2.0
    g2 = _apply([(1, 9.0)])                  # seq 1, value 9.0
    assert merge_snapshots(g1, g2)["g"]["value"] == 2.0
    assert merge_snapshots(g2, g1)["g"]["value"] == 2.0


def test_merge_type_and_bounds_mismatch_raise():
    with pytest.raises(ValueError):
        merge_snapshots({"x": {"type": "counter", "value": 1}},
                        {"x": {"type": "gauge", "value": 1, "seq": 1}})
    h1 = MetricsRegistry()
    h1.histogram("h", bounds=(1, 2)).observe(1)
    h2 = MetricsRegistry()
    h2.histogram("h", bounds=(1, 3)).observe(1)
    with pytest.raises(ValueError):
        merge_snapshots(h1.snapshot(), h2.snapshot())


@pytest.mark.hyp
@settings(max_examples=50, deadline=None)
@given(st.lists(st.lists(st.tuples(st.integers(0, 2),
                                   st.integers(0, 10_000)),
                         max_size=8),
                min_size=3, max_size=3),
       st.permutations([0, 1, 2]))
def test_merge_associative_and_order_independent(shard_ops, order):
    """Any grouping / ordering of per-shard snapshots merges to the same
    total — the property that makes the registry shardable."""
    snaps = [_apply(ops) for ops in shard_ops]
    left = merge_snapshots(merge_snapshots(snaps[0], snaps[1]), snaps[2])
    right = merge_snapshots(snaps[0], merge_snapshots(snaps[1], snaps[2]))
    permuted = merge_snapshots(*[snaps[i] for i in order])
    assert left == right
    # gauge values may legitimately differ across orders only when two
    # shards tie on seq; merge breaks the tie by value, making even that
    # deterministic — so full equality must hold
    assert left == permuted


# ---------------------------------------------------------------------------
# scheduler telemetry contract
# ---------------------------------------------------------------------------

def _drained_sched(tracer=None, n_req=3, **kw):
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    kw.setdefault("n_slots", 2)
    kw.setdefault("capacity", 64)
    kw.setdefault("buckets", (8, 16, 32))
    sched = ServeScheduler(params, cfg, tracer=tracer, **kw)
    reqs = [_request_material(seed=20 + i, n_ctx=3, k=3)
            for i in range(n_req)]
    rids = [sched.submit(ctx, cands) for ctx, cands in reqs]
    return sched, params, rids


def test_telemetry_keys_match_schema():
    sched, _, _ = _drained_sched()
    sched.run()
    tel = sched.telemetry()
    assert set(tel) == set(TELEMETRY_SCHEMA)


def test_reset_telemetry_zeroes_every_schema_key():
    """The reset contract is data, not prose: every key the schema marks
    resettable returns exactly its documented zero after
    ``reset_telemetry()``; config/state keys are left meaningful."""
    sched, _, _ = _drained_sched()
    sched.run()
    assert sched.telemetry()["steps"] > 0
    sched.reset_telemetry()
    tel = sched.telemetry()
    for key, spec in TELEMETRY_SCHEMA.items():
        if "reset" not in spec:
            continue                         # config/state: not resettable
        want = spec["reset"]
        if want == "zero_map":
            assert all(v == 0 for v in tel[key].values()), (key, tel[key])
        else:
            assert tel[key] == want, (key, tel[key], want)


def test_telemetry_snapshot_does_not_alias_scheduler_state():
    sched, _, _ = _drained_sched()
    sched.run()
    tel = sched.telemetry()
    tel["bucket_steps"][8] = 999_999
    tel["watchdog_rows"].append(7)
    tel["watchdog_stuck_rids"].append(7)
    fresh = sched.telemetry()
    assert fresh["bucket_steps"].get(8) != 999_999
    assert 7 not in fresh["watchdog_rows"]
    assert 7 not in fresh["watchdog_stuck_rids"]


# ---------------------------------------------------------------------------
# tracing the serving hot path
# ---------------------------------------------------------------------------

def test_scheduler_drain_traces_nested_spans_and_events():
    """Acceptance mirror of ``serve_bench --trace``: a drain must emit
    scheduler-step spans nesting the per-unit prefill-chunk/burst spans,
    plus admission and hot-swap instants, and the document must pass the
    schema gate CI runs."""
    tracer = SpanTracer()
    sched, params, rids = _drained_sched(tracer=tracer)
    sched.step()                             # some pre-swap progress
    sched.update_params(params, version=2)   # hot_swap instant mid-drain
    res = sched.run()
    assert set(res) == set(rids)

    doc = sched.tracer.to_chrome_trace()
    assert validate_chrome_trace(doc) == []
    evs = doc["traceEvents"]
    steps = [e for e in evs if e["ph"] == "X"
             and e["name"] == "scheduler.step"]
    units = [e for e in evs if e["ph"] == "X"
             and e["name"] in ("prefill_chunk", "burst")]
    instants = {e["name"] for e in evs if e["ph"] == "i"}
    assert steps and units
    assert {"submit", "admission", "hot_swap", "finish"} <= instants
    # every unit span nests (positionally, same thread) inside a step span
    for u in units:
        assert any(s["tid"] == u["tid"]
                   and s["ts"] <= u["ts"]
                   and u["ts"] + u["dur"] <= s["ts"] + s["dur"]
                   for s in steps), u
    # the dispatched step spans carry their jit bucket
    assert any("args" in s and "bucket" in s["args"] for s in steps)
    # and queue depth was emitted as a counter series
    assert any(e["ph"] == "C" and e["name"] == "queue_depth" for e in evs)


def _count_syncs(monkeypatch, tracer):
    """Drain a scheduler while counting host<->device sync points:
    np.asarray on device arrays + jax.block_until_ready."""
    counts = {"asarray": 0, "block": 0}
    real_asarray, real_block = np.asarray, jax.block_until_ready

    def counting_asarray(a, *args, **kw):
        if isinstance(a, jax.Array):
            counts["asarray"] += 1
        return real_asarray(a, *args, **kw)

    def counting_block(x):
        counts["block"] += 1
        return real_block(x)

    monkeypatch.setattr(np, "asarray", counting_asarray)
    monkeypatch.setattr(jax, "block_until_ready", counting_block)
    try:
        sched, _, rids = _drained_sched(tracer=tracer)
        res = sched.run()
    finally:
        monkeypatch.undo()
    scores = np.asarray([res[r].scores for r in rids])
    return counts, scores


def test_tracing_adds_zero_device_syncs(monkeypatch):
    """The hard requirement on the tentpole: with tracing enabled the
    serving hot path performs exactly the same device syncs as untraced
    (the one-step-behind harvest ``np.asarray`` stays the only one)."""
    base, scores0 = _count_syncs(monkeypatch, tracer=None)
    tr = SpanTracer()
    traced, scores1 = _count_syncs(monkeypatch, tracer=tr)
    assert traced == base, (traced, base)
    assert base["block"] == 0                # block only in warmup()
    assert base["asarray"] > 0               # harvest syncs happened
    np.testing.assert_array_equal(scores0, scores1)
    assert len(tr) > 0 and tr.dropped == 0


# ---------------------------------------------------------------------------
# trainer compile/steady split
# ---------------------------------------------------------------------------

def test_trainer_compile_vs_steady_split():
    params = {"w": np.zeros(2, np.float32)}
    state = init_train_state(params, OptimizerConfig(lr=1e-3))
    sleeps = iter([0.05, 0.002, 0.002, 0.002])

    def step_fn(state, batch, rng):
        time.sleep(next(sleeps))             # first "step" = compile
        return state, {"loss": np.float32(0.5)}

    tr = SpanTracer()
    trainer = Trainer(step_fn, state, log_every=100, tracer=tr)
    trainer.run(iter([{}] * 4), n_steps=4)
    t = trainer.timing()
    assert trainer.compile_s is not None and trainer.compile_s >= 0.05
    assert t["steady_steps"] == 3
    assert 0 < t["step_s"] < t["compile_s"]
    assert len(trainer.history) == 4
    spans = [e for e in tr.events() if e["name"] == "train.step"]
    assert [e["args"]["step"] for e in spans] == [1, 2, 3, 4]


# ---------------------------------------------------------------------------
# obs_report CLI
# ---------------------------------------------------------------------------

def test_obs_report_summarize_and_cli(tmp_path, capsys):
    clk = ManualClock()
    tr = SpanTracer(clock=clk)
    for _ in range(3):
        with tr.span("scheduler.step"):
            clk.advance(0.002)
        tr.instant("admission", rid=1)
        tr.counter("queue_depth", 2)
    s = summarize(tr.to_chrome_trace())
    assert s["spans"]["scheduler.step"]["count"] == 3
    assert s["spans"]["scheduler.step"]["mean_ms"] == pytest.approx(2.0)
    assert s["instants"] == {"admission": 3}
    assert s["counters_last"] == {"queue_depth": 2}
    assert s["dropped_events"] == 0

    path = tmp_path / "trace.json"
    out_json = tmp_path / "summary.json"
    tr.save(str(path))
    assert obs_report_main([str(path), "--json", str(out_json)]) == 0
    assert "scheduler.step" in capsys.readouterr().out
    assert json.loads(out_json.read_text())["instants"] == {"admission": 3}


def test_obs_report_rejects_malformed(tmp_path, capsys):
    broken = tmp_path / "broken.json"
    broken.write_text("{not json")
    assert obs_report_main([str(broken)]) == 1
    invalid = tmp_path / "invalid.json"
    invalid.write_text(json.dumps({"traceEvents": [{"ph": "Z"}]}))
    assert obs_report_main([str(invalid)]) == 1
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"traceEvents": []}))
    assert obs_report_main([str(empty)]) == 1
    capsys.readouterr()
