"""ObjectStore publish/subscribe fault injection, and version-pure swaps.

The publisher/subscriber contract (``repro.stream.publish``): a serving
shard must keep scoring no matter what the store does — a torn write, a
GC'd version, a gap in the sequence, an unreachable store — and a fleet
configured with ``drain_before_swap`` must never score one request under
two weight versions. Each fault here is injected the way it happens in
production (a truncated ``arrays.npz`` behind an intact ``meta.json`` is
exactly what a crashed copy leaves), and each regression test pins
behavior that the pre-fix code got wrong: ``poll`` used to propagate the
``np.load`` failure, and a no-drain scheduler demonstrably mixes versions
inside a straddling request.

Runs on one device — the multi-device fleet versions live in
tests/test_multihost.py.
"""
import os

import jax
import numpy as np
import pytest

from repro.models.transformer import init_params
from repro.serve.scheduler import ServeScheduler
from repro.stream.publish import (LocalDirStore, ObjectStore, ParamPublisher,
                                  ParamSubscriber)

from test_serve import _cfg, _request_material


def _params(seed, cfg):
    return init_params(jax.random.PRNGKey(seed), cfg)


def _tree_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)


def _corrupt_arrays(directory, version):
    """A torn write: the array payload is truncated but ``meta.json``
    survives, so the version still lists as complete."""
    path = os.path.join(directory, f"step_{version:010d}", "arrays.npz")
    with open(path, "r+b") as f:
        f.truncate(16)
    return path


class TestStoreFaults:

    def test_torn_write_is_skipped_not_raised(self, tmp_path):
        """A corrupt newest version must not take the subscriber down
        (pre-fix ``poll`` propagated the load error): it lands in
        ``skipped`` and the subscriber falls back to the newest *good*
        version in the same poll."""
        cfg = _cfg()
        p0, p1 = _params(0, cfg), _params(1, cfg)
        pub = ParamPublisher(str(tmp_path))
        pub.publish(0, p0)
        pub.publish(1, p1)
        _corrupt_arrays(str(tmp_path), 1)

        sub = ParamSubscriber(str(tmp_path), p0)
        got = sub.poll()
        assert got is not None and got[0] == 0
        _tree_equal(got[1], p0)
        assert sub.skipped == [1]

    def test_bad_version_never_reread_and_recovery(self, tmp_path):
        """After skipping a torn version the subscriber neither re-reads it
        on later polls nor gets stuck: the next good publish delivers."""
        cfg = _cfg()
        p0, p2 = _params(0, cfg), _params(2, cfg)
        pub = ParamPublisher(str(tmp_path))
        pub.publish(0, p0)
        pub.publish(1, _params(1, cfg))
        _corrupt_arrays(str(tmp_path), 1)

        sub = ParamSubscriber(str(tmp_path), p0, version=0)
        assert sub.poll() is None            # only the torn v1 is newer
        assert sub.poll() is None            # not re-read, not raised
        assert sub.skipped == [1]
        pub.publish(2, p2)
        got = sub.poll()
        assert got is not None and got[0] == 2
        _tree_equal(got[1], p2)

    def test_version_gap_is_not_an_error(self, tmp_path):
        """Versions need not be consecutive (keep-k GC, skipped publishes):
        the subscriber simply takes the newest readable one."""
        cfg = _cfg()
        p0, p5 = _params(0, cfg), _params(5, cfg)
        pub = ParamPublisher(str(tmp_path))
        pub.publish(0, p0)
        pub.publish(5, p5)

        sub = ParamSubscriber(str(tmp_path), p0)
        got = sub.poll()
        assert got is not None and got[0] == 5
        assert sub.poll() is None

    def test_unreachable_store_keeps_serving(self):
        """A store whose listing itself fails (network mount gone) polls as
        None — the shard keeps its current weights."""

        class DownStore(ObjectStore):
            def versions(self):
                raise OSError("store unreachable")

        sub = ParamSubscriber(DownStore(), template=None)
        assert sub.poll() is None

    def test_keep_k_gc_never_strands_a_slow_subscriber(self, tmp_path):
        """Publishing past ``keep`` GCs old versions; a subscriber that
        slept through all of them still lands on the newest survivor."""
        cfg = _cfg()
        ps = [_params(i, cfg) for i in range(5)]
        store = LocalDirStore(str(tmp_path), keep=2)
        pub = ParamPublisher(store)
        for i, p in enumerate(ps):
            pub.publish(i, p)
        assert store.versions() == [3, 4]
        sub = ParamSubscriber(store, ps[0])
        got = sub.poll()
        assert got is not None and got[0] == 4
        _tree_equal(got[1], ps[4])


class TestDrainBeforeSwap:

    def _mid_flight(self, cfg, p_old, **kw):
        """A scheduler with one request genuinely straddling a swap: the
        single-token buckets force one decode dispatch per candidate, so
        after one step the remaining candidates are still in flight."""
        sched = ServeScheduler(p_old, cfg, n_slots=2, capacity=64,
                               buckets=(8,), **kw)
        ctx, cands = _request_material(seed=11, n_ctx=4, k=6)
        rid = sched.submit(ctx, cands)
        sched.step()
        assert any(r.active for r in sched._rows)
        return sched, rid

    def test_no_drain_mixes_versions(self):
        """The failure mode, demonstrated: without draining, a request in
        flight across ``update_params`` scores some candidates under each
        version — its KV context was built under the old weights and kept.
        This is the bounded-staleness default, and exactly what
        ``drain_before_swap`` exists to forbid."""
        cfg = _cfg()
        sched, rid = self._mid_flight(cfg, _params(0, cfg))
        sched.update_params(_params(1, cfg), version=1)
        res = sched.run()[rid]
        assert res.params_versions == [None, 1]

    def test_drain_before_swap_is_version_pure(self):
        """With ``drain_before_swap=True`` the same straddling request is
        finished under the old weights before the swap lands: every result
        reports exactly one version, and the drain is visible in
        telemetry."""
        cfg = _cfg()
        sched, rid = self._mid_flight(cfg, _params(0, cfg),
                                      drain_before_swap=True)
        sched.update_params(_params(1, cfg), version=1)
        res = sched.run()[rid]
        assert res.params_versions == [None]
        assert sched.params_version == 1
        tel = sched.telemetry()
        assert tel["swap_drains"] == 1
        assert tel["swap_drain_steps"] >= 1
        # and the swap still took: new work scores under the new weights
        ctx, cands = _request_material(seed=12, n_ctx=3, k=2)
        rid2 = sched.submit(ctx, cands)
        assert sched.run()[rid2].params_versions == [1]

    def test_drained_scores_equal_undisturbed_old_params_run(self):
        """Version purity is also *value* purity: the drained request's
        scores are exactly what an undisturbed old-params scheduler
        produces — the swap contributed nothing to them."""
        cfg = _cfg()
        p_old = _params(0, cfg)
        sched, rid = self._mid_flight(cfg, p_old, drain_before_swap=True)
        sched.update_params(_params(1, cfg), version=1)
        got = sched.run()[rid].scores

        plain = ServeScheduler(p_old, cfg, n_slots=2, capacity=64,
                               buckets=(8,))
        ctx, cands = _request_material(seed=11, n_ctx=4, k=6)
        rid2 = plain.submit(ctx, cands)
        want = plain.run()[rid2].scores
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_subscriber_poll_inside_drain_does_not_recurse(self, tmp_path):
        """``drain_before_swap``'s drain loop runs ``step()``, which polls
        the param source; a publish already sitting in the store must not
        re-enter ``update_params`` mid-drain (the ``_in_swap`` guard) —
        the drain finishes, then exactly one swap lands."""
        cfg = _cfg()
        p0, p1 = _params(0, cfg), _params(1, cfg)
        pub = ParamPublisher(str(tmp_path))
        sched, rid = self._mid_flight(cfg, p0, drain_before_swap=True)
        pub.publish(1, p1)
        sub = ParamSubscriber(str(tmp_path), p0)
        sched.attach_param_source(sub.poll, poll_every=1)
        res = sched.run()[rid]
        assert res.params_versions == [None]
        assert sched.params_version == 1
        assert sched.telemetry()["swap_drains"] == 1
