"""Decode-attention kernel: kernel vs dense oracle on raw operands, and the
engine's attn_impl="pallas" decode path vs dense across the full serve
matrix (GQA/MLA x window/ring x commit/no-commit x seg-isolated slates)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn.ops import decode_attention
from repro.kernels.decode_attn.ref import decode_attention_ref
from repro.models.transformer import init_params
from repro.serve.cache import init_lm_cache
from repro.serve.engine import make_decode_fn

from test_serve import _cfg


# ---------------------------------------------------------------------------
# kernel vs oracle on raw operands
# ---------------------------------------------------------------------------

def _operands(seed=0, B=3, s=5, H=4, Hk=2, D=8, Dv=8, cap=22):
    r = np.random.default_rng(seed)
    f32 = lambda *shape: jnp.asarray(r.normal(size=shape), jnp.float32)
    q, k, v = f32(B, s, H, D), f32(B, cap, Hk, D), f32(B, cap, Hk, Dv)
    qn, kn = f32(B, s, H, D), f32(B, cap, Hk, D)
    alibi = jnp.asarray(r.uniform(0.1, 1.0, H), jnp.float32)
    pos_k = np.full((B, cap), -1, np.int32)          # rows at different fill
    pos_k[0, :10] = np.arange(10)
    pos_k[1, :17] = np.arange(17)                    # row 2 stays empty
    pos_q = np.tile(np.arange(10, 10 + s, dtype=np.int32), (B, 1))
    sum_q = r.random((B, s)) < 0.4
    seg_k = np.full((B, cap), -1, np.int32)
    seg_k[0, 7:10] = [0, 0, 1]
    seg_q = np.zeros((B, s), np.int32)
    seg_q[0] = [0, 0, 1, 1, 1]
    return dict(q=q, k=k, v=v, pos_q=jnp.asarray(pos_q),
                pos_k=jnp.asarray(pos_k)), dict(
        sum_q=jnp.asarray(sum_q), seg_q=jnp.asarray(seg_q),
        seg_k=jnp.asarray(seg_k), qn=qn, kn=kn, alibi=alibi)


@pytest.mark.parametrize("window", [0, 6])
@pytest.mark.parametrize("use_nope", [False, True])
@pytest.mark.parametrize("use_seg", [False, True])
def test_kernel_matches_oracle(window, use_nope, use_seg):
    base, opt = _operands()
    kw = dict(window=window, block_size=8, interpret=True)
    ref_kw = dict(window=window)
    if use_nope:
        kw.update(is_sum_q=opt["sum_q"], q_nope=opt["qn"],
                  k_nope=opt["kn"], alibi=opt["alibi"])
        ref_kw.update(sum_q=opt["sum_q"], q_nope=opt["qn"],
                      k_nope=opt["kn"], alibi=opt["alibi"])
    if use_seg:
        kw.update(seg_q=opt["seg_q"], seg_k=opt["seg_k"])
        ref_kw.update(seg_q=opt["seg_q"], seg_k=opt["seg_k"])
    got = decode_attention(**base, **kw)
    want = decode_attention_ref(**base, **ref_kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # a fully-empty cache row must produce exactly zero output
    assert np.all(np.asarray(got)[2] == 0.0)


def test_kernel_mqa_value_dim():
    """MQA (Hk=1) with Dv != Dqk — the absorbed-MLA operand shape."""
    base, _ = _operands(Hk=1, Dv=5)
    got = decode_attention(**base, window=0, block_size=16, interpret=True)
    want = decode_attention_ref(**base, window=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_kernel_pads_ragged_capacity():
    """Capacity not divisible by the block: padded slots must act empty."""
    base, _ = _operands(cap=22)
    got = decode_attention(**base, window=0, block_size=16, interpret=True)
    want = decode_attention_ref(**base, window=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# engine: attn_impl="pallas" decode vs dense, full serve matrix
# ---------------------------------------------------------------------------

def _run_sequence(cfg, params, decode, *, seed, window, burst):
    """Chunked commits then (optionally) a seg-isolated non-commit burst
    with one invalid padding slot; returns the per-step score arrays."""
    B, S = 2, 10
    r = np.random.default_rng(seed)
    toks = r.integers(8, 128, (B, S)).astype(np.int32)
    pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S)).copy()
    is_sum = toks == 9
    cache = init_lm_cache(cfg, B, 20, dtype=jnp.float32)
    outs = []
    p, cache = decode(params, cache, toks[:, :6], pos[:, :6], is_sum[:, :6])
    outs.append(np.asarray(p))
    if burst:
        bt, bp = toks[:, 6:10], pos[:, 6:10]
        bs = np.zeros((B, 4), bool)
        bs[:, 1] = bs[:, 3] = True                      # two [SUM] readouts
        seg = np.asarray([[0, 0, 1, 1]] * B, np.int32)  # two-candidate slate
        valid = np.ones((B, 4), bool)
        valid[1, 3] = False                             # right-padded row
        commit = np.zeros((B,), bool)
        p, cache = decode(params, cache, bt, bp, bs, valid, commit, seg)
        outs.append(np.asarray(p))
        # non-committing: a repeat burst must reproduce the same scores
        p2, _ = decode(params, cache, bt, bp, bs, valid, commit, seg)
        outs.append(np.asarray(p2))
    else:
        for t in range(6, S):
            p, cache = decode(params, cache, toks[:, t:t + 1],
                              pos[:, t:t + 1], is_sum[:, t:t + 1])
            outs.append(np.asarray(p))
    return outs


@pytest.mark.parametrize("attn_type", ["gqa", "mla"])
@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("burst", [False, True])
def test_pallas_decode_matches_dense(attn_type, window, burst):
    """The fused decode kernel must reproduce the dense decode path <=1e-4
    across GQA/MLA, unlimited/windowed, one-token decode and commit=False
    seg-isolated bursts with invalid padding."""
    cfg = _cfg(attn_type)
    params = init_params(jax.random.PRNGKey(0), cfg)
    dense = make_decode_fn(cfg, window=window, ring=False)
    pallas = make_decode_fn(cfg, window=window, ring=False,
                            attn_impl="pallas", block_size=8)
    want = _run_sequence(cfg, params, dense, seed=0, window=window,
                         burst=burst)
    got = _run_sequence(cfg, params, pallas, seed=0, window=window,
                        burst=burst)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=1e-4)
    if burst:   # the kernel path is non-committing too: repeat == first
        np.testing.assert_array_equal(got[1], got[2])


def test_pallas_ring_decode_matches_dense():
    """Ring cache (wrapped physical slots, monotone logical positions):
    the kernel's positional mask must not care about wrap order."""
    from repro.models.transformer import ModelConfig
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64, head_dim=16, window=8,
                      attn_impl="dense", remat=False)
    params = init_params(jax.random.PRNGKey(1), cfg)
    B, cap, W, T = 1, 12, 8, 30
    dense = make_decode_fn(cfg, window=W, ring=True)
    pallas = make_decode_fn(cfg, window=W, ring=True, attn_impl="pallas",
                            block_size=4)
    r = np.random.default_rng(1)
    toks = r.integers(8, 64, (B, T)).astype(np.int32)
    pos = np.arange(T, dtype=np.int32)[None]
    cd = init_lm_cache(cfg, B, cap, dtype=jnp.float32)
    cp = init_lm_cache(cfg, B, cap, dtype=jnp.float32)
    ns = np.zeros((B, 1), bool)
    for t in range(T):
        pd, cd = dense(params, cd, toks[:, t:t + 1], pos[:, t:t + 1], ns)
        pp, cp = pallas(params, cp, toks[:, t:t + 1], pos[:, t:t + 1], ns)
        np.testing.assert_allclose(np.asarray(pp), np.asarray(pd), atol=1e-4)


def test_pallas_decode_equals_prefill():
    """End to end: token-by-token pallas decode reproduces prefill scores
    (the decode==prefill contract, now on the kernel path)."""
    from repro.serve.engine import make_prefill_fn
    cfg = _cfg()
    p = init_params(jax.random.PRNGKey(0), cfg)
    B, S, W = 2, 12, 8
    r = np.random.default_rng(0)
    toks = r.integers(8, 128, (B, S)).astype(np.int32)
    toks[:, -1] = 2
    pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S)).copy()
    is_sum = toks == 2
    valid = np.ones((B, S), bool)
    p_pre = make_prefill_fn(cfg, window=W)(
        p, {"tokens": toks, "positions": pos, "is_sum": is_sum,
            "valid": valid})
    decode = make_decode_fn(cfg, window=W, ring=False, attn_impl="pallas",
                            block_size=4)
    cache = init_lm_cache(cfg, B, S, dtype=jnp.float32)
    for t in range(S):
        pc, cache = decode(p, cache, toks[:, t:t + 1], pos[:, t:t + 1],
                           is_sum[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(pc[:, 0]),
                               np.asarray(p_pre[:, -1]), atol=2e-5)
