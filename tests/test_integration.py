"""End-to-end integration: the paper's training pipeline on the synthetic
corpus — DTI training must learn (loss down, AUC > chance) and its [SUM]
scores must be consistent between training-style and serving-style passes."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.dti import batch_prompts, build_streaming_prompts
from repro.core.metrics import auc
from repro.data.synthetic import make_ctr_dataset, split_users
from repro.launch.train import (build_prompt_sets, evaluate_lm,
                                make_lm_loss_fn)
from repro.models.transformer import init_params
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny_run():
    cfg = dataclasses.replace(get_arch("dti-llama").smoke, n_layers=2,
                              d_model=64, d_ff=128, vocab_size=2048)
    ds = make_ctr_dataset(n_users=24, n_items=120, seq_len=40,
                          vocab_size=cfg.vocab_size, label_scale=5.0)
    splits = split_users(ds)
    train_prompts, test_prompts, test_labels, stats = build_prompt_sets(
        ds, splits, paradigm="dti", n_ctx=6, k=4, max_len=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ocfg = OptimizerConfig(lr=1e-3, schedule="cosine", warmup_steps=10,
                           total_steps=120)
    loss_fn = make_lm_loss_fn(cfg, window=0)
    state = init_train_state(params, ocfg)
    step = make_train_step(loss_fn, ocfg)
    rng = np.random.default_rng(0)
    losses = []
    batches = batch_prompts(train_prompts * 50, 16, rng=rng)
    for i in range(120):
        state, m = step(state, next(batches), jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    metrics = evaluate_lm(state.params, cfg, 0, test_prompts, test_labels)
    return losses, metrics


def test_dti_training_learns(tiny_run):
    losses, metrics = tiny_run
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.9
    assert np.isfinite(losses).all()


def test_dti_beats_chance_auc(tiny_run):
    _, metrics = tiny_run
    assert metrics["auc"] > 0.55, metrics


def test_metrics_complete(tiny_run):
    _, metrics = tiny_run
    assert set(metrics) == {"auc", "log_loss", "f1"}
    assert 0 < metrics["log_loss"] < 2.0
