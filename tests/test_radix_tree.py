"""RadixTree: path-compressed prefix index + page layer.

Deterministic unit tests plus hypothesis property tests against two
oracles: a brute-force prefix scan over the live owner sequences, and
``ContextTrie`` (the reference hash-trie) — both must agree with
``RadixTree.match`` on every query. Skipped-not-failed when hypothesis is
absent (tests/_hyp.py)."""
import pytest

from repro.data.requests import ContextTrie, RadixTree

from _hyp import HAVE_HYPOTHESIS, given, settings, st


# ---------------------------------------------------------------------------
# the depth-0 regression (the ContextTrie.match bookkeeping bug)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", [ContextTrie, RadixTree])
def test_match_depth0_reports_no_owners(cls):
    """A first-token mismatch must report through_owners == set(), not the
    root's through set (which holds every owner): a depth-0 'match' shares
    nothing, so there is nothing to reuse. Pre-fix, ContextTrie returned
    the root's through set and the admission ladder could trim a retained
    block back to an empty prefix."""
    t = cls()
    t.insert([1, 2, 3], "a")
    t.insert([4, 5], "b")
    end_d, ends, thr_d, thr = t.match([9, 9, 9])
    assert (end_d, ends) == (0, set())
    assert (thr_d, thr) == (0, set())
    # empty query is the same degenerate case
    assert t.match([]) == (0, set(), 0, set())


# ---------------------------------------------------------------------------
# owner API — deterministic
# ---------------------------------------------------------------------------

def test_radix_insert_match_remove_mirrors_trie_semantics():
    t = RadixTree()
    t.insert([1, 2, 3], "a")
    t.insert([1, 2, 3, 4, 5], "b")
    t.insert([1, 9], "c")
    end_d, ends, thr_d, thr = t.match([1, 2, 3, 4, 5, 6])
    assert (end_d, ends) == (5, {"b"}) and (thr_d, thr) == (5, {"b"})
    end_d, ends, thr_d, thr = t.match([1, 2, 3, 4])
    assert (end_d, ends) == (3, {"a"}) and (thr_d, thr) == (4, {"b"})
    end_d, ends, thr_d, thr = t.match([1, 2, 7])
    assert (end_d, ends) == (0, set()) and thr_d == 2 and thr == {"a", "b"}
    assert t.owner_length("b") == 5
    t.remove([1, 2, 3, 4, 5], "b")
    end_d, ends, thr_d, thr = t.match([1, 2, 3, 4])
    assert (end_d, ends) == (3, {"a"}) and (thr_d, thr) == (3, {"a"})
    t.remove([1, 2, 3], "a")
    t.remove([1, 9], "c")
    assert len(t) == 0 and not t._root.kids


def test_radix_partial_edge_depth_counted():
    """Path compression must not round the match depth down to a node
    boundary: a query diverging mid-edge still shares the edge's prefix."""
    t = RadixTree()
    t.insert([1, 2, 3, 4, 5, 6], "a")
    end_d, ends, thr_d, thr = t.match([1, 2, 3, 9])
    assert (end_d, ends) == (0, set())
    assert (thr_d, thr) == (3, {"a"})


def test_radix_one_sequence_per_owner():
    t = RadixTree()
    t.insert([1], "a")
    with pytest.raises(AssertionError):
        t.insert([2], "a")


def test_radix_split_preserves_owner_sets():
    """Inserting a diverging sequence splits an edge; owners covering the
    split point must appear in the upper node's through set."""
    t = RadixTree()
    t.insert([1, 2, 3, 4], "a")
    t.insert([1, 2, 9], "b")             # splits [1,2,3,4] after 2 tokens
    end_d, ends, thr_d, thr = t.match([1, 2])
    assert (end_d, ends) == (0, set())
    assert (thr_d, thr) == (2, {"a", "b"})
    t.remove([1, 2, 3, 4], "a")
    assert t.match([1, 2, 9]) == (3, {"b"}, 3, {"b"})


# ---------------------------------------------------------------------------
# owner API — property tests vs brute force and vs ContextTrie
# ---------------------------------------------------------------------------

def _oracle_match(seqs, tokens):
    """Brute-force ContextTrie.match semantics over live sequences."""
    def cpl(s):
        i = 0
        while i < len(s) and i < len(tokens) and s[i] == tokens[i]:
            i += 1
        return i
    end_depth, end_owners = 0, set()
    thr_depth = 0
    for o, s in seqs.items():
        l = cpl(s)
        thr_depth = max(thr_depth, l)
        if l and l == len(s):
            if l > end_depth:
                end_depth, end_owners = l, {o}
            elif l == end_depth:
                end_owners.add(o)
    if thr_depth == 0:
        return 0, set(), 0, set()
    thr_owners = {o for o, s in seqs.items() if cpl(s) >= thr_depth}
    return end_depth, end_owners, thr_depth, thr_owners


_ops = st.lists(
    st.tuples(st.sampled_from(["ins", "del", "match"]),
              st.integers(0, 7),
              st.lists(st.integers(0, 3), min_size=0, max_size=10)),
    min_size=1, max_size=60)


@pytest.mark.hyp
@settings(max_examples=200, deadline=None)
@given(_ops)
def test_radix_matches_bruteforce_and_trie(ops):
    """Any interleaving of insert/remove/match agrees with the brute-force
    oracle AND with ContextTrie on every query."""
    radix, trie, live = RadixTree(), ContextTrie(), {}
    for op, owner, toks in ops:
        if op == "ins" and owner not in live and toks:
            radix.insert(toks, owner)
            trie.insert(toks, owner)
            live[owner] = list(toks)
        elif op == "del" and owner in live:
            radix.remove(live[owner], owner)
            trie.remove(live[owner], owner)
            del live[owner]
        else:
            want = _oracle_match(live, toks)
            assert radix.match(toks) == want
            assert trie.match(toks) == want
            assert len(radix) == len(trie) == len(live)
    for o, s in live.items():
        assert radix.owner_length(o) == len(s)
        got = radix.match(s)
        assert o in got[1] and got[0] == len(s)


# ---------------------------------------------------------------------------
# page layer — deterministic
# ---------------------------------------------------------------------------

def test_attach_and_match_pages_roundtrip():
    t = RadixTree(page_size=4)
    seq = list(range(20, 31))            # 11 tokens -> 2 full pages
    new = t.attach_pages(seq, [7, 8])
    assert new == [7, 8] and t.held_pages() == 2
    assert t.match_pages(seq) == (8, [7, 8])
    # a shorter query only reaches the pages it covers
    assert t.match_pages(seq[:6]) == (4, [7])
    assert t.match_pages(seq[:3]) == (0, [])
    # diverging queries stop at the divergence
    assert t.match_pages(seq[:4] + [99] * 6) == (4, [7])
    assert t.match_pages([99]) == (0, [])
    # re-attaching the same prefix adopts nothing new, even with fresh ids
    assert t.attach_pages(seq, [7, 9]) == []
    assert t.match_pages(seq) == (8, [7, 8])


def test_attach_pages_extends_a_published_prefix():
    t = RadixTree(page_size=2)
    assert t.attach_pages([1, 2, 3, 4], [5, 6]) == [5, 6]
    # a longer commit of the same prefix publishes only the new tail pages
    assert t.attach_pages([1, 2, 3, 4, 7, 8], [5, 6, 9]) == [9]
    assert t.match_pages([1, 2, 3, 4, 7, 8, 0]) == (6, [5, 6, 9])


def test_evict_pages_lru_and_refcount_gate():
    import numpy as np
    t = RadixTree(page_size=2)
    t.attach_pages([1, 2, 3, 4], [0, 1])
    t.attach_pages([8, 9], [2])
    t.match_pages([1, 2, 3, 4])          # touch -> [8,9] is now LRU
    ref = np.array([1, 1, 1], np.int32)
    assert t.evict_pages(1, ref) == [2]  # LRU node first
    # deepest-first within a node: page index 1 before 0
    assert t.evict_pages(1, ref) == [1]
    # a page something else still references (ref > 1) is never evicted
    ref = np.array([2, 2, 2], np.int32)
    assert t.evict_pages(5, ref) == []
    assert t.match_pages([1, 2]) == (2, [0])


def test_owner_removal_keeps_page_nodes():
    """A stolen row's prefix stays indexed: removing the owner must not
    prune nodes that still hold pages (the cross-row reuse guarantee)."""
    t = RadixTree(page_size=2)
    t.insert([1, 2, 3, 4], "row0")
    t.attach_pages([1, 2, 3, 4], [5, 6])
    t.remove([1, 2, 3, 4], "row0")
    assert len(t) == 0
    assert t.match_pages([1, 2, 3, 4]) == (4, [5, 6])
    # owner queries see nothing (no committed row), pages still there
    assert t.match([1, 2, 3, 4])[1] == set()
    assert set(t.drop_all_pages()) == {5, 6}
    assert t.held_pages() == 0 and not t._root.kids


# ---------------------------------------------------------------------------
# page layer — property test vs a flat-dict oracle
# ---------------------------------------------------------------------------

_PS = 2
_page_ops = st.lists(
    st.tuples(st.sampled_from(["attach", "match"]),
              st.lists(st.integers(0, 2), min_size=0, max_size=8)),
    min_size=1, max_size=40)


@pytest.mark.hyp
@settings(max_examples=150, deadline=None)
@given(_page_ops)
def test_page_layer_matches_flat_oracle(ops):
    """attach/match agree with a flat dict keyed by the page's covering
    token tuple (the semantic content of the radix page index)."""
    t = RadixTree(page_size=_PS)
    flat = {}                            # tuple(tokens[:i*ps]) -> pid
    next_pid = [0]
    for op, toks in ops:
        n_full = len(toks) // _PS
        if op == "attach":
            pids = []
            for i in range(n_full):
                key = tuple(toks[:(i + 1) * _PS])
                if key not in flat:
                    flat[key] = next_pid[0]
                    next_pid[0] += 1
                pids.append(flat[key])
            got_new = t.attach_pages(toks, pids)
            assert set(got_new) <= set(pids)
        else:
            covered, pages = t.match_pages(toks)
            assert covered == len(pages) * _PS
            # every matched page is the indexed page for that exact prefix
            for i, pid in enumerate(pages):
                assert flat.get(tuple(toks[:(i + 1) * _PS])) == pid
            # maximality: if the oracle knows the next page, so must we
            nxt = tuple(toks[:(len(pages) + 1) * _PS])
            if len(nxt) == (len(pages) + 1) * _PS:
                assert nxt not in flat


if not HAVE_HYPOTHESIS:                   # pragma: no cover
    pass
