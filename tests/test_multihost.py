"""Multi-device scale-out lane: sharded serving == single-device serving.

Every test here needs 8 devices; normal single-CPU runs skip the whole
module, and the ``tier1-multidevice`` CI job provides them via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set in the job's
environment — it must land before the first jax import, so an in-test
``os.environ`` write is too late). On that runtime the schedulers place
their KV caches and params on a real ``(2, 4)`` ``(data, model)`` mesh
(``repro.launch.mesh.make_serve_mesh`` /
``repro.sharding.partition.cache_specs``), and the acceptance bar is the
same one every serving feature answers to: scores must match the
single-device drain (docs/sharding.md).
"""
import jax
import numpy as np
import pytest

from repro.data.requests import make_request_stream
from repro.data.synthetic import make_ctr_dataset
from repro.launch.mesh import make_serve_mesh
from repro.models.transformer import init_params
from repro.serve.scheduler import ServeScheduler
from repro.stream.publish import ParamPublisher, replicated_subscribers
from repro.stream.shard import fleet_serve_snapshot, shard_key

from test_serve import _cfg

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _reqs(cfg, *, n=4, seed=3, repeat_frac=0.25):
    ds = make_ctr_dataset(n_users=4, n_items=30, seq_len=10,
                          vocab_size=cfg.vocab_size)
    return make_request_stream(ds, n_requests=n, k=2, n_ctx=3, seed=seed,
                               repeat_frac=repeat_frac)


def _drain(params, cfg, reqs, *, mesh=None, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("capacity", 64)
    kw.setdefault("buckets", (8, 16))
    s = ServeScheduler(params, cfg, mesh=mesh, **kw)
    rids = [s.submit(r["context"], r["candidates"]) for r in reqs]
    out = s.run()
    return np.asarray([out[r].scores for r in rids]), s


class TestShardedEqualsUnsharded:
    """The 16-cell equivalence matrix: every serving configuration —
    decode impl x attention family x cache layout x KV dtype — must score
    identically (<= 1e-4) on the (2, 4) mesh and on one device. GSPMD may
    only reorder floating-point reductions; anything larger means a leaf
    was given a semantically-unsafe layout (the whole-head granularity
    rule of ``serve_param_specs`` exists because exactly that happened:
    sub-head sharding of the fused k projection drifted by ~1e-1)."""

    @pytest.mark.parametrize("kv_dtype", [None, "int8"])
    @pytest.mark.parametrize("layout", ["contiguous", "paged"])
    @pytest.mark.parametrize("attn_type", ["gqa", "mla"])
    @pytest.mark.parametrize("attn_impl", ["dense", "pallas"])
    def test_matrix(self, attn_impl, attn_type, layout, kv_dtype):
        cfg = _cfg(attn_type)
        params = init_params(jax.random.PRNGKey(0), cfg)
        reqs = _reqs(cfg)
        kw = dict(attn_impl=attn_impl, kv_dtype=kv_dtype,
                  paged=layout == "paged",
                  page_size=8 if layout == "paged" else 16)
        want, _ = _drain(params, cfg, reqs, **kw)
        got, sched = _drain(params, cfg, reqs,
                            mesh=make_serve_mesh(2, 4), **kw)
        np.testing.assert_allclose(got, want, atol=1e-4)
        assert sched.telemetry()["mesh"] == {"data": 2, "model": 4}

    def test_pool_pressure_on_sharded_slot_axis(self):
        """Eviction/adoption churn on the *sharded* global page pool: the
        reclamation paths move KV between slots that live on different
        data shards, and scores still match the single-device run."""
        cfg = _cfg()
        params = init_params(jax.random.PRNGKey(4), cfg)
        reqs = _reqs(cfg, n=10, seed=5, repeat_frac=0.3)
        kw = dict(paged=True, page_size=8, n_pages=10)
        want, _ = _drain(params, cfg, reqs, **kw)
        got, sched = _drain(params, cfg, reqs,
                            mesh=make_serve_mesh(2, 4), **kw)
        np.testing.assert_allclose(got, want, atol=1e-4)
        assert sched.telemetry()["page_evictions"] > 0


class TestFleetSwap:
    """Fleet semantics on the real mesh: replicated subscribers over one
    store, every shard draining before it swaps."""

    def test_fleet_wide_drain_before_swap_is_version_pure(self, tmp_path):
        """A publish landing while every shard has requests in flight must
        never mix weight versions inside one request, fleet-wide: each
        shard drains its in-flight work under the old params, then swaps
        (``drain_before_swap=True``), and its remaining queue scores under
        the new ones."""
        cfg = _cfg()
        p0 = init_params(jax.random.PRNGKey(0), cfg)
        p1 = init_params(jax.random.PRNGKey(1), cfg)
        mesh = make_serve_mesh(2, 4)
        reqs = _reqs(cfg, n=8, seed=7)

        pub = ParamPublisher(str(tmp_path))
        subs = replicated_subscribers(str(tmp_path), p0, 2, version=0)
        scheds = [ServeScheduler(p0, cfg, n_slots=2, capacity=64,
                                 buckets=(8, 16), mesh=mesh,
                                 drain_before_swap=True)
                  for _ in range(2)]
        rids = [[], []]
        for r in reqs:
            i = shard_key(r, 2)
            rids[i].append(scheds[i].submit(r["context"], r["candidates"]))
        for s in scheds:                 # work is genuinely in flight
            s.step()
            assert any(r.active for r in s._rows)
        pub.publish(1, p1)
        for s, sub in zip(scheds, subs):
            s.attach_param_source(sub.poll, poll_every=1)
        results = [s.run() for s in scheds]

        versions = []
        for res, ids in zip(results, rids):
            for rid in ids:
                vs = res[rid].params_versions
                assert len(vs) == 1, f"mixed versions {vs}"
                versions.append(vs[0])
        # the swap really happened on every shard (old AND new versions
        # served, each purely — the pre-publish params carry version None)
        # and the drains were counted
        assert {None, 1} <= set(versions)
        assert all(s.params_version == 1 for s in scheds)
        tel = fleet_serve_snapshot(scheds)
        assert tel["serve.swap_drains"]["value"] == 2
        assert tel["serve.swap_drain_steps"]["value"] >= 2
