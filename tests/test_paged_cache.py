"""Paged multi-tenant KV cache: page pool, page-table gather, scheduler
equivalence, radix map-in, prewarm, and the bookkeeping bugfix sweep's
regression tests (trim ring guard, double-free detection, admission-time
capacity rejection, telemetry reset)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.requests import make_request_stream
from repro.data.synthetic import make_ctr_dataset
from repro.models.transformer import init_params
from repro.serve.cache import (adopt_slots, init_lm_cache, is_paged,
                               page_size_of, physical_slots, trim_slots)
from repro.serve.pages import PagePool
from repro.serve.scheduler import ServeScheduler

from test_serve import _cfg


# ---------------------------------------------------------------------------
# PagePool
# ---------------------------------------------------------------------------

def test_pool_alloc_free_roundtrip():
    pool = PagePool(4, 8)
    a = pool.alloc(3)
    assert sorted(a) == [0, 1, 2] and pool.free_count() == 1
    assert all(pool.ref[p] == 1 for p in a)
    # short alloc: None and *no state change*
    assert pool.alloc(2) is None
    assert pool.free_count() == 1 and pool.pages_in_use() == 3
    pool.incref([a[0]])
    pool.decref([a[0]])
    assert pool.ref[a[0]] == 1           # still held by the first ref
    pool.decref(a)
    assert pool.free_count() == 4 and pool.pages_in_use() == 0
    assert (pool.ref == 0).all()
    b = pool.alloc(4)
    assert sorted(b) == [0, 1, 2, 3]
    assert pool.alloc_total == 7


def test_pool_guards_refcount_misuse():
    pool = PagePool(2, 4)
    (p,) = pool.alloc(1)
    pool.decref([p])
    with pytest.raises(AssertionError):
        pool.decref([p])                 # already free
    with pytest.raises(AssertionError):
        pool.incref([p])                 # incref on unallocated


# ---------------------------------------------------------------------------
# paged cache layout: page tables, gather map, adopt
# ---------------------------------------------------------------------------

def test_physical_slots_follow_page_table():
    cfg = _cfg()
    cache = init_lm_cache(cfg, 2, 16, dtype=jnp.float32,
                          page_size=4, n_pages=8)
    assert is_paged(cache) and page_size_of(cache) == 4
    # KV lives on a global slot axis: n_pages * page_size physical slots
    assert cache["k"].shape[1] == 32
    pt = np.full((2, 4), -1, np.int32)
    pt[0, :2] = [5, 1]                   # row 0: logical 0..7 -> pages 5,1
    pt[1, 0] = 3
    cache = dict(cache, page_table=jnp.asarray(pt))
    flat = np.asarray(physical_slots(cache))
    assert flat.shape == (2, 16)
    np.testing.assert_array_equal(flat[0, :8],
                                  [20, 21, 22, 23, 4, 5, 6, 7])
    assert (flat[0, 8:] == -1).all()
    np.testing.assert_array_equal(flat[1, :4], [12, 13, 14, 15])
    assert (flat[1, 4:] == -1).all()


def test_adopt_slots_installs_prefix_bookkeeping():
    cfg = _cfg()
    cache = init_lm_cache(cfg, 2, 8, dtype=jnp.float32)
    mask = jnp.asarray(np.array([True, False]))
    out = adopt_slots(cache, mask, jnp.asarray(np.array([5, 0], np.int32)))
    pos = np.asarray(out["pos"])
    np.testing.assert_array_equal(pos[0], [0, 1, 2, 3, 4, -1, -1, -1])
    assert (pos[1] == -1).all()          # unmasked row untouched
    assert np.asarray(out["cursor"])[0] == 5
    assert np.asarray(out["cursor"])[1] == 0


def test_trim_slots_refuses_ring_caches():
    """Satellite regression: on a ring cache slot index != committed
    order, so trimming by slot index would corrupt attendability — the
    misuse must be a named error, not silent corruption."""
    cfg = _cfg()
    cache = init_lm_cache(cfg, 1, 8, dtype=jnp.float32)
    mask = jnp.asarray(np.array([True]))
    keep = jnp.asarray(np.array([4], np.int32))
    with pytest.raises(ValueError, match=r"ring"):
        trim_slots(cache, mask, keep, ring=True)
    trim_slots(cache, mask, keep, ring=False)      # non-ring fine


# ---------------------------------------------------------------------------
# scheduler equivalence: paged scores == contiguous scores, byte for byte
# ---------------------------------------------------------------------------

def _run_stream(params, cfg, reqs, *, paged, attn_impl, overlap):
    sched = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                           buckets=(8, 16), attn_impl=attn_impl,
                           overlap=overlap, paged=paged, page_size=8)
    rids = [sched.submit(r["context"], r["candidates"]) for r in reqs]
    out = sched.run()
    return {rid: out[rid].scores for rid in rids}, sched


@pytest.mark.parametrize("attn_impl,overlap", [
    ("dense", True), ("dense", False),
    ("pallas", True), ("pallas", False),
])
def test_paged_scores_identical_to_contiguous(attn_impl, overlap):
    """The page-table gather presents byte-identical per-row views to the
    attention (dense einsums and the Pallas kernel alike), so a paged
    scheduler must reproduce the contiguous scheduler's scores exactly —
    across admission rungs, revisits, steals and chunked prefill."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = make_ctr_dataset(n_users=4, n_items=30, seq_len=10,
                          vocab_size=cfg.vocab_size)
    reqs = make_request_stream(ds, n_requests=8, k=2, n_ctx=3, seed=3,
                               repeat_frac=0.5)
    got, sched_p = _run_stream(params, cfg, reqs, paged=True,
                               attn_impl=attn_impl, overlap=overlap)
    want, _ = _run_stream(params, cfg, reqs, paged=False,
                          attn_impl=attn_impl, overlap=overlap)
    assert got == want                    # float-exact, not allclose
    assert sched_p.telemetry()["paged"] is True


def test_cache_write_drops_unmapped_sentinel():
    """A -1 write index means "this logical slot has no page — drop the
    write". jax wraps negative scatter indices numpy-style *before*
    mode="drop" applies, so a raw -1 would land on the pool's highest
    physical slot — a live page once the pool fills. Regression: the
    sentinel must remap past the pool end and leave the last slot alone."""
    from repro.serve.engine import _cache_write
    buf = jnp.zeros((16, 2))
    new = jnp.ones((1, 3, 2))
    write_idx = jnp.array([[4, -1, 5]], jnp.int32)
    out = _cache_write(buf, None, new, bidx=None, write_idx=write_idx)
    assert out[4].tolist() == [1.0, 1.0] and out[5].tolist() == [1.0, 1.0]
    assert out[15].tolist() == [0.0, 0.0]    # pre-fix: clobbered by the -1
    assert float(jnp.abs(out).sum()) == 4.0  # and nothing else was touched


def test_paged_identical_under_pool_pressure():
    """Byte-identity must survive the reclamation paths: a pool far
    smaller than slots x capacity forces index eviction and row steals,
    and the paged scheduler still reproduces contiguous scores exactly.
    Regression for the -1 write-index wrap: under pressure the pool's
    last page is live, so a wrapped pad-token write corrupts real KV
    (harmless-looking with a roomy pool, where the high pages stay
    unallocated)."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(4), cfg)
    ds = make_ctr_dataset(n_users=4, n_items=30, seq_len=10,
                          vocab_size=cfg.vocab_size)
    reqs = make_request_stream(ds, n_requests=10, k=2, n_ctx=3, seed=5,
                               repeat_frac=0.3)

    def run(paged):
        s = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                           buckets=(8, 16), paged=paged, page_size=8,
                           n_pages=10 if paged else None)
        rids = [s.submit(r["context"], r["candidates"]) for r in reqs]
        out = s.run()
        return [out[r].scores for r in rids], s.telemetry()

    got, tel = run(True)
    want, _ = run(False)
    assert got == want                    # float-exact, not allclose
    assert tel["page_evictions"] > 0      # the pressure paths actually ran


def test_cross_row_radix_hit_after_steal():
    """The tentpole guarantee: a prefix whose row was stolen is still
    served from the radix page index — zero recompute, identical scores —
    where the per-slot contiguous cache must recompute (0 cross-row
    hits)."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(1), cfg)
    ctx = [list(range(10, 30))]          # 21 tokens incl BOS: 2 full pages

    def run(paged):
        s = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                           buckets=(8, 16), paged=paged, page_size=8)
        r0 = s.submit(ctx, [[30]])
        base = s.run()[r0].scores
        for t in range(4):               # roll both rows over -> steal
            s.submit([[40 + t] * 20], [[31]])
        s.run()
        r1 = s.submit(ctx, [[30]])
        again = s.run()[r1]
        return base, again, s.telemetry()

    base_p, again_p, tel_p = run(True)
    base_c, again_c, tel_c = run(False)
    assert base_p == base_c == again_p.scores == again_c.scores
    assert tel_p["cross_row_hits"] == 1 and tel_p["cross_row_tokens"] == 16
    assert again_p.shared_prefix_tokens == 16
    assert tel_c["cross_row_hits"] == 0
    assert again_c.shared_prefix_tokens == 0
    assert tel_p["prefix_hit_rate"] > tel_c["prefix_hit_rate"]


def test_partial_trim_unindexes_the_boundary_page():
    """A sub-page partial-prefix trim (rung 3) on a row whose boundary
    page is held only by the radix index must drop the index's hold and
    recommit in place — not round the keep down to a page boundary and
    lose the share (ref == 2 means row + index; only a third holder, a
    reading row, forces alignment). Scores stay identical to contiguous
    and the dropped prefix is no longer matchable."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(5), cfg)
    ctx1 = [[10, 11, 12], [13, 14, 15], [16, 17, 18], [19, 20, 21]]
    ctx2 = [[10, 11, 12], [80, 81], [82, 83, 84]]   # shares BOS + 3 tokens

    def run(paged):
        s = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                           buckets=(8, 16), paged=paged, page_size=8)
        s.submit(ctx1, [[30]])
        s.run()
        r2 = s.submit(ctx2, [[30]])
        return s.run()[r2], s

    got, sp = run(True)
    want, _ = run(False)
    assert got.scores == want.scores
    assert got.shared_prefix_tokens == want.shared_prefix_tokens == 4
    # ctx1's published page 0 was un-indexed (rewritten under ctx2), so
    # the old full-page prefix can no longer be adopted cross-row
    flat1 = [sp.sp.bos] + [t for it in ctx1 for t in it]
    assert sp._trie.match_pages(flat1) == (0, [])


def test_prewarm_primes_the_radix_index():
    """A stream-side prewarm (candidate-less request) commits and indexes
    a hot user's prefix so the *first* real request already shares it;
    scores match a cold run exactly."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(2), cfg)
    hist = [[50, 51, 52], [53, 54, 55], [56, 57, 58], [59, 60, 61]]

    cold = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                          buckets=(8, 16), paged=True, page_size=8)
    r = cold.submit(hist, [[70, 71]])
    want = cold.run()[r].scores

    warm = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                          buckets=(8, 16), paged=True, page_size=8)
    prid = warm.prewarm(hist)
    assert prid is not None
    pre = warm.run()
    assert pre[prid].scores == []        # nothing scored, context committed
    r2 = warm.submit(hist, [[70, 71]])
    got = warm.run()[r2]
    assert got.scores == want
    assert got.shared_prefix_tokens == 13          # BOS + 12 history tokens
    assert got.prefill_tokens == 0                 # fully served from cache
    # re-warming a resident prefix is a no-op
    assert warm.prewarm(hist) is None
    # prewarm is only meaningful under sharing
    ns = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                        buckets=(8, 16), share_prefix=False)
    assert ns.prewarm(hist) is None


def test_page_pool_pressure_evicts_lru_index_pages():
    """With a pool smaller than slots x capacity, index-held pages are
    reclaimed LRU-first instead of failing admission; eviction count is
    surfaced in telemetry."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(3), cfg)
    s = ServeScheduler(params, cfg, n_slots=2, capacity=64, buckets=(8, 16),
                       paged=True, page_size=8, n_pages=10)
    for t in range(5):
        s.submit([[40 + t] * 20], [[31]])
    out = s.run()
    assert all(len(r.scores) == 1 for r in out.values())
    tel = s.telemetry()
    assert tel["page_evictions"] > 0
    assert tel["pages_in_use"] <= 10


# ---------------------------------------------------------------------------
# bookkeeping bugfix sweep: regressions with named failures
# ---------------------------------------------------------------------------

def test_reset_telemetry_clears_kv_bytes_and_evictions_together():
    """Satellite regression: the quantized-KV telemetry (kv_bytes
    committed this window) and the pool's eviction counter are reset by
    the same reset_telemetry call — a partial reset would make the
    bytes-per-eviction trend lie across bench windows. Static capacity
    figures (pool bytes, per-token bytes) survive the reset."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(7), cfg)
    s = ServeScheduler(params, cfg, n_slots=2, capacity=64, buckets=(8, 16),
                       kv_dtype="int8", paged=True, page_size=8, n_pages=10)
    for t in range(5):
        s.submit([[40 + t] * 20], [[31]])
    s.run()
    tel = s.telemetry()
    assert tel["kv_dtype"] == "int8"
    assert tel["kv_bytes_committed"] > 0
    assert tel["page_evictions"] > 0
    assert tel["pool_bytes"] == 10 * 8 * tel["kv_token_bytes"]
    s.reset_telemetry()
    tel = s.telemetry()
    assert tel["kv_bytes_committed"] == 0
    assert tel["page_evictions"] == 0
    assert s._pool.evictions == 0
    # capacity facts are properties of the cache, not the window
    assert tel["pool_capacity_tokens"] == 80
    assert tel["kv_token_bytes"] > 0 and tel["kv_bytes"] > 0


def test_double_free_detection_names_row_and_rids():
    """Satellite regression: over-freeing a row's refcount used to
    saturate silently on device (resetting pos/cursor under an active
    sharer); the batched row-op flush must now fail loudly, naming the
    row and its active rids."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(4), cfg)
    s = ServeScheduler(params, cfg, n_slots=2, capacity=64, buckets=(8, 16))
    rid = s.submit([[10, 11, 12]], [[20]])
    s.run()
    # the finished request's row is retained with exactly one reference;
    # queueing two frees against it is the double-free shape
    row = next(i for i, r in enumerate(s._rows) if r.retained)
    assert s._row_ref[row] == 1
    s._mark("free", row)
    s._mark("free", row)
    with pytest.raises(RuntimeError,
                       match=rf"double-free.*row {row}.*freeing 2"):
        s._flush_row_ops()
    assert rid in s._results or True     # scores already harvested above


def test_capacity_overflow_rejected_at_submit():
    """Satellite regression: a context + burst that cannot fit capacity
    must be refused at submit time with the lengths named — commits past
    capacity would silently scatter-drop KV."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(5), cfg)
    s = ServeScheduler(params, cfg, n_slots=2, capacity=16, buckets=(8,))
    with pytest.raises(ValueError,
                       match=r"request 3: context 13 \+ candidate 0 burst 5 "
                             r"tokens overflow capacity 16"):
        s.submit([[20 + i] for i in range(12)], [[1, 2, 3, 4]], rid=3)
    # nothing was queued or placed
    assert not s._queue and all(not r.active for r in s._rows)


def test_burst_only_telemetry_and_reset():
    """Satellite regression: budget_utilization must be None (not a
    ZeroDivisionError) when no prefill was dispatched, and
    reset_telemetry must clear the watchdog state."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(6), cfg)
    s = ServeScheduler(params, cfg, n_slots=2, capacity=64, buckets=(8, 16))
    assert s.telemetry()["budget_utilization"] is None   # nothing dispatched
    # simulate a tripped watchdog, then reset
    s._watchdog_rows.add(1)
    s.watchdog_fired = 2
    s.watchdog_stuck_rids = [7]
    assert s.telemetry()["watchdog_rows"] == [1]
    s.reset_telemetry()
    tel = s.telemetry()
    assert tel["watchdog_fired"] == 0
    assert tel["watchdog_rows"] == [] and tel["watchdog_stuck_rids"] == []
    assert tel["budget_utilization"] is None
