"""Int8 quantized KV cache: the quantization-error test harness that
gates the tentpole.

Layers of guarantee, weakest math to strongest system property:

1. ``quantize_q8``/``dequantize_q8`` round-trip error is bounded by half
   a quantization step (scale/2) per element — the symmetric-int8
   contract every downstream tolerance derives from.
2. RoPE commutes with the per-slot scale (rotation never crosses a
   scale group), which is what lets the kernel rope raw codes and
   multiply the scale afterwards.
3. The Pallas kernel's in-VMEM dequant + read-time rope matches the
   dense reference bit-for-bit-ish (fp32 softmax noise only) on raw
   codes, for both the GQA layout (one scale group) and the absorbed-MLA
   layout (two groups split at ``rope_start``).
4. Scale invariance under paging: scales ride the same slot axis as the
   codes, so page adoption, steals and evictions move both together with
   zero requantization — int8 paged-under-pressure scores are *float
   exact* against int8 contiguous, and a cross-row adopted prefix
   reproduces its original scores exactly.
5. End-to-end tolerance: int8 decode sits within a documented bound of
   the fp32 scores on every cell of the GQA/MLA x dense/pallas x
   contiguous/paged/pool-pressure matrix (~1e-3 observed at this scale;
   the 2e-2 gate catches a broken dequant path, not noise).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core.quant import Q8_MAX, dequantize_q8, quantize_q8
from repro.data.requests import make_request_stream
from repro.data.synthetic import make_ctr_dataset
from repro.kernels.decode_attn.ops import decode_attention
from repro.kernels.decode_attn.ref import decode_attention_ref
from repro.models.layers import apply_rope
from repro.models.transformer import init_params
from repro.serve.cache import init_lm_cache, is_quantized, kv_token_bytes
from repro.serve.scheduler import ServeScheduler

from test_serve import _cfg

# Documented end-to-end tolerance for int8 KV vs fp32 scores on the
# smoke-scale configs below. Observed |dp| is ~1e-3; anything near the
# gate means a dequant/scale-plumbing bug, not quantization noise.
INT8_SCORE_TOL = 2e-2


# ---------------------------------------------------------------------------
# 1. the quantizer's error bound
# ---------------------------------------------------------------------------

def test_dequant_error_bound(rng):
    """|x - dq(q(x))| <= scale/2 per element, across magnitudes."""
    for mag in (1e-3, 1.0, 37.5, 1e4):
        x = jnp.asarray(rng.normal(0, mag, (5, 7, 16)), jnp.float32)
        q, scale = quantize_q8(x)
        assert q.dtype == jnp.int8
        assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= int(Q8_MAX)
        err = jnp.abs(x - dequantize_q8(q, scale))
        bound = scale[..., None] / 2 + 1e-6 * mag
        assert bool(jnp.all(err <= bound))


def test_zero_groups_are_safe():
    """An all-zero scale group must not divide by zero: codes come back
    zero and dequantize to finite zeros."""
    x = jnp.zeros((2, 3, 8), jnp.float32)
    q, scale = quantize_q8(x)
    assert bool(jnp.all(q == 0))
    out = dequantize_q8(q, scale)
    assert bool(jnp.all(jnp.isfinite(out))) and bool(jnp.all(out == 0))
    # mixed: one live group next to a dead one
    x = x.at[0, 0].set(jnp.arange(8, dtype=jnp.float32))
    q, scale = quantize_q8(x)
    assert bool(jnp.all(jnp.isfinite(dequantize_q8(q, scale))))


@pytest.mark.hyp
@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                min_size=1, max_size=64))
def test_roundtrip_error_bound_property(xs):
    x = jnp.asarray(xs, jnp.float32)
    q, scale = quantize_q8(x)
    err = jnp.abs(x - dequantize_q8(q, scale))
    assert bool(jnp.all(err <= scale / 2 + 1e-3))


# ---------------------------------------------------------------------------
# 2. RoPE commutes with the scale (the kernel's rope-codes-then-scale)
# ---------------------------------------------------------------------------

def test_rope_commutes_with_per_slot_scale(rng):
    """Rotation mixes dims only *within* one (slot, head) scale group, so
    rope(codes) * scale == rope(codes * scale) — the identity the kernel
    exploits to dequantize after roping raw codes."""
    B, cap, Hk, D = 2, 9, 2, 16
    x = jnp.asarray(rng.normal(0, 2.0, (B, cap, Hk, D)), jnp.float32)
    q, scale = quantize_q8(x)
    pos = jnp.asarray(rng.integers(0, 50, (B, cap)), jnp.int32)
    scale_first = apply_rope(dequantize_q8(q, scale), pos)
    scale_after = apply_rope(q.astype(jnp.float32), pos) * scale[..., None]
    np.testing.assert_allclose(np.asarray(scale_first),
                               np.asarray(scale_after), atol=1e-5)


# ---------------------------------------------------------------------------
# 3. kernel == dense reference on raw int8 codes
# ---------------------------------------------------------------------------

def _quant_operands(rng, *, hk, d, dv, rope_start):
    """Build a quantized decode problem: fp32 truth -> codes + scales in
    the cache layout (G=1 whole-key scales, or G=2 split at rope_start)."""
    B, s, H, cap = 2, 3, 4, 40
    kf = jnp.asarray(rng.normal(0, 1.5, (B, cap, hk, d)), jnp.float32)
    vf = jnp.asarray(rng.normal(0, 1.5, (B, cap, hk, dv)), jnp.float32)
    if rope_start:
        c_q, c_s = quantize_q8(kf[..., :rope_start])
        p_q, p_s = quantize_q8(kf[..., rope_start:])
        k = jnp.concatenate([c_q, p_q], axis=-1)
        k_scale = jnp.stack([c_s, p_s], axis=-1)        # (B, cap, hk, 2)
    else:
        k, k_s = quantize_q8(kf)
        k_scale = k_s[..., None]                        # (B, cap, hk, 1)
    v, v_scale = quantize_q8(vf)
    pos_k = np.broadcast_to(np.arange(cap, dtype=np.int32), (B, cap)).copy()
    pos_k[:, 33:] = -1                                  # empty tail slots
    pos_k[1, 7] = -1                                    # and a hole
    pos_q = np.tile(np.array([[33, 34, 35]], np.int32), (B, 1))
    q = jnp.asarray(rng.normal(0, 1.0, (B, s, H, d)), jnp.float32)
    qn = jnp.asarray(rng.normal(0, 1.0, (B, s, H, d)), jnp.float32)
    sum_q = jnp.asarray(np.array([[0, 1, 0], [1, 0, 1]], bool))
    alibi = jnp.linspace(0.1, 0.4, H, dtype=jnp.float32)
    kw = dict(pos_q=jnp.asarray(pos_q), pos_k=jnp.asarray(pos_k),
              window=0, k_scale=k_scale, v_scale=v_scale,
              rope_start=rope_start)
    return q, k, v, qn, sum_q, alibi, kw


@pytest.mark.parametrize("geom", [
    dict(hk=2, d=16, dv=16, rope_start=0),     # GQA: one scale group
    dict(hk=1, d=12, dv=8, rope_start=8),      # MLA: latent|rope groups
])
def test_kernel_matches_ref_on_int8_codes(rng, geom):
    q, k, v, qn, sum_q, alibi, kw = _quant_operands(rng, **geom)
    want = decode_attention_ref(q, k, v, kw["pos_q"], kw["pos_k"],
                                window=0, sum_q=sum_q, q_nope=qn,
                                alibi=alibi, k_scale=kw["k_scale"],
                                v_scale=kw["v_scale"],
                                rope_start=kw["rope_start"])
    got = decode_attention(q, k, v, kw["pos_q"], kw["pos_k"], window=0,
                           is_sum_q=sum_q, q_nope=qn, alibi=alibi,
                           k_scale=kw["k_scale"], v_scale=kw["v_scale"],
                           rope_start=kw["rope_start"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_kernel_quant_rejects_external_nope_stream(rng):
    """On the quant path the NoPE stream *is* the unroped dequant of the
    codes; passing a separate k_nope would desynchronise them."""
    q, k, v, qn, sum_q, alibi, kw = _quant_operands(
        rng, hk=2, d=16, dv=16, rope_start=0)
    with pytest.raises(AssertionError):
        decode_attention(q, k, v, kw["pos_q"], kw["pos_k"], window=0,
                         is_sum_q=sum_q, q_nope=qn,
                         k_nope=jnp.zeros_like(k, jnp.float32),
                         alibi=alibi, k_scale=kw["k_scale"],
                         v_scale=kw["v_scale"], rope_start=0)


# ---------------------------------------------------------------------------
# 4/5. end to end through the scheduler
# ---------------------------------------------------------------------------

def _stream(params, cfg, reqs, *, kv_dtype, attn_impl="dense",
            layout="contiguous"):
    paged = layout != "contiguous"
    s = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                       buckets=(8, 16), attn_impl=attn_impl,
                       kv_dtype=kv_dtype, paged=paged,
                       page_size=8 if paged else 16,
                       n_pages=10 if layout == "pressure" else None)
    rids = [s.submit(r["context"], r["candidates"]) for r in reqs]
    out = s.run()
    return [out[r].scores for r in rids], s


def _reqs(cfg, *, n=6, seed=3, repeat_frac=0.5):
    ds = make_ctr_dataset(n_users=4, n_items=30, seq_len=10,
                          vocab_size=cfg.vocab_size)
    return make_request_stream(ds, n_requests=n, k=2, n_ctx=3, seed=seed,
                               repeat_frac=repeat_frac)


def test_int8_cache_layout_and_telemetry():
    cfg = _cfg()
    cache = init_lm_cache(cfg, 2, 16, dtype=jnp.float32, kv_dtype="int8")
    assert is_quantized(cache)
    assert cache["k"].dtype == jnp.int8
    assert cache["k_scale"].dtype == jnp.float32
    # scale sidecars share the slot axis with the codes
    assert cache["k_scale"].shape[:3] == cache["k"].shape[:3]
    assert kv_token_bytes(cache) < kv_token_bytes(
        init_lm_cache(cfg, 2, 16, dtype=jnp.float32))


def test_int8_paged_pressure_exact_vs_int8_contiguous():
    """Scale invariance under adoption/steal/eviction: the sidecars move
    with the codes, so pool pressure changes *where* KV lives but never
    its dequantized value — scores are float-exact, not merely close."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(4), cfg)
    reqs = _reqs(cfg, n=10, seed=5, repeat_frac=0.3)
    got, sched = _stream(params, cfg, reqs, kv_dtype="int8",
                         layout="pressure")
    want, _ = _stream(params, cfg, reqs, kv_dtype="int8",
                      layout="contiguous")
    assert got == want                    # float-exact, not allclose
    tel = sched.telemetry()
    assert tel["page_evictions"] > 0      # the reclamation paths ran
    assert tel["kv_dtype"] == "int8"


def test_cross_row_adoption_preserves_scales():
    """A prefix adopted cross-row after its original row was stolen must
    reproduce the original scores exactly — the adopted pages carry their
    scales, nothing requantizes."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(1), cfg)
    ctx = [list(range(10, 30))]
    s = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                       buckets=(8, 16), kv_dtype="int8",
                       paged=True, page_size=8)
    r0 = s.submit(ctx, [[30]])
    base = s.run()[r0].scores
    for t in range(4):                    # roll both rows over -> steal
        s.submit([[40 + t] * 20], [[31]])
    s.run()
    r1 = s.submit(ctx, [[30]])
    again = s.run()[r1]
    assert again.scores == base           # bit-equal through adoption
    assert again.shared_prefix_tokens == 16
    assert s.telemetry()["cross_row_tokens"] == 16


@pytest.mark.parametrize("layout", ["contiguous", "paged", "pressure"])
@pytest.mark.parametrize("attn_impl", ["dense", "pallas"])
@pytest.mark.parametrize("attn_type", ["gqa", "mla"])
def test_int8_scores_within_tolerance_of_fp32(attn_type, attn_impl, layout):
    """The acceptance matrix: every (attn x impl x layout) cell's int8
    scores sit within INT8_SCORE_TOL of the fp32 run."""
    cfg = _cfg(attn_type)
    params = init_params(jax.random.PRNGKey(0), cfg)
    reqs = _reqs(cfg, n=5, seed=7)
    got, _ = _stream(params, cfg, reqs, kv_dtype="int8",
                     attn_impl=attn_impl, layout=layout)
    want, _ = _stream(params, cfg, reqs, kv_dtype=None,
                      attn_impl=attn_impl, layout=layout)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=INT8_SCORE_TOL)


@pytest.mark.parametrize("attn_type", ["gqa", "mla"])
def test_int8_dense_matches_int8_pallas(attn_type):
    """Dense dequant-then-attend and the kernel's in-VMEM dequant read
    the same codes: their scores differ only by fp32 reduction order."""
    cfg = _cfg(attn_type)
    params = init_params(jax.random.PRNGKey(2), cfg)
    reqs = _reqs(cfg, n=5, seed=9)
    got, _ = _stream(params, cfg, reqs, kv_dtype="int8",
                     attn_impl="pallas")
    want, _ = _stream(params, cfg, reqs, kv_dtype="int8",
                      attn_impl="dense")
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-4)
