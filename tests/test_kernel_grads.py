"""Gradient equivalence of the Pallas windowed-attention custom VJP.

Three layers of checks, all against ``attention_dense`` (the exact DTI
reference) with the kernel in interpret mode on CPU:

* kernel-level dq/dk/dv (+ dq_nope/dk_nope/dv0) over the DTI feature
  matrix: GQA head grouping, SUM isolation on/off, NoPE+ALiBi SUM rows,
  hidden-state reset, packed ``segment_ids``, key-padding;
* end-to-end ``jax.grad`` of the DTI CTR loss through the full
  transformer (GQA and MLA configs, packed and unpacked batches) with
  ``attn_impl="pallas"`` vs ``attn_impl="dense"``;
* leakage-under-grad: gradients of one packed segment's loss w.r.t.
  another segment's attention inputs are *exactly* zero on the dense,
  blocked and Pallas paths (deterministic case + hypothesis sweep over
  random segment layouts).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.core.dti import build_streaming_prompts, pack_prompts
from repro.core.windowed import (ResetConfig, attention_blocked,
                                 attention_dense)
from repro.kernels.windowed_attn.ops import windowed_attention
from repro.launch.train import make_lm_loss_fn
from repro.models.layers import alibi_slopes
from repro.models.transformer import ModelConfig, init_params

KEY = jax.random.PRNGKey(11)
TOL = 1e-4          # acceptance bound: max-abs error vs the dense reference


def _rand(shape, i, dtype=jnp.float32):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, dtype)


def _tree_max_err(a, b):
    diffs = jax.tree_util.tree_map(
        lambda x, y: float(jnp.abs(x.astype(jnp.float32)
                                   - y.astype(jnp.float32)).max()), a, b)
    return max(jax.tree_util.tree_leaves(diffs))


# ---------------------------------------------------------------------------
# kernel-level dq/dk/dv equivalence
# ---------------------------------------------------------------------------

class TestKernelGrads:
    @pytest.mark.parametrize("name,B,S,H,Hk,D,W,blk,sum_iso,nope,res", [
        ("gqa_full",    2, 128, 4, 2, 16, 32, 32, True,  True,  True),
        ("mla_heads",   1, 128, 4, 4, 16, 32, 32, True,  True,  True),
        ("no_iso",      1,  64, 2, 1,  8, 16, 16, False, True,  True),
        ("no_nope",     1,  64, 2, 2,  8, 16, 16, True,  False, False),
        ("no_reset",    1,  64, 4, 2,  8, 16, 16, True,  True,  False),
        ("reset_only",  1,  64, 2, 2,  8, 16, 16, True,  False, True),
        ("odd_window",  1,  96, 2, 2,  8, 24, 32, True,  True,  True),
    ])
    def test_dqkv_match_dense(self, name, B, S, H, Hk, D, W, blk,
                              sum_iso, nope, res):
        r = np.random.default_rng(len(name))
        q, qn = _rand((B, S, H, D), 0), _rand((B, S, H, D), 3)
        k, kn = _rand((B, S, Hk, D), 1), _rand((B, S, Hk, D), 4)
        v, v0 = _rand((B, S, Hk, D), 2), _rand((B, S, Hk, D), 5)
        w = _rand((B, S, H, D), 9)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        is_sum = jnp.asarray(r.random((B, S)) < 0.15)
        valid = jnp.asarray(r.random((B, S)) < 0.9)
        kw = dict(pos_q=pos, pos_k=pos, window=W, is_sum_q=is_sum,
                  is_sum_k=is_sum, valid_k=valid, sum_isolated=sum_iso)
        if nope:
            kw.update(q_nope=qn, k_nope=kn, alibi=alibi_slopes(H))
        if res:
            kw.update(v0=v0, reset=ResetConfig(0.05, 0.3, W / 2))

        def loss(fn, extra=()):
            def f(q, k, v, *rest):
                kw2 = dict(kw)
                for key, val in zip(extra, rest):
                    kw2[key] = val
                return (fn(q, k, v, **kw2) * w).sum()
            return f

        extra = (("q_nope", "k_nope") if nope else ()) + \
                (("v0",) if res else ())
        rest = tuple({"q_nope": qn, "k_nope": kn, "v0": v0}[e] for e in extra)
        argn = tuple(range(3 + len(rest)))
        g_ref = jax.grad(loss(attention_dense, extra), argn)(q, k, v, *rest)
        g_pl = jax.grad(
            loss(lambda *a, **kk: windowed_attention(*a, **kk,
                                                     block_size=blk),
                 extra), argn)(q, k, v, *rest)
        for nm, a, b in zip(("dq", "dk", "dv") + extra, g_ref, g_pl):
            err = float(jnp.abs(a - b).max())
            assert err <= TOL, f"{name}/{nm}: {err}"

    def test_packed_segments_grads(self):
        B, H, D, W, blk = 1, 2, 8, 8, 16
        lens = [16, 16, 16, 16]
        S = sum(lens)
        seg = jnp.asarray(np.repeat(np.arange(len(lens)), lens)[None],
                          jnp.int32)
        pos = jnp.asarray(np.concatenate([np.arange(n) for n in lens])[None],
                          jnp.int32)
        q, k, v = (_rand((B, S, H, D), i) for i in range(3))
        w = _rand((B, S, H, D), 9)
        kw = dict(pos_q=pos, pos_k=pos, window=W, seg_q=seg, seg_k=seg)
        g_ref = jax.grad(lambda *a: (attention_dense(*a, **kw) * w).sum(),
                         (0, 1, 2))(q, k, v)
        g_pl = jax.grad(lambda *a: (windowed_attention(
            *a, **kw, block_size=blk) * w).sum(), (0, 1, 2))(q, k, v)
        assert _tree_max_err(g_ref, g_pl) <= TOL

    def test_mla_value_dim(self):
        """Dv != Dqk (MLA heads): fwd and grads on the split value dim."""
        B, S, H, D, DV, W, blk = 1, 64, 2, 16, 8, 16, 16
        q = _rand((B, S, H, D), 0)
        k = _rand((B, S, H, D), 1)
        v = _rand((B, S, H, DV), 2)
        w = _rand((B, S, H, DV), 9)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        kw = dict(pos_q=pos, pos_k=pos, window=W)
        o_ref = attention_dense(q, k, v, **kw)
        o_pl = windowed_attention(q, k, v, **kw, block_size=blk)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                                   atol=TOL, rtol=TOL)
        g_ref = jax.grad(lambda *a: (attention_dense(*a, **kw) * w).sum(),
                         (0, 1, 2))(q, k, v)
        g_pl = jax.grad(lambda *a: (windowed_attention(
            *a, **kw, block_size=blk) * w).sum(), (0, 1, 2))(q, k, v)
        assert _tree_max_err(g_ref, g_pl) <= TOL

    def test_bf16_grads_finite_and_close(self):
        B, S, H, D, W = 1, 64, 2, 16, 16
        q, k, v = (_rand((B, S, H, D), i, jnp.bfloat16) for i in range(3))
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        kw = dict(pos_q=pos, pos_k=pos, window=W)
        f = lambda fn: lambda q: fn(q, k, v, **kw).astype(jnp.float32).sum()
        g_ref = jax.grad(f(attention_dense))(q)
        g_pl = jax.grad(f(lambda *a, **kk: windowed_attention(
            *a, **kk, block_size=16)))(q)
        assert bool(jnp.isfinite(g_pl.astype(jnp.float32)).all())
        np.testing.assert_allclose(np.asarray(g_ref, np.float32),
                                   np.asarray(g_pl, np.float32),
                                   atol=3e-2, rtol=3e-2)


# ---------------------------------------------------------------------------
# end-to-end: jax.grad of the DTI CTR loss through the transformer
# ---------------------------------------------------------------------------

MAX_LEN = 64


def _gqa_cfg(impl):
    return ModelConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                       d_ff=64, vocab_size=64, window=16, attn_impl=impl,
                       attn_block_size=16, dti_sum_token=True, remat=False)


def _mla_cfg(impl):
    return ModelConfig(n_layers=2, d_model=32, n_heads=2, d_ff=64,
                       vocab_size=64, window=16, attn_type="mla",
                       q_lora_rank=0, kv_lora_rank=16, qk_nope_dim=8,
                       qk_rope_dim=8, v_head_dim=8, attn_impl=impl,
                       attn_block_size=16, dti_sum_token=True, remat=False)


def _batch(packed=False, n_users=3):
    prompts = []
    for s in range(n_users):
        r = np.random.default_rng(s)
        toks = [list(map(int, r.integers(8, 60, size=int(r.integers(2, 4)))))
                for _ in range(8)]
        labels = list(map(int, r.integers(0, 2, size=8)))
        prompts += build_streaming_prompts(toks, labels, n_ctx=2, k=3,
                                           max_len=MAX_LEN)
    if packed:
        prompts = pack_prompts(prompts, MAX_LEN)
    return {key: jnp.asarray(np.stack([p[key] for p in prompts]))
            for key in prompts[0]}


class TestEndToEndGrads:
    @pytest.mark.parametrize("make_cfg,packed", [
        (_gqa_cfg, False), (_gqa_cfg, True), (_mla_cfg, False),
    ])
    def test_loss_grads_match_dense(self, make_cfg, packed):
        batch = _batch(packed=packed)
        grads = {}
        for impl in ("dense", "pallas"):
            cfg = make_cfg(impl)
            params = init_params(jax.random.PRNGKey(0), cfg)
            loss_fn = make_lm_loss_fn(cfg, cfg.window)
            loss, _ = loss_fn(params, batch, jax.random.PRNGKey(0))
            grads[impl] = jax.grad(
                lambda p: loss_fn(p, batch, jax.random.PRNGKey(0))[0])(params)
            assert np.isfinite(float(loss))
        err = _tree_max_err(grads["dense"], grads["pallas"])
        assert err <= TOL, f"param-grad mismatch {err}"


# ---------------------------------------------------------------------------
# leakage under grad: packed segments stay isolated in the backward pass
# ---------------------------------------------------------------------------

def _leakage_case(lens, window, seed, with_sum, target_seg):
    """Grads of segment ``target_seg``'s output w.r.t. q/k/v must be
    *exactly* zero at every other segment's positions, on all paths."""
    B, H, D = 1, 2, 8
    blk = 8
    S = ((sum(lens) + blk - 1) // blk) * blk
    n_pad = S - sum(lens)
    seg = np.concatenate([np.repeat(np.arange(len(lens)), lens),
                          np.full(n_pad, -1)])
    pos = np.concatenate([np.concatenate([np.arange(n) for n in lens]),
                          np.zeros(n_pad, np.int64)])
    valid = seg >= 0
    r = np.random.default_rng(seed)
    is_sum = (r.random(S) < 0.25) & valid if with_sum else np.zeros(S, bool)
    seg_j = jnp.asarray(seg[None], jnp.int32)
    pos_j = jnp.asarray(pos[None], jnp.int32)
    q, k, v = (_rand((B, S, H, D), i + seed) for i in range(3))
    qn, kn, v0 = (_rand((B, S, H, D), i + seed + 5) for i in range(3))
    kw = dict(pos_q=pos_j, pos_k=pos_j, window=window, seg_q=seg_j,
              seg_k=seg_j, valid_k=jnp.asarray(valid[None]))
    if with_sum:
        kw.update(is_sum_q=jnp.asarray(is_sum[None]),
                  is_sum_k=jnp.asarray(is_sum[None]), q_nope=qn, k_nope=kn,
                  alibi=alibi_slopes(H), v0=v0,
                  reset=ResetConfig(0.05, 0.3, window / 2))
    sel = jnp.asarray((seg == target_seg)[None, :, None, None])
    others = (seg != target_seg) & valid

    impls = {
        "dense": lambda *a: attention_dense(*a, **kw),
        "blocked": lambda *a: attention_blocked(*a, **kw),
        "pallas": lambda *a: windowed_attention(*a, **kw, block_size=blk),
    }
    for name, fn in impls.items():
        gq, gk, gv = jax.grad(
            lambda q, k, v: jnp.sum(jnp.where(sel, fn(q, k, v), 0.0)),
            (0, 1, 2))(q, k, v)
        for gname, g in (("dq", gq), ("dk", gk), ("dv", gv)):
            leak = float(jnp.abs(g[0, others]).max())
            assert leak == 0.0, f"{name}/{gname} leaks {leak}"


class TestLeakageUnderGrad:
    def test_deterministic_layout(self):
        _leakage_case([12, 9, 7], window=8, seed=0, with_sum=True,
                      target_seg=1)
        _leakage_case([5, 17], window=4, seed=1, with_sum=False,
                      target_seg=0)

    @pytest.mark.hyp
    @settings(max_examples=8, deadline=None)
    @given(st.lists(st.integers(min_value=2, max_value=12), min_size=2,
                    max_size=4),
           st.sampled_from([1, 2, 4, 8]),   # divides padded S (blocked path)
           st.integers(min_value=0, max_value=10 ** 6),
           st.booleans())
    def test_random_layouts(self, lens, window, seed, with_sum):
        _leakage_case(lens, window=window, seed=seed, with_sum=with_sum,
                      target_seg=seed % len(lens))
