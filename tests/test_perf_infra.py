"""Measurement + distribution infrastructure: the trip-count-aware HLO
analyzer (calibrated against known computations), the activation-pinning
policy, and the MoE scatter-combine against a dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo import analyze_hlo
from repro.sharding.act import (activation_mesh, constrain_tokens,
                                current_mesh)


class TestAnalyzeHLO:
    def test_matmul_flops_exact(self):
        a = jnp.zeros((128, 64));  b = jnp.zeros((64, 32))
        txt = jax.jit(lambda a, b: a @ b).lower(a, b).compile().as_text()
        an = analyze_hlo(txt)
        assert an["flops"] == 2 * 128 * 64 * 32

    def test_scan_multiplies_by_trip_count(self):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out
        x = jnp.zeros((64, 64));  w = jnp.zeros((64, 64))
        txt = jax.jit(f).lower(x, w).compile().as_text()
        an = analyze_hlo(txt)
        assert an["flops"] == 7 * 2 * 64**3

    def test_nested_scan(self):
        def f(x, w):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ w, None
                ci, _ = jax.lax.scan(inner, c, None, length=3)
                return ci, None
            out, _ = jax.lax.scan(outer, x, None, length=5)
            return out
        x = jnp.eye(32);  w = jnp.eye(32)
        txt = jax.jit(f).lower(x, w).compile().as_text()
        an = analyze_hlo(txt)
        assert an["flops"] == 15 * 2 * 32**3

    def test_xla_cost_analysis_undercounts_scans(self):
        """The reason analyze_hlo exists: XLA's own cost analysis visits
        while bodies once. If this test ever fails, XLA fixed it upstream
        and the analyzer can be retired."""
        def f(x, w):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=8)
            return out
        x = jnp.zeros((64, 64));  w = jnp.zeros((64, 64))
        c = jax.jit(f).lower(x, w).compile()
        from repro.launch.hlo import xla_cost_analysis
        xla_flops = xla_cost_analysis(c).get("flops", 0)
        assert xla_flops < 2 * 2 * 64**3          # counts ~1 iteration

    def test_bytes_positive_and_fusion_aware(self):
        a = jnp.zeros((256, 256))
        txt = jax.jit(lambda a: jnp.tanh(a) + 1.0).lower(a).compile().as_text()
        an = analyze_hlo(txt)
        # one fused elementwise op: >= in+out, well under 10x
        assert 2 * 256 * 256 * 4 <= an["bytes"] <= 10 * 256 * 256 * 4


class TestActivationPolicy:
    def test_identity_without_mesh(self):
        x = jnp.ones((4, 8))
        assert constrain_tokens(x) is x

    def test_policy_scopes(self):
        from repro.launch.mesh import make_cpu_mesh
        mesh = make_cpu_mesh()
        assert current_mesh() is None
        with activation_mesh(mesh, "data", "model"):
            assert current_mesh() is mesh
            x = constrain_tokens(jnp.ones((4, 8, 16)))
            assert x.shape == (4, 8, 16)
        assert current_mesh() is None

    def test_kinds_produce_valid_specs(self):
        from repro.launch.mesh import make_cpu_mesh
        mesh = make_cpu_mesh()
        with activation_mesh(mesh, "data", "model"):
            for kind, shape in [("boundary", (2, 8, 16)),
                                ("heads", (2, 8, 4, 4)),
                                ("ffn", (2, 8, 32))]:
                out = constrain_tokens(jnp.ones(shape), kind=kind)
                assert out.shape == shape


class TestMoECombine:
    def test_scatter_combine_matches_dense_oracle(self):
        from repro.models.layers import swiglu
        from repro.models.moe import init_moe, moe_ffn
        E, K, D, F = 8, 3, 32, 16
        p = init_moe(jax.random.PRNGKey(0), D, n_experts=E, moe_d_ff=F,
                     top_k=K, n_shared=1, shared_d_ff=F)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 20, D))
        out, aux = moe_ffn(p, x, n_experts=E, top_k=K, capacity_factor=8.0,
                           norm_topk=False)

        xt = x.reshape(-1, D)
        logits = xt.astype(jnp.float32) @ p["router"]["w"]
        gates, ids = jax.lax.top_k(logits, K)
        gates = jnp.take_along_axis(jax.nn.softmax(logits, -1), ids, -1)
        ref = jnp.zeros_like(xt)
        for e in range(E):
            hg = jax.nn.silu(xt @ p["w_gate"][e])
            hu = xt @ p["w_up"][e]
            ye = (hg * hu) @ p["w_down"][e]
            w = ((ids == e) * gates).sum(-1)
            ref = ref + ye * w[:, None]
        ref = (ref + swiglu(p["shared"], xt)).reshape(x.shape)
        np.testing.assert_allclose(out, ref, atol=2e-5)

    def test_norm_topk_variant(self):
        from repro.models.moe import init_moe, moe_ffn
        p = init_moe(jax.random.PRNGKey(0), 16, n_experts=4, moe_d_ff=8,
                     top_k=2)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 16))
        out, aux = moe_ffn(p, x, n_experts=4, top_k=2, capacity_factor=8.0,
                           norm_topk=True)
        assert np.isfinite(np.asarray(out)).all()
        assert float(aux) > 0

    def test_capacity_drops_bounded(self):
        """With capacity_factor=0.5 some tokens drop; outputs stay finite
        and dropped tokens still get the shared-expert contribution."""
        from repro.models.moe import init_moe, moe_ffn
        p = init_moe(jax.random.PRNGKey(0), 16, n_experts=2, moe_d_ff=8,
                     top_k=2, n_shared=1, shared_d_ff=8)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
        out, _ = moe_ffn(p, x, n_experts=2, top_k=2, capacity_factor=0.5,
                         norm_topk=False)
        assert np.isfinite(np.asarray(out)).all()
