"""Continuous-batching scheduler: batched == sequential == independent
prefills, slot eviction/readmission, no cross-request leakage through the
shared batched cache."""
import jax
import numpy as np
import pytest

from repro.data.requests import make_request_stream
from repro.data.synthetic import make_ctr_dataset
from repro.models.transformer import ModelConfig, init_params
from repro.serve.scheduler import ServeScheduler

from test_serve import _cfg, _independent_scores, _request_material


def _sched(params, cfg, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("capacity", 64)
    kw.setdefault("buckets", (8, 16, 32))
    return ServeScheduler(params, cfg, **kw)


@pytest.mark.parametrize("attn_type", ["gqa", "mla"])
@pytest.mark.parametrize("attn_impl", ["dense", "pallas"])
def test_scheduler_matches_independent_prefills(attn_type, attn_impl):
    """Decode bursts against the shared context cache == k standalone
    sliding-window prefills (the acceptance bar of the serving subsystem),
    on both the dense decode path and the fused Pallas kernel."""
    cfg = _cfg(attn_type)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ctx, cands = _request_material(seed=3)
    sched = _sched(params, cfg, attn_impl=attn_impl)
    rid = sched.submit(ctx, cands)
    res = sched.run()[rid]
    want = _independent_scores(params, cfg, ctx, cands, max_len=96)
    np.testing.assert_allclose(np.asarray(res.scores), want, atol=1e-4)
    assert res.cached_tokens == (len(cands) - 1) * res.context_tokens
    assert 0.0 < res.cache_hit_fraction < 1.0


@pytest.mark.parametrize("attn_impl", ["dense", "pallas"])
def test_scheduler_windowed_matches_independent(attn_impl):
    """The window term must bind identically on the prefill and burst paths."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(4), cfg)
    ctx, cands = _request_material(seed=4, n_ctx=5)
    W = 8
    sched = _sched(params, cfg, window=W, attn_impl=attn_impl)
    rid = sched.submit(ctx, cands)
    res = sched.run()[rid]
    want = _independent_scores(params, cfg, ctx, cands, max_len=96, window=W)
    np.testing.assert_allclose(np.asarray(res.scores), want, atol=1e-4)


def test_eviction_and_readmission():
    """More requests than slots: every request is scored, slots are reused,
    and batching never changes a score vs running each request alone."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(1), cfg)
    reqs = [_request_material(seed=10 + i, n_ctx=3, k=3) for i in range(5)]

    solo = []
    for ctx, cands in reqs:
        s = _sched(params, cfg, n_slots=1)
        rid = s.submit(ctx, cands)
        solo.append(s.run()[rid].scores)

    sched = _sched(params, cfg, n_slots=2)       # 5 requests through 2 slots
    rids = [sched.submit(ctx, cands) for ctx, cands in reqs]
    res = sched.run()
    assert len(res) == len(reqs)
    assert all(not r.active for r in sched._rows)  # everything evicted
    for rid, want in zip(rids, solo):
        np.testing.assert_allclose(res[rid].scores, want, atol=1e-5)


def test_no_cross_request_leakage():
    """A request's scores must be invariant to whatever shares the batch:
    rows of the batched cache are hard request boundaries."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(2), cfg)
    ctx_a, cands_a = _request_material(seed=20)
    ctx_b, cands_b = _request_material(seed=21, n_ctx=6, k=2)

    alone = _sched(params, cfg, n_slots=2)
    rid_alone = alone.submit(ctx_a, cands_a)
    scores_alone = alone.run()[rid_alone].scores

    together = _sched(params, cfg, n_slots=2)
    rid_a = together.submit(ctx_a, cands_a)
    together.submit(ctx_b, cands_b)
    scores_together = together.run()[rid_a].scores
    np.testing.assert_allclose(scores_together, scores_alone, atol=1e-6)


def test_multi_candidate_burst_packing():
    """Many short candidates ride one burst; a slate wider than the largest
    bucket is split but still scored correctly and in order."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(3), cfg)
    ctx, _ = _request_material(seed=30, n_ctx=3)
    cands = [[8 + j, 9 + j] for j in range(12)]  # 12 * 3 tok > bucket 16
    sched = _sched(params, cfg, buckets=(8, 16))
    rid = sched.submit(ctx, cands)
    res = sched.run()[rid]
    want = _independent_scores(params, cfg, ctx, cands, max_len=96)
    np.testing.assert_allclose(np.asarray(res.scores), want, atol=1e-4)
    # 1 context chunk + ceil(12*3/16)=3 bursts, not 12 single-candidate steps
    assert sched.n_steps <= 4


def test_tight_capacity_burst_packing():
    """Bursts must stay within the cache rows left above the context even
    when the bucket is larger, and chunk padding that points past capacity
    must be dropped, not clamped onto the last slot (which would corrupt
    the burst's own [SUM] entry)."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(6), cfg)
    ctx = [[20 + i] for i in range(14)]            # 1 + 14 context tokens
    cands = [[40 + j, 50 + j] for j in range(6)]   # 6 x (2 tok + [SUM])
    # capacity 24 leaves 9 slots above the 15-token context < bucket 16
    sched = _sched(params, cfg, n_slots=1, capacity=24, buckets=(16,))
    rid = sched.submit(ctx, cands)
    res = sched.run()[rid]
    want = _independent_scores(params, cfg, ctx, cands, max_len=96)
    np.testing.assert_allclose(np.asarray(res.scores), want, atol=1e-4)


def test_request_stream_feeds_scheduler():
    """The synthetic request generator produces schedulable requests."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(5), cfg)
    ds = make_ctr_dataset(n_users=4, n_items=30, seq_len=8,
                          vocab_size=cfg.vocab_size)
    reqs = make_request_stream(ds, n_requests=3, k=4, n_ctx=3, seed=0)
    sched = _sched(params, cfg, capacity=96, buckets=(16, 32))
    rids = [sched.submit(r["context"], r["candidates"]) for r in reqs]
    res = sched.run()
    for rid in rids:
        assert len(res[rid].scores) == 4
        assert all(0.0 <= p <= 1.0 for p in res[rid].scores)
