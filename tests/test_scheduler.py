"""Continuous-batching scheduler: batched == sequential == independent
prefills, slot eviction/readmission, no cross-request leakage through the
shared batched cache."""
import jax
import numpy as np
import pytest

from repro.data.requests import make_request_stream
from repro.data.synthetic import make_ctr_dataset
from repro.models.transformer import ModelConfig, init_params
from repro.serve.scheduler import ServeScheduler

from test_serve import _cfg, _independent_scores, _request_material


def _sched(params, cfg, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("capacity", 64)
    kw.setdefault("buckets", (8, 16, 32))
    return ServeScheduler(params, cfg, **kw)


@pytest.mark.parametrize("attn_type", ["gqa", "mla"])
@pytest.mark.parametrize("attn_impl", ["dense", "pallas"])
def test_scheduler_matches_independent_prefills(attn_type, attn_impl):
    """Decode bursts against the shared context cache == k standalone
    sliding-window prefills (the acceptance bar of the serving subsystem),
    on both the dense decode path and the fused Pallas kernel."""
    cfg = _cfg(attn_type)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ctx, cands = _request_material(seed=3)
    sched = _sched(params, cfg, attn_impl=attn_impl)
    rid = sched.submit(ctx, cands)
    res = sched.run()[rid]
    want = _independent_scores(params, cfg, ctx, cands, max_len=96)
    np.testing.assert_allclose(np.asarray(res.scores), want, atol=1e-4)
    assert res.cached_tokens == (len(cands) - 1) * res.context_tokens
    assert 0.0 < res.cache_hit_fraction < 1.0


@pytest.mark.parametrize("attn_impl", ["dense", "pallas"])
def test_scheduler_windowed_matches_independent(attn_impl):
    """The window term must bind identically on the prefill and burst paths."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(4), cfg)
    ctx, cands = _request_material(seed=4, n_ctx=5)
    W = 8
    sched = _sched(params, cfg, window=W, attn_impl=attn_impl)
    rid = sched.submit(ctx, cands)
    res = sched.run()[rid]
    want = _independent_scores(params, cfg, ctx, cands, max_len=96, window=W)
    np.testing.assert_allclose(np.asarray(res.scores), want, atol=1e-4)


def test_eviction_and_readmission():
    """More requests than slots: every request is scored, slots are reused,
    and batching never changes a score vs running each request alone."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(1), cfg)
    reqs = [_request_material(seed=10 + i, n_ctx=3, k=3) for i in range(5)]

    solo = []
    for ctx, cands in reqs:
        s = _sched(params, cfg, n_slots=1)
        rid = s.submit(ctx, cands)
        solo.append(s.run()[rid].scores)

    sched = _sched(params, cfg, n_slots=2)       # 5 requests through 2 slots
    rids = [sched.submit(ctx, cands) for ctx, cands in reqs]
    res = sched.run()
    assert len(res) == len(reqs)
    assert all(not r.active for r in sched._rows)  # everything evicted
    for rid, want in zip(rids, solo):
        np.testing.assert_allclose(res[rid].scores, want, atol=1e-5)


def test_no_cross_request_leakage():
    """A request's scores must be invariant to whatever shares the batch:
    rows of the batched cache are hard request boundaries."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(2), cfg)
    ctx_a, cands_a = _request_material(seed=20)
    ctx_b, cands_b = _request_material(seed=21, n_ctx=6, k=2)

    alone = _sched(params, cfg, n_slots=2)
    rid_alone = alone.submit(ctx_a, cands_a)
    scores_alone = alone.run()[rid_alone].scores

    together = _sched(params, cfg, n_slots=2)
    rid_a = together.submit(ctx_a, cands_a)
    together.submit(ctx_b, cands_b)
    scores_together = together.run()[rid_a].scores
    np.testing.assert_allclose(scores_together, scores_alone, atol=1e-6)


def test_multi_candidate_burst_packing():
    """Many short candidates ride one burst; a slate wider than the largest
    bucket is split but still scored correctly and in order."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(3), cfg)
    ctx, _ = _request_material(seed=30, n_ctx=3)
    cands = [[8 + j, 9 + j] for j in range(12)]  # 12 * 3 tok > bucket 16
    sched = _sched(params, cfg, buckets=(8, 16))
    rid = sched.submit(ctx, cands)
    res = sched.run()[rid]
    want = _independent_scores(params, cfg, ctx, cands, max_len=96)
    np.testing.assert_allclose(np.asarray(res.scores), want, atol=1e-4)
    # 1 context chunk + ceil(12*3/16)=3 bursts, not 12 single-candidate steps
    assert sched.n_steps <= 4


def test_tight_capacity_burst_packing():
    """Bursts must stay within the cache rows left above the context even
    when the bucket is larger, and chunk padding that points past capacity
    must be dropped, not clamped onto the last slot (which would corrupt
    the burst's own [SUM] entry)."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(6), cfg)
    ctx = [[20 + i] for i in range(14)]            # 1 + 14 context tokens
    cands = [[40 + j, 50 + j] for j in range(6)]   # 6 x (2 tok + [SUM])
    # capacity 24 leaves 9 slots above the 15-token context < bucket 16
    sched = _sched(params, cfg, n_slots=1, capacity=24, buckets=(16,))
    rid = sched.submit(ctx, cands)
    res = sched.run()[rid]
    want = _independent_scores(params, cfg, ctx, cands, max_len=96)
    np.testing.assert_allclose(np.asarray(res.scores), want, atol=1e-4)


@pytest.mark.parametrize("attn_impl", ["dense", "pallas"])
@pytest.mark.parametrize("overlap", [True, False])
def test_chunked_prefill_matches_monolithic(attn_impl, overlap):
    """A context committed via budget-cut chunks (here budget 5, far below
    the largest bucket) must score byte-identically to the pre-budget
    monolithic largest-bucket chunking: chunking only changes *when* KV
    lands in the cache, never what a burst attends."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(7), cfg)
    ctx, cands = _request_material(seed=40, n_ctx=10)   # 41 ctx tokens
    kw = dict(buckets=(8, 16), capacity=64, attn_impl=attn_impl)

    mono = _sched(params, cfg, monolithic_prefill=True, overlap=False, **kw)
    rid = mono.submit(ctx, cands)
    want = mono.run()[rid].scores

    chunked = _sched(params, cfg, prefill_budget=5, overlap=overlap, **kw)
    rid = chunked.submit(ctx, cands)
    res = chunked.run()[rid]
    np.testing.assert_array_equal(np.asarray(res.scores), np.asarray(want))
    # the budget really did split the commit across steps
    assert chunked.n_steps > mono.n_steps
    tel = chunked.telemetry()
    assert tel["prefill_tokens"] == 41
    assert tel["watchdog_fired"] == 0


def test_chunked_prefill_never_inflates_burst_bucket():
    """The latency-uniformity contract: with a long prefill and a short
    burst co-batched, budgeted scheduling must keep every wave in the
    smallest bucket (bursts pick the shape; chunks are cut to fit), where
    monolithic prefill drags waves into the largest bucket."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(8), cfg)
    ctx_long, _ = _request_material(seed=41, n_ctx=10)  # 41 tokens to commit
    ctx_short, _ = _request_material(seed=42, n_ctx=1)
    cands = [[10, 11]]                                  # 3-token bursts

    def bucket_hist(**kw):
        s = _sched(params, cfg, buckets=(8, 32), capacity=96, **kw)
        s.submit(ctx_long, cands)
        s.submit(ctx_short, cands)
        s.run()
        return s.telemetry()["bucket_steps"]

    mono = bucket_hist(monolithic_prefill=True, overlap=False)
    assert mono[32] > 0                     # prefill inflated the wave
    budgeted = bucket_hist(prefill_budget=8)
    assert budgeted[32] == 0                # nothing ever left bucket 8
    assert budgeted[8] > 0


@pytest.mark.parametrize("attn_impl", ["dense", "pallas"])
def test_hot_swap_mid_prefill_restarts_under_new_params(attn_impl):
    """A weight swap landing while a context is still committing must not
    leave mixed-version KV inside one block: the commit restarts from
    position 0 under the new params, and the final scores are
    byte-identical to a fresh scheduler that only ever saw the new
    params."""
    cfg = _cfg()
    p_old = init_params(jax.random.PRNGKey(9), cfg)
    p_new = init_params(jax.random.PRNGKey(10), cfg)
    ctx, cands = _request_material(seed=43, n_ctx=10)   # 41 ctx tokens
    kw = dict(buckets=(8,), capacity=64, prefill_budget=8,
              attn_impl=attn_impl)

    sched = _sched(p_old, cfg, **kw)
    rid = sched.submit(ctx, cands)
    sched.step()                             # a few old-param chunks land
    sched.step()
    assert any(r.pending_commit > 0 for r in sched._rows)  # mid-prefill
    sched.update_params(p_new)
    res = sched.run()[rid]

    fresh = _sched(p_new, cfg, **kw)
    rid2 = fresh.submit(ctx, cands)
    want = fresh.run()[rid2].scores
    np.testing.assert_array_equal(np.asarray(res.scores), np.asarray(want))
    # restart accounting: the full context was (re)committed by this request
    assert res.prefill_tokens == 41 and res.shared_prefix_tokens == 0


def test_watchdog_flags_stalled_row_and_run_terminates():
    """A row whose backlog can never dispatch (here: a corrupted commit
    gate with no committer to drain it) must fire the watchdog and let
    ``run`` drain everything else instead of hanging."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(11), cfg)
    ctx_a, cands_a = _request_material(seed=44, n_ctx=2, k=3)
    ctx_b, _ = _request_material(seed=45, n_ctx=2)
    cands_b = [[8 + j, 9 + j] for j in range(12)]       # many bursts
    sched = _sched(params, cfg, buckets=(8,), watchdog_steps=2)
    rid_a = sched.submit(ctx_a, cands_a)
    rid_b = sched.submit(ctx_b, cands_b)
    sched.step()                             # both admitted
    row_a = next(r for r in sched._rows
                 if r.active and r.active[0].rid == rid_a)
    while sched._committer(row_a) is not None:
        sched.step()                         # drain rid_a's real prefill
    row_a.pending_commit = 1                 # gate bursts forever
    res = sched.run()
    tel = sched.telemetry()
    assert tel["watchdog_fired"] >= 1
    assert rid_a in tel["watchdog_stuck_rids"]
    assert rid_a not in res                  # stuck, surfaced — not hung
    assert len(res[rid_b].scores) == 12      # everyone else drained fine


def test_latency_split_queue_plus_service():
    """queue_s (submit -> admitted) + service_s (admitted -> last score)
    must partition latency_s exactly, and queueing must actually register
    when requests outnumber rows."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(12), cfg)
    reqs = [_request_material(seed=50 + i, n_ctx=3, k=3) for i in range(5)]
    sched = _sched(params, cfg, n_slots=2, share_prefix=False)
    rids = [sched.submit(ctx, cands) for ctx, cands in reqs]
    res = sched.run()
    for rid in rids:
        r = res[rid]
        assert r.queue_s >= 0.0 and r.service_s > 0.0
        assert r.latency_s == pytest.approx(r.queue_s + r.service_s,
                                            abs=1e-9)
    # 5 requests through 2 rows: the later ones demonstrably queued
    assert max(res[r].queue_s for r in rids) > 0.0


def test_submit_rejections_name_request_and_candidate():
    """Oversized submissions must say *which* request and candidate were
    rejected, so bench/stream integrations can log the offender."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(13), cfg)
    sched = _sched(params, cfg, buckets=(8,), capacity=16)
    with pytest.raises(ValueError, match=r"request 7: candidate 1 "):
        sched.submit([[10, 11]], [[12, 13], list(range(20, 40))], rid=7)
    with pytest.raises(ValueError, match=r"request 9: context 13 "):
        sched.submit([[20 + i] for i in range(12)], [[12, 13, 14]], rid=9)


def test_request_stream_feeds_scheduler():
    """The synthetic request generator produces schedulable requests."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(5), cfg)
    ds = make_ctr_dataset(n_users=4, n_items=30, seq_len=8,
                          vocab_size=cfg.vocab_size)
    reqs = make_request_stream(ds, n_requests=3, k=4, n_ctx=3, seed=0)
    sched = _sched(params, cfg, capacity=96, buckets=(16, 32))
    rids = [sched.submit(r["context"], r["candidates"]) for r in reqs]
    res = sched.run()
    for rid in rids:
        assert len(res[rid].scores) == 4
        assert all(0.0 <= p <= 1.0 for p in res[rid].scores)
