"""Partition rules, divisibility guards, ZeRO-1 layout, HLO collective
parser, and the full 40-cell (smoke-scale) lower+compile sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, all_cells, get_arch
from repro.launch.hlo import collective_bytes, count_op
from repro.launch.mesh import make_cpu_mesh
from repro.launch.steps import build_cell
from repro.sharding.partition import (make_param_specs, rules_for,
                                      spec_for_shape, zero1_specs)


class TestSpecResolution:
    def _mesh(self):
        # 1-device mesh still carries axis names, so rule logic is exact
        return make_cpu_mesh()

    def test_divisibility_drop(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # sizes 1 always divide -> spec kept
        assert spec_for_shape((4, 8), (None, "model"), mesh) == P(None, "model")

    def test_right_alignment_for_scan_stack(self):
        mesh = self._mesh()
        # (L, d, f) with template (d, f) rules -> leading layer dim unsharded
        spec = spec_for_shape((12, 64, 128), (None, "model"), mesh)
        assert spec == P(None, None, "model")

    def test_lm_rules_match_expected_leaves(self):
        mesh = self._mesh()
        cfg = get_arch("qwen2-1.5b").smoke
        from repro.models.transformer import init_params
        shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        specs = make_param_specs(shapes, rules_for("lm"), mesh)
        flat = {"/".join(str(k) for k in path): s for path, s in
                jax.tree_util.tree_flatten_with_path(specs)[0]}
        q_key = next(k for k in flat if "attn" in k and "'q'" in k
                     and "w" in k)
        assert flat[q_key].spec == P(None, None, "model")
        o_key = next(k for k in flat if "attn" in k and "'o'" in k
                     and "w" in k)
        assert flat[o_key].spec == P(None, "model", None)

    def test_zero1_adds_data_axis(self):
        mesh = self._mesh()
        shapes = {"w": jax.ShapeDtypeStruct((64, 128), jnp.float32)}
        base = {"w": NamedSharding(mesh, P(None, "model"))}
        z = zero1_specs(shapes, base, mesh)
        assert z["w"].spec == P("data", "model")

    def test_zero1_skips_fsdp_leaves(self):
        mesh = self._mesh()
        shapes = {"w": jax.ShapeDtypeStruct((64, 128), jnp.float32)}
        base = {"w": NamedSharding(mesh, P("data", "model"))}
        z = zero1_specs(shapes, base, mesh)
        assert z["w"].spec == P("data", "model")     # unchanged


class TestHLOParser:
    HLO = """
  %ag = bf16[16,256]{1,0} all-gather(%x), replica_groups=[32,16]<=[512], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%y), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups=[2,8]<=[16], dimensions={0}
  %cp = bf16[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot = f32[8,8]{1,0} dot(%a, %b)
"""

    def test_counts_and_bytes(self):
        out = collective_bytes(self.HLO, n_devices=16)
        assert out["count"] == 4
        ag = 16 * 256 * 2 * 15 / 16
        ar = 2 * 1024 * 4 * 3 / 4
        rs = 64 * 4 * 7
        cp = 32 * 32 * 2
        np.testing.assert_allclose(out["all-gather"], ag)
        np.testing.assert_allclose(out["all-reduce"], ar)
        np.testing.assert_allclose(out["reduce-scatter"], rs)
        np.testing.assert_allclose(out["collective-permute"], cp)
        np.testing.assert_allclose(out["total"], ag + ar + rs + cp)

    def test_tuple_shapes(self):
        hlo = "%t = (f32[8]{0}, bf16[4,4]{1,0}) all-reduce(%a, %b), replica_groups={{0,1}}\n"
        out = collective_bytes(hlo, n_devices=2)
        expect = 2 * (8 * 4 + 16 * 2) * 1 / 2
        np.testing.assert_allclose(out["all-reduce"], expect)

    def test_count_op(self):
        assert count_op(self.HLO, "all-gather") == 1
        assert count_op(self.HLO, "dot") == 1


class TestCellCompilation:
    """Every graded (arch x shape) cell must lower AND compile with its real
    sharded step fn — at smoke scale on the CPU mesh here; the production
    512-device pass is `python -m repro.launch.dryrun` (EXPERIMENTS.md)."""

    # ~40 XLA lower+compile invocations: excluded from the quick tier-1
    # loop (-m "not slow"); the tier1-multidevice lane runs it in full
    @pytest.mark.slow
    @pytest.mark.parametrize("arch,shape", all_cells())
    def test_cell_lowers_and_compiles(self, arch, shape):
        mesh = make_cpu_mesh()
        cell = build_cell(arch, shape, mesh, smoke=True)
        compiled = jax.jit(cell.step_fn,
                           donate_argnums=cell.donate).lower(
            *cell.args).compile()
        assert compiled.cost_analysis() is not None

    def test_train_cell_executes(self):
        mesh = make_cpu_mesh()
        cell = build_cell("qwen2-moe-a2.7b", "train_4k", mesh, smoke=True)

        def materialize(sds, c=[0]):
            c[0] += 1
            r = np.random.default_rng(c[0])
            if sds.dtype == jnp.int32:
                return jnp.asarray(r.integers(0, 4, sds.shape), jnp.int32)
            if sds.dtype == jnp.bool_:
                return jnp.asarray(r.random(sds.shape) < 0.5)
            return jnp.asarray(0.02 * r.normal(size=sds.shape), sds.dtype)

        state = jax.tree_util.tree_map(materialize, cell.args[0])
        batch = jax.tree_util.tree_map(materialize, cell.args[1])
        new_state, metrics = jax.jit(cell.step_fn)(state, batch)
        assert np.isfinite(float(metrics["loss"]))
