"""Serving engine: decode == prefill, ring == full cache, absorbed MLA,
CTRServer end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import ModelConfig, init_params
from repro.serve.cache import init_lm_cache, slot_indices
from repro.serve.engine import CTRServer, make_decode_fn, make_prefill_fn

MLA = dict(q_lora_rank=24, kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=8,
           v_head_dim=16)
MOE = dict(moe=True, n_experts=4, n_shared_experts=1, top_k=2, moe_d_ff=32,
           first_dense_layers=1, norm_topk=False, capacity_factor=8.0)


def _cfg(attn_type="gqa", moe=False):
    extra = dict(MLA) if attn_type == "mla" else {}
    extra.update(MOE if moe else {})
    return ModelConfig(n_layers=3, d_model=48, n_heads=4,
                       n_kv_heads=2 if attn_type == "gqa" else 4,
                       d_ff=96, vocab_size=128, head_dim=12,
                       attn_type=attn_type, window=8, attn_impl="dense",
                       dti_sum_token=True, remat=False, **extra)


@pytest.mark.parametrize("attn_type", ["gqa", "mla"])
@pytest.mark.parametrize("moe", [False, True])
def test_decode_equals_prefill(attn_type, moe):
    """Feeding tokens one at a time through the cache must reproduce the
    prefill scores exactly (absorbed MLA included)."""
    cfg = _cfg(attn_type, moe)
    p = init_params(jax.random.PRNGKey(0), cfg)
    B, S, W = 2, 12, 8
    r = np.random.default_rng(0)
    toks = r.integers(8, 128, (B, S)).astype(np.int32)
    toks[:, -1] = 2
    pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S)).copy()
    is_sum = toks == 2
    valid = np.ones((B, S), bool)
    p_pre = make_prefill_fn(cfg, window=W)(
        p, {"tokens": toks, "positions": pos, "is_sum": is_sum,
            "valid": valid})
    decode = make_decode_fn(cfg, window=W, ring=False)
    cache = init_lm_cache(cfg, B, S, dtype=jnp.float32)
    for t in range(S):
        pc, cache = decode(p, cache, toks[:, t:t + 1], pos[:, t:t + 1],
                           is_sum[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(pc[:, 0]),
                               np.asarray(p_pre[:, -1]), atol=2e-5)


def test_ring_equals_full():
    """Ring buffer of capacity >= window+1 must match an unbounded cache at
    any logical position (what makes long_500k O(window))."""
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64, head_dim=16, window=8,
                      attn_impl="dense", remat=False)
    p = init_params(jax.random.PRNGKey(1), cfg)
    B, cap, W, T = 1, 12, 8, 40
    dec_r = make_decode_fn(cfg, window=W, ring=True)
    dec_f = make_decode_fn(cfg, window=W, ring=False)
    r = np.random.default_rng(1)
    toks = r.integers(8, 64, (B, T)).astype(np.int32)
    pos = np.arange(T, dtype=np.int32)[None]
    c_r = init_lm_cache(cfg, B, cap, dtype=jnp.float32)
    c_f = init_lm_cache(cfg, B, T, dtype=jnp.float32)
    ns = np.zeros((B, 1), bool)
    for t in range(T):
        pr, c_r = dec_r(p, c_r, toks[:, t:t + 1], pos[:, t:t + 1], ns)
        pf, c_f = dec_f(p, c_f, toks[:, t:t + 1], pos[:, t:t + 1], ns)
    np.testing.assert_allclose(np.asarray(pr), np.asarray(pf), atol=1e-5)


def test_slot_indices_wrap():
    cache = {"pos": jnp.zeros((2, 4), jnp.int32),
             "cursor": jnp.asarray([3, 0])}
    idx = slot_indices(cache, 2, ring=True)
    np.testing.assert_array_equal(np.asarray(idx), [[3, 0], [0, 1]])
    idx = slot_indices(cache, 2, ring=False)
    np.testing.assert_array_equal(np.asarray(idx), [[3, 4], [0, 1]])


def test_mla_latent_cache_is_small():
    cfg = _cfg("mla")
    cache = init_lm_cache(cfg, 2, 16)
    assert "ckv" in cache and "kpe" in cache
    # latent, not per-head: (L, B, cap, r_kv)
    assert cache["ckv"].shape == (3, 2, 16, cfg.kv_lora_rank)


def test_ctr_server_scores_prompts():
    from repro.core.dti import SpecialTokens, build_sliding_prompts
    from repro.data.synthetic import make_ctr_dataset
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = make_ctr_dataset(n_users=2, n_items=40, seq_len=12,
                          vocab_size=cfg.vocab_size)
    toks, labels = ds.user_prompt_material(0)
    prompts = build_sliding_prompts(toks, labels, n_ctx=2, max_len=64)
    server = CTRServer(params, cfg, max_len=64)
    scores = server.score(prompts[:4])
    assert len(scores) == 4
    assert all(0.0 <= s <= 1.0 for s in scores)
