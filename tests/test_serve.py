"""Serving engine: decode == prefill, ring == full cache, absorbed MLA,
multi-target shared-context prefill, CTRServer end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dti import (build_multi_target_request, build_sliding_prompts,
                            candidate_sum_slots)
from repro.models.transformer import ModelConfig, init_params
from repro.serve.cache import free_slots, init_lm_cache, slot_indices
from repro.serve.engine import (CTRServer, make_decode_fn,
                                make_multi_target_prefill_fn, make_prefill_fn)

MLA = dict(q_lora_rank=24, kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=8,
           v_head_dim=16)
MOE = dict(moe=True, n_experts=4, n_shared_experts=1, top_k=2, moe_d_ff=32,
           first_dense_layers=1, norm_topk=False, capacity_factor=8.0)


def _cfg(attn_type="gqa", moe=False):
    extra = dict(MLA) if attn_type == "mla" else {}
    extra.update(MOE if moe else {})
    return ModelConfig(n_layers=3, d_model=48, n_heads=4,
                       n_kv_heads=2 if attn_type == "gqa" else 4,
                       d_ff=96, vocab_size=128, head_dim=12,
                       attn_type=attn_type, window=8, attn_impl="dense",
                       dti_sum_token=True, remat=False, **extra)


@pytest.mark.parametrize("attn_type", ["gqa", "mla"])
@pytest.mark.parametrize("moe", [False, True])
def test_decode_equals_prefill(attn_type, moe):
    """Feeding tokens one at a time through the cache must reproduce the
    prefill scores exactly (absorbed MLA included)."""
    cfg = _cfg(attn_type, moe)
    p = init_params(jax.random.PRNGKey(0), cfg)
    B, S, W = 2, 12, 8
    r = np.random.default_rng(0)
    toks = r.integers(8, 128, (B, S)).astype(np.int32)
    toks[:, -1] = 2
    pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S)).copy()
    is_sum = toks == 2
    valid = np.ones((B, S), bool)
    p_pre = make_prefill_fn(cfg, window=W)(
        p, {"tokens": toks, "positions": pos, "is_sum": is_sum,
            "valid": valid})
    decode = make_decode_fn(cfg, window=W, ring=False)
    cache = init_lm_cache(cfg, B, S, dtype=jnp.float32)
    for t in range(S):
        pc, cache = decode(p, cache, toks[:, t:t + 1], pos[:, t:t + 1],
                           is_sum[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(pc[:, 0]),
                               np.asarray(p_pre[:, -1]), atol=2e-5)


def test_ring_equals_full():
    """Ring buffer of capacity >= window+1 must match an unbounded cache at
    any logical position (what makes long_500k O(window))."""
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64, head_dim=16, window=8,
                      attn_impl="dense", remat=False)
    p = init_params(jax.random.PRNGKey(1), cfg)
    B, cap, W, T = 1, 12, 8, 40
    dec_r = make_decode_fn(cfg, window=W, ring=True)
    dec_f = make_decode_fn(cfg, window=W, ring=False)
    r = np.random.default_rng(1)
    toks = r.integers(8, 64, (B, T)).astype(np.int32)
    pos = np.arange(T, dtype=np.int32)[None]
    c_r = init_lm_cache(cfg, B, cap, dtype=jnp.float32)
    c_f = init_lm_cache(cfg, B, T, dtype=jnp.float32)
    ns = np.zeros((B, 1), bool)
    for t in range(T):
        pr, c_r = dec_r(p, c_r, toks[:, t:t + 1], pos[:, t:t + 1], ns)
        pf, c_f = dec_f(p, c_f, toks[:, t:t + 1], pos[:, t:t + 1], ns)
    np.testing.assert_allclose(np.asarray(pr), np.asarray(pf), atol=1e-5)


def test_slot_indices_wrap():
    cache = {"pos": jnp.zeros((2, 4), jnp.int32),
             "cursor": jnp.asarray([3, 0])}
    idx = slot_indices(cache, 2, ring=True)
    np.testing.assert_array_equal(np.asarray(idx), [[3, 0], [0, 1]])
    idx = slot_indices(cache, 2, ring=False)
    np.testing.assert_array_equal(np.asarray(idx), [[3, 4], [0, 1]])


def test_mla_latent_cache_is_small():
    cfg = _cfg("mla")
    cache = init_lm_cache(cfg, 2, 16)
    assert "ckv" in cache and "kpe" in cache
    # latent, not per-head: (L, B, cap, r_kv)
    assert cache["ckv"].shape == (3, 2, 16, cfg.kv_lora_rank)


def _request_material(seed=0, n_ctx=4, k=4, vocab=128):
    r = np.random.default_rng(seed)
    ctx = [list(r.integers(8, vocab, 4)) for _ in range(n_ctx)]
    cands = [list(r.integers(8, vocab, int(r.integers(2, 5))))
             for _ in range(k)]
    return ctx, cands


def _independent_scores(params, cfg, ctx, cands, max_len, window=None):
    """k standalone [BOS] ctx cand [SUM] sliding-window prefills."""
    pre = make_prefill_fn(cfg, window=window)
    out = []
    for cand in cands:
        (prompt,) = build_sliding_prompts(
            ctx + [cand], [0] * (len(ctx) + 1), n_ctx=len(ctx),
            max_len=max_len)
        p = np.asarray(pre(params, {k: v[None] for k, v in prompt.items()}))
        out.append(p[0, np.flatnonzero(prompt["is_sum"])[-1]])
    return np.asarray(out)


@pytest.mark.parametrize("attn_type", ["gqa", "mla"])
def test_multi_target_prefill_matches_independent(attn_type):
    """One prefill over a shared-context row (context segment + k isolated
    [SUM]-terminated candidate segments) must reproduce k independent
    sliding-window prefills — the serving-side version of the paper's
    shared-context trick."""
    cfg = _cfg(attn_type)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ctx, cands = _request_material()
    row = build_multi_target_request(ctx, cands, max_len=96)
    p = np.asarray(make_multi_target_prefill_fn(cfg)(
        params, {k: v[None] for k, v in row.items()}))
    got = p[0, candidate_sum_slots(row)]
    want = _independent_scores(params, cfg, ctx, cands, max_len=96)
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_multi_target_no_cross_candidate_leakage():
    """Perturbing one candidate's tokens must leave every other candidate's
    score bit-identical — candidates share the context, never each other."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(1), cfg)
    ctx, cands = _request_material(seed=1)
    prefill = make_multi_target_prefill_fn(cfg)

    def scores(cands_):
        row = build_multi_target_request(ctx, cands_, max_len=96)
        p = np.asarray(prefill(params, {k: v[None] for k, v in row.items()}))
        return p[0, candidate_sum_slots(row)]

    base = scores(cands)
    mutated = [list(c) for c in cands]
    mutated[1] = [9, 10, 11]                     # different tokens AND length
    got = scores(mutated)
    np.testing.assert_array_equal(np.delete(got, 1), np.delete(base, 1))
    assert got[1] != base[1]


def test_decode_burst_does_not_commit():
    """A commit=False decode burst must score against the cached context and
    leave pos/cursor untouched, so repeated bursts see the pristine cache."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(2), cfg)
    decode = make_decode_fn(cfg, window=0, ring=False)
    cache = init_lm_cache(cfg, 1, 32, dtype=jnp.float32)
    r = np.random.default_rng(2)
    ctx = r.integers(8, 128, (1, 6)).astype(np.int32)
    pos = np.arange(6, dtype=np.int32)[None]
    ns = np.zeros((1, 6), bool)
    _, cache = decode(params, cache, ctx, pos, ns)         # commit context

    burst_t = np.asarray([[40, 41, 2]], np.int32)          # cand + [SUM]
    burst_p = np.asarray([[6, 7, 8]], np.int32)
    burst_s = np.asarray([[False, False, True]])
    ones, no_commit = np.ones((1, 3), bool), np.zeros((1,), bool)
    p1, c1 = decode(params, cache, burst_t, burst_p, burst_s, ones, no_commit)
    np.testing.assert_array_equal(np.asarray(c1["pos"]),
                                  np.asarray(cache["pos"]))
    np.testing.assert_array_equal(np.asarray(c1["cursor"]),
                                  np.asarray(cache["cursor"]))
    p2, _ = decode(params, c1, burst_t, burst_p, burst_s, ones, no_commit)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_free_slots_resets_only_masked_rows():
    cfg = _cfg()
    cache = init_lm_cache(cfg, 2, 8, dtype=jnp.float32)
    cache["pos"] = cache["pos"].at[:, :3].set(jnp.arange(3))
    cache["cursor"] = jnp.asarray([3, 3], jnp.int32)
    out = free_slots(cache, jnp.asarray([True, False]))
    assert int(out["cursor"][0]) == 0 and int(out["cursor"][1]) == 3
    assert np.all(np.asarray(out["pos"][0]) == -1)
    np.testing.assert_array_equal(np.asarray(out["pos"][1]),
                                  np.asarray(cache["pos"][1]))


def test_ctr_server_scores_prompts():
    from repro.core.dti import SpecialTokens, build_sliding_prompts
    from repro.data.synthetic import make_ctr_dataset
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = make_ctr_dataset(n_users=2, n_items=40, seq_len=12,
                          vocab_size=cfg.vocab_size)
    toks, labels = ds.user_prompt_material(0)
    prompts = build_sliding_prompts(toks, labels, n_ctx=2, max_len=64)
    server = CTRServer(params, cfg, max_len=64)
    scores = server.score(prompts[:4])
    assert len(scores) == 4
    assert all(0.0 <= s <= 1.0 for s in scores)
