"""Cross-request prefix sharing: the context-hash trie, refcounted cache
ops, and scheduler-level sharing (byte-identical scores, hit accounting)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.requests import ContextTrie
from repro.models.transformer import init_params
from repro.serve.cache import (free_slots, init_lm_cache, retain_slots,
                               trim_slots)
from repro.serve.scheduler import ServeScheduler

from test_serve import _cfg, _independent_scores, _request_material


# ---------------------------------------------------------------------------
# ContextTrie
# ---------------------------------------------------------------------------

def test_trie_insert_match_remove():
    t = ContextTrie()
    t.insert([1, 2, 3], "a")
    t.insert([1, 2, 3, 4, 5], "b")
    t.insert([1, 9], "c")
    # full-prefix (terminal) match at the deepest end
    end_d, ends, thr_d, thr = t.match([1, 2, 3, 4, 5, 6])
    assert (end_d, ends) == (5, {"b"})
    assert (thr_d, thr) == (5, {"b"})
    # terminal "a" at 3, "b" passes through deeper
    end_d, ends, thr_d, thr = t.match([1, 2, 3, 4])
    assert (end_d, ends) == (3, {"a"})
    assert (thr_d, thr) == (4, {"b"})
    # divergent tail: only the shared prefix matches
    end_d, ends, thr_d, thr = t.match([1, 2, 7])
    assert (end_d, ends) == (0, set())
    assert thr_d == 2 and thr == {"a", "b"}
    assert t.owner_length("b") == 5
    t.remove([1, 2, 3, 4, 5], "b")
    end_d, ends, thr_d, thr = t.match([1, 2, 3, 4])
    assert (end_d, ends) == (3, {"a"}) and (thr_d, thr) == (3, {"a"})
    t.remove([1, 2, 3], "a")
    t.remove([1, 9], "c")
    assert len(t) == 0 and not t._root["kids"]      # pruned empty


def test_trie_one_sequence_per_owner():
    t = ContextTrie()
    t.insert([1], "a")
    with pytest.raises(AssertionError):
        t.insert([2], "a")


# ---------------------------------------------------------------------------
# refcounted cache ops
# ---------------------------------------------------------------------------

def test_refcount_retain_free_cycle():
    """free_slots decrements; the row resets only at refcount zero."""
    cfg = _cfg()
    cache = init_lm_cache(cfg, 2, 8, dtype=jnp.float32)
    cache["pos"] = cache["pos"].at[:, :3].set(jnp.arange(3))
    cache["cursor"] = jnp.asarray([3, 3], jnp.int32)
    both = jnp.asarray([True, True])
    row0 = jnp.asarray([True, False])
    cache = retain_slots(retain_slots(cache, both), row0)   # ref = [2, 1]
    np.testing.assert_array_equal(np.asarray(cache["ref"]), [2, 1])
    cache = free_slots(cache, row0)                         # ref = [1, 1]
    assert int(cache["cursor"][0]) == 3                     # still held
    np.testing.assert_array_equal(np.asarray(cache["pos"][0]),
                                  [0, 1, 2, -1, -1, -1, -1, -1])
    cache = free_slots(cache, both)                         # ref = [0, 0]
    assert np.all(np.asarray(cache["pos"]) == -1)
    np.testing.assert_array_equal(np.asarray(cache["cursor"]), [0, 0])
    # a zero-ref free still resets (legacy idiom) and saturates at 0
    cache = free_slots(cache, row0)
    np.testing.assert_array_equal(np.asarray(cache["ref"]), [0, 0])


def test_trim_slots_rolls_back_to_prefix():
    cfg = _cfg()
    cache = init_lm_cache(cfg, 2, 8, dtype=jnp.float32)
    cache["pos"] = cache["pos"].at[:, :5].set(jnp.arange(5))
    cache["cursor"] = jnp.asarray([5, 5], jnp.int32)
    out = trim_slots(cache, jnp.asarray([True, False]),
                     jnp.asarray([2, 2], jnp.int32))
    np.testing.assert_array_equal(np.asarray(out["pos"][0]),
                                  [0, 1, -1, -1, -1, -1, -1, -1])
    np.testing.assert_array_equal(np.asarray(out["cursor"]), [2, 5])
    np.testing.assert_array_equal(np.asarray(out["pos"][1]),
                                  np.asarray(cache["pos"][1]))


# ---------------------------------------------------------------------------
# scheduler-level sharing
# ---------------------------------------------------------------------------

def _solo_baseline(params, cfg, ctx, cands, **kw):
    """The same request scored on a fresh scheduler with sharing off."""
    s = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                       buckets=(8, 16, 32), share_prefix=False, **kw)
    rid = s.submit(ctx, cands)
    return s.run()[rid]


@pytest.mark.parametrize("attn_impl", ["dense", "pallas"])
def test_exact_prefix_share_scores_byte_identical(attn_impl):
    """Two sequential requests with the same context: the second commits
    nothing, reuses the retained block, and its scores are byte-identical
    to an unshared run — sharing changes which row a burst reads, never
    what it attends."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ctx, cands_a = _request_material(seed=3)
    cands_b = [[70, 71], [72, 73, 74], [75]]
    sched = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                           buckets=(8, 16, 32), attn_impl=attn_impl)
    ra = sched.submit(ctx, cands_a)
    sched.run()
    rb = sched.submit(ctx, cands_b)
    got = sched.run()[rb]
    want = _solo_baseline(params, cfg, ctx, cands_b, attn_impl=attn_impl)
    np.testing.assert_array_equal(np.asarray(got.scores),
                                  np.asarray(want.scores))
    n = got.context_tokens
    assert got.shared_prefix_tokens == n and got.prefill_tokens == 0
    # all k context reads came from cache: hit fraction strictly above the
    # unshared (k-1)/k reuse level, and the accounting closes
    k = len(cands_b)
    assert got.cached_tokens == k * n
    assert got.cache_hit_fraction > want.cache_hit_fraction > 0
    assert sched.shared_admissions == 1


def test_partial_prefix_share_and_hit_fractions():
    """A request sharing only a proper prefix trims the retained block,
    commits just its tail, and still matches independent prefills; hit
    accounting reflects the shared tokens."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(1), cfg)
    ctx, cands = _request_material(seed=5)
    sched = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                           buckets=(8, 16, 32))
    sched.submit(ctx, cands)
    sched.run()
    ctx2 = [list(ctx[0]), list(ctx[1]), [60, 61], [62, 63, 64]]
    r2 = sched.submit(ctx2, cands)
    got = sched.run()[r2]
    want = _independent_scores(params, cfg, ctx2, cands, max_len=96)
    np.testing.assert_allclose(np.asarray(got.scores), want, atol=1e-4)
    shared = 1 + len(ctx[0]) + len(ctx[1])          # BOS + two interactions
    assert got.shared_prefix_tokens == shared
    assert got.prefill_tokens == got.context_tokens - shared
    base = _solo_baseline(params, cfg, ctx2, cands)
    assert got.cache_hit_fraction > base.cache_hit_fraction


def test_concurrent_share_rides_suffix_bursts():
    """Two in-flight requests, the second extending the first's committed
    context: the suffix rides each burst (no commit onto the busy block)
    and both requests match their independent-prefill scores."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(2), cfg)
    ctx, cands_a = _request_material(seed=7)
    ctx_b = [list(c) for c in ctx] + [[80, 81]]
    cands_b = [[85, 86], [87]]
    sched = ServeScheduler(params, cfg, n_slots=1, capacity=64,
                           buckets=(8, 16, 32))
    ra = sched.submit(ctx, cands_a)
    rb = sched.submit(ctx_b, cands_b)
    res = sched.run()
    want_a = _independent_scores(params, cfg, ctx, cands_a, max_len=96)
    want_b = _independent_scores(params, cfg, ctx_b, cands_b, max_len=96)
    np.testing.assert_allclose(np.asarray(res[ra].scores), want_a, atol=1e-4)
    np.testing.assert_allclose(np.asarray(res[rb].scores), want_b, atol=1e-4)
    assert res[rb].prefill_tokens == 0              # nothing committed
    assert res[rb].shared_prefix_tokens == res[ra].context_tokens
    # the 2-token suffix rode each burst: burst feed exceeds the slate
    assert res[rb].burst_tokens > sum(len(c) + 1 for c in cands_b)
    assert res[rb].cache_hit_fraction > 0


def test_same_wave_submission_shares_after_commit_gate():
    """An original and its revisit submitted together (admitted in the
    same wave, onto plenty of rows): the revisit must still share the
    original's block — its bursts are gated until the block's commits
    drain — and both must match independent prefills."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(5), cfg)
    ctx, cands_a = _request_material(seed=9)
    cands_b = [[91, 92], [93]]
    sched = ServeScheduler(params, cfg, n_slots=4, capacity=64,
                           buckets=(8, 16, 32))
    ra = sched.submit(ctx, cands_a)
    rb = sched.submit(ctx, cands_b)                 # same context, same wave
    res = sched.run()
    want_a = _independent_scores(params, cfg, ctx, cands_a, max_len=96)
    want_b = _independent_scores(params, cfg, ctx, cands_b, max_len=96)
    np.testing.assert_allclose(np.asarray(res[ra].scores), want_a, atol=1e-4)
    np.testing.assert_allclose(np.asarray(res[rb].scores), want_b, atol=1e-4)
    assert res[rb].shared_prefix_tokens == res[ra].context_tokens
    assert res[rb].prefill_tokens == 0
    assert sched.shared_admissions == 1


def test_no_sharing_below_min_prefix():
    """Contexts that agree only on [BOS] must not trigger sharing (and
    must still score correctly through steal/readmission)."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(3), cfg)
    sched = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                           buckets=(8, 16, 32), min_shared_prefix=4)
    for seed in (11, 12, 13):
        ctx, cands = _request_material(seed=seed, n_ctx=3, k=2)
        rid = sched.submit(ctx, cands)
        res = sched.run()[rid]
        want = _independent_scores(params, cfg, ctx, cands, max_len=96)
        np.testing.assert_allclose(np.asarray(res.scores), want, atol=1e-4)
        assert res.shared_prefix_tokens == 0
    assert sched.shared_admissions == 0


def test_weight_swap_invalidates_retained_blocks():
    """A weight hot-swap must drop retained context blocks: their KV
    encodes the old weights, and sharing them would score post-swap
    traffic against stale context. Post-swap requests re-commit and match
    a scheduler born with the new weights."""
    cfg = _cfg()
    p_old = init_params(jax.random.PRNGKey(0), cfg)
    p_new = init_params(jax.random.PRNGKey(1), cfg)
    ctx, cands = _request_material(seed=6)
    sched = ServeScheduler(p_old, cfg, n_slots=2, capacity=64,
                           buckets=(8, 16, 32))
    sched.submit(ctx, cands)
    sched.run()                                     # block now retained
    sched.update_params(p_new, version=1)
    rid = sched.submit(ctx, cands)                  # same context, new w
    got = sched.run()[rid]
    assert got.shared_prefix_tokens == 0            # no stale sharing
    fresh = ServeScheduler(p_new, cfg, n_slots=2, capacity=64,
                           buckets=(8, 16, 32))
    rid2 = fresh.submit(ctx, cands)
    np.testing.assert_allclose(np.asarray(got.scores),
                               np.asarray(fresh.run()[rid2].scores),
                               atol=1e-6)


def test_retained_blocks_survive_runs_and_steal():
    """Retained contexts persist across run() calls; when every row is
    retained a fresh unrelated request steals the LRU block and scores
    correctly."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(4), cfg)
    sched = ServeScheduler(params, cfg, n_slots=2, capacity=64,
                           buckets=(8, 16, 32))
    material = [_request_material(seed=20 + i, n_ctx=3, k=2)
                for i in range(3)]
    for ctx, cands in material[:2]:                 # fill + retain both rows
        sched.submit(ctx, cands)
        sched.run()
    assert all(r.retained for r in sched._rows)
    np.testing.assert_array_equal(
        np.asarray(sched.cache["ref"]), [1, 1])     # retention holds
    ctx, cands = material[2]                        # unrelated: steals LRU
    rid = sched.submit(ctx, cands)
    res = sched.run()[rid]
    want = _independent_scores(params, cfg, ctx, cands, max_len=96)
    np.testing.assert_allclose(np.asarray(res.scores), want, atol=1e-4)
    assert res.shared_prefix_tokens == 0
