"""Training runtime: optimizer math, schedules, LoRA masking, grad accum,
compression, checkpointing (atomic/keep-k/elastic), fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   compress_int8, decompress_int8,
                                   ef_compress_grads, init_opt_state,
                                   schedule_lr)
from repro.train.resilience import FailureSupervisor, StragglerMonitor
from repro.train.trainer import (TrainOptions, Trainer, init_train_state,
                                 make_train_step)


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        cfg = OptimizerConfig(lr=0.1, schedule="const", warmup_steps=1,
                              total_steps=100, weight_decay=0.0,
                              grad_clip=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = init_opt_state(cfg, params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}          # d/dw w^2
            params, state, _ = adamw_update(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_schedules(self):
        for sched in ("cosine", "wsd", "const"):
            cfg = OptimizerConfig(lr=1.0, schedule=sched, warmup_steps=10,
                                  total_steps=100, min_lr_frac=0.1)
            lrs = [float(schedule_lr(cfg, jnp.asarray(s)))
                   for s in range(100)]
            assert lrs[0] < lrs[9]                  # warmup
            assert max(lrs) <= 1.0 + 1e-6
        # WSD holds stable then decays
        cfg = OptimizerConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                              total_steps=100, decay_frac=0.2)
        mid = float(schedule_lr(cfg, jnp.asarray(50)))
        end = float(schedule_lr(cfg, jnp.asarray(99)))
        assert abs(mid - 1.0) < 1e-5 and end < 0.2

    def test_lora_trainable_mask_freezes_base(self):
        cfg = OptimizerConfig(lr=0.1, schedule="const", trainable="lora")
        params = {"w": jnp.ones((4, 4)),
                  "lora_a": jnp.ones((4, 2)), "lora_b": jnp.zeros((2, 4))}
        state = init_opt_state(cfg, params)
        grads = {k: jnp.ones_like(v) for k, v in params.items()}
        new, _, _ = adamw_update(cfg, grads, state, params)
        np.testing.assert_array_equal(new["w"], params["w"])       # frozen
        assert float(jnp.abs(new["lora_a"] - params["lora_a"]).max()) > 0

    def test_grad_clip(self):
        cfg = OptimizerConfig(lr=1e-3, grad_clip=1.0, schedule="const")
        params = {"w": jnp.zeros(3)}
        state = init_opt_state(cfg, params)
        _, _, stats = adamw_update(cfg, {"w": jnp.asarray([1e3, 0, 0])},
                                   state, params)
        assert float(stats["grad_norm"]) > 100     # reported pre-clip

    @pytest.mark.hyp
    @given(st.lists(st.floats(-100, 100), min_size=2, max_size=40))
    @settings(max_examples=30, deadline=None)
    def test_int8_roundtrip_error_bound(self, xs):
        g = jnp.asarray(xs, jnp.float32)
        q, s = compress_int8(g)
        err = jnp.abs(decompress_int8(q, s) - g)
        assert float(err.max()) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_preserves_sum(self):
        """Over many steps the EF residual keeps the compressed stream
        unbiased: sum(deq) -> sum(g)."""
        r = np.random.default_rng(0)
        g = jnp.asarray(r.normal(size=(64,)), jnp.float32)
        err = jnp.zeros_like(g)
        total = jnp.zeros_like(g)
        for _ in range(50):
            deq, err = ef_compress_grads(g, err)
            total = total + deq
        np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                                   atol=float(jnp.abs(g).max()) / 50)


class TestTrainStep:
    def _loss(self, params, batch, rng):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    def _batch(self, n=32, seed=0):
        r = np.random.default_rng(seed)
        x = r.normal(size=(n, 4)).astype(np.float32)
        w_true = np.asarray([1.0, -2.0, 3.0, 0.5], np.float32)
        return {"x": x, "y": x @ w_true}

    def test_grad_accum_matches_full_batch(self):
        cfg = OptimizerConfig(lr=1e-2, schedule="const")
        params = {"w": jnp.zeros(4)}
        b = self._batch()
        s1 = init_train_state(params, cfg, TrainOptions(donate=False))
        s2 = init_train_state(params, cfg,
                              TrainOptions(grad_accum=4, donate=False))
        f1 = make_train_step(self._loss, cfg, TrainOptions(donate=False))
        f4 = make_train_step(self._loss, cfg,
                             TrainOptions(grad_accum=4, donate=False))
        s1, m1 = f1(s1, b, jax.random.PRNGKey(0))
        s2, m2 = f4(s2, b, jax.random.PRNGKey(0))
        np.testing.assert_allclose(np.asarray(s1.params["w"]),
                                   np.asarray(s2.params["w"]), atol=1e-6)

    def test_training_reduces_loss(self):
        cfg = OptimizerConfig(lr=5e-2, schedule="const", warmup_steps=1,
                              weight_decay=0.0)
        state = init_train_state({"w": jnp.zeros(4)}, cfg)
        step = make_train_step(self._loss, cfg)
        losses = []
        for i in range(200):
            state, m = step(state, self._batch(seed=i),
                            jax.random.PRNGKey(i))
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.05 * losses[0]


class TestCheckpoint:
    def test_roundtrip_atomic_keepk(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, save_interval=1,
                                async_write=False)
        state = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                 "b": jnp.asarray(1.5, jnp.bfloat16)}
        for step in (1, 2, 3):
            mgr.save(step, state, meta={"step": step})
        assert mgr.all_steps() == [2, 3]              # keep-k gc
        target = {"w": jnp.zeros((2, 3)), "b": jnp.asarray(0, jnp.bfloat16)}
        restored = mgr.restore(target)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))
        assert restored["b"].dtype == jnp.bfloat16
        assert mgr.restore_meta()["meta"]["step"] == 3

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        mgr.save(1, {"w": jnp.zeros((2, 3))})
        with pytest.raises(ValueError):
            mgr.restore({"w": jnp.zeros((3, 3))})

    def test_elastic_restore_onto_new_sharding(self, tmp_path):
        """Save unsharded, restore with explicit shardings (the lose-a-pod
        path: restore is mesh-agnostic)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_cpu_mesh
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        state = {"w": jnp.arange(8, dtype=jnp.float32)}
        mgr.save(1, state)
        mesh = make_cpu_mesh()
        shardings = {"w": NamedSharding(mesh, P("data"))}
        restored = mgr.restore({"w": jnp.zeros(8)}, shardings=shardings)
        assert restored["w"].sharding == shardings["w"]
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(8))

    def test_crash_resume_full_state_with_compression(self, tmp_path):
        """Crash-resume of a FULL TrainState — params, AdamW moments
        (incl. fp32 master), and the error-feedback residual with gradient
        compression on. A mid-run failure (via FailureSupervisor) restores
        from ``latest_step`` and the resumed run reproduces the
        uninterrupted one bit-for-bit."""
        opts = TrainOptions(compress_grads=True, donate=False)
        cfg = OptimizerConfig(lr=1e-2, schedule="const", warmup_steps=1)

        def loss(params, batch, rng):
            return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2), {}

        def batch(i):
            r = np.random.default_rng(i)
            x = r.normal(size=(8, 4)).astype(np.float32)
            return {"x": x, "y": x @ np.asarray([1.0, -2.0, 3.0, 0.5],
                                                np.float32)}

        step = make_train_step(loss, cfg, opts)
        params = {"w": jnp.zeros(4)}

        def run_steps(state, lo, hi):
            for i in range(lo, hi):
                state, _ = step(state, batch(i), jax.random.PRNGKey(i))
            return state

        # uninterrupted reference over 6 steps
        ref = run_steps(init_train_state(params, cfg, opts), 0, 6)
        assert ref.ef_error is not None            # compression engaged

        mgr = CheckpointManager(str(tmp_path), save_interval=1,
                                async_write=False)
        state = run_steps(init_train_state(params, cfg, opts), 0, 4)
        mgr.save(4, state, meta={"step": 4})

        calls = {"n": 0}

        def attempt():
            calls["n"] += 1
            if calls["n"] == 1:                    # simulated mid-run failure
                raise RuntimeError("pod lost at step 5")
            restored = mgr.restore(
                init_train_state(params, cfg, opts),
                step=mgr.latest_step())
            # the round-trip is exact: every leaf incl. moments + residual
            for a, b in zip(jax.tree_util.tree_leaves(restored),
                            jax.tree_util.tree_leaves(state)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            start = mgr.restore_meta()["meta"]["step"]
            return run_steps(restored, start, 6)

        from repro.train.resilience import FailureSupervisor
        final = FailureSupervisor(lambda: None, max_failures=2).attempt(attempt)
        for a, b in zip(jax.tree_util.tree_leaves(final),
                        jax.tree_util.tree_leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_trainer_resume(self, tmp_path):
        cfg = OptimizerConfig(lr=1e-2, schedule="const")

        def loss(params, batch, rng):
            return jnp.mean((params["w"] - 1.0) ** 2), {}

        def batches():
            while True:
                yield {}

        state = init_train_state({"w": jnp.zeros(2)}, cfg)
        step = make_train_step(loss, cfg, TrainOptions(donate=False))
        mgr = CheckpointManager(str(tmp_path), save_interval=5,
                                async_write=False)
        t1 = Trainer(step, state, ckpt=mgr, log_fn=lambda *_: None)
        t1.run(batches(), n_steps=7)
        assert t1.step == 7
        t2 = Trainer(step, init_train_state({"w": jnp.zeros(2)}, cfg),
                     ckpt=mgr, log_fn=lambda *_: None)
        t2.resume_if_possible()
        assert t2.step == 7
        np.testing.assert_allclose(np.asarray(t2.state.params["w"]),
                                   np.asarray(t1.state.params["w"]))


class TestResilience:
    def test_straggler_flagging(self):
        mon = StragglerMonitor(4, threshold=1.5, patience=2)
        for step in range(5):
            times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 5.0}
            rep = mon.update(step, times)
        assert rep.stragglers == [3]
        assert rep.worst_ratio > 1.5

    def test_no_false_positives(self):
        mon = StragglerMonitor(4)
        for step in range(10):
            rep = mon.update(step, {h: 1.0 + 0.01 * h for h in range(4)})
        assert rep.stragglers == []

    def test_failure_supervisor_recovers(self):
        calls = {"n": 0, "recovered": 0}

        def recover():
            calls["recovered"] += 1

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("pod lost")
            return "done"

        sup = FailureSupervisor(recover, max_failures=5)
        assert sup.attempt(flaky) == "done"
        assert calls["recovered"] == 2

    def test_failure_supervisor_budget(self):
        sup = FailureSupervisor(lambda: None, max_failures=2)
        with pytest.raises(RuntimeError):
            sup.attempt(lambda: (_ for _ in ()).throw(RuntimeError("x")))
