"""Shard-and-merge exactness: the properties the fleet aggregation rides on.

``repro.stream.shard`` claims its aggregation is *exact*, not approximate:
partition a stream over any number of shards, accumulate per shard, merge —
and you get the single-shard value of the unpartitioned stream, to the
float. These hypothesis properties pin that claim for every merged
quantity: ``StreamingAUC`` / ``StreamingLogLoss`` (the eval side),
``shard_events`` routing (the stream side), and merged ``serve.*``-style
registry snapshots (the serve side). If any of these drifted from exact,
fleet dashboards would silently disagree with single-host reruns.
"""
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.metrics import StreamingAUC, StreamingLogLoss
from repro.obs.metrics import MetricsRegistry, merge_snapshots
from repro.stream.shard import (fleet_serve_snapshot, merged_streaming_auc,
                                merged_streaming_log_loss, shard_events,
                                shard_key)

# one observation: (user id, label, score)
_OBS = st.tuples(st.integers(0, 50), st.integers(0, 1),
                 st.floats(0.0, 1.0, allow_nan=False))


def _accumulate(cls_kwargs, cls, obs):
    acc = cls(**cls_kwargs)
    if obs:
        _, labels, scores = zip(*obs)
        acc.update(labels, scores)
    return acc


@pytest.mark.hyp
@settings(max_examples=60, deadline=None)
@given(st.lists(_OBS, max_size=60), st.integers(1, 7))
def test_sharded_auc_merges_to_global(obs, n_shards):
    """Routing observations by user over any shard count and merging the
    per-shard AUC accumulators reproduces the global AUC *bit-exactly*
    (integer bin histograms add — no float path at all)."""
    global_acc = _accumulate({}, StreamingAUC, obs)
    shards = [
        _accumulate({}, StreamingAUC,
                    [o for o in obs if shard_key({"user": o[0]},
                                                 n_shards) == s])
        for s in range(n_shards)]
    merged = merged_streaming_auc(shards)
    np.testing.assert_array_equal(merged.pos, global_acc.pos)
    np.testing.assert_array_equal(merged.neg, global_acc.neg)
    assert merged.value() == global_acc.value()
    # inputs must not have been mutated (shards keep accumulating)
    assert sum(int(s.n) for s in shards) == global_acc.n == merged.n


@pytest.mark.hyp
@settings(max_examples=60, deadline=None)
@given(st.lists(_OBS, max_size=60), st.integers(1, 7))
def test_sharded_log_loss_merges_to_global(obs, n_shards):
    """Per-shard log-loss sums merge to the global sum up to float
    re-association (each observation's term is computed identically; only
    the addition order differs across shard partitions)."""
    global_acc = _accumulate({}, StreamingLogLoss, obs)
    shards = [
        _accumulate({}, StreamingLogLoss,
                    [o for o in obs if shard_key({"user": o[0]},
                                                 n_shards) == s])
        for s in range(n_shards)]
    merged = merged_streaming_log_loss(shards)
    assert merged.n == global_acc.n
    np.testing.assert_allclose(merged.total, global_acc.total,
                               rtol=1e-12, atol=1e-12)


@pytest.mark.hyp
@settings(max_examples=60, deadline=None)
@given(st.lists(st.lists(st.tuples(st.integers(0, 50), st.integers(0, 1)),
                         max_size=6),
                max_size=8),
       st.integers(1, 5))
def test_shard_events_partitions_exactly(ticks, n_shards):
    """``shard_events`` is a partition, not a resample: every event lands
    on exactly one shard (the one its user hashes to), tick alignment is
    preserved on every shard, and within-tick order survives."""
    streams = [[{"user": u, "label": y} for u, y in tick] for tick in ticks]
    shards = shard_events(streams, n_shards)
    assert len(shards) == n_shards
    for s, shard in enumerate(shards):
        assert len(shard) == len(streams)          # tick-aligned
        for tick in shard:
            for e in tick:
                assert shard_key(e, n_shards) == s
    for t, tick in enumerate(streams):             # nothing lost, order kept
        for s in range(n_shards):
            mine = [e for e in tick if shard_key(e, n_shards) == s]
            assert shards[s][t] == mine


# per-shard registry activity: (counter increments, gauge value,
# histogram observations)
_SHARD_OPS = st.tuples(st.lists(st.integers(0, 100), max_size=5),
                       st.floats(0, 1e6, allow_nan=False),
                       st.lists(st.floats(0, 100, allow_nan=False),
                                max_size=5))


def _registry(ops):
    (incs, gauge, hist) = ops
    m = MetricsRegistry()
    c = m.counter("serve.steps")
    for i in incs:
        c.inc(i)
    m.gauge("serve.queue_depth_now").set(gauge)
    h = m.histogram("serve.step_ms", bounds=(1.0, 10.0, 100.0))
    for v in hist:
        h.observe(v)
    return m


class _Sched:
    """The duck type ``fleet_serve_snapshot`` consumes: anything with a
    ``metrics`` registry."""

    def __init__(self, metrics):
        self.metrics = metrics


@pytest.mark.hyp
@settings(max_examples=60, deadline=None)
@given(st.lists(_SHARD_OPS, min_size=1, max_size=5),
       st.randoms(use_true_random=False))
def test_fleet_serve_snapshot_equals_global_registry(shard_ops, rnd):
    """Merged per-shard ``serve.*`` snapshots equal the snapshot of one
    registry that saw every shard's activity — counters and histograms
    exactly; the gauge resolves to the max over ``(seq, value)``, which is
    what a fleet point-in-time gauge means. Shard order must not matter."""
    scheds = [_Sched(_registry(ops)) for ops in shard_ops]
    merged = fleet_serve_snapshot(scheds)
    shuffled = list(scheds)
    rnd.shuffle(shuffled)
    assert fleet_serve_snapshot(shuffled) == merged

    everything = _registry((
        [i for ops in shard_ops for i in ops[0]],
        0.0,                                  # gauges handled below
        [v for ops in shard_ops for v in ops[2]],
    )).snapshot(prefix="serve.")
    assert merged["serve.steps"] == everything["serve.steps"]
    assert (merged["serve.step_ms"]["counts"]
            == everything["serve.step_ms"]["counts"])
    np.testing.assert_allclose(merged["serve.step_ms"]["total"],
                               everything["serve.step_ms"]["total"],
                               rtol=1e-12)
    # every shard set its gauge once (seq=1), so the merged gauge is the
    # tie-broken max — deterministic and equal to the plain max of values
    assert (merged["serve.queue_depth_now"]["value"]
            == max(ops[1] for ops in shard_ops))


def test_merge_matches_scheduler_registry_names():
    """Non-hypothesis smoke: merging two real merge_snapshots inputs with
    disjoint and overlapping names keeps the union (a shard that never
    evicted still contributes its other counters)."""
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("serve.steps").inc(3)
    a.counter("serve.page_evictions").inc(1)
    b.counter("serve.steps").inc(4)
    merged = merge_snapshots(a.snapshot(), b.snapshot())
    assert merged["serve.steps"]["value"] == 7
    assert merged["serve.page_evictions"]["value"] == 1
