"""Per-architecture smoke tests: every assigned arch instantiates its
reduced config and runs a real forward/train step on CPU — output shapes
correct, losses finite, and a short training run moves the loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch
from repro.launch.smoke import train_smoke

LM_ARCHS = [a for a in ASSIGNED if get_arch(a).family == "lm"]
OTHER_ARCHS = [a for a in ASSIGNED if get_arch(a).family != "lm"]


class TestArchSmoke:
    @pytest.mark.parametrize("arch", ASSIGNED)
    def test_train_smoke(self, arch):
        res = train_smoke(arch, steps=8, batch=4)
        assert np.isfinite(res["losses"]).all()
        # not diverging: median of the tail, not the single last step —
        # 8 constant-lr steps oscillate on some archs (noise, not divergence)
        assert np.median(res["losses"][-4:]) < res["first"] * 1.5

    @pytest.mark.parametrize("arch", ["qwen2-1.5b", "deepseek-v2-236b"])
    def test_loss_decreases(self, arch):
        res = train_smoke(arch, steps=25, batch=8, lr=3e-3)
        assert res["last"] < res["first"]


class TestLMForward:
    @pytest.mark.parametrize("arch", LM_ARCHS)
    def test_forward_shapes_no_nan(self, arch):
        from repro.models.transformer import forward, init_params
        cfg = get_arch(arch).smoke
        p = init_params(jax.random.PRNGKey(0), cfg)
        B, S = 2, 4 * max(cfg.window, 32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab_size)
        out = forward(p, cfg, toks)
        assert out["hidden"].shape == (B, S, cfg.d_model)
        assert np.isfinite(np.asarray(out["hidden"], jnp.float32)).all()

    @pytest.mark.parametrize("arch", LM_ARCHS)
    def test_dti_forward(self, arch):
        from repro.models.transformer import forward, init_params
        cfg = get_arch(arch).smoke
        p = init_params(jax.random.PRNGKey(0), cfg)
        B, S = 2, 4 * max(cfg.window, 32)
        r = np.random.default_rng(0)
        toks = jnp.asarray(r.integers(8, cfg.vocab_size, (B, S)), jnp.int32)
        is_sum = jnp.asarray(r.random((B, S)) < 0.1)
        out = forward(p, cfg, toks, is_sum=is_sum, dti_enabled=True)
        assert np.isfinite(np.asarray(out["hidden"], jnp.float32)).all()

    def test_moe_aux_loss_positive(self):
        from repro.models.transformer import forward, init_params
        cfg = get_arch("qwen2-moe-a2.7b").smoke
        p = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                  cfg.vocab_size)
        out = forward(p, cfg, toks)
        assert float(out["aux_loss"]) > 0

    def test_lora_params_exist_for_peft_archs(self):
        from repro.models.transformer import init_params
        cfg = get_arch("deepseek-v2-236b").smoke
        p = init_params(jax.random.PRNGKey(0), cfg)
        leaves = jax.tree_util.tree_flatten_with_path(p)[0]
        assert any("lora_a" in str(path) for path, _ in leaves)


class TestRecsysModels:
    def test_xdeepfm_cin_shapes(self):
        from repro.models.recsys import init_xdeepfm, xdeepfm_forward
        cfg = get_arch("xdeepfm").smoke
        p = init_xdeepfm(jax.random.PRNGKey(0), cfg)
        ids = jnp.zeros((4, len(cfg.field_vocabs)), jnp.int32)
        out = xdeepfm_forward(p, cfg, ids)
        assert out.shape == (4,)

    def test_din_multi_target_matches_single(self):
        """The DTI transplant: k targets sharing one history pass must equal
        k independent single-target passes."""
        from repro.models.recsys import (din_forward, din_forward_multi,
                                         init_din)
        cfg = get_arch("din").smoke
        p = init_din(jax.random.PRNGKey(0), cfg)
        r = np.random.default_rng(0)
        hist = jnp.asarray(r.integers(0, 1000, (3, 20)), jnp.int32)
        targets = jnp.asarray(r.integers(0, 1000, (3, 5)), jnp.int32)
        multi = din_forward_multi(p, cfg, hist, targets)
        for j in range(5):
            single = din_forward(p, cfg, hist, targets[:, j])
            np.testing.assert_allclose(multi[:, j], single, atol=1e-5)

    def test_sasrec_windowed_option(self):
        """cfg.window>0: positions beyond the window cannot influence the
        last hidden state (DTI's alignment argument applied to SASRec)."""
        import dataclasses
        from repro.models.recsys import init_sasrec, sasrec_encode
        cfg = dataclasses.replace(get_arch("sasrec").smoke, window=4,
                                  seq_len=16)
        p = init_sasrec(jax.random.PRNGKey(0), cfg)
        r = np.random.default_rng(0)
        hist = jnp.asarray(r.integers(0, 1000, (2, 16)), jnp.int32)
        h1 = sasrec_encode(p, cfg, hist)
        hist2 = hist.at[:, :4].set(7)        # only positions 0..3 change
        h2 = sasrec_encode(p, cfg, hist2)
        # with 1 block, last position attends [11..15] -> unchanged
        np.testing.assert_allclose(h1[:, -1], h2[:, -1], atol=1e-5)

    def test_mind_retrieval_matches_forward_scores(self):
        from repro.models.recsys import init_mind, mind_interests, mind_retrieval
        cfg = get_arch("mind").smoke
        p = init_mind(jax.random.PRNGKey(0), cfg)
        r = np.random.default_rng(0)
        hist = jnp.asarray(r.integers(0, 1000, (1, 20)), jnp.int32)
        cands = jnp.asarray(r.integers(0, 1000, (32,)), jnp.int32)
        scores = mind_retrieval(p, cfg, hist, cands)
        assert scores.shape == (32,)
        assert np.isfinite(np.asarray(scores)).all()


class TestGNN:
    def test_edge_valid_masks_padding(self):
        from repro.models.gnn import gin_forward, init_gin
        cfg = get_arch("gin-tu").smoke
        p = init_gin(jax.random.PRNGKey(0), cfg)
        r = np.random.default_rng(0)
        x = jnp.asarray(r.normal(size=(20, cfg.d_feat)), jnp.float32)
        es = jnp.asarray(r.integers(0, 20, 40), jnp.int32)
        ed = jnp.asarray(r.integers(0, 20, 40), jnp.int32)
        ev = jnp.asarray(np.arange(40) < 30)
        out1 = gin_forward(p, cfg, x, es, ed, edge_valid=ev)
        # perturbing masked edges changes nothing
        es2 = es.at[35].set(3)
        out2 = gin_forward(p, cfg, x, es2, ed, edge_valid=ev)
        np.testing.assert_allclose(out1, out2, atol=1e-6)
        # truncated graph gives the same result
        out3 = gin_forward(p, cfg, x, es[:30], ed[:30])
        np.testing.assert_allclose(out1, out3, atol=1e-6)

    def test_graph_classification(self):
        from repro.data.sampler import make_molecule_batch
        from repro.models.gnn import gin_graph_forward, init_gin
        cfg = get_arch("gin-tu").smoke
        p = init_gin(jax.random.PRNGKey(0), cfg)
        x, es, ed, gids, ys = make_molecule_batch(4, 10, 20, cfg.d_feat,
                                                  cfg.n_classes)
        out = gin_graph_forward(p, cfg, jnp.asarray(x), jnp.asarray(es),
                                jnp.asarray(ed), jnp.asarray(gids), 4)
        assert out.shape == (4, cfg.n_classes)
