"""Unit tests for the paper's core machinery: prompts, masks, reset, Eq. 3,
metrics, losses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.dti import (PromptStats, SpecialTokens, batch_prompts,
                            build_sliding_prompts, build_streaming_prompts,
                            window_tokens)
from repro.core.flops import (dti_flops, flops_reduction_approx,
                              flops_reduction_exact, sliding_window_flops)
from repro.core.losses import ctr_logits, ctr_loss
from repro.core.metrics import auc, ctr_metrics, f1, log_loss
from repro.core.windowed import ResetConfig, dti_mask, reset_alpha

SP = SpecialTokens()


def _items(m, tok_len=3, seed=0):
    r = np.random.default_rng(seed)
    toks = [[int(t) for t in r.integers(SP.n_reserved, 100, tok_len)]
            for _ in range(m)]
    labels = r.integers(0, 2, m)
    return toks, labels


# ---------------------------------------------------------------------------
# prompt builders (paper §3.1, §3.2)
# ---------------------------------------------------------------------------

class TestPrompts:
    def test_sliding_window_count(self):
        toks, labels = _items(30)
        prompts = build_sliding_prompts(toks, labels, n_ctx=5, max_len=256)
        assert len(prompts) == 30 - 5          # m - n prompts

    def test_streaming_count(self):
        toks, labels = _items(30)
        prompts = build_streaming_prompts(toks, labels, n_ctx=5, k=5,
                                          max_len=256)
        assert len(prompts) == 5               # ceil((m - n) / k)

    def test_streaming_k_targets_per_prompt(self):
        toks, labels = _items(25)
        prompts = build_streaming_prompts(toks, labels, n_ctx=5, k=4,
                                          max_len=256)
        for p in prompts[:-1]:
            assert int(p["is_sum"].sum()) == 4

    def test_labels_only_at_sum_positions(self):
        toks, labels = _items(20)
        for build, kw in [(build_sliding_prompts, {}),
                          (build_streaming_prompts, {"k": 3})]:
            for p in build(toks, labels, n_ctx=4, max_len=256, **kw):
                assert not np.any(p["labels"][~p["is_sum"]])

    def test_streaming_label_alignment(self):
        toks, labels = _items(20)
        prompts = build_streaming_prompts(toks, labels, n_ctx=4, k=3,
                                          max_len=256)
        got = np.concatenate([p["labels"][p["is_sum"]] for p in prompts])
        np.testing.assert_array_equal(got, labels[4:])

    def test_token_budget_ratio(self):
        """Streaming prompts shrink total tokens ~k/(1 + k/n)-fold — the
        redundancy elimination that drives Eq. 3."""
        toks, labels = _items(200, tok_len=4)
        s_sw, s_dti = PromptStats(), PromptStats()
        build_sliding_prompts(toks, labels, n_ctx=20, max_len=4096,
                              stats=s_sw)
        build_streaming_prompts(toks, labels, n_ctx=20, k=50, max_len=4096,
                                stats=s_dti)
        assert s_sw.n_tokens / s_dti.n_tokens > 5.0
        assert s_dti.n_targets == 180

    def test_batching_shapes(self):
        toks, labels = _items(30)
        prompts = build_streaming_prompts(toks, labels, n_ctx=5, k=5,
                                          max_len=128)
        b = next(batch_prompts(prompts, 4))
        assert b["tokens"].shape == (4, 128)
        assert b["valid"].dtype == bool

    def test_window_tokens_cap(self):
        assert window_tokens(20, 5.0) <= 1024    # the paper's cap
        assert window_tokens(2, 3.0) == 9


# ---------------------------------------------------------------------------
# masks + reset (paper §3.3, §4.1)
# ---------------------------------------------------------------------------

class TestMaskAndReset:
    def test_mask_causal_window(self):
        pos = jnp.arange(16)[None]
        m = np.asarray(dti_mask(pos, pos, window=4))[0]
        for t in range(16):
            for s in range(16):
                expect = 0 <= t - s <= 4
                assert m[t, s] == expect

    def test_mask_sum_isolation(self):
        pos = jnp.arange(8)[None]
        is_sum = jnp.zeros((1, 8), bool).at[0, 3].set(True)
        m = np.asarray(dti_mask(pos, pos, window=8, is_sum_k=is_sum))[0]
        assert m[3, 3]                       # SUM attends itself
        assert not m[4:, 3].any()            # nobody else attends the SUM

    @pytest.mark.hyp
    @given(st.integers(0, 2000))
    @settings(max_examples=30, deadline=None)
    def test_reset_alpha_bounds(self, d):
        cfg = ResetConfig(0.1, 0.4, 512.0)
        a = float(reset_alpha(jnp.asarray(d), cfg))
        assert 0.1 <= a <= 0.4 + 1e-6

    def test_reset_alpha_monotone(self):
        cfg = ResetConfig(0.0, 0.3, 512.0)
        d = jnp.arange(0, 1200, 10)
        a = np.asarray(reset_alpha(d, cfg))
        assert np.all(np.diff(a) >= -1e-9)
        mid = float(reset_alpha(jnp.asarray(512), cfg))
        assert abs(mid - 0.15) < 1e-6        # midpoint -> (ymin+ymax)/2


# ---------------------------------------------------------------------------
# FLOPs model (paper §3.5)
# ---------------------------------------------------------------------------

class TestEq3:
    def test_paper_example(self):
        """n=20 ctx, k=50 targets: the paper quotes 14.28x."""
        c = 10                               # tokens per interaction
        red = flops_reduction_approx(N=20 * c, K=50 * c, k=50)
        assert abs(red - 14.2857) < 1e-3

    def test_exact_matches_ratio(self):
        m, n, k, c, d, L = 5000, 20, 50, 10, 256, 4
        N, K = n * c, k * c
        sw = sliding_window_flops(m, n, N, d, L)
        dt = dti_flops(m, k, N, K, d, L)
        assert abs(sw / dt - flops_reduction_exact(m, n, k, N, K)
                   * (N + d) / (N + d)) / (sw / dt) < 0.35
        # approx converges to exact as m -> inf
        assert abs(flops_reduction_exact(10**7, n, k, N, K)
                   - flops_reduction_approx(N, K, k)) < 0.01

    @pytest.mark.hyp
    @given(st.integers(2, 60))
    @settings(max_examples=20, deadline=None)
    def test_reduction_increases_with_k(self, k):
        assert (flops_reduction_approx(200, (k + 1) * 10, k + 1)
                > flops_reduction_approx(200, k * 10, k))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_auc_perfect_and_random(self):
        y = np.array([0, 0, 1, 1])
        assert auc(y, np.array([.1, .2, .8, .9])) == 1.0
        assert auc(y, np.array([.9, .8, .2, .1])) == 0.0
        assert auc(y, np.array([.5, .5, .5, .5])) == 0.5

    def test_auc_ties_average_rank(self):
        y = np.array([0, 1, 0, 1])
        s = np.array([.3, .3, .1, .9])
        assert abs(auc(y, s) - 0.875) < 1e-9

    @pytest.mark.hyp
    @given(st.lists(st.tuples(st.integers(0, 1),
                              st.floats(0.01, 0.99)), min_size=6,
                    max_size=60))
    @settings(max_examples=30, deadline=None)
    def test_auc_monotonic_invariance(self, pairs):
        y = np.array([p[0] for p in pairs])
        s = np.array([p[1] for p in pairs])
        if y.min() == y.max():
            return
        a1 = auc(y, s)
        # power-of-two scale + shift: strictly monotone AND exact in floats
        # (sigmoid-style transforms can collapse near-equal scores into
        # ties, legitimately changing the tie-averaged AUC)
        a2 = auc(y, 4.0 * s - 1.0)
        assert abs(a1 - a2) < 1e-9

    def test_log_loss_known(self):
        y = np.array([1, 0])
        p = np.array([0.8, 0.2])
        expect = -np.mean([np.log(0.8), np.log(0.8)])
        assert abs(log_loss(y, p) - expect) < 1e-9

    def test_f1(self):
        y = np.array([1, 1, 0, 0])
        s = np.array([.9, .4, .6, .1])
        # tp=1 fp=1 fn=1 -> f1 = 0.5
        assert abs(f1(y, s) - 0.5) < 1e-9


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

class TestCTRLoss:
    def _setup(self):
        from repro.models.transformer import ModelConfig, init_params
        cfg = ModelConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                          d_ff=64, vocab_size=64, head_dim=16, remat=False)
        params = init_params(jax.random.PRNGKey(0), cfg)
        return cfg, params

    def test_loss_only_counts_sum_positions(self):
        cfg, params = self._setup()
        h = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
        mask = jnp.zeros((2, 8), bool).at[:, 3].set(True)
        labels = jnp.zeros((2, 8), jnp.int32).at[:, 3].set(1)
        l1, _ = ctr_loss(params, cfg, h, mask, labels, yes_id=3, no_id=4)
        # corrupting labels off the SUM positions must not change the loss
        labels2 = labels.at[:, 5].set(1)
        l2, _ = ctr_loss(params, cfg, h, mask, labels2, yes_id=3, no_id=4)
        assert float(l1) == float(l2)

    def test_bidimensional_softmax(self):
        cfg, params = self._setup()
        h = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32))
        logits = ctr_logits(params, cfg, h, 3, 4)
        assert logits.shape == (1, 4, 2)
        p = jax.nn.softmax(logits, axis=-1)
        np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, rtol=1e-5)
