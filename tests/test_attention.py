"""Attention path equivalence (dense == blocked == pallas) + semantic
properties of the DTI attention (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.windowed import (ResetConfig, attention_blocked,
                                 attention_dense)
from repro.kernels.windowed_attn.ops import windowed_attention
from repro.models.layers import alibi_slopes

KEY = jax.random.PRNGKey(0)


def _rand(shape, i, dtype=jnp.float32):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, dtype)


def _inputs(B=2, S=128, H=4, Hk=2, D=16, seed=0, dtype=jnp.float32):
    r = np.random.default_rng(seed)
    q, qn = _rand((B, S, H, D), seed, dtype), _rand((B, S, H, D), seed + 3, dtype)
    k, kn = _rand((B, S, Hk, D), seed + 1, dtype), _rand((B, S, Hk, D), seed + 4, dtype)
    v, v0 = _rand((B, S, Hk, D), seed + 2, dtype), _rand((B, S, Hk, D), seed + 5, dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    is_sum = jnp.asarray(r.random((B, S)) < 0.15)
    valid = jnp.asarray(r.random((B, S)) < 0.9)
    return q, k, v, qn, kn, v0, pos, is_sum, valid


FLAG_SETS = [
    dict(),                                        # plain window
    dict(sum=True),                                # isolation only
    dict(sum=True, nope=True),                     # + NoPE/ALiBi
    dict(sum=True, nope=True, reset=True),         # full DTI
]


def _kwargs(flags, W, q, k, v, qn, kn, v0, pos, is_sum, valid, H):
    kw = dict(pos_q=pos, pos_k=pos, window=W, valid_k=valid)
    if flags.get("sum"):
        kw.update(is_sum_q=is_sum, is_sum_k=is_sum)
    if flags.get("nope"):
        kw.update(q_nope=qn, k_nope=kn, alibi=alibi_slopes(H))
    if flags.get("reset"):
        kw.update(v0=v0, reset=ResetConfig(0.05, 0.3, W / 2))
    return kw


class TestEquivalence:
    @pytest.mark.parametrize("flags", FLAG_SETS)
    @pytest.mark.parametrize("W", [32, 64])
    def test_blocked_equals_dense(self, flags, W):
        q, k, v, qn, kn, v0, pos, is_sum, valid = _inputs()
        kw = _kwargs(flags, W, q, k, v, qn, kn, v0, pos, is_sum, valid, 4)
        o_d = attention_dense(q, k, v, **kw)
        o_b = attention_blocked(q, k, v, **kw)
        np.testing.assert_allclose(o_d, o_b, atol=2e-5)

    @pytest.mark.parametrize("flags", FLAG_SETS)
    def test_pallas_equals_dense(self, flags):
        W = 32
        q, k, v, qn, kn, v0, pos, is_sum, valid = _inputs()
        kw = _kwargs(flags, W, q, k, v, qn, kn, v0, pos, is_sum, valid, 4)
        o_d = attention_dense(q, k, v, **kw)
        o_p = windowed_attention(q, k, v, **kw, block_size=32)
        np.testing.assert_allclose(o_d, o_p, atol=2e-5)

    @pytest.mark.parametrize("S,W,blk", [(256, 64, 32), (256, 96, 32),
                                         (512, 128, 128), (128, 128, 64)])
    def test_pallas_shape_sweep(self, S, W, blk):
        q, k, v, qn, kn, v0, pos, is_sum, valid = _inputs(S=S)
        kw = _kwargs(FLAG_SETS[3], W, q, k, v, qn, kn, v0, pos, is_sum,
                     valid, 4)
        o_d = attention_dense(q, k, v, **kw)
        o_p = windowed_attention(q, k, v, **kw, block_size=blk)
        np.testing.assert_allclose(o_d, o_p, atol=2e-5)

    def test_pallas_bf16(self):
        W = 32
        q, k, v, qn, kn, v0, pos, is_sum, valid = _inputs(dtype=jnp.bfloat16)
        kw = _kwargs(FLAG_SETS[3], W, q, k, v, qn, kn, v0, pos, is_sum,
                     valid, 4)
        o_d = attention_dense(q, k, v, **kw).astype(jnp.float32)
        o_p = windowed_attention(q, k, v, **kw,
                                 block_size=32).astype(jnp.float32)
        np.testing.assert_allclose(o_d, o_p, atol=3e-2, rtol=3e-2)

    def test_mha_no_gqa(self):
        q, k, v, qn, kn, v0, pos, is_sum, valid = _inputs(Hk=4)
        kw = _kwargs(FLAG_SETS[3], 32, q, k, v, qn, kn, v0, pos, is_sum,
                     valid, 4)
        o_d = attention_dense(q, k, v, **kw)
        o_p = windowed_attention(q, k, v, **kw, block_size=32)
        o_b = attention_blocked(q, k, v, **kw)
        np.testing.assert_allclose(o_d, o_p, atol=2e-5)
        np.testing.assert_allclose(o_d, o_b, atol=2e-5)


class TestSemantics:
    """The paper's claims about the mechanism, asserted as properties."""

    def test_window_locality(self):
        """Perturbing a key/value older than `window` must not change a
        query's output — DTI's train/serve alignment guarantee."""
        B, S, H, D, W = 1, 64, 2, 8, 16
        q, k, v, *_ , pos, is_sum, valid = _inputs(B, S, H, H, D)
        valid = jnp.ones((B, S), bool)
        t = 50
        out1 = attention_dense(q, k, v, pos_q=pos, pos_k=pos, window=W)
        k2 = k.at[:, : t - W].set(9.9)
        v2 = v.at[:, : t - W].set(-9.9)
        out2 = attention_dense(q, k2, v2, pos_q=pos, pos_k=pos, window=W)
        np.testing.assert_allclose(out1[:, t], out2[:, t], atol=1e-6)

    def test_sum_isolation_protects_stream(self):
        """Perturbing a [SUM] token's k/v must not change any OTHER token's
        output (the modeling fix: readout states never pollute the stream)."""
        B, S, H, D, W = 1, 32, 2, 8, 16
        q, k, v, *_, pos, _, _ = _inputs(B, S, H, H, D)
        is_sum = jnp.zeros((B, S), bool).at[0, 10].set(True)
        kw = dict(pos_q=pos, pos_k=pos, window=W, is_sum_q=is_sum,
                  is_sum_k=is_sum, sum_isolated=True)
        out1 = attention_dense(q, k, v, **kw)
        out2 = attention_dense(q, k.at[:, 10].set(7.7),
                               v.at[:, 10].set(-7.7), **kw)
        keep = np.ones(S, bool)
        keep[10] = False
        np.testing.assert_allclose(out1[0, keep], out2[0, keep], atol=1e-6)

    def test_alibi_shifts_sum_rows_only(self):
        B, S, H, D, W = 1, 32, 2, 8, 16
        q, k, v, qn, kn, _, pos, _, _ = _inputs(B, S, H, H, D)
        is_sum = jnp.zeros((B, S), bool).at[0, 20].set(True)
        base = dict(pos_q=pos, pos_k=pos, window=W, is_sum_q=is_sum,
                    is_sum_k=is_sum, q_nope=qn, k_nope=kn)
        o1 = attention_dense(q, k, v, **base, alibi=alibi_slopes(H))
        o2 = attention_dense(q, k, v, **base, alibi=10 * alibi_slopes(H))
        # non-SUM rows identical, SUM row changes
        keep = np.ones(S, bool)
        keep[20] = False
        np.testing.assert_allclose(o1[0, keep], o2[0, keep], atol=1e-6)
        assert float(jnp.max(jnp.abs(o1[0, 20] - o2[0, 20]))) > 1e-4

    @pytest.mark.hyp
    @given(st.integers(1, 6))
    @settings(max_examples=6, deadline=None)
    def test_reset_pulls_toward_v0(self, seed):
        """With y→1 the SUM row's output approaches attention over v0."""
        B, S, H, D, W = 1, 32, 2, 8, 32
        q, k, v, qn, kn, v0, pos, _, _ = _inputs(B, S, H, H, D, seed=seed)
        is_sum = jnp.zeros((B, S), bool).at[0, 31].set(True)
        kw = dict(pos_q=pos, pos_k=pos, window=W, is_sum_q=is_sum,
                  is_sum_k=is_sum)
        full = attention_dense(q, k, v, **kw, v0=v0,
                               reset=ResetConfig(1.0, 1.0, 0.0))
        target = attention_dense(q, k, v0, **kw)   # pure v0 attention
        np.testing.assert_allclose(full[0, 31], target[0, 31], atol=1e-4)

    def test_rows_with_no_keys_are_zero(self):
        B, S, H, D = 1, 16, 2, 8
        q, k, v, *_ , pos, _, _ = _inputs(B, S, H, H, D)
        valid = jnp.zeros((B, S), bool)
        out = attention_dense(q, k, v, pos_q=pos, pos_k=pos, window=4,
                              valid_k=valid)
        np.testing.assert_allclose(out, 0.0, atol=1e-7)
