"""hypothesis import shim.

When hypothesis is installed the real ``given``/``settings``/``st`` are
re-exported. When it is not (the tier-1 environment carries only jax, numpy
and pytest), the property tests are collected but skipped, and everything
else in the importing module still runs. ``st`` is an inert object that
accepts any attribute/call chain so strategy expressions evaluated at
decoration time (``st.lists(st.floats(...), ...)``) never raise.
"""
try:
    from hypothesis import assume, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _InertStrategy:
        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _InertStrategy()

    def assume(condition):
        return True

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco


__all__ = ["HAVE_HYPOTHESIS", "assume", "given", "settings", "st"]
