"""Streaming continual-training subsystem (repro.stream + satellites).

The acceptance bar: training on incrementally-extended prompts for a user
whose history grows m -> m+Δ yields the same supervised (target, context)
pairs — and grad-identical batches under packing — as rebuilding the full
DTI corpus and keeping only the new targets; plus streaming metrics,
pipeline shape discipline, online-trainer eval/publication, and weight
hot-swap into live serving.
"""
import jax
import numpy as np
import pytest

from repro.core.dti import (build_streaming_prompts, pack_prompts,
                            prompt_length)
from repro.core.metrics import StreamingAUC, StreamingLogLoss, auc, log_loss
from repro.data.requests import make_event_stream, warm_histories
from repro.data.synthetic import make_ctr_dataset
from repro.models.transformer import ModelConfig, init_params
from repro.stream import (IncrementalDTI, OnlineTrainer, ParamPublisher,
                          ParamSubscriber, StreamPipeline,
                          make_stream_loss_fn)
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptimizerConfig

N_CTX, K, MAX_LEN = 4, 3, 128


def _cfg():
    return ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                       d_ff=64, vocab_size=256, head_dim=16,
                       attn_type="gqa", window=0, attn_impl="dense",
                       dti_sum_token=True, remat=False)


def _history(m, seed=0):
    rng = np.random.default_rng(seed)
    items = [[int(x) for x in rng.integers(8, 200, int(rng.integers(2, 5)))]
             for _ in range(m)]
    labels = [int(x) for x in rng.integers(0, 2, m)]
    return items, labels


def _events(items, labels, lo, hi, user=0):
    return [{"user": user, "item_tokens": items[i], "label": labels[i]}
            for i in range(lo, hi)]


def _rebuild_keep_new(items, labels, m_old):
    """Reference: full DTI rebuild over the grown history, keeping only the
    targets that did not exist at m_old (target_mask on their [SUM]s)."""
    rows = []
    for gi, r in enumerate(build_streaming_prompts(
            items, labels, n_ctx=N_CTX, k=K, max_len=MAX_LEN)):
        gs = N_CTX + gi * K
        tm = np.zeros(MAX_LEN, bool)
        for j, p in enumerate(np.flatnonzero(r["is_sum"])):
            if gs + j >= m_old:
                tm[p] = True
        if tm.any():
            r = dict(r)
            r["target_mask"] = tm
            rows.append(r)
    return rows


def _supervised_pairs(rows):
    """(causal token prefix, label) per supervised [SUM] — the pair the
    loss actually trains on."""
    out = []
    for r in rows:
        for p in np.flatnonzero(r["target_mask"]):
            out.append((tuple(r["tokens"][: p + 1].tolist()),
                        int(r["labels"][p])))
    return sorted(out)


class TestStreamingMetrics:
    def test_histogram_auc_close_to_exact_10k(self, rng):
        labels = (rng.random(10_000) < 0.35).astype(int)
        # scores correlated with labels, heavy ties via rounding
        scores = np.clip(0.3 * labels + 0.5 * rng.random(10_000), 0, 1)
        scores = np.round(scores, 3)
        acc = StreamingAUC()
        for lo in range(0, 10_000, 1000):           # streamed in chunks
            acc.update(labels[lo:lo + 1000], scores[lo:lo + 1000])
        assert abs(acc.value() - auc(labels, scores)) <= 1e-3

    def test_merge_equals_single_pass(self, rng):
        labels = (rng.random(4000) < 0.5).astype(int)
        scores = rng.random(4000)
        whole = StreamingAUC().update(labels, scores)
        a = StreamingAUC().update(labels[:1500], scores[:1500])
        b = StreamingAUC().update(labels[1500:], scores[1500:])
        assert a.merge(b).value() == whole.value()
        la = StreamingLogLoss().update(labels[:1500], scores[:1500])
        lb = StreamingLogLoss().update(labels[1500:], scores[1500:])
        assert la.merge(lb).value() == pytest.approx(
            log_loss(labels, scores), abs=1e-12)

    def test_degenerate_one_class(self):
        assert StreamingAUC().update([1, 1], [0.2, 0.9]).value() == 0.5
        assert StreamingAUC().value() == 0.5


class TestIncrementalEquivalence:
    def test_supervised_pairs_match_rebuild(self):
        """m -> m+Δ with Δ delivered in uneven calls: every new target is
        supervised exactly once, against exactly the causal context the
        full rebuild would give it."""
        m0, d = 9, 7
        items, labels = _history(m0 + d)
        inc = IncrementalDTI(n_ctx=N_CTX, k=K, max_len=MAX_LEN)
        inc.seed_history(0, items[:m0], labels[:m0])
        rows = []
        for lo, hi in ((m0, m0 + 1), (m0 + 1, m0 + 4), (m0 + 4, m0 + d)):
            rows += inc.extend_prompts(_events(items, labels, lo, hi))
        ref = _rebuild_keep_new(items, labels, m0)
        assert _supervised_pairs(rows) == _supervised_pairs(ref)

    def test_single_call_rows_byte_identical(self):
        """Δ in one call: the emitted rows ARE the rebuilt-and-filtered rows."""
        m0, d = 10, 6
        items, labels = _history(m0 + d, seed=1)
        inc = IncrementalDTI(n_ctx=N_CTX, k=K, max_len=MAX_LEN)
        inc.seed_history(0, items[:m0], labels[:m0])
        rows = inc.extend_prompts(_events(items, labels, m0, m0 + d))
        ref = _rebuild_keep_new(items, labels, m0)
        assert len(rows) == len(ref)
        for r, s in zip(rows, ref):
            assert set(r) == set(s)
            for key in r:
                np.testing.assert_array_equal(r[key], s[key], err_msg=key)

    def test_grad_identical_under_packing(self):
        """Packed incremental batches and packed rebuilt-and-filtered
        batches produce the same gradients: unsupervised suffix targets a
        partial emission lacks are causally invisible to the supervised
        positions, and target_mask zeroes their loss weight."""
        cfg = _cfg()
        params = init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = make_stream_loss_fn(cfg, window=0)
        m0, d = 8, 6
        items, labels = _history(m0 + d, seed=2)
        inc = IncrementalDTI(n_ctx=N_CTX, k=K, max_len=MAX_LEN)
        inc.seed_history(0, items[:m0], labels[:m0])
        rows = []
        for lo, hi in ((m0, m0 + 2), (m0 + 2, m0 + 3), (m0 + 3, m0 + d)):
            rows += inc.extend_prompts(_events(items, labels, lo, hi))
        ref = _rebuild_keep_new(items, labels, m0)
        assert len(rows) > len(ref)          # partial emissions happened

        def grads(rs):
            batch = {k: np.stack([r[k] for r in pack_prompts(rs, MAX_LEN)])
                     for k in rs[0]}
            g, _ = jax.grad(lambda p: loss_fn(p, batch,
                                              jax.random.PRNGKey(0)),
                            has_aux=True)(params)
            return g

        ga, gb = grads(rows), grads(ref)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                    np.asarray(b), atol=1e-6),
            ga, gb)

    def test_event_cost_is_group_local(self):
        """One new event touches O(n_ctx + k) interactions' tokens, never
        the full history — the incremental cost claim."""
        items, labels = _history(60, seed=3)
        inc = IncrementalDTI(n_ctx=N_CTX, k=K, max_len=MAX_LEN)
        inc.seed_history(0, items[:59], labels[:59])
        rows = inc.extend_prompts(_events(items, labels, 59, 60))
        assert len(rows) == 1
        bound = sum(len(t) + 2 for t in items[-(N_CTX + K):]) + 1
        assert prompt_length(rows[0]) <= bound
        assert inc.buffered_interactions(0) <= N_CTX + K

    def test_unsupervised_seed_keeps_pending_history(self):
        """seed_history(supervised=False) must not trim interactions its
        first emission still needs: the whole backlog is supervised against
        exactly the full-rebuild corpus."""
        items, labels = _history(20, seed=5)
        inc = IncrementalDTI(n_ctx=N_CTX, k=K, max_len=MAX_LEN)
        inc.seed_history(0, items, labels, supervised=False)
        assert inc.extend_prompts([]) == []            # nothing new arrived
        more_items, more_labels = _history(1, seed=6)
        items, labels = items + more_items, labels + more_labels
        rows = inc.extend_prompts(_events(items, labels, 20, 21))
        ref = _rebuild_keep_new(items, labels, 0)      # everything is new
        assert _supervised_pairs(rows) == _supervised_pairs(ref)
        assert inc.buffered_interactions(0) <= N_CTX + K

    def test_pack_rejects_mixed_target_mask(self):
        items, labels = _history(12, seed=7)
        inc = IncrementalDTI(n_ctx=N_CTX, k=K, max_len=MAX_LEN)
        inc.seed_history(0, items[:8], labels[:8])
        masked = inc.extend_prompts(_events(items, labels, 8, 12))
        plain = build_streaming_prompts(items, labels, n_ctx=N_CTX, k=K,
                                        max_len=MAX_LEN)
        with pytest.raises(AssertionError):
            pack_prompts(masked + plain, MAX_LEN)
        with pytest.raises(AssertionError):
            pack_prompts(plain + masked, MAX_LEN)

    def test_unseen_user_and_short_history_emit_nothing_until_ready(self):
        items, labels = _history(N_CTX + 1, seed=4)
        inc = IncrementalDTI(n_ctx=N_CTX, k=K, max_len=MAX_LEN)
        assert inc.extend_prompts(_events(items, labels, 0, N_CTX)) == []
        rows = inc.extend_prompts(_events(items, labels, N_CTX, N_CTX + 1))
        assert len(rows) == 1
        assert int(rows[0]["target_mask"].sum()) == 1


class TestPipeline:
    def _setup(self, n_ticks=3, users=4):
        ds = make_ctr_dataset(n_users=users, n_items=50, seq_len=16,
                              vocab_size=256, seed=0)
        inc = IncrementalDTI(n_ctx=N_CTX, k=K, max_len=MAX_LEN)
        for u, (toks, labels) in enumerate(warm_histories(ds,
                                                          start_frac=0.5)):
            inc.seed_history(u, toks, labels)
        ticks = make_event_stream(ds, n_ticks=n_ticks, start_frac=0.5,
                                  seed=0)
        return inc, ticks

    def test_fixed_shapes_and_exactly_once_supervision(self):
        inc, ticks = self._setup()
        n_events = sum(len(t) for t in ticks)
        pipe = StreamPipeline(iter(ticks), inc, batch_size=3)
        targets = 0
        for batch in pipe.batches():
            assert batch["tokens"].shape == (3, MAX_LEN)
            assert set(batch) >= {"tokens", "positions", "segment_ids",
                                  "is_sum", "labels", "valid", "target_mask"}
            targets += int(batch["target_mask"].sum())
        assert targets == n_events          # every event supervised once
        assert pipe.stats.n_targets == n_events
        assert 0.0 < pipe.stats.pad_fraction < 1.0

    def test_buckets_bound_sequence_dim(self):
        inc, ticks = self._setup()
        pipe = StreamPipeline(iter(ticks), inc, batch_size=2,
                              buckets=(64, MAX_LEN))
        shapes = {b["tokens"].shape[1] for b in pipe.batches()}
        assert shapes <= {64, MAX_LEN}

    def test_stop_releases_put_blocked_worker(self):
        """An abandoned consumer + stop() must not leak a worker thread
        blocked on the bounded queue."""
        inc, ticks = self._setup(n_ticks=8)
        pipe = StreamPipeline(iter(ticks), inc, batch_size=1, queue_size=1)
        gen = pipe.batches()
        next(gen)                        # worker now blocked on a full queue
        pipe.stop()
        assert not pipe._thread.is_alive()

    def test_worker_errors_surface(self):
        inc, _ = self._setup()

        def bad_source():
            yield [{"user": 0}]              # malformed event

        pipe = StreamPipeline(bad_source(), inc, batch_size=2)
        with pytest.raises(KeyError):
            list(pipe.batches())


class TestOnlineTrainer:
    def _trainer(self, tmp_path=None, **kw):
        cfg = _cfg()
        params = init_params(jax.random.PRNGKey(0), cfg)
        ocfg = OptimizerConfig(lr=1e-3, schedule="const", warmup_steps=1,
                               total_steps=1000)
        ckpt = (CheckpointManager(str(tmp_path), save_interval=1,
                                  async_write=False)
                if tmp_path is not None else None)
        kw.setdefault("window_targets", 8)
        return OnlineTrainer(make_stream_loss_fn(cfg, window=0), params,
                             ocfg, ckpt=ckpt, **kw), cfg

    def _stream(self, n_ticks=3):
        ds = make_ctr_dataset(n_users=4, n_items=50, seq_len=16,
                              vocab_size=256, seed=0)
        inc = IncrementalDTI(n_ctx=N_CTX, k=K, max_len=MAX_LEN)
        for u, (toks, labels) in enumerate(warm_histories(ds,
                                                          start_frac=0.5)):
            inc.seed_history(u, toks, labels)
        return StreamPipeline(
            iter(make_event_stream(ds, n_ticks=n_ticks, start_frac=0.5,
                                   seed=0)),
            inc, batch_size=2)

    def test_trains_evaluates_and_windows(self):
        ot, _ = self._trainer()
        ot.run(self._stream().batches())
        assert ot.step > 0
        assert all(np.isfinite(r["loss"]) for r in ot.history)
        assert len(ot.eval_windows) >= 1    # full windows rolled on their own
        assert all(w.n_targets >= ot.window_targets
                   for w in ot.eval_windows)
        ot.flush_windows()                  # close the partial tail window
        assert ot.lifetime_auc.n == sum(w.n_targets for w in ot.eval_windows)
        assert 0.0 <= ot.lifetime_auc.value() <= 1.0
        if len(ot.eval_windows) >= 2:
            assert set(ot.drift()) == {"d_auc", "d_log_loss"}

    def test_checkpoint_warm_start(self, tmp_path):
        ot, _ = self._trainer(tmp_path)
        ot.run(self._stream().batches())
        resumed, _ = self._trainer(tmp_path)
        assert resumed.resume_if_possible()
        assert resumed.step == ot.step
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            resumed.state.params, ot.state.params)
        # optimizer moments came back too (warm start, not params-only)
        assert int(resumed.state.opt.step) == int(ot.state.opt.step)

    def test_publishes_versions(self, tmp_path):
        pub = ParamPublisher(str(tmp_path))
        ot, _ = self._trainer(publisher=pub, publish_every=2)
        ot.run(self._stream().batches())
        assert ot.published_version == ot.step
        assert pub.latest_version() == ot.step


class TestPublishHotSwap:
    def test_publisher_subscriber_roundtrip(self, tmp_path):
        cfg = _cfg()
        p0 = init_params(jax.random.PRNGKey(0), cfg)
        p1 = jax.tree_util.tree_map(lambda x: x + 1.0, p0)
        pub = ParamPublisher(str(tmp_path))
        sub = ParamSubscriber(str(tmp_path), p0)
        assert sub.poll() is None            # nothing published yet
        pub.publish(1, p1)
        version, got = sub.poll()
        assert version == 1
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            got, p1)
        assert sub.poll() is None            # no re-delivery

    def test_scheduler_hot_swap_keeps_inflight_slots(self, tmp_path):
        """Weights published mid-request land between decode steps; the
        in-flight request finishes on its slot (never evicted) and later
        requests score under the new weights."""
        from repro.serve.scheduler import ServeScheduler
        cfg = _cfg()
        p_old = init_params(jax.random.PRNGKey(0), cfg)
        p_new = init_params(jax.random.PRNGKey(1), cfg)
        ctx = [[10 + i] for i in range(4)]
        cands = [[30 + j, 40 + j] for j in range(8)]  # several bursts

        swaps = {"n": 0}

        def source():
            swaps["n"] += 1
            return (7, p_new) if swaps["n"] == 2 else None

        sched = ServeScheduler(p_old, cfg, n_slots=2, capacity=64,
                               buckets=(8,))
        sched.attach_param_source(source, poll_every=1)
        rid = sched.submit(ctx, cands)
        res = sched.run()[rid]
        assert len(res.scores) == len(cands)
        assert all(0.0 <= s <= 1.0 for s in res.scores)
        assert sched.params_version == 7
        assert sched.params is p_new

        # post-swap requests match a scheduler born with the new weights
        rid2 = sched.submit(ctx, cands)
        after = sched.run()[rid2]
        fresh = ServeScheduler(p_new, cfg, n_slots=2, capacity=64,
                               buckets=(8,))
        want_rid = fresh.submit(ctx, cands)
        np.testing.assert_allclose(after.scores,
                                   fresh.run()[want_rid].scores, atol=1e-6)

    def test_ctr_server_update_params(self):
        from repro.serve.engine import CTRServer
        cfg = _cfg()
        server = CTRServer(init_params(jax.random.PRNGKey(0), cfg), cfg,
                           max_len=64)
        p_new = init_params(jax.random.PRNGKey(1), cfg)
        server.update_params(p_new)
        assert server.params is p_new


def test_stream_bench_machinery_token_reduction(tmp_path):
    """The bench's replay harness at toy scale: streaming DTI reaches
    freshness (every new target trained exactly once) with a large
    supervised-token reduction vs periodic full retrain. The committed
    BENCH_stream.json (CI `stream-bench` job) carries the >=5x smoke
    numbers; this guards the machinery."""
    from benchmarks.stream_bench import main
    res = main(["--users", "6", "--seq", "24", "--ticks", "6",
                "--k", "3", "--n-ctx", "4", "--warm-epochs", "1",
                "--json", str(tmp_path / "BENCH_stream.json")])
    assert (tmp_path / "BENCH_stream.json").exists()
    modes = res["modes"]
    assert set(modes) == {"full_sw", "full_dti", "stream_dti"}
    red = res["token_reduction_vs_full_retrain"]
    assert red["full_sw"] >= 5.0
    assert red["full_dti"] >= 2.0
    for m in modes.values():
        assert m["trained_tokens"] > 0 and m["steps"] > 0
        assert m["auc_over_time"]
    assert modes["stream_dti"]["freshness_p95_s"] > 0.0


class TestPrefixPrewarmer:
    """Stream->serve cache priming: hot-user selection, re-warm gating,
    swap-tick behaviour. The scheduler end of the contract (candidate-less
    admission, radix publication, identical scores) is covered by
    tests/test_paged_cache.py::test_prewarm_primes_the_radix_index."""

    class _Sched:
        def __init__(self):
            self.calls = []

        def prewarm(self, context):
            self.calls.append([list(t) for t in context])
            return len(self.calls)       # a fake rid

    def _dti(self, users):
        inc = IncrementalDTI(n_ctx=N_CTX, k=K, max_len=MAX_LEN)
        for u, m in users.items():
            items, labels = _history(m, seed=u)
            inc.seed_history(u, items, labels)
        return inc

    def test_hot_users_warm_once_until_history_grows(self):
        from repro.stream import PrefixPrewarmer
        inc = self._dti({0: 3, 1: 3, 2: 3})
        sched = self._Sched()
        pw = PrefixPrewarmer(inc, sched, top_k=2, min_events=2.0, decay=0.5)
        pw.observe([{"user": 0}] * 5 + [{"user": 1}] * 4 + [{"user": 2}] * 1)
        rids = pw.tick()
        # top_k=2 by heat: users 0 and 1; user 2 is below min_events
        assert len(rids) == 2 and pw.warmed == 2
        assert sched.calls[0] == [list(t) for t in inc._users[0].items]
        # same heat, same histories -> nothing new to warm
        pw.observe([{"user": 0}] * 5 + [{"user": 1}] * 4)
        assert pw.tick() == []
        # history growth re-arms the user
        inc.extend_prompts(_events(*_history(4, seed=0), 3, 4, user=0))
        pw.observe([{"user": 0}] * 5)
        assert len(pw.tick()) == 1
        assert sched.calls[-1] == [list(t) for t in inc._users[0].items]

    def test_swap_tick_skips_and_rearms(self):
        from repro.stream import PrefixPrewarmer
        inc = self._dti({0: 3})
        sched = self._Sched()
        pw = PrefixPrewarmer(inc, sched, top_k=1, min_events=1.0, decay=1.0)
        pw.observe([{"user": 0}] * 3)
        assert len(pw.tick()) == 1
        # a hot-swap tick warms nothing but drops the warmed markers...
        assert pw.tick(swapped=True) == []
        assert pw.skipped_swap_ticks == 1
        # ...so the unchanged prefix re-warms under the new weights
        assert len(pw.tick()) == 1 and pw.warmed == 2

    def test_heat_decays_cold_users_out(self):
        from repro.stream import PrefixPrewarmer
        inc = self._dti({0: 3})
        sched = self._Sched()
        pw = PrefixPrewarmer(inc, sched, top_k=4, min_events=2.0, decay=0.5)
        pw.observe([{"user": 0}] * 4)
        assert len(pw.tick()) == 1       # heat 4 -> 2.0, still hot
        assert pw.tick() == []           # 1.0: below the gate (and warmed)
        for _ in range(12):
            pw.tick()
        assert pw._heat == {}            # decayed out entirely
