"""Per-kernel allclose sweeps against the pure-jnp oracles (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import reference_embedding_bag
from repro.kernels.windowed_attn.ops import windowed_attention
from repro.kernels.windowed_attn.ref import reference_attention
from repro.core.windowed import ResetConfig
from repro.models.layers import alibi_slopes

KEY = jax.random.PRNGKey(7)


class TestWindowedAttnKernel:
    @pytest.mark.parametrize("B,S,H,Hk,D,W,blk", [
        (1, 128, 2, 1, 8, 32, 32),
        (2, 256, 4, 2, 16, 64, 64),
        (2, 256, 4, 4, 32, 128, 64),
        (1, 512, 8, 2, 64, 128, 128),
        (3, 192, 6, 3, 16, 64, 64),     # non-pow2 batch/heads
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, B, S, H, Hk, D, W, blk, dtype):
        r = np.random.default_rng(B * S + H)
        def rand(shape, i):
            return jax.random.normal(jax.random.fold_in(KEY, i), shape,
                                     dtype)
        q, qn = rand((B, S, H, D), 0), rand((B, S, H, D), 3)
        k, kn = rand((B, S, Hk, D), 1), rand((B, S, Hk, D), 4)
        v, v0 = rand((B, S, Hk, D), 2), rand((B, S, Hk, D), 5)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        is_sum = jnp.asarray(r.random((B, S)) < 0.1)
        valid = jnp.asarray(r.random((B, S)) < 0.9)
        kw = dict(pos_q=pos, pos_k=pos, window=W, is_sum_q=is_sum,
                  is_sum_k=is_sum, valid_k=valid, q_nope=qn, k_nope=kn,
                  alibi=alibi_slopes(H), v0=v0,
                  reset=ResetConfig(0.05, 0.3, W / 2))
        o_ref = reference_attention(q, k, v, **kw).astype(jnp.float32)
        o_pl = windowed_attention(q, k, v, **kw,
                                  block_size=blk).astype(jnp.float32)
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(o_ref, o_pl, atol=tol, rtol=tol)

    def test_jit_and_grad_through_kernel(self):
        B, S, H, D, W = 1, 128, 2, 16, 32
        q = jax.random.normal(KEY, (B, S, H, D))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, D))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, D))
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

        @jax.jit
        def f(q):
            return windowed_attention(q, k, v, pos_q=pos, pos_k=pos,
                                      window=W, block_size=32).sum()
        v1 = f(q)
        assert np.isfinite(float(v1))


class TestEmbeddingBagKernel:
    @pytest.mark.parametrize("V,D,B,H", [
        (64, 8, 4, 3), (512, 32, 16, 8), (1000, 128, 8, 20), (37, 16, 5, 7),
    ])
    @pytest.mark.parametrize("mode", ["sum", "mean"])
    def test_sweep(self, V, D, B, H, mode, rng):
        table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, V, (B, H)), jnp.int32)
        valid = jnp.asarray(rng.random((B, H)) < 0.8)
        o_ref = reference_embedding_bag(table, ids, valid, mode=mode)
        o_pl = embedding_bag(table, ids, valid, mode=mode)
        np.testing.assert_allclose(o_ref, o_pl, atol=1e-5, rtol=1e-5)

    def test_weights(self, rng):
        table = jnp.asarray(rng.normal(size=(100, 16)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, 100, (8, 5)), jnp.int32)
        w = jnp.asarray(rng.normal(size=(8, 5)), jnp.float32)
        o_ref = reference_embedding_bag(table, ids, None, mode="sum",
                                        weights=w)
        o_pl = embedding_bag(table, ids, None, mode="sum", weights=w)
        np.testing.assert_allclose(o_ref, o_pl, atol=1e-5, rtol=1e-5)

    def test_bf16_table(self, rng):
        table = jnp.asarray(rng.normal(size=(64, 32)), jnp.bfloat16)
        ids = jnp.asarray(rng.integers(0, 64, (4, 6)), jnp.int32)
        o_ref = reference_embedding_bag(table, ids, None).astype(jnp.float32)
        o_pl = embedding_bag(table, ids, None).astype(jnp.float32)
        np.testing.assert_allclose(o_ref, o_pl, atol=2e-2, rtol=2e-2)

    def test_all_invalid_bag_is_zero(self, rng):
        table = jnp.asarray(rng.normal(size=(10, 8)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, 10, (2, 4)), jnp.int32)
        valid = jnp.zeros((2, 4), bool)
        np.testing.assert_allclose(embedding_bag(table, ids, valid), 0.0)

    @pytest.mark.parametrize("mode", ["sum", "mean"])
    def test_int8_table_scale_fold_is_exact(self, mode, rng):
        """A per-row-quantized table folds its scales into the gather
        weights *exactly* (the bag is a weighted sum), so the int8 path
        must match the reference bag over the dequantized table to fp32
        reduction noise — no quantization tolerance in sight."""
        from repro.core.quant import dequantize_q8, quantize_q8
        V, D, B, H = 200, 16, 8, 6
        table = jnp.asarray(rng.normal(0, 3.0, (V, D)), jnp.float32)
        codes, scale = quantize_q8(table)          # per-row scales (V,)
        ids = jnp.asarray(rng.integers(0, V, (B, H)), jnp.int32)
        valid = jnp.asarray(rng.random((B, H)) < 0.8)
        o_ref = reference_embedding_bag(dequantize_q8(codes, scale),
                                        ids, valid, mode=mode)
        o_q = embedding_bag(codes, ids, valid, mode=mode,
                            table_scale=scale)
        assert o_q.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_q),
                                   atol=1e-5, rtol=1e-5)
