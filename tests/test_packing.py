"""Segment-aware prompt packing: packer invariants + packed-vs-unpacked
equivalence of the full forward/loss on dense, blocked and (interpret-mode)
Pallas attention paths, + cross-segment isolation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dti import (PromptStats, SpecialTokens,
                            build_streaming_prompts, pack_prompts,
                            prompt_length)
from repro.core.windowed import attention_dense
from repro.launch.train import make_lm_loss_fn
from repro.models.transformer import ModelConfig, forward, init_params

MAX_LEN = 64


def _user_material(seed, n_items=8):
    r = np.random.default_rng(seed)
    toks = [list(map(int, r.integers(8, 60, size=int(r.integers(2, 4)))))
            for _ in range(n_items)]
    labels = list(map(int, r.integers(0, 2, size=n_items)))
    return toks, labels


def _prompts(n_users=3, n_ctx=2, k=3, stats=None):
    out = []
    for s in range(n_users):
        toks, labels = _user_material(s)
        out += build_streaming_prompts(toks, labels, n_ctx=n_ctx, k=k,
                                       max_len=MAX_LEN, stats=stats)
    return out


def _stack(prompts):
    return {key: jnp.asarray(np.stack([p[key] for p in prompts]))
            for key in prompts[0]}


def _cfg(impl, window):
    return ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                       d_ff=64, vocab_size=64, window=window, attn_impl=impl,
                       dti_sum_token=True, remat=False)


def _segment_slices(row):
    """[(segment_id, bool-mask over the row)] for each packed segment."""
    seg = row["segment_ids"]
    return [(s, seg == s) for s in range(int(seg.max()) + 1)]


def _origin_index(prompts):
    """(tokens, labels) of the trimmed prompt -> index in `prompts`."""
    idx = {}
    for i, p in enumerate(prompts):
        n = prompt_length(p)
        idx[(tuple(p["tokens"][:n]), tuple(p["labels"][:n]))] = i
    return idx


class TestPacker:
    def test_every_prompt_placed_once_no_straddle(self):
        prompts = _prompts(n_users=4)
        rows = pack_prompts(prompts, MAX_LEN)
        placed = []
        for row in rows:
            off = 0
            for s, m in _segment_slices(row):
                # segments are contiguous, in order, valid exactly there
                idxs = np.flatnonzero(m)
                assert (idxs == np.arange(off, off + len(idxs))).all()
                assert row["valid"][m].all()
                # positions restart at 0 per segment
                assert (row["positions"][m] == np.arange(len(idxs))).all()
                off += len(idxs)
                placed.append((tuple(row["tokens"][m]),
                               tuple(row["labels"][m])))
            # padding tail: segment -1, invalid
            assert (row["segment_ids"][off:] == -1).all()
            assert not row["valid"][off:].any()
        orig = [(tuple(p["tokens"][p["valid"]]), tuple(p["labels"][p["valid"]]))
                for p in prompts]
        assert sorted(placed) == sorted(orig)

    def test_pad_fraction_not_worse(self):
        unpacked = PromptStats()
        prompts = _prompts(n_users=4, stats=unpacked)
        packed = PromptStats()
        pack_prompts(prompts, MAX_LEN, stats=packed)
        assert packed.n_tokens == unpacked.n_tokens
        assert packed.n_targets == unpacked.n_targets
        assert packed.pad_fraction <= unpacked.pad_fraction
        assert packed.n_rows <= unpacked.n_rows

    def test_oversized_prompt_rejected(self):
        prompts = _prompts(n_users=1)
        with pytest.raises(AssertionError):
            pack_prompts(prompts, prompt_length(prompts[0]) - 1)


class TestPackedEquivalence:
    """A packed batch must produce the same per-token hidden states and the
    same loss as the equivalent unpacked batch, on every attention path."""

    @pytest.mark.parametrize("impl,window", [("dense", 0), ("dense", 16),
                                             ("blocked", 16),
                                             ("pallas", 16)])
    def test_forward_and_loss_match(self, impl, window):
        prompts = _prompts()
        rows = pack_prompts(prompts, MAX_LEN)
        assert len(rows) < len(prompts)      # packing actually happened
        unpacked, packed = _stack(prompts), _stack(rows)

        cfg = _cfg(impl, window)
        params = init_params(jax.random.PRNGKey(0), cfg)

        def hidden(b):
            return np.asarray(forward(
                params, cfg, b["tokens"], positions=b["positions"],
                is_sum=b["is_sum"], valid=b["valid"],
                segment_ids=b["segment_ids"], dti_enabled=True,
                window=window)["hidden"])

        hu, hp = hidden(unpacked), hidden(packed)
        orig = _origin_index(prompts)
        checked = 0
        for ri, row in enumerate(rows):
            for s, m in _segment_slices(row):
                i = orig[(tuple(row["tokens"][m]), tuple(row["labels"][m]))]
                n = int(m.sum())
                np.testing.assert_allclose(hp[ri][m], hu[i][:n], atol=5e-6,
                                           rtol=1e-5)
                checked += 1
        assert checked == len(prompts)

        loss_fn = make_lm_loss_fn(cfg, window)
        lu, _ = loss_fn(params, unpacked, jax.random.PRNGKey(0))
        lp, _ = loss_fn(params, packed, jax.random.PRNGKey(0))
        np.testing.assert_allclose(float(lu), float(lp), atol=1e-6)

    @pytest.mark.parametrize("impl,window", [("dense", 16), ("blocked", 16),
                                             ("pallas", 16)])
    def test_no_cross_segment_leakage(self, impl, window):
        """Perturbing tokens of one packed segment must not change any other
        segment's hidden states."""
        prompts = _prompts()
        rows = pack_prompts(prompts, MAX_LEN)
        row = next(r for r in rows if r["segment_ids"].max() >= 1)
        cfg = _cfg(impl, window)
        params = init_params(jax.random.PRNGKey(1), cfg)

        def hidden(r):
            b = _stack([r])
            return np.asarray(forward(
                params, cfg, b["tokens"], positions=b["positions"],
                is_sum=b["is_sum"], valid=b["valid"],
                segment_ids=b["segment_ids"], dti_enabled=True,
                window=window)["hidden"])[0]

        h1 = hidden(row)
        mutated = {k: v.copy() for k, v in row.items()}
        m0 = row["segment_ids"] == 0
        r = np.random.default_rng(7)
        mutated["tokens"][m0] = r.integers(8, 60, size=int(m0.sum()))
        h2 = hidden(mutated)
        others = (row["segment_ids"] >= 1)
        np.testing.assert_allclose(h1[others], h2[others], atol=1e-6)
        # and segment 0 itself did change
        assert np.abs(h1[m0] - h2[m0]).max() > 1e-3

    def test_dense_mask_segment_term(self):
        """Unit check on attention_dense: same positions in different
        segments never attend each other."""
        B, S, H, D = 1, 8, 2, 4
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(jax.random.fold_in(key, 0), (B, S, H, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
        pos = jnp.asarray([[0, 1, 2, 3, 0, 1, 2, 3]], jnp.int32)
        seg = jnp.asarray([[0, 0, 0, 0, 1, 1, 1, 1]], jnp.int32)
        out = attention_dense(q, k, v, pos_q=pos, pos_k=pos, window=0,
                              seg_q=seg, seg_k=seg)
        # segment 1 must equal running segment 1 alone
        alone = attention_dense(q[:, 4:], k[:, 4:], v[:, 4:],
                                pos_q=pos[:, 4:], pos_k=pos[:, 4:], window=0)
        np.testing.assert_allclose(out[:, 4:], alone, atol=1e-6)
