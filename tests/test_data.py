"""Data substrate: tokenizer, synthetic corpora, graph sampler, sparse
embedding ops."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.data.recsys_gen import RecsysGenerator
from repro.data.requests import (make_event_stream, make_request_stream,
                                 stream_digest, warm_histories)
from repro.data.sampler import (make_community_graph, make_molecule_batch,
                                sample_neighbors)
from repro.data.synthetic import make_ctr_dataset, split_users
from repro.data.tokenizer import HashTokenizer
from repro.sparse.embedding import (embedding_bag, embedding_bag_ragged,
                                    embedding_lookup, hash_bucket)


class TestTokenizer:
    def test_deterministic_and_in_range(self):
        tok = HashTokenizer(2048)
        ids = tok.encode("dark river v17 dark river")
        assert ids[0] == ids[3] and ids[1] == ids[4]
        assert all(tok.sp.n_reserved <= i < 2048 for i in ids)

    @pytest.mark.hyp
    @given(st.text(alphabet=st.characters(codec="ascii",
                                          categories=["L", "N"]),
                   min_size=1, max_size=12))
    @settings(max_examples=50, deadline=None)
    def test_never_collides_with_specials(self, word):
        tok = HashTokenizer(512)
        assert tok.token_id(word) >= tok.sp.n_reserved


class TestSyntheticCTR:
    def test_labels_follow_latents(self):
        """The corpus must carry learnable signal: affinity sign predicts
        the label far better than chance."""
        ds = make_ctr_dataset(n_users=64, n_items=200, seq_len=50,
                              label_scale=5.0)
        correct = total = 0
        for u, seq in enumerate(ds.sequences):
            # recompute affinity via the stored latents
            z = ds.item_latent[seq["items"]]
            # user latent unknown; use rating as proxy for affinity sign
            pred = (seq["ratings"] >= 3).astype(int)
            correct += int((pred == seq["labels"]).sum())
            total += len(pred)
        assert correct / total > 0.65

    def test_split_is_chronological(self):
        ds = make_ctr_dataset(n_users=4, n_items=50, seq_len=40)
        train, val, test = split_users(ds)
        toks, labels = ds.user_prompt_material(0)
        assert len(train[0][0]) == 32            # 80%
        assert test[0][2] == 36                  # test starts at 90%


class TestStreams:
    """Byte-determinism of seeded request/event streams — stream_bench
    replays must be reproducible run to run."""

    def _ds(self):
        return make_ctr_dataset(n_users=6, n_items=60, seq_len=20,
                                vocab_size=512, seed=3)

    def test_request_stream_same_seed_byte_identical(self):
        kw = dict(n_requests=12, k=4, n_ctx=5, seed=7)
        a = make_request_stream(self._ds(), **kw)
        b = make_request_stream(self._ds(), **kw)
        assert a == b
        assert stream_digest(a) == stream_digest(b)
        c = make_request_stream(self._ds(), **dict(kw, seed=8))
        assert stream_digest(c) != stream_digest(a)
        # plain python payloads only (what the digest canonicalises)
        for req in a:
            assert isinstance(req["user"], int)
            assert all(isinstance(t, int) for it in req["context"]
                       for t in it)

    def test_request_stream_heavy_tail_bounds_and_determinism(self):
        """Heavy-tailed context lengths: per-request lengths span
        [n_ctx, n_ctx_tail], stay byte-deterministic per seed, and leave
        the default (constant-length) stream byte-identical to before."""
        kw = dict(n_requests=24, k=3, n_ctx=4, seed=9, n_ctx_tail=16)
        a = make_request_stream(self._ds(), **kw)
        b = make_request_stream(self._ds(), **kw)
        assert a == b
        lens = [len(r["context"]) for r in a]
        assert min(lens) >= 4 and max(lens) <= 16
        assert len(set(lens)) > 1                # actually mixed-length
        # the tail knob must not perturb the default draw sequence
        base = dict(n_requests=12, k=4, n_ctx=5, seed=7)
        assert (make_request_stream(self._ds(), **base)
                == make_request_stream(self._ds(), **base, n_ctx_tail=None))

    def test_request_stream_heavy_tail_revisits_copy_source_length(self):
        """Revisits copy their source's (possibly long) context verbatim,
        so prefix sharing still sees exact repeats under the tail."""
        kw = dict(n_requests=30, k=2, n_ctx=3, seed=11, n_ctx_tail=12,
                  repeat_frac=0.5)
        reqs = make_request_stream(self._ds(), **kw)
        ctxs = [tuple(tuple(it) for it in r["context"]) for r in reqs]
        assert len(set(ctxs)) < len(ctxs)        # some exact repeats

    def test_event_stream_same_seed_byte_identical(self):
        kw = dict(n_ticks=4, start_frac=0.5, end_frac=0.9, seed=5)
        a = make_event_stream(self._ds(), **kw)
        b = make_event_stream(self._ds(), **kw)
        assert a == b
        assert stream_digest(a) == stream_digest(b)
        assert stream_digest(make_event_stream(
            self._ds(), **dict(kw, seed=6))) != stream_digest(a)

    def test_event_stream_preserves_per_user_chronology(self):
        ds = self._ds()
        ticks = make_event_stream(ds, n_ticks=3, start_frac=0.5,
                                  end_frac=0.9, seed=0)
        flat = [ev for tick in ticks for ev in tick]
        seen = {}
        for ev in flat:
            if ev["user"] in seen:
                assert ev["index"] == seen[ev["user"]] + 1
            seen[ev["user"]] = ev["index"]
        # warm prefix + replayed slice tile each user's timeline exactly
        warm = warm_histories(ds, start_frac=0.5)
        for u, (toks, _) in enumerate(warm):
            first = min((ev["index"] for ev in flat if ev["user"] == u),
                        default=None)
            if first is not None:
                assert first == len(toks)

    def test_event_stream_covers_slice_once(self):
        ds = self._ds()
        ticks = make_event_stream(ds, n_ticks=5, start_frac=0.5,
                                  end_frac=1.0, seed=1)
        per_user = {}
        for tick in ticks:
            for ev in tick:
                per_user.setdefault(ev["user"], []).append(ev["index"])
        for u in range(len(ds.sequences)):
            m = len(ds.user_prompt_material(u)[0])
            assert sorted(per_user[u]) == list(range(m // 2, m))


class TestGraphSampler:
    def test_fanout_bounds(self, rng):
        g = make_community_graph(500, 8, 16, 4)
        seeds = rng.choice(500, size=16, replace=False)
        sub = sample_neighbors(g, seeds, [5, 3], rng=rng)
        # padded allocation: seeds x prod(f+1) nodes, seeds x sum(cumprod f)
        assert sub.node_ids.shape[0] == 16 * (1 + 5) * (1 + 3)
        assert sub.edge_src.shape[0] == 16 * (5 + 15)
        assert int(sub.node_valid.sum()) <= 16 * (1 + 5 + 15)
        n_real = int(sub.edge_valid.sum())
        assert 0 < n_real <= 16 * 20
        # all edge endpoints are valid local nodes
        n_nodes = int(sub.node_valid.sum())
        assert sub.edge_src[sub.edge_valid].max() < n_nodes
        assert sub.edge_dst[sub.edge_valid].max() < n_nodes

    def test_seeds_are_first(self, rng):
        g = make_community_graph(100, 4, 8, 3)
        seeds = np.asarray([7, 13, 42])
        sub = sample_neighbors(g, seeds, [2], rng=rng)
        np.testing.assert_array_equal(sub.node_ids[:3], seeds)
        np.testing.assert_array_equal(sub.seed_local, [0, 1, 2])

    def test_molecule_batch_shapes(self):
        x, es, ed, gids, ys = make_molecule_batch(8, 30, 64, 16, 2)
        assert x.shape == (240, 16)
        assert es.shape == ed.shape == (512,)
        assert gids.max() == 7 and ys.shape == (8,)


class TestRecsysGen:
    def test_seq_labels_learnable(self, rng):
        gen = RecsysGenerator(10_000, scale=6.0)
        b = gen.seq_batch(4096, 20, rng=rng)
        # the latent rule should produce both classes, not constant labels
        assert 0.2 < b["labels"].mean() < 0.8

    def test_field_batch_ranges(self, rng):
        gen = RecsysGenerator(100)
        b = gen.field_batch(128, [10, 20, 30], rng=rng)
        assert b["ids"].shape == (128, 3)
        assert (b["ids"][:, 2] < 30).all()


class TestSparseEmbedding:
    def test_ragged_equals_padded(self, rng):
        table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, 50, (4, 6)), jnp.int32)
        valid = jnp.asarray(rng.random((4, 6)) < 0.7)
        padded = embedding_bag(table, ids, valid, mode="sum")
        flat = ids.reshape(-1)[valid.reshape(-1)]
        seg = jnp.repeat(jnp.arange(4), 6)[valid.reshape(-1)]
        ragged = embedding_bag_ragged(table, flat, seg, 4)
        np.testing.assert_allclose(padded, ragged, atol=1e-6)

    def test_lookup_matches_rows(self, rng):
        table = jnp.asarray(rng.normal(size=(20, 4)), jnp.float32)
        ids = jnp.asarray([3, 7], jnp.int32)
        np.testing.assert_array_equal(np.asarray(embedding_lookup(table, ids)),
                                      np.asarray(table[jnp.asarray([3, 7])]))

    @pytest.mark.hyp
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_hash_bucket_in_range(self, x):
        out = int(hash_bucket(jnp.asarray([x]), 1000)[0])
        assert 0 <= out < 1000
