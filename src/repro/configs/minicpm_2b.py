"""minicpm-2b [arXiv:2404.06395; hf:openbmb/MiniCPM-2B] — dense llama-like LM.

40L d_model=2304 36H (kv=36, i.e. full MHA) d_ff=5760 vocab=122753, trained
with the WSD schedule (the optimizer's "wsd" schedule reproduces it).
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="minicpm-2b", n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab_size=122753, head_dim=64, attn_type="gqa",
    rope_theta=10000.0, window=1024, attn_impl="blocked",
    dti_sum_token=True, param_dtype="bfloat16", compute_dtype="bfloat16",
    remat=True, tie_embeddings=True,   # MiniCPM ties embeddings
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab_size=512, head_dim=16, window=32, attn_impl="blocked",
    dti_sum_token=True, tie_embeddings=True,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="minicpm-2b", family="lm", config=FULL, smoke=SMOKE,
        shapes=lm_shapes(), profile="tp",   # dp explored in §Perf: 13.5s->~0 collective but +15GiB fp32
        # optimizer buffers (GSPMD replicated-output backprop); tp fits HBM
        source="arXiv:2404.06395; hf",
        notes="WSD schedule; tied embeddings; full MHA (kv=36).",
    )
