"""deepseek-v2-236b [arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2] — MoE+MLA.

60L d_model=5120 128H vocab=102400. MLA: q_lora=1536, kv_lora=512,
qk_nope=128, qk_rope=64, v_head=128. MoE: 160 routed top-6 + 2 shared,
moe_intermediate=1536, first layer dense (d_ff=12288).

Training posture is LoRA PEFT (the paper's own setting): base weights stay
bf16 and the optimizer state exists only for LoRA leaves — that is what
makes 236B trainable on a 256-chip v5e pod (see DESIGN.md §5); the sharding
profile is fsdp_tp (experts EP over "model", dense dims over "data").
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-236b", n_layers=60, d_model=5120, n_heads=128,
    n_kv_heads=128, d_ff=12288, vocab_size=102400, attn_type="mla",
    q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    moe=True, n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
    shared_d_ff=1536, first_dense_layers=1, norm_topk=False,
    rope_theta=10000.0, window=1024, attn_impl="blocked",
    dti_sum_token=True, param_dtype="bfloat16", compute_dtype="bfloat16",
    remat=True, lora_rank=8,
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab_size=512, attn_type="mla",
    q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=8,
    v_head_dim=16,
    moe=True, n_experts=8, n_shared_experts=2, top_k=2, moe_d_ff=32,
    shared_d_ff=32, first_dense_layers=1, norm_topk=False,
    window=32, attn_impl="blocked", dti_sum_token=True, lora_rank=4,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="deepseek-v2-236b", family="lm", config=FULL, smoke=SMOKE,
        # 60-layer scan carries at seq 4k need 16-way microbatching to fit
        # (1 seq/device/micro); prefill chunks its 32-prompt batch in two
        # sequential halves for the same reason. Smaller archs use 4 / 1.
        shapes=lm_shapes(grad_accum=16, prefill_chunks=2),
        profile="fsdp_tp", trainable="lora",
        source="arXiv:2405.04434; hf",
        notes="EP=16 (160 experts / 16), MLA absorbed decode; LoRA training "
              "(paper-faithful PEFT) keeps optimizer memory O(rank).",
    )
