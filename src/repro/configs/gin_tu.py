"""gin-tu [arXiv:1810.00826] — Graph Isomorphism Network.

n_layers=5 d_hidden=64 aggregator=sum eps=learnable. d_feat / n_classes are
shape-dependent (each GNN shape cell is its own dataset scale), so the step
builder overrides them per shape; FULL carries the full_graph_sm values.
"""
import dataclasses

from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn import GNNConfig

FULL = GNNConfig(name="gin-tu", n_layers=5, d_hidden=64, d_feat=1433,
                 n_classes=7, aggregator="sum", learnable_eps=True)

SMOKE = GNNConfig(name="gin-tu-smoke", n_layers=2, d_hidden=16, d_feat=8,
                  n_classes=3, aggregator="sum", learnable_eps=True)


def config_for_shape(shape_params: dict) -> GNNConfig:
    return dataclasses.replace(FULL, d_feat=shape_params["d_feat"],
                               n_classes=shape_params["n_classes"])


def spec() -> ArchSpec:
    return ArchSpec(
        name="gin-tu", family="gnn", config=FULL, smoke=SMOKE,
        shapes=GNN_SHAPES, profile="tp",
        source="arXiv:1810.00826; paper",
        notes="DTI inapplicable (no autoregressive shared-context stream); "
              "message passing = gather + segment_sum, edges sharded over "
              "the data axis (DESIGN.md §Arch-applicability).",
    )
