"""qwen2-1.5b [arXiv:2407.10671; hf:Qwen/Qwen2-1.5B] — dense GQA LM.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, QKV bias.
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="qwen2-1.5b", n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151936, head_dim=128, attn_type="gqa",
    qkv_bias=True, rope_theta=1000000.0, window=1024, attn_impl="blocked",
    dti_sum_token=True, param_dtype="bfloat16", compute_dtype="bfloat16",
    remat=True, tie_embeddings=True,   # Qwen2-1.5B ties embeddings
)

SMOKE = ModelConfig(
    name="qwen2-1.5b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=192, vocab_size=512, head_dim=16, qkv_bias=True, window=32,
    attn_impl="blocked", dti_sum_token=True, tie_embeddings=True,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="qwen2-1.5b", family="lm", config=FULL, smoke=SMOKE,
        shapes=lm_shapes(), profile="tp",   # dp explored in §Perf: 13.5s->~0 collective but +15GiB fp32
        # optimizer buffers (GSPMD replicated-output backprop); tp fits HBM
        source="arXiv:2407.10671; hf",
        notes="GQA kv=2 with QKV bias; tied embeddings.",
    )
