"""din [arXiv:1706.06978] — Deep Interest Network (target attention).

embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80. Item table 2^26 rows;
embed_dim 18 does not divide 16, so the table row-shards over the model
axis. The multi-target train step (`din_forward_multi`) is the DTI
transplant: k targets share one history-embedding pass.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

FULL = RecsysConfig(name="din", kind="din", embed_dim=18,
                    n_items=67_108_864, seq_len=100,
                    attn_mlp=(80, 40), head_mlp=(200, 80))

SMOKE = RecsysConfig(name="din-smoke", kind="din", embed_dim=8,
                     n_items=1000, seq_len=20, attn_mlp=(16,),
                     head_mlp=(32,))


def spec() -> ArchSpec:
    return ArchSpec(
        name="din", family="recsys", config=FULL, smoke=SMOKE,
        shapes=RECSYS_SHAPES, profile="tp",
        source="arXiv:1706.06978; paper",
        notes="DTI partially applies: multi-target DIN shares the history "
              "pass across k targets (DESIGN.md §Arch-applicability); "
              "retrieval_cand chunks 1M candidates through target attention.",
    )
