"""dti-llama — the paper's own setup: Llama-3.1-8B + LoRA + DTI training.

[arXiv:2407.21783 for the backbone; the DTI paper fine-tunes it with LoRA
rank {8,16} on q,k,v,o,up,down,gate.] Not one of the 40 assigned cells, but
the configuration the reproduction experiments and examples are anchored to.
``REPRO`` is the width-reduced variant every CPU experiment trains for real.

``FULL`` trains on the fused Pallas windowed-attention path
(``attn_impl="pallas"``): the kernel has a flash-style custom-VJP backward
(dq + dk/dv passes over the window-banded schedule), so both the forward
and the gradient step run fused on TPU — the paper's 92% training-time
reduction is a *training*-pass number, and the blocked jnp path is kept
only as the CPU-friendly oracle.
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="dti-llama-8b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128, attn_type="gqa",
    rope_theta=500000.0, window=1024, attn_impl="pallas",
    dti_sum_token=True, param_dtype="bfloat16", compute_dtype="bfloat16",
    remat=True, lora_rank=8,
)

# The CPU-trainable repro model (≈6M params): full DTI machinery, small dims.
REPRO = ModelConfig(
    name="dti-llama-repro", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=344, vocab_size=2048, head_dim=32, attn_type="gqa",
    rope_theta=10000.0, window=0, attn_impl="dense",
    dti_sum_token=True, remat=False,
)

SMOKE = REPRO


def spec() -> ArchSpec:
    return ArchSpec(
        name="dti-llama", family="lm", config=FULL, smoke=SMOKE,
        shapes=lm_shapes(), profile="tp", trainable="lora",
        source="arXiv:2407.21783 backbone; DTI paper appendix",
        notes="The paper's own arch; repro experiments use REPRO.",
    )
