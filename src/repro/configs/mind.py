"""mind [arXiv:1904.08030] — multi-interest capsule retrieval/ranking.

embed_dim=64 n_interests=4 capsule_iters=3. Item table sized 2^24 rows
(huge-embedding regime); 64 % 16 == 0 so the table column-shards over the
model axis (lookups stay local).
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

FULL = RecsysConfig(name="mind", kind="mind", embed_dim=64,
                    n_items=16_777_216, seq_len=100, n_interests=4,
                    capsule_iters=3)

SMOKE = RecsysConfig(name="mind-smoke", kind="mind", embed_dim=16,
                     n_items=1000, seq_len=20, n_interests=2,
                     capsule_iters=2)


def spec() -> ArchSpec:
    return ArchSpec(
        name="mind", family="recsys", config=FULL, smoke=SMOKE,
        shapes=RECSYS_SHAPES, profile="tp",
        source="arXiv:1904.08030; unverified",
        notes="DTI inapplicable (pointwise scorer over capsule summaries); "
              "retrieval_cand = one (K,D)x(D,C) matmul over 1M candidates.",
    )
