"""minicpm3-4b [hf:openbmb/MiniCPM3-4B] — dense LM with MLA attention.

62L d_model=2560 40H d_ff=6400 vocab=73448. MLA dims per the HF config:
q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64, qk_rope_head_dim=32,
v_head_dim=64 (best-effort from the public config; noted in DESIGN.md).
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="minicpm3-4b", n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab_size=73448, attn_type="mla",
    q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32,
    v_head_dim=64, rope_theta=10000.0, window=1024, attn_impl="blocked",
    dti_sum_token=True, param_dtype="bfloat16", compute_dtype="bfloat16",
    remat=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="minicpm3-4b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab_size=512, attn_type="mla",
    q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=8,
    v_head_dim=16, window=32, attn_impl="blocked", dti_sum_token=True,
    tie_embeddings=True,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="minicpm3-4b", family="lm", config=FULL, smoke=SMOKE,
        shapes=lm_shapes(), profile="tp",   # dp explored in §Perf: 13.5s->~0 collective but +15GiB fp32
        # optimizer buffers (GSPMD replicated-output backprop); tp fits HBM
        source="hf:openbmb/MiniCPM3-4B",
        notes="MLA; decode uses the absorbed latent-cache path "
              "(repro.serve.engine).",
    )
