"""sasrec [arXiv:1808.09781] — causal self-attention sequential recommender.

embed_dim=50 n_blocks=2 n_heads=1 seq_len=50. Item table 2^22 rows. SASRec
natively trains all positions in parallel — it is the k=m limiting case of
DTI; cfg.window>0 adds the paper's windowed alignment.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

FULL = RecsysConfig(name="sasrec", kind="sasrec", embed_dim=50,
                    n_items=4_194_304, seq_len=50, n_blocks=2, n_heads=1)

SMOKE = RecsysConfig(name="sasrec-smoke", kind="sasrec", embed_dim=16,
                     n_items=1000, seq_len=20, n_blocks=1, n_heads=1)


def spec() -> ArchSpec:
    return ArchSpec(
        name="sasrec", family="recsys", config=FULL, smoke=SMOKE,
        shapes=RECSYS_SHAPES, profile="tp",
        source="arXiv:1808.09781; paper",
        notes="Native DTI (k=m limit): all-position parallel training; "
              "retrieval_cand = last hidden state dot 1M item embeddings.",
    )
