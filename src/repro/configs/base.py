"""Config substrate: ArchSpec (one per assigned architecture) + ShapeSpec.

Every architecture ships its exact public-literature FULL config, a reduced
SMOKE config of the same family (runs a real step on CPU in tests), and its
own shape table. ``repro.launch.specs`` turns (arch, shape, mesh) into
ShapeDtypeStruct input stand-ins; ``repro.launch.steps`` builds the step fn.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell. ``kind`` selects the step fn lowered for it:

    lm:      train | prefill | decode | decode_ring
    gnn:     graph_full | graph_sampled | graph_batched
    recsys:  train | serve | retrieval
    """
    name: str
    kind: str
    params: Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                     # "lm" | "gnn" | "recsys"
    config: Any                     # full-size model config
    smoke: Any                      # reduced same-family config
    shapes: Dict[str, ShapeSpec]
    profile: str = "tp"             # sharding profile ("tp" | "fsdp_tp")
    trainable: Optional[str] = None  # None = full fine-tune, "lora" = PEFT
    source: str = ""                # public citation
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        return self.shapes[name]


# The four LM shapes are shared verbatim by all five LM archs.
def lm_shapes(*, window: int = 1024, k_targets: int = 50,
              ring_capacity: int = 2048,
              grad_accum: int = 4,
              prefill_chunks: int = 1) -> Dict[str, ShapeSpec]:
    return {
        "train_4k": ShapeSpec("train_4k", "train",
                              dict(seq_len=4096, global_batch=256,
                                   window=window, k_targets=k_targets,
                                   grad_accum=grad_accum)),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                                 dict(seq_len=32768, global_batch=32,
                                      window=window,
                                      prefill_chunks=prefill_chunks)),
        "decode_32k": ShapeSpec("decode_32k", "decode",
                                dict(cache_len=32768, global_batch=128,
                                     window=window)),
        # Sub-quadratic 500k decode is a corollary of the paper's windowed
        # causal attention: the KV cache is a ring buffer of `ring_capacity`
        # slots regardless of the 524288 logical position (DESIGN.md §4).
        "long_500k": ShapeSpec("long_500k", "decode_ring",
                               dict(cache_len=524288, global_batch=1,
                                    window=window,
                                    ring_capacity=ring_capacity)),
    }


RECSYS_SHAPES: Dict[str, ShapeSpec] = {
    "train_batch": ShapeSpec("train_batch", "train", dict(batch=65536)),
    "serve_p99": ShapeSpec("serve_p99", "serve", dict(batch=512)),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                dict(batch=1, n_candidates=1_000_000)),
}


def _pad(n: int, mult: int = 512) -> int:
    return ((n + mult - 1) // mult) * mult


GNN_SHAPES: Dict[str, ShapeSpec] = {
    # counts padded to multiples of 512 so edge/node arrays shard evenly;
    # `*_raw` keeps the literature value, valid-masks cover the padding.
    "full_graph_sm": ShapeSpec("full_graph_sm", "graph_full",
                               dict(n_nodes=_pad(2708), n_edges=_pad(10556),
                                    n_nodes_raw=2708, n_edges_raw=10556,
                                    d_feat=1433, n_classes=7)),
    "minibatch_lg": ShapeSpec("minibatch_lg", "graph_sampled",
                              dict(n_nodes=232_965, n_edges=114_615_892,
                                   batch_nodes=1024, fanouts=(15, 10),
                                   d_feat=602, n_classes=41)),
    "ogb_products": ShapeSpec("ogb_products", "graph_full",
                              dict(n_nodes=_pad(2_449_029),
                                   n_edges=_pad(61_859_140),
                                   n_nodes_raw=2_449_029,
                                   n_edges_raw=61_859_140,
                                   d_feat=100, n_classes=47)),
    "molecule": ShapeSpec("molecule", "graph_batched",
                          dict(n_nodes=30, n_edges=64, batch=128,
                               d_feat=16, n_classes=2)),
}


__all__ = ["ArchSpec", "ShapeSpec", "lm_shapes", "RECSYS_SHAPES",
           "GNN_SHAPES"]
