"""xdeepfm [arXiv:1803.05170] — CIN + DNN + linear over 39 sparse fields.

n_sparse=39 embed_dim=10 cin_layers=200-200-200 mlp=400-400. Field vocab
sizes follow a criteo/avazu-like power-law mixture (~17.5M total rows);
embed_dim 10 does not divide the 16-way model axis, so tables row-shard
(lookup lowers to a partitioned gather + psum combine).
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

FIELD_VOCABS = tuple([4_194_304] * 3 + [1_048_576] * 4 + [65_536] * 8
                     + [4_096] * 12 + [256] * 12)
assert len(FIELD_VOCABS) == 39

FULL = RecsysConfig(name="xdeepfm", kind="xdeepfm", embed_dim=10,
                    field_vocabs=FIELD_VOCABS,
                    cin_layers=(200, 200, 200), dnn_dims=(400, 400))

SMOKE = RecsysConfig(name="xdeepfm-smoke", kind="xdeepfm", embed_dim=8,
                     field_vocabs=(64,) * 6, cin_layers=(16, 16),
                     dnn_dims=(32,))


def spec() -> ArchSpec:
    return ArchSpec(
        name="xdeepfm", family="recsys", config=FULL, smoke=SMOKE,
        shapes=RECSYS_SHAPES, profile="tp",
        source="arXiv:1803.05170; paper",
        notes="DTI inapplicable (non-sequential feature interaction); "
              "retrieval_cand varies the item field over 1M ids in chunks.",
    )
