"""repro.configs — one module per assigned architecture (+ the paper's own).

``get_arch(name)`` returns the ArchSpec; ``ASSIGNED`` lists the 10 graded
architectures (40 dry-run cells), ``ALL`` adds the paper's dti-llama.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ArchSpec, ShapeSpec

from repro.configs import (deepseek_v2_236b, din, dti_llama, gin_tu, mind,
                           minicpm3_4b, minicpm_2b, qwen2_1_5b,
                           qwen2_moe_a2_7b, sasrec, xdeepfm)

_MODULES = {
    "minicpm-2b": minicpm_2b,
    "qwen2-1.5b": qwen2_1_5b,
    "minicpm3-4b": minicpm3_4b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "gin-tu": gin_tu,
    "mind": mind,
    "xdeepfm": xdeepfm,
    "din": din,
    "sasrec": sasrec,
    "dti-llama": dti_llama,
}

ASSIGNED: List[str] = [n for n in _MODULES if n != "dti-llama"]
ALL: List[str] = list(_MODULES)


def get_arch(name: str) -> ArchSpec:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return _MODULES[name].spec()


def all_cells(archs=None) -> List[tuple]:
    """Every (arch, shape) pair — the 40 graded cells by default."""
    out = []
    for a in (archs or ASSIGNED):
        spec = get_arch(a)
        for s in spec.shapes:
            out.append((a, s))
    return out


__all__ = ["ArchSpec", "ShapeSpec", "get_arch", "all_cells", "ASSIGNED",
           "ALL"]
