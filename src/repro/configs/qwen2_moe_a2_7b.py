"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B] — MoE LM.

24L d_model=2048 16H (kv=16) vocab=151936. 60 routed experts (top-4,
moe_intermediate=1408) + 4 shared experts (5632 total shared intermediate =
4 x 1408). norm_topk_prob=False in the public config.
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=5632, vocab_size=151936, head_dim=128,
    attn_type="gqa", qkv_bias=True,
    moe=True, n_experts=60, n_shared_experts=4, top_k=4, moe_d_ff=1408,
    shared_d_ff=1408, first_dense_layers=0, norm_topk=False,
    rope_theta=1000000.0, window=1024, attn_impl="blocked",
    dti_sum_token=True, param_dtype="bfloat16", compute_dtype="bfloat16",
    remat=True,
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16, qkv_bias=True,
    moe=True, n_experts=8, n_shared_experts=2, top_k=2, moe_d_ff=32,
    shared_d_ff=32, norm_topk=False, window=32, attn_impl="blocked",
    dti_sum_token=True,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="qwen2-moe-a2.7b", family="lm", config=FULL, smoke=SMOKE,
        shapes=lm_shapes(), profile="tp",
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
        notes="60 experts do not divide the 16-way model axis, so expert "
              "weights shard on moe_d_ff (1408 % 16 == 0) — TP-inside-expert "
              "instead of EP; deepseek-v2 exercises the EP layout.",
    )
