"""Host-side span tracer with a Chrome-trace-event / Perfetto exporter.

Model: **spans** ("X" complete events — a name, a start, a duration,
optional args), **instants** ("i" events — points in time like
``admission`` / ``hot_swap`` / ``finish``), and **counters** ("C"
events — per-step series like ``queue_depth``).  Nesting is positional,
the Chrome way: a span whose ``[ts, ts+dur]`` range sits inside another
span's range on the same thread renders as its child; no parent ids are
stored, so emitting a span is just a clock read and a ``deque.append``.

Overhead contract (asserted by ``tests/test_obs.py``):

- events live in a bounded ring (``deque(maxlen=capacity)``); when full,
  the oldest events fall off and ``dropped`` counts them — tracing can
  never grow memory without bound or block the hot path;
- no locks: ``deque.append`` is atomic under the GIL, so the stream
  pipeline's worker thread and the scheduler thread share one tracer;
- no device syncs: the tracer touches only host clocks and Python
  objects.  The serving hot path keeps exactly one device sync (the
  one-step-behind ``np.asarray`` in the scheduler's harvest) whether or
  not tracing is on.
- the clock is injected (``clock=``), so tests assert exact timings
  with a :class:`repro.obs.clock.ManualClock` instead of tolerances.

``NULL_TRACER`` is the default tracer everywhere: every method is a
no-op returning a shared null span, so untraced code pays one attribute
lookup and one call per site.
"""
from __future__ import annotations

import json
import os
import threading
from collections import deque
from typing import Dict, Iterable, List, Optional

from repro.obs.clock import monotonic

_VALID_PH = ("X", "i", "C", "M")


class _Span:
    """Context manager for one "X" event; reusable args via ``set``."""

    __slots__ = ("_tr", "name", "args", "_t0")

    def __init__(self, tr: "SpanTracer", name: str, args: Optional[Dict]):
        self._tr = tr
        self.name = name
        self.args = args
        self._t0 = 0.0

    def set(self, **kw) -> None:
        """Attach args discovered mid-span (e.g. the chosen jit bucket).

        Must be called before the ``with`` block exits — the event is
        written at ``__exit__``.
        """
        if self.args is None:
            self.args = kw
        else:
            self.args.update(kw)

    def __enter__(self) -> "_Span":
        self._t0 = self._tr.clock()
        return self

    def __exit__(self, *exc) -> None:
        tr = self._tr
        t1 = tr.clock()
        tr._push({"name": self.name, "ph": "X",
                  "ts": (self._t0 - tr._epoch) * 1e6,
                  "dur": (t1 - self._t0) * 1e6,
                  "pid": tr.pid, "tid": threading.get_ident(),
                  **({"args": self.args} if self.args else {})})


class _NullSpan:
    """Shared no-op span: zero allocation on the untraced path."""

    __slots__ = ()

    def set(self, **kw) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Do-nothing tracer; the default wired into every subsystem."""

    enabled = False
    jax_annotate = False
    dropped = 0

    def span(self, name: str, **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **args) -> None:
        pass

    def counter(self, name: str, value) -> None:
        pass

    def clear(self) -> None:
        pass

    def __len__(self) -> int:
        return 0


NULL_TRACER = NullTracer()


class SpanTracer:
    """Ring-buffered host tracer emitting Chrome trace events.

    Parameters
    ----------
    clock: a ``() -> float`` seconds source; injected for determinism
        (defaults to the repo monotonic clock).
    capacity: ring size in events; the oldest events are dropped (and
        counted in ``dropped``) when full.
    jax_annotate: when True, instrumented dispatch sites additionally
        open ``jax.profiler`` annotations (see ``repro.obs.profile``),
        so device timelines carry the same names as host spans.
    """

    enabled = True

    def __init__(self, clock=monotonic, capacity: int = 65536, *,
                 jax_annotate: bool = False):
        self.clock = clock
        self.capacity = int(capacity)
        self.jax_annotate = bool(jax_annotate)
        self.pid = os.getpid()
        self._events: deque = deque(maxlen=self.capacity)
        self.dropped = 0
        self._epoch = clock()

    # -- emit ---------------------------------------------------------
    def _push(self, ev: Dict) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args or None)

    def instant(self, name: str, **args) -> None:
        self._push({"name": name, "ph": "i", "s": "t",
                    "ts": (self.clock() - self._epoch) * 1e6,
                    "pid": self.pid, "tid": threading.get_ident(),
                    **({"args": args} if args else {})})

    def counter(self, name: str, value) -> None:
        self._push({"name": name, "ph": "C",
                    "ts": (self.clock() - self._epoch) * 1e6,
                    "pid": self.pid, "tid": threading.get_ident(),
                    "args": {"value": value}})

    # -- inspect / export ---------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    def events(self) -> List[Dict]:
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()
        self.dropped = 0
        self._epoch = self.clock()

    def to_chrome_trace(self) -> Dict:
        """The ``{"traceEvents": [...]}`` document Perfetto loads."""
        meta = [{"name": "process_name", "ph": "M", "pid": self.pid,
                 "tid": 0, "ts": 0,
                 "args": {"name": "repro"}}]
        return {"traceEvents": meta + self.events(),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


def validate_chrome_trace(doc, *, require_nonempty: bool = True
                          ) -> List[str]:
    """Schema check for an exported trace; returns a list of problems.

    An empty list means the document is a well-formed Chrome trace
    (``traceEvents`` array of X/i/C/M events with numeric timestamps,
    non-negative durations and int pid/tid) that Perfetto will load.
    CI runs this (via ``repro.launch.obs_report``) on the serve-bench
    trace artifact and fails the job on any problem.
    """
    problems: List[str] = []
    if not isinstance(doc, dict):
        return [f"trace root must be an object, got {type(doc).__name__}"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    n_real = 0
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        name, ph = ev.get("name"), ev.get("ph")
        if not isinstance(name, str) or not name:
            problems.append(f"{where}: missing/empty name")
        if ph not in _VALID_PH:
            problems.append(f"{where}: bad ph {ph!r}")
            continue
        if ph != "M":
            n_real += 1
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: bad ts {ts!r}")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                problems.append(f"{where}: {key} must be int")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X event bad dur {dur!r}")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                problems.append(f"{where}: C event needs args")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"{where}: args must be an object")
    if require_nonempty and n_real == 0 and not problems:
        problems.append("trace has no events (metadata only)")
    return problems
