"""Optional ``jax.profiler`` hooks; degrade to no-ops when unavailable.

Two entry points:

- :func:`trace` — context manager around a whole run, writing a device
  profile to a directory (``serve_bench --jax-profile DIR``).
- :func:`annotate` — a ``TraceAnnotation`` so host-side span names show
  up on the device timeline; the scheduler opens one around each
  dispatch when its tracer was built with ``jax_annotate=True``.

Both swallow a missing/broken profiler (old jax, no backend support)
rather than making observability a hard dependency: the host-side
tracer keeps working regardless.
"""
from __future__ import annotations

from contextlib import contextmanager, nullcontext

try:  # profiler availability depends on jax version/build
    from jax import profiler as _jax_profiler
except Exception:  # pragma: no cover - env without jax.profiler
    _jax_profiler = None


def available() -> bool:
    return _jax_profiler is not None


@contextmanager
def trace(log_dir):
    """``jax.profiler.trace`` if available, else a no-op."""
    if _jax_profiler is None or not log_dir:
        yield
        return
    try:
        ctx = _jax_profiler.trace(str(log_dir))
    except Exception:
        yield
        return
    with ctx:
        yield


def annotate(name: str):
    """``jax.profiler.TraceAnnotation(name)`` if available, else no-op."""
    if _jax_profiler is None:
        return nullcontext()
    try:
        return _jax_profiler.TraceAnnotation(name)
    except Exception:
        return nullcontext()
