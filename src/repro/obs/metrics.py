"""Typed, mergeable metrics: counters, gauges, histograms, a registry.

Design contract — **snapshots merge associatively and commutatively**,
the same discipline ``StreamingAUC`` / ``StreamingLogLoss`` follow in
``repro.core.metrics``.  That is what makes the registry usable across
stream shards and (eventually) hosts: any grouping / ordering of
partial snapshots merges to the same total.

Merge rules:

- **counter** — values add (ints stay ints, so integer counters merge
  bit-exactly).
- **histogram** — fixed, identical bucket bounds; per-bin counts,
  ``total`` and ``count`` add; ``min`` / ``max`` combine by min / max
  (``None`` when empty is the merge identity).
- **gauge** — last-writer-wins can't be made order-independent, so a
  gauge carries a monotonically increasing ``seq`` and merge picks the
  larger ``(seq, value)`` pair — max is associative and commutative.
  Within one process this is exactly last-writer-wins.

Snapshots are plain dicts of plain data (no shared references into the
registry): mutating a snapshot never perturbs the registry, and two
snapshots never alias each other.
"""
from __future__ import annotations

from bisect import bisect_right
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

Snapshot = Dict[str, Dict]

#: Default histogram bounds: 1-2-5 decades, good for counts and depths.
DEFAULT_BOUNDS: Tuple[float, ...] = (
    1, 2, 5, 10, 20, 50, 100, 200, 500, 1000, 2000, 5000)


class Counter:
    """Monotonic-by-convention additive metric. Merge: sum."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def set(self, v) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0

    def snapshot(self) -> Dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Point-in-time value. Merge: max over ``(seq, value)``."""

    __slots__ = ("name", "value", "seq")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self.seq = 0

    def set(self, v) -> None:
        self.value = v
        self.seq += 1

    def reset(self) -> None:
        self.value = 0.0
        self.seq = 0

    def snapshot(self) -> Dict:
        return {"type": "gauge", "value": self.value, "seq": self.seq}


class Histogram:
    """Fixed-bound histogram with exact total / count / min / max.

    ``counts`` has ``len(bounds) + 1`` bins; observation ``v`` lands in
    the first bin whose upper bound is ``>= v`` (last bin is overflow).
    ``mean`` is exact (from ``total``), not bin-approximated.
    """

    __slots__ = ("name", "bounds", "counts", "total", "count",
                 "vmin", "vmax")

    def __init__(self, name: str, bounds: Sequence[float] = DEFAULT_BOUNDS):
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError(f"histogram {name}: bounds must be "
                             f"strictly increasing, got {bounds}")
        self.reset()

    def observe(self, v) -> None:
        self.counts[bisect_right(self.bounds, v)] += 1
        self.total += v
        self.count += 1
        if self.vmin is None or v < self.vmin:
            self.vmin = v
        if self.vmax is None or v > self.vmax:
            self.vmax = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def reset(self) -> None:
        self.counts: List[int] = [0] * (len(self.bounds) + 1)
        self.total = 0
        self.count = 0
        self.vmin = None
        self.vmax = None

    def snapshot(self) -> Dict:
        return {"type": "histogram", "bounds": list(self.bounds),
                "counts": list(self.counts), "total": self.total,
                "count": self.count, "min": self.vmin, "max": self.vmax}


class MetricsRegistry:
    """Create-or-get store of named metrics with prefix-scoped reset.

    Names are dot-separated, ``<subsystem>.<noun>[.<qualifier>]``
    (see ``docs/observability.md``).  ``reset(prefix=...)`` resets only
    metrics under that prefix, which is how the scheduler's
    ``reset_telemetry()`` zeroes its ``serve.*`` counters without
    touching the one-shot ``jit.*`` compile gauges.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"requested {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Sequence[float] = DEFAULT_BOUNDS) -> Histogram:
        h = self._get(name, Histogram, bounds)
        if h.bounds != tuple(float(b) for b in bounds):
            raise ValueError(f"histogram {name!r} already registered "
                             f"with bounds {h.bounds}")
        return h

    def names(self, prefix: str = "") -> List[str]:
        return sorted(n for n in self._metrics if n.startswith(prefix))

    def snapshot(self, prefix: str = "") -> Snapshot:
        """Deep, non-aliasing copy of all metrics under ``prefix``."""
        return {n: m.snapshot() for n, m in sorted(self._metrics.items())
                if n.startswith(prefix)}

    def reset(self, prefix: str = "") -> None:
        for n, m in self._metrics.items():
            if n.startswith(prefix):
                m.reset()


def _merge_two(a: Dict, b: Dict, name: str) -> Dict:
    if a["type"] != b["type"]:
        raise ValueError(f"merge {name!r}: type mismatch "
                         f"{a['type']} vs {b['type']}")
    if a["type"] == "counter":
        return {"type": "counter", "value": a["value"] + b["value"]}
    if a["type"] == "gauge":
        win = a if (a["seq"], a["value"]) >= (b["seq"], b["value"]) else b
        return {"type": "gauge", "value": win["value"],
                "seq": max(a["seq"], b["seq"])}
    if a["type"] == "histogram":
        if a["bounds"] != b["bounds"]:
            raise ValueError(f"merge {name!r}: histogram bounds differ")
        lo = [v for v in (a["min"], b["min"]) if v is not None]
        hi = [v for v in (a["max"], b["max"]) if v is not None]
        return {"type": "histogram", "bounds": list(a["bounds"]),
                "counts": [x + y for x, y in zip(a["counts"], b["counts"])],
                "total": a["total"] + b["total"],
                "count": a["count"] + b["count"],
                "min": min(lo) if lo else None,
                "max": max(hi) if hi else None}
    raise ValueError(f"merge {name!r}: unknown type {a['type']!r}")


def merge_snapshots(*snaps: Snapshot) -> Snapshot:
    """Merge snapshots associatively; missing names merge as identity."""
    out: Snapshot = {}
    for snap in snaps:
        for name, m in snap.items():
            cur = out.get(name)
            if cur is not None:
                out[name] = _merge_two(cur, m, name)
            elif m["type"] == "histogram":
                out[name] = dict(m, counts=list(m["counts"]),
                                 bounds=list(m["bounds"]))
            else:
                out[name] = dict(m)
    return {n: out[n] for n in sorted(out)}
