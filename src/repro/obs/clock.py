"""The repo-wide duration clock.

Every elapsed-time measurement (scheduler steps, trainer steps, bench
reps, resilience retry buckets) goes through :func:`monotonic` so that
durations are immune to wall-clock jumps (NTP slew, manual resets).
``time.time()`` survives only where a *timestamp with calendar meaning*
is required — checkpoint metadata — via :func:`wall`, which exists so
grep can distinguish deliberate wall-clock reads from stragglers.

Tests inject deterministic clocks instead of monkeypatching:
``SpanTracer(clock=fake)`` and :class:`ManualClock` make timing
assertions exact rather than tolerance-based.
"""
from __future__ import annotations

import time

#: The duration clock: monotonic, sub-microsecond resolution.
monotonic = time.perf_counter


def wall() -> float:
    """Wall-clock *timestamp* (seconds since epoch).

    Only for metadata that must survive process restarts with calendar
    meaning (checkpoint manifests).  Never subtract two ``wall()`` reads
    to get a duration — use :func:`monotonic`.
    """
    return time.time()


class ManualClock:
    """Deterministic clock for tests: advances only when told to."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        self.now += float(dt)
