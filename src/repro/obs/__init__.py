"""Unified observability layer: span tracing, mergeable metrics, profiling.

Three small, dependency-free pieces shared by serve / train / stream:

- :mod:`repro.obs.clock` — the single monotonic clock every duration in
  the repo is measured on (``time.time()`` is reserved for checkpoint
  metadata timestamps, where wall-clock meaning matters more than
  monotonicity).
- :mod:`repro.obs.trace` — a host-side span tracer with explicit clock
  injection and a ring-buffered event store, exporting Chrome trace
  event / Perfetto JSON.  ``NULL_TRACER`` is the default everywhere, so
  untraced hot paths pay only a no-op attribute call.
- :mod:`repro.obs.metrics` — typed counters / gauges / histograms whose
  snapshots merge associatively (the same discipline
  ``StreamingAUC`` / ``StreamingLogLoss`` follow), superseding the
  ad-hoc counter dicts in the scheduler, page pool and stream windows.
- :mod:`repro.obs.profile` — optional ``jax.profiler`` trace /
  annotation hooks that degrade to no-ops when the profiler is absent.

See ``docs/observability.md`` for the span model, naming scheme and the
overhead contract (zero new device syncs on the serving hot path).
"""
from repro.obs.clock import monotonic, wall
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               merge_snapshots)
from repro.obs.trace import (NULL_TRACER, NullTracer, SpanTracer,
                             validate_chrome_trace)

__all__ = [
    "monotonic", "wall",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "merge_snapshots",
    "SpanTracer", "NullTracer", "NULL_TRACER", "validate_chrome_trace",
]
