"""Synthetic serving request streams and interaction event streams.

Two stream shapes, both built on the same latent-factor corpus as training
(`repro.data.synthetic`) so scheduler / benchmark / continual-training runs
exercise realistic token-length distributions:

* ``make_request_stream``  — serving requests: per page view, one user's
  recent interaction history and a slate of k candidate items to score.
  Context interactions carry their rating token, candidates are unrated
  (their click is what serving predicts). Consumed by
  ``repro.serve.scheduler.ServeScheduler.submit``,
  ``CTRServer.score_multi_target`` and ``benchmarks/serve_bench.py``.
* ``make_event_stream``    — training events: each user's *future*
  interactions replayed in chronological per-user order, interleaved
  across users and sliced into arrival ticks. Consumed by
  ``repro.stream`` (incremental DTI) and ``benchmarks/stream_bench.py``.

Determinism contract: every draw comes from one ``np.random.default_rng``
(PCG64) in a fixed, documented order, and every emitted value is a plain
Python int/list — no set/dict iteration, no float jitter — so the same
seed yields a byte-identical stream (``stream_digest`` canonicalises a
stream for comparison; regression test in tests/test_data.py).
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, List

import numpy as np

from repro.data.synthetic import CTRDataset


def make_request_stream(ds: CTRDataset, *, n_requests: int, k: int,
                        n_ctx: int, seed: int = 0) -> List[Dict]:
    """Draw ``n_requests`` requests: a random user's ``n_ctx`` consecutive
    interactions (with rating tokens) as context, and ``k`` random items
    (without ratings) as the candidate slate. Returns dicts with ``context``
    and ``candidates``, each a list of per-item token lists.

    Draw order per request (fixed so seeded runs are byte-deterministic):
    user id, context window offset, then the k candidate item ids.
    """
    rng = np.random.default_rng(seed)
    out = []
    n_items = len(ds.item_tokens)
    for _ in range(n_requests):
        u = int(rng.integers(0, len(ds.sequences)))
        toks, _ = ds.user_prompt_material(u)
        assert len(toks) >= n_ctx, f"user history {len(toks)} < n_ctx {n_ctx}"
        lo = int(rng.integers(0, len(toks) - n_ctx + 1))
        cands = rng.integers(0, n_items, size=k)
        out.append({
            "user": u,
            "context": [[int(t) for t in it] for it in toks[lo: lo + n_ctx]],
            "candidates": [[int(t) for t in ds.item_tokens[int(i)]]
                           for i in cands],
        })
    return out


def make_event_stream(ds: CTRDataset, *, n_ticks: int,
                      start_frac: float = 0.5, end_frac: float = 1.0,
                      seed: int = 0) -> List[List[Dict]]:
    """Replay a slice of every user's history as a stream of arrival ticks.

    Interactions before ``start_frac`` of each user's timeline are the warm
    corpus (seed them into the incremental builder / pretrain on them);
    those from ``end_frac`` on are held back (an untouched chronological
    tail for evaluation); the rest become events. Per-user chronology is preserved — user u's i-th
    event always precedes their (i+1)-th — while users interleave in a
    seeded random order (one global shuffle of (user, slot) pairs, then a
    stable per-user reorder). The flat order is sliced into ``n_ticks``
    near-equal chunks.

    Each event is ``{"user", "index", "item_tokens", "label"}`` where
    ``index`` is the interaction's absolute position in the user's history
    and ``item_tokens`` includes the rating token (the same per-interaction
    material training prompts are built from).
    """
    assert n_ticks > 0 and 0.0 <= start_frac < end_frac <= 1.0
    rng = np.random.default_rng(seed)
    events: List[Dict] = []
    pending: List[List[Dict]] = []
    for u in range(len(ds.sequences)):
        toks, labels = ds.user_prompt_material(u)
        start = int(len(toks) * start_frac)
        end = int(len(toks) * end_frac)
        pending.append([
            {"user": u, "index": i,
             "item_tokens": [int(t) for t in toks[i]],
             "label": int(labels[i])}
            for i in range(start, end)])
    owners = np.repeat(np.arange(len(pending)),
                       [len(p) for p in pending])
    rng.shuffle(owners)
    cursor = [0] * len(pending)
    for u in owners:                       # per-user order preserved
        events.append(pending[u][cursor[u]])
        cursor[u] += 1
    n = len(events)
    ticks, lo = [], 0
    for t in range(n_ticks):
        hi = (n * (t + 1)) // n_ticks
        ticks.append(events[lo:hi])
        lo = hi
    return ticks


def warm_histories(ds: CTRDataset, *, start_frac: float = 0.5):
    """The warm prefix ``make_event_stream`` does not replay: per user,
    (per-interaction token lists, labels) up to ``start_frac``."""
    out = []
    for u in range(len(ds.sequences)):
        toks, labels = ds.user_prompt_material(u)
        start = int(len(toks) * start_frac)
        out.append(([[int(t) for t in it] for it in toks[:start]],
                    [int(l) for l in labels[:start]]))
    return out


def stream_digest(stream) -> str:
    """Canonical sha256 of a request/event stream (nested python
    ints/lists/dicts; dict keys sorted) — the byte-determinism regression
    check: same seed, same digest."""
    blob = json.dumps(stream, sort_keys=True, separators=(",", ":"),
                      default=int).encode()
    return hashlib.sha256(blob).hexdigest()


__all__ = ["make_request_stream", "make_event_stream", "warm_histories",
           "stream_digest"]
