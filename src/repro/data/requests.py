"""Synthetic serving request streams (one user context + k candidate items).

The request shape end-to-end LLM rankers serve: per page view, one user's
recent interaction history and a slate of k candidate items to score. Built
on the same latent-factor corpus as training (`repro.data.synthetic`), so
scheduler/benchmark runs exercise realistic token-length distributions:
context interactions carry their rating token, candidates are unrated
(their click is what serving predicts).

Consumed by ``repro.serve.scheduler.ServeScheduler.submit``,
``CTRServer.score_multi_target`` and ``benchmarks/serve_bench.py``.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.data.synthetic import CTRDataset


def make_request_stream(ds: CTRDataset, *, n_requests: int, k: int,
                        n_ctx: int, seed: int = 0) -> List[Dict]:
    """Draw ``n_requests`` requests: a random user's ``n_ctx`` consecutive
    interactions (with rating tokens) as context, and ``k`` random items
    (without ratings) as the candidate slate. Returns dicts with ``context``
    and ``candidates``, each a list of per-item token lists."""
    rng = np.random.default_rng(seed)
    out = []
    n_items = len(ds.item_tokens)
    for _ in range(n_requests):
        u = int(rng.integers(0, len(ds.sequences)))
        toks, _ = ds.user_prompt_material(u)
        assert len(toks) >= n_ctx, f"user history {len(toks)} < n_ctx {n_ctx}"
        lo = int(rng.integers(0, len(toks) - n_ctx + 1))
        cands = rng.integers(0, n_items, size=k)
        out.append({
            "user": u,
            "context": toks[lo: lo + n_ctx],
            "candidates": [list(ds.item_tokens[i]) for i in cands],
        })
    return out


__all__ = ["make_request_stream"]
