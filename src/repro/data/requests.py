"""Synthetic serving request streams, interaction event streams, and the
context-hash trie serving uses to detect shareable prefixes.

Two stream shapes, both built on the same latent-factor corpus as training
(`repro.data.synthetic`) so scheduler / benchmark / continual-training runs
exercise realistic token-length distributions:

* ``make_request_stream``  — serving requests: per page view, one user's
  recent interaction history and a slate of k candidate items to score.
  Context interactions carry their rating token, candidates are unrated
  (their click is what serving predicts). ``repeat_frac`` re-issues
  earlier contexts with fresh slates (the "same user, next page view"
  shape) so schedulers exercising cross-request prefix sharing see hits.
  Consumed by ``repro.serve.scheduler.ServeScheduler.submit``,
  ``CTRServer.score_multi_target`` and ``benchmarks/serve_bench.py``.
* ``make_event_stream``    — training events: each user's *future*
  interactions replayed in chronological per-user order, interleaved
  across users and sliced into arrival ticks. Consumed by
  ``repro.stream`` (incremental DTI) and ``benchmarks/stream_bench.py``.

``ContextTrie`` indexes committed context token sequences so admission can
find, in O(|new context|), the deepest already-cached prefix of an
incoming request (see docs/serving.md for the sharing model).

Determinism contract: every draw comes from one ``np.random.default_rng``
(PCG64) in a fixed, documented order, and every emitted value is a plain
Python int/list — no set/dict iteration, no float jitter — so the same
seed yields a byte-identical stream (``stream_digest`` canonicalises a
stream for comparison; regression test in tests/test_data.py).
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.data.synthetic import CTRDataset


def make_request_stream(ds: CTRDataset, *, n_requests: int, k: int,
                        n_ctx: int, seed: int = 0,
                        repeat_frac: float = 0.0,
                        n_ctx_tail: int = None,
                        tail_alpha: float = 1.5) -> List[Dict]:
    """Draw ``n_requests`` requests: a random user's ``n_ctx`` consecutive
    interactions (with rating tokens) as context, and ``k`` random items
    (without ratings) as the candidate slate. Returns dicts with ``context``
    and ``candidates``, each a list of per-item token lists.

    ``repeat_frac`` > 0 makes that fraction of requests (after the first)
    *revisits*: the same user + context window as an earlier request but a
    freshly drawn candidate slate — the traffic shape cross-request prefix
    sharing exploits (one user paging through results, or a hot context).

    ``n_ctx_tail`` (> ``n_ctx``) switches the per-request context length
    from the constant ``n_ctx`` to a heavy-tailed draw: ``n_ctx`` plus a
    Pareto(``tail_alpha``) excess, clamped to ``n_ctx_tail`` interactions.
    Most requests stay near ``n_ctx``; a few are much longer — the
    mixed-length traffic shape whose tail a batched scheduler must not let
    one long prefill impose on every co-batched short slate (the
    ``--ctx-heavy-tail`` workload of benchmarks/serve_bench.py). Alpha 1.5
    is the classic infinite-variance web-traffic tail.

    Draw order per request (fixed so seeded runs are byte-deterministic):
    [revisit coin + source index when ``repeat_frac > 0``,] [context
    length when ``n_ctx_tail`` is set,] user id, context window offset,
    then the k candidate item ids; revisits skip the length/user/offset
    draws (they copy their source's context). Defaults draw exactly the
    historical sequence, so pre-existing seeded streams are unchanged.
    """
    rng = np.random.default_rng(seed)
    out = []
    n_items = len(ds.item_tokens)
    if n_ctx_tail is not None:
        assert n_ctx_tail >= n_ctx, "n_ctx_tail must be >= n_ctx"
    for _ in range(n_requests):
        revisit = None
        if repeat_frac > 0.0 and out:
            if float(rng.random()) < repeat_frac:
                revisit = out[int(rng.integers(0, len(out)))]
        if revisit is not None:
            u = revisit["user"]
            context = [list(it) for it in revisit["context"]]
        else:
            n_i = n_ctx
            if n_ctx_tail is not None:
                n_i = min(n_ctx + int(n_ctx * float(rng.pareto(tail_alpha))),
                          n_ctx_tail)
            u = int(rng.integers(0, len(ds.sequences)))
            toks, _ = ds.user_prompt_material(u)
            assert len(toks) >= n_i, (
                f"user history {len(toks)} < context length {n_i}")
            lo = int(rng.integers(0, len(toks) - n_i + 1))
            context = [[int(t) for t in it] for it in toks[lo: lo + n_i]]
        cands = rng.integers(0, n_items, size=k)
        out.append({
            "user": u,
            "context": context,
            "candidates": [[int(t) for t in ds.item_tokens[int(i)]]
                           for i in cands],
        })
    return out


class ContextTrie:
    """Hash-trie over context token sequences -> opaque owner handles.

    Serving admission asks one question per incoming request: *of the
    context blocks currently committed in the KV cache, which shares the
    longest prefix with this request's context, and does any of them end
    inside it?* The trie answers in O(|context|): nodes are hash maps
    keyed by token id; each node records the owners whose full context
    **ends** there and the owners whose context **passes through** it.

    Owners are opaque hashables (the scheduler uses cache row ids). One
    owner owns at most one sequence at a time — re-inserting an owner
    under a new sequence requires removing the old one first (the
    scheduler does this when it extends or trims a retained context).
    """

    def __init__(self):
        self._root = self._node()
        self._len: Dict[object, int] = {}       # owner -> |its sequence|

    @staticmethod
    def _node() -> Dict:
        return {"kids": {}, "ends": set(), "through": set()}

    def __len__(self) -> int:
        return len(self._len)

    def owner_length(self, owner) -> int:
        """Length of the sequence ``owner`` currently owns (KeyError if
        absent)."""
        return self._len[owner]

    def insert(self, tokens: Sequence[int], owner) -> None:
        assert owner not in self._len, f"owner {owner!r} already in trie"
        node = self._root
        node["through"].add(owner)
        for t in tokens:
            node = node["kids"].setdefault(int(t), self._node())
            node["through"].add(owner)
        node["ends"].add(owner)
        self._len[owner] = len(tokens)

    def remove(self, tokens: Sequence[int], owner) -> None:
        assert self._len.get(owner) == len(tokens), (
            f"owner {owner!r} does not own a length-{len(tokens)} sequence")
        node, path = self._root, []
        node["through"].discard(owner)
        for t in tokens:
            path.append((node, int(t)))
            node = node["kids"][int(t)]
            node["through"].discard(owner)
        node["ends"].discard(owner)
        del self._len[owner]
        # prune now-unowned branches so the trie stays O(live contexts)
        for parent, t in reversed(path):
            child = parent["kids"][t]
            if not child["through"]:
                del parent["kids"][t]

    def match(self, tokens: Sequence[int]) -> Tuple[int, set, int, set]:
        """Walk ``tokens`` as deep as the trie goes.

        Returns ``(end_depth, end_owners, through_depth, through_owners)``:

        * ``end_owners`` — owners whose **entire** sequence is a prefix of
          ``tokens``, at the deepest such depth ``end_depth`` (these can be
          reused as-is: commit/score only the suffix);
        * ``through_owners`` — owners passing through the deepest reachable
          node at ``through_depth`` (their sequences share the first
          ``through_depth`` tokens with ``tokens`` but continue past it —
          reusable only by trimming back to the shared prefix).

        Empty sets / depth 0 when nothing matches.
        """
        node = self._root
        end_depth, end_owners = 0, set()
        depth = 0
        for t in tokens:
            nxt = node["kids"].get(int(t))
            if nxt is None:
                break
            node = nxt
            depth += 1
            if node["ends"]:
                end_depth, end_owners = depth, set(node["ends"])
        return end_depth, end_owners, depth, set(node["through"])


def make_event_stream(ds: CTRDataset, *, n_ticks: int,
                      start_frac: float = 0.5, end_frac: float = 1.0,
                      seed: int = 0) -> List[List[Dict]]:
    """Replay a slice of every user's history as a stream of arrival ticks.

    Interactions before ``start_frac`` of each user's timeline are the warm
    corpus (seed them into the incremental builder / pretrain on them);
    those from ``end_frac`` on are held back (an untouched chronological
    tail for evaluation); the rest become events. Per-user chronology is preserved — user u's i-th
    event always precedes their (i+1)-th — while users interleave in a
    seeded random order (one global shuffle of (user, slot) pairs, then a
    stable per-user reorder). The flat order is sliced into ``n_ticks``
    near-equal chunks.

    Each event is ``{"user", "index", "item_tokens", "label"}`` where
    ``index`` is the interaction's absolute position in the user's history
    and ``item_tokens`` includes the rating token (the same per-interaction
    material training prompts are built from).
    """
    assert n_ticks > 0 and 0.0 <= start_frac < end_frac <= 1.0
    rng = np.random.default_rng(seed)
    events: List[Dict] = []
    pending: List[List[Dict]] = []
    for u in range(len(ds.sequences)):
        toks, labels = ds.user_prompt_material(u)
        start = int(len(toks) * start_frac)
        end = int(len(toks) * end_frac)
        pending.append([
            {"user": u, "index": i,
             "item_tokens": [int(t) for t in toks[i]],
             "label": int(labels[i])}
            for i in range(start, end)])
    owners = np.repeat(np.arange(len(pending)),
                       [len(p) for p in pending])
    rng.shuffle(owners)
    cursor = [0] * len(pending)
    for u in owners:                       # per-user order preserved
        events.append(pending[u][cursor[u]])
        cursor[u] += 1
    n = len(events)
    ticks, lo = [], 0
    for t in range(n_ticks):
        hi = (n * (t + 1)) // n_ticks
        ticks.append(events[lo:hi])
        lo = hi
    return ticks


def warm_histories(ds: CTRDataset, *, start_frac: float = 0.5):
    """The warm prefix ``make_event_stream`` does not replay: per user,
    (per-interaction token lists, labels) up to ``start_frac``."""
    out = []
    for u in range(len(ds.sequences)):
        toks, labels = ds.user_prompt_material(u)
        start = int(len(toks) * start_frac)
        out.append(([[int(t) for t in it] for it in toks[:start]],
                    [int(l) for l in labels[:start]]))
    return out


def stream_digest(stream) -> str:
    """Canonical sha256 of a request/event stream (nested python
    ints/lists/dicts; dict keys sorted) — the byte-determinism regression
    check: same seed, same digest."""
    blob = json.dumps(stream, sort_keys=True, separators=(",", ":"),
                      default=int).encode()
    return hashlib.sha256(blob).hexdigest()


__all__ = ["make_request_stream", "ContextTrie", "make_event_stream",
           "warm_histories", "stream_digest"]
