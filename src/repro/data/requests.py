"""Synthetic serving request streams, interaction event streams, and the
context-hash trie serving uses to detect shareable prefixes.

Two stream shapes, both built on the same latent-factor corpus as training
(`repro.data.synthetic`) so scheduler / benchmark / continual-training runs
exercise realistic token-length distributions:

* ``make_request_stream``  — serving requests: per page view, one user's
  recent interaction history and a slate of k candidate items to score.
  Context interactions carry their rating token, candidates are unrated
  (their click is what serving predicts). ``repeat_frac`` re-issues
  earlier contexts with fresh slates (the "same user, next page view"
  shape) so schedulers exercising cross-request prefix sharing see hits.
  Consumed by ``repro.serve.scheduler.ServeScheduler.submit``,
  ``CTRServer.score_multi_target`` and ``benchmarks/serve_bench.py``.
* ``make_event_stream``    — training events: each user's *future*
  interactions replayed in chronological per-user order, interleaved
  across users and sliced into arrival ticks. Consumed by
  ``repro.stream`` (incremental DTI) and ``benchmarks/stream_bench.py``.

``ContextTrie`` indexes committed context token sequences so admission can
find, in O(|new context|), the deepest already-cached prefix of an
incoming request (see docs/serving.md for the sharing model).
``RadixTree`` is its path-compressed successor: the same owner API plus a
page layer mapping full pages of committed prefixes to KV pool pages
(``repro.serve.pages``), so prefixes survive row eviction and are reusable
across every cache row. The scheduler uses ``RadixTree``; ``ContextTrie``
remains as the reference hash-trie implementation.

Determinism contract: every draw comes from one ``np.random.default_rng``
(PCG64) in a fixed, documented order, and every emitted value is a plain
Python int/list — no set/dict iteration, no float jitter — so the same
seed yields a byte-identical stream (``stream_digest`` canonicalises a
stream for comparison; regression test in tests/test_data.py).
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.data.synthetic import CTRDataset


def make_request_stream(ds: CTRDataset, *, n_requests: int, k: int,
                        n_ctx: int, seed: int = 0,
                        repeat_frac: float = 0.0,
                        n_ctx_tail: int = None,
                        tail_alpha: float = 1.5) -> List[Dict]:
    """Draw ``n_requests`` requests: a random user's ``n_ctx`` consecutive
    interactions (with rating tokens) as context, and ``k`` random items
    (without ratings) as the candidate slate. Returns dicts with ``context``
    and ``candidates``, each a list of per-item token lists.

    ``repeat_frac`` > 0 makes that fraction of requests (after the first)
    *revisits*: the same user + context window as an earlier request but a
    freshly drawn candidate slate — the traffic shape cross-request prefix
    sharing exploits (one user paging through results, or a hot context).

    ``n_ctx_tail`` (> ``n_ctx``) switches the per-request context length
    from the constant ``n_ctx`` to a heavy-tailed draw: ``n_ctx`` plus a
    Pareto(``tail_alpha``) excess, clamped to ``n_ctx_tail`` interactions.
    Most requests stay near ``n_ctx``; a few are much longer — the
    mixed-length traffic shape whose tail a batched scheduler must not let
    one long prefill impose on every co-batched short slate (the
    ``--ctx-heavy-tail`` workload of benchmarks/serve_bench.py). Alpha 1.5
    is the classic infinite-variance web-traffic tail.

    Draw order per request (fixed so seeded runs are byte-deterministic):
    [revisit coin + source index when ``repeat_frac > 0``,] [context
    length when ``n_ctx_tail`` is set,] user id, context window offset,
    then the k candidate item ids; revisits skip the length/user/offset
    draws (they copy their source's context). Defaults draw exactly the
    historical sequence, so pre-existing seeded streams are unchanged.
    """
    rng = np.random.default_rng(seed)
    out = []
    n_items = len(ds.item_tokens)
    if n_ctx_tail is not None:
        assert n_ctx_tail >= n_ctx, "n_ctx_tail must be >= n_ctx"
    for _ in range(n_requests):
        revisit = None
        if repeat_frac > 0.0 and out:
            if float(rng.random()) < repeat_frac:
                revisit = out[int(rng.integers(0, len(out)))]
        if revisit is not None:
            u = revisit["user"]
            context = [list(it) for it in revisit["context"]]
        else:
            n_i = n_ctx
            if n_ctx_tail is not None:
                n_i = min(n_ctx + int(n_ctx * float(rng.pareto(tail_alpha))),
                          n_ctx_tail)
            u = int(rng.integers(0, len(ds.sequences)))
            toks, _ = ds.user_prompt_material(u)
            assert len(toks) >= n_i, (
                f"user history {len(toks)} < context length {n_i}")
            lo = int(rng.integers(0, len(toks) - n_i + 1))
            context = [[int(t) for t in it] for it in toks[lo: lo + n_i]]
        cands = rng.integers(0, n_items, size=k)
        out.append({
            "user": u,
            "context": context,
            "candidates": [[int(t) for t in ds.item_tokens[int(i)]]
                           for i in cands],
        })
    return out


class ContextTrie:
    """Hash-trie over context token sequences -> opaque owner handles.

    Serving admission asks one question per incoming request: *of the
    context blocks currently committed in the KV cache, which shares the
    longest prefix with this request's context, and does any of them end
    inside it?* The trie answers in O(|context|): nodes are hash maps
    keyed by token id; each node records the owners whose full context
    **ends** there and the owners whose context **passes through** it.

    Owners are opaque hashables (the scheduler uses cache row ids). One
    owner owns at most one sequence at a time — re-inserting an owner
    under a new sequence requires removing the old one first (the
    scheduler does this when it extends or trims a retained context).
    """

    def __init__(self):
        self._root = self._node()
        self._len: Dict[object, int] = {}       # owner -> |its sequence|

    @staticmethod
    def _node() -> Dict:
        return {"kids": {}, "ends": set(), "through": set()}

    def __len__(self) -> int:
        return len(self._len)

    def owner_length(self, owner) -> int:
        """Length of the sequence ``owner`` currently owns (KeyError if
        absent)."""
        return self._len[owner]

    def insert(self, tokens: Sequence[int], owner) -> None:
        assert owner not in self._len, f"owner {owner!r} already in trie"
        node = self._root
        node["through"].add(owner)
        for t in tokens:
            node = node["kids"].setdefault(int(t), self._node())
            node["through"].add(owner)
        node["ends"].add(owner)
        self._len[owner] = len(tokens)

    def remove(self, tokens: Sequence[int], owner) -> None:
        assert self._len.get(owner) == len(tokens), (
            f"owner {owner!r} does not own a length-{len(tokens)} sequence")
        node, path = self._root, []
        node["through"].discard(owner)
        for t in tokens:
            path.append((node, int(t)))
            node = node["kids"][int(t)]
            node["through"].discard(owner)
        node["ends"].discard(owner)
        del self._len[owner]
        # prune now-unowned branches so the trie stays O(live contexts)
        for parent, t in reversed(path):
            child = parent["kids"][t]
            if not child["through"]:
                del parent["kids"][t]

    def match(self, tokens: Sequence[int]) -> Tuple[int, set, int, set]:
        """Walk ``tokens`` as deep as the trie goes.

        Returns ``(end_depth, end_owners, through_depth, through_owners)``:

        * ``end_owners`` — owners whose **entire** sequence is a prefix of
          ``tokens``, at the deepest such depth ``end_depth`` (these can be
          reused as-is: commit/score only the suffix);
        * ``through_owners`` — owners passing through the deepest reachable
          node at ``through_depth`` (their sequences share the first
          ``through_depth`` tokens with ``tokens`` but continue past it —
          reusable only by trimming back to the shared prefix).

        Empty sets / depth 0 when nothing matches. In particular a
        first-token mismatch reports ``through_owners == set()``, *not* the
        root's through set (which holds every owner): a depth-0 "match"
        shares nothing, so there is nothing to reuse.
        """
        node = self._root
        end_depth, end_owners = 0, set()
        depth = 0
        for t in tokens:
            nxt = node["kids"].get(int(t))
            if nxt is None:
                break
            node = nxt
            depth += 1
            if node["ends"]:
                end_depth, end_owners = depth, set(node["ends"])
        if depth == 0:
            return end_depth, end_owners, 0, set()
        return end_depth, end_owners, depth, set(node["through"])


class _RadixNode:
    """One path-compressed node: the edge from its parent spans logical
    depths ``(start, start + len(edge)]``."""

    __slots__ = ("edge", "start", "kids", "ends", "through", "pages",
                 "last_used", "parent")

    def __init__(self, edge: List[int], start: int, parent):
        self.edge = edge            # token label on the edge from parent
        self.start = start          # depth at which this edge begins
        self.kids: Dict[int, "_RadixNode"] = {}   # first edge token -> child
        self.ends = set()           # owners whose sequence ends at self.end
        self.through = set()        # owners whose sequence covers >= self.end
        self.pages: Dict[int, int] = {}   # page index -> pool page id
        self.last_used = 0
        self.parent = parent

    @property
    def end(self) -> int:
        return self.start + len(self.edge)


class RadixTree:
    """Path-compressed radix tree over context token sequences, with an
    optional **page layer** indexing the KV-cache pages that hold each
    full page of a committed prefix (see ``repro.serve.pages.PagePool``
    and docs/serving.md).

    Drop-in upgrade of :class:`ContextTrie` for the scheduler's admission
    ladder: the owner API (``insert``/``remove``/``match``/
    ``owner_length``) has identical semantics — including the fixed
    depth-0 contract: a first-token mismatch returns empty owner sets,
    never the root's — but nodes are O(live branching points), not O(live
    tokens). On top of it, three page-layer calls make prefixes reusable
    across *all* rows, not just rows whose block is still retained:

    * ``attach_pages(tokens, pages)`` — publish the pool pages holding the
      full pages of ``tokens`` (the index takes one pool reference per
      page it newly adopts; the caller performs the incref).
    * ``match_pages(tokens)`` — longest contiguous indexed page run
      covering a prefix of ``tokens`` (what a new admission can map into
      its page table instead of recomputing).
    * ``evict_pages(need, page_ref)`` — reclaim least-recently-used pages
      held *only* by the index (pool refcount 1), deepest-first within a
      node so contiguous prefixes shrink from the tail.

    Page nodes may outlive their owners (a stolen row's prefix stays
    indexed until evicted); owner removal never prunes a node that still
    holds pages.
    """

    def __init__(self, page_size: int = 0):
        self._root = _RadixNode([], 0, None)
        self._len: Dict[object, int] = {}       # owner -> |its sequence|
        self._page_size = int(page_size)
        self._clock = 0

    def __len__(self) -> int:
        return len(self._len)

    def owner_length(self, owner) -> int:
        """Length of the sequence ``owner`` currently owns (KeyError if
        absent)."""
        return self._len[owner]

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _split(self, child: _RadixNode, j: int) -> _RadixNode:
        """Split ``child``'s edge after ``j`` tokens; return the new upper
        node. Owners and pages redistribute by the spans they cover."""
        parent = child.parent
        upper = _RadixNode(child.edge[:j], child.start, parent)
        parent.kids[upper.edge[0]] = upper
        child.edge = child.edge[j:]
        child.start += j
        child.parent = upper
        upper.kids[child.edge[0]] = child
        # every owner below the split covers the upper span too; nobody can
        # end exactly at the new boundary yet (that would have split earlier)
        upper.through = set(child.through)
        upper.last_used = child.last_used
        if self._page_size:
            ps = self._page_size
            moved = [p for p in child.pages if (p + 1) * ps <= upper.end]
            for p in moved:
                upper.pages[p] = child.pages.pop(p)
        return upper

    def _extend_path(self, tokens: Sequence[int]) -> List[_RadixNode]:
        """Create/split nodes so the path spelling ``tokens`` ends on a node
        boundary; return the nodes along it (root excluded), each fully
        covered by ``tokens``."""
        node, i, n = self._root, 0, len(tokens)
        path: List[_RadixNode] = []
        while i < n:
            t = int(tokens[i])
            child = node.kids.get(t)
            if child is None:
                child = _RadixNode([int(x) for x in tokens[i:]], i, node)
                node.kids[t] = child
                path.append(child)
                return path
            e = child.edge
            j, m = 0, min(len(e), n - i)
            while j < m and e[j] == int(tokens[i + j]):
                j += 1
            if j == len(e):
                path.append(child)
                node, i = child, i + j
                continue
            upper = self._split(child, j)
            path.append(upper)
            if i + j == n:
                return path
            node, i = upper, i + j
            # next iteration diverges from the lower half -> fresh leaf
        return path

    def insert(self, tokens: Sequence[int], owner) -> None:
        assert owner not in self._len, f"owner {owner!r} already in tree"
        clock = self._tick()
        self._root.through.add(owner)
        path = self._extend_path(tokens)
        for nd in path:
            nd.through.add(owner)
            nd.last_used = clock
        (path[-1] if path else self._root).ends.add(owner)
        self._len[owner] = len(tokens)

    def _maybe_prune(self, node: _RadixNode) -> None:
        while (node is not self._root and not node.through and not node.ends
               and not node.kids and not node.pages):
            parent = node.parent
            del parent.kids[node.edge[0]]
            node = parent

    def remove(self, tokens: Sequence[int], owner) -> None:
        assert self._len.get(owner) == len(tokens), (
            f"owner {owner!r} does not own a length-{len(tokens)} sequence")
        self._root.through.discard(owner)
        node, i = self._root, 0
        while i < len(tokens):
            child = node.kids[int(tokens[i])]
            assert child.edge == [int(t) for t in
                                  tokens[i:i + len(child.edge)]], (
                "owner path must lie on node boundaries")
            child.through.discard(owner)
            node, i = child, i + len(child.edge)
        node.ends.discard(owner)
        del self._len[owner]
        self._maybe_prune(node)

    def match(self, tokens: Sequence[int]) -> Tuple[int, set, int, set]:
        """Identical contract to :meth:`ContextTrie.match` — see its
        docstring; depth 0 always reports empty owner sets."""
        node, i, n = self._root, 0, len(tokens)
        depth, thr = 0, set()
        end_depth, end_owners = 0, set()
        clock = self._tick()
        while i < n:
            child = node.kids.get(int(tokens[i]))
            if child is None:
                break
            e = child.edge
            j, m = 0, min(len(e), n - i)
            while j < m and e[j] == int(tokens[i + j]):
                j += 1
            child.last_used = clock
            depth = i + j
            thr = child.through
            i += j
            if j < len(e):
                break
            if child.ends:
                end_depth, end_owners = depth, set(child.ends)
            node = child
        if depth == 0:
            return 0, set(), 0, set()
        return end_depth, end_owners, depth, set(thr)

    # -- page layer ---------------------------------------------------------

    def attach_pages(self, tokens: Sequence[int],
                     pages: Sequence[int]) -> List[int]:
        """Index pool pages covering ``tokens[:len(pages) * page_size]``;
        ``pages[i]`` holds tokens ``[i*ps, (i+1)*ps)``. Returns the page
        ids *newly* adopted (the caller takes one pool reference per
        returned id); indices already indexed keep their existing id."""
        ps = self._page_size
        assert ps > 0, "tree built without a page_size"
        assert len(tokens) >= len(pages) * ps
        path = self._extend_path([int(t) for t in tokens[:len(pages) * ps]])
        clock = self._tick()
        new: List[int] = []
        k = 0
        for nd in path:
            nd.last_used = clock
            while k < len(pages) and (k + 1) * ps <= nd.end:
                if k not in nd.pages:
                    nd.pages[k] = int(pages[k])
                    new.append(int(pages[k]))
                k += 1
        assert k == len(pages)
        return new

    def match_pages(self, tokens: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest contiguous indexed page run covering a prefix of
        ``tokens``: returns ``(covered_tokens, page_ids)`` with
        ``covered_tokens == len(page_ids) * page_size``."""
        ps = self._page_size
        assert ps > 0, "tree built without a page_size"
        node, i, n = self._root, 0, len(tokens)
        clock = self._tick()
        got: List[int] = []
        while i < n:
            child = node.kids.get(int(tokens[i]))
            if child is None:
                break
            e = child.edge
            j, m = 0, min(len(e), n - i)
            while j < m and e[j] == int(tokens[i + j]):
                j += 1
            child.last_used = clock
            depth = i + j
            while ((len(got) + 1) * ps <= depth
                   and len(got) in child.pages):
                got.append(child.pages[len(got)])
            if j < len(e) or (len(got) + 1) * ps <= depth:
                break                     # diverged, exhausted, or page gap
            node, i = child, depth
        return len(got) * ps, got

    def evict_pages(self, need: int, page_ref) -> List[int]:
        """Drop up to ``need`` least-recently-used pages held only by the
        index (``page_ref[pid] == 1``), deepest-first within a node.
        Returns the evicted page ids; the caller releases the pool
        reference for each."""
        nodes, stack = [], [self._root]
        while stack:
            nd = stack.pop()
            stack.extend(nd.kids.values())
            if nd.pages:
                nodes.append(nd)
        nodes.sort(key=lambda nd: (nd.last_used, -nd.start))
        out: List[int] = []
        for nd in nodes:
            if len(out) >= need:
                break
            for pidx in sorted(nd.pages, reverse=True):
                if len(out) >= need:
                    break
                pid = nd.pages[pidx]
                if page_ref[pid] == 1:
                    del nd.pages[pidx]
                    out.append(pid)
            self._maybe_prune(nd)
        return out

    def drop_pages(self, tokens: Sequence[int], from_page: int) -> List[int]:
        """Un-index the pages covering ``tokens[from_page * page_size:]``
        (global page index ``>= from_page`` along the matching path).
        Returns the dropped page ids; the caller releases the index's
        pool reference for each. Used when a trim needs to recommit into
        a partially-covered boundary page: dropping the boundary (and the
        now-unreachable deeper pages behind it) makes it private again,
        so the rewrite cannot corrupt a prefix some future adoption would
        map in. Pages other rows still read keep their row references —
        only the index's hold is released."""
        ps = self._page_size
        assert ps > 0, "tree built without a page_size"
        node, i, n = self._root, 0, len(tokens)
        out: List[int] = []
        touched: List[_RadixNode] = []
        while i < n:
            child = node.kids.get(int(tokens[i]))
            if child is None:
                break
            e = child.edge
            j, m = 0, min(len(e), n - i)
            while j < m and e[j] == int(tokens[i + j]):
                j += 1
            touched.append(child)
            for pidx in [p for p in child.pages if p >= from_page]:
                out.append(child.pages.pop(pidx))
            if j < len(e):
                break
            node, i = child, i + j
        for nd in reversed(touched):
            self._maybe_prune(nd)
        return out

    def drop_all_pages(self) -> List[int]:
        """Flush the whole page layer (weight hot-swap: indexed KV was
        computed under the old parameters). Returns every held page id."""
        out, stack, seen = [], [self._root], []
        while stack:
            nd = stack.pop()
            stack.extend(nd.kids.values())
            seen.append(nd)
            out.extend(nd.pages.values())
            nd.pages.clear()
        for nd in reversed(seen):
            self._maybe_prune(nd)
        return out

    def held_pages(self) -> int:
        """Number of pages currently held by the index (telemetry)."""
        n, stack = 0, [self._root]
        while stack:
            nd = stack.pop()
            stack.extend(nd.kids.values())
            n += len(nd.pages)
        return n


def make_event_stream(ds: CTRDataset, *, n_ticks: int,
                      start_frac: float = 0.5, end_frac: float = 1.0,
                      seed: int = 0) -> List[List[Dict]]:
    """Replay a slice of every user's history as a stream of arrival ticks.

    Interactions before ``start_frac`` of each user's timeline are the warm
    corpus (seed them into the incremental builder / pretrain on them);
    those from ``end_frac`` on are held back (an untouched chronological
    tail for evaluation); the rest become events. Per-user chronology is preserved — user u's i-th
    event always precedes their (i+1)-th — while users interleave in a
    seeded random order (one global shuffle of (user, slot) pairs, then a
    stable per-user reorder). The flat order is sliced into ``n_ticks``
    near-equal chunks.

    Each event is ``{"user", "index", "item_tokens", "label"}`` where
    ``index`` is the interaction's absolute position in the user's history
    and ``item_tokens`` includes the rating token (the same per-interaction
    material training prompts are built from).
    """
    assert n_ticks > 0 and 0.0 <= start_frac < end_frac <= 1.0
    rng = np.random.default_rng(seed)
    events: List[Dict] = []
    pending: List[List[Dict]] = []
    for u in range(len(ds.sequences)):
        toks, labels = ds.user_prompt_material(u)
        start = int(len(toks) * start_frac)
        end = int(len(toks) * end_frac)
        pending.append([
            {"user": u, "index": i,
             "item_tokens": [int(t) for t in toks[i]],
             "label": int(labels[i])}
            for i in range(start, end)])
    owners = np.repeat(np.arange(len(pending)),
                       [len(p) for p in pending])
    rng.shuffle(owners)
    cursor = [0] * len(pending)
    for u in owners:                       # per-user order preserved
        events.append(pending[u][cursor[u]])
        cursor[u] += 1
    n = len(events)
    ticks, lo = [], 0
    for t in range(n_ticks):
        hi = (n * (t + 1)) // n_ticks
        ticks.append(events[lo:hi])
        lo = hi
    return ticks


def warm_histories(ds: CTRDataset, *, start_frac: float = 0.5):
    """The warm prefix ``make_event_stream`` does not replay: per user,
    (per-interaction token lists, labels) up to ``start_frac``."""
    out = []
    for u in range(len(ds.sequences)):
        toks, labels = ds.user_prompt_material(u)
        start = int(len(toks) * start_frac)
        out.append(([[int(t) for t in it] for it in toks[:start]],
                    [int(l) for l in labels[:start]]))
    return out


def stream_digest(stream) -> str:
    """Canonical sha256 of a request/event stream (nested python
    ints/lists/dicts; dict keys sorted) — the byte-determinism regression
    check: same seed, same digest."""
    blob = json.dumps(stream, sort_keys=True, separators=(",", ":"),
                      default=int).encode()
    return hashlib.sha256(blob).hexdigest()


__all__ = ["make_request_stream", "ContextTrie", "RadixTree",
           "make_event_stream", "warm_histories", "stream_digest"]
