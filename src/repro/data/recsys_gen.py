"""Synthetic recsys batches with latent-factor labels (learnable signal)."""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


class RecsysGenerator:
    """Latent-factor CTR world: label = Bernoulli(sigmoid(z_t . mean(z_hist)))."""

    def __init__(self, n_items: int, latent_dim: int = 8, *, seed: int = 0,
                 scale: float = 4.0):
        rng = np.random.default_rng(seed)
        # only materialise latents for a small active slice of the huge vocab
        self.active = min(n_items, 50_000)
        self.z = rng.normal(size=(self.active, latent_dim)) / np.sqrt(latent_dim)
        self.n_items = n_items
        self.scale = scale

    def seq_batch(self, batch: int, seq_len: int, *, rng: np.random.Generator
                  ) -> Dict[str, np.ndarray]:
        hist = rng.integers(0, self.active, size=(batch, seq_len))
        target = rng.integers(0, self.active, size=(batch,))
        user = self.z[hist].mean(axis=1)
        aff = np.einsum("bd,bd->b", self.z[target], user) * self.scale
        labels = (rng.random(batch) < 1 / (1 + np.exp(-aff))).astype(np.int32)
        return {"hist": hist.astype(np.int32), "target": target.astype(np.int32),
                "labels": labels}

    def field_batch(self, batch: int, vocab_sizes: Sequence[int], *,
                    rng: np.random.Generator) -> Dict[str, np.ndarray]:
        """xDeepFM-style multi-field batch; label from a random bilinear rule."""
        f = len(vocab_sizes)
        ids = np.stack([rng.integers(0, v, size=batch) for v in vocab_sizes],
                       axis=1)
        # learnable rule: parity of a fixed hash of the first few fields
        key = (ids[:, 0] * 2654435761 + ids[:, 1 % f] * 40503) % 97
        p = 1 / (1 + np.exp(-(key.astype(np.float64) - 48.5) / 12.0))
        labels = (rng.random(batch) < p).astype(np.int32)
        return {"ids": ids.astype(np.int32), "labels": labels}


__all__ = ["RecsysGenerator"]
