"""repro.data — synthetic corpora, tokenizer, samplers, serving request
streams and training event streams."""
from repro.data.tokenizer import HashTokenizer
from repro.data.synthetic import CTRDataset, make_ctr_dataset, split_users
from repro.data.sampler import (Graph, SampledSubgraph, make_community_graph,
                                make_molecule_batch, sample_neighbors)
from repro.data.recsys_gen import RecsysGenerator
from repro.data.requests import (make_event_stream, make_request_stream,
                                 stream_digest, warm_histories)
