"""Deterministic hash word-piece tokenizer (offline container, no HF).

Words map to stable ids in [n_reserved, vocab) via FNV-1a; special tokens
(PAD/BOS/SUM/YES/NO/SEP) live below n_reserved and match
``repro.core.dti.SpecialTokens``.
"""
from __future__ import annotations

from typing import List

from repro.core.dti import SpecialTokens


def _fnv1a(s: str) -> int:
    h = 0x811C9DC5
    for ch in s.encode():
        h = ((h ^ ch) * 0x01000193) & 0xFFFFFFFF
    return h


class HashTokenizer:
    def __init__(self, vocab_size: int = 8192,
                 sp: SpecialTokens = SpecialTokens()):
        assert vocab_size > sp.n_reserved
        self.vocab_size = vocab_size
        self.sp = sp

    def token_id(self, word: str) -> int:
        span = self.vocab_size - self.sp.n_reserved
        return self.sp.n_reserved + _fnv1a(word.lower()) % span

    def encode(self, text: str) -> List[int]:
        return [self.token_id(w) for w in text.split()]

    def encode_item(self, title: str, genres: str, rating: int) -> List[int]:
        """Tokenise one interaction the way the paper's prompts do:
        'title: ... genres: ... rating: r' separated from neighbours."""
        toks = [self.sp.sep]
        toks += self.encode(title)
        toks += [self.token_id(f"genre={genres}")]
        toks += [self.token_id(f"rating={rating}")]
        return toks


__all__ = ["HashTokenizer"]
