"""Graph data: generators + a real CSR neighbor sampler (minibatch_lg).

The sampler implements GraphSAGE-style layered fanout sampling
(arXiv:1706.02216): given seed nodes and fanouts [f1, f2], it samples f1
neighbors per seed, then f2 per frontier node, emitting a fixed-shape padded
subgraph (TPU-friendly: no ragged shapes reach the jitted step).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    """CSR adjacency + features + labels."""
    indptr: np.ndarray      # (N+1,)
    indices: np.ndarray     # (E,)
    x: np.ndarray           # (N, d)
    y: np.ndarray           # (N,)

    @property
    def n_nodes(self) -> int:
        return self.x.shape[0]

    @property
    def n_edges(self) -> int:
        return self.indices.shape[0]

    def edge_list(self) -> Tuple[np.ndarray, np.ndarray]:
        dst = np.repeat(np.arange(self.n_nodes), np.diff(self.indptr))
        return self.indices.astype(np.int32), dst.astype(np.int32)


def make_community_graph(n_nodes: int, avg_degree: int, d_feat: int,
                         n_classes: int, *, seed: int = 0,
                         homophily: float = 0.8) -> Graph:
    """Random graph with community structure: labels = community, features =
    noisy one-hot community signal (so GIN can actually learn)."""
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, n_classes, size=n_nodes)
    n_edges = n_nodes * avg_degree
    src = rng.integers(0, n_nodes, size=n_edges)
    same = rng.random(n_edges) < homophily
    # homophilous edges: destination from same community (approx via resample)
    dst = rng.integers(0, n_nodes, size=n_edges)
    pool = {}
    for c in range(n_classes):
        pool[c] = np.flatnonzero(comm == c)
    for c in range(n_classes):
        sel = same & (comm[src] == c)
        if sel.any() and len(pool[c]):
            dst[sel] = rng.choice(pool[c], size=int(sel.sum()))
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, dst + 1, 1)
    indptr = np.cumsum(indptr)
    x = rng.normal(scale=1.0, size=(n_nodes, d_feat)).astype(np.float32)
    sig = min(d_feat, n_classes)
    x[np.arange(n_nodes), comm % sig] += 2.0
    return Graph(indptr.astype(np.int64), src.astype(np.int32), x,
                 comm.astype(np.int32))


@dataclasses.dataclass
class SampledSubgraph:
    """Fixed-shape padded subgraph from layered neighbor sampling."""
    node_ids: np.ndarray    # (max_nodes,) original ids, -1 pad
    node_valid: np.ndarray  # (max_nodes,) bool
    edge_src: np.ndarray    # (max_edges,) local ids, pad points at 0
    edge_dst: np.ndarray
    edge_valid: np.ndarray  # (max_edges,) bool
    seed_local: np.ndarray  # (n_seeds,) local ids of the seeds


def sample_neighbors(g: Graph, seeds: np.ndarray, fanouts: Sequence[int],
                     *, rng: np.random.Generator) -> SampledSubgraph:
    """Layered uniform sampling. Local node 0..n_seeds-1 are the seeds."""
    local = {int(s): i for i, s in enumerate(seeds)}
    nodes: List[int] = list(map(int, seeds))
    e_src: List[int] = []
    e_dst: List[int] = []
    frontier = list(map(int, seeds))
    for f in fanouts:
        nxt: List[int] = []
        for u in frontier:
            lo, hi = g.indptr[u], g.indptr[u + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, deg)
            picks = rng.choice(g.indices[lo:hi], size=take,
                               replace=deg < f)
            for v in picks:
                v = int(v)
                if v not in local:
                    local[v] = len(nodes)
                    nodes.append(v)
                    nxt.append(v)
                e_src.append(local[v])
                e_dst.append(local[u])
        frontier = nxt

    max_nodes = len(seeds) * int(np.prod([f + 1 for f in fanouts]))
    max_edges = len(seeds) * int(np.sum(np.cumprod(fanouts)))
    node_ids = np.full((max_nodes,), -1, np.int64)
    node_ids[: len(nodes)] = nodes
    node_valid = node_ids >= 0
    es = np.zeros((max_edges,), np.int32)
    ed = np.zeros((max_edges,), np.int32)
    ev = np.zeros((max_edges,), bool)
    es[: len(e_src)] = e_src
    ed[: len(e_dst)] = e_dst
    ev[: len(e_src)] = True
    return SampledSubgraph(node_ids, node_valid, es, ed, ev,
                           np.arange(len(seeds), dtype=np.int32))


def make_molecule_batch(batch: int, n_nodes: int, n_edges: int, d_feat: int,
                        n_classes: int, *, seed: int = 0):
    """Batched small graphs packed into one disjoint union (molecule shape)."""
    rng = np.random.default_rng(seed)
    xs, srcs, dsts, gids, ys = [], [], [], [], []
    for b in range(batch):
        base = b * n_nodes
        x = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
        src = rng.integers(0, n_nodes, size=n_edges) + base
        dst = rng.integers(0, n_nodes, size=n_edges) + base
        y = int(rng.integers(0, n_classes))
        x[:, y % d_feat] += 1.5      # learnable signal
        xs.append(x)
        srcs.append(src)
        dsts.append(dst)
        gids.append(np.full(n_nodes, b, np.int32))
        ys.append(y)
    return (np.concatenate(xs), np.concatenate(srcs).astype(np.int32),
            np.concatenate(dsts).astype(np.int32), np.concatenate(gids),
            np.asarray(ys, np.int32))


__all__ = ["Graph", "make_community_graph", "SampledSubgraph",
           "sample_neighbors", "make_molecule_batch"]
