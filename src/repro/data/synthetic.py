"""Synthetic MovieLens-like CTR corpus with learnable latent-factor labels.

Why synthetic: the container is offline. Why learnable: the repro experiment
(Table 1 analog) needs AUC well above 0.5 so SW-vs-DTI quality differences
are measurable. Construction:

  item i   ~ latent z_i in R^f, plus a textual description whose words are
             deterministic functions of sign(z_i) buckets — the text fully
             identifies the latent (an LLM can in principle recover z from
             the words).
  user u   ~ latent p_u.
  rating   = quantised affinity (1..5) from p_u . z_i  (appears in the text,
             so context interactions reveal the user's preference direction)
  label    = Bernoulli(sigmoid(scale * p_u . z_i))     ('yes'/'no' target)

A model that reads the context interactions (items + ratings) can infer p_u
and predict the target's label — exactly the paper's task shape.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.dti import SpecialTokens
from repro.data.tokenizer import HashTokenizer

_ADJ = ["dark", "silent", "lost", "golden", "broken", "electric", "crimson",
        "frozen", "hidden", "iron", "lucky", "midnight", "neon", "paper",
        "quiet", "raging", "secret", "turbo", "velvet", "wild"]
_NOUN = ["river", "empire", "garden", "signal", "harbor", "mirror", "engine",
         "forest", "galaxy", "anthem", "circus", "desert", "echo", "fortune",
         "horizon", "island", "jungle", "kingdom", "lantern", "meadow"]
_GENRE = ["action", "comedy", "drama", "horror", "romance", "scifi",
          "thriller", "western"]


@dataclasses.dataclass
class CTRDataset:
    item_tokens: List[List[int]]          # token seq per item id
    item_latent: np.ndarray               # (I, f)
    sequences: List[Dict[str, np.ndarray]]  # per user: items, ratings, labels
    tokenizer: HashTokenizer
    avg_item_tokens: float

    def user_prompt_material(self, u: int) -> Tuple[List[List[int]], np.ndarray]:
        """-> (per-interaction token lists incl. rating token, labels)."""
        seq = self.sequences[u]
        toks = []
        for item, rating in zip(seq["items"], seq["ratings"]):
            t = list(self.item_tokens[item])
            t.append(self.tokenizer.token_id(f"rating={rating}"))
            toks.append(t)
        return toks, seq["labels"]


def make_ctr_dataset(*, n_users: int = 64, n_items: int = 400,
                     seq_len: int = 80, min_seq_len: int | None = None,
                     latent_dim: int = 4,
                     vocab_size: int = 2048, label_scale: float = 3.0,
                     seed: int = 0) -> CTRDataset:
    """``min_seq_len``: when set, per-user history lengths are drawn
    uniformly from [min_seq_len, seq_len] instead of all-equal — the
    long-tailed regime real CTR corpora live in (short histories + partial
    last-k groups are what segment packing reclaims)."""
    rng = np.random.default_rng(seed)
    tok = HashTokenizer(vocab_size)

    z = rng.normal(size=(n_items, latent_dim)) / np.sqrt(latent_dim)
    item_tokens: List[List[int]] = []
    for i in range(n_items):
        # words deterministically encode the latent's sign pattern + id hash
        buckets = (z[i] > 0).astype(int)
        adj = _ADJ[(i * 7 + buckets[0] * 10) % len(_ADJ)]
        noun = _NOUN[(i * 13 + buckets[1 % latent_dim] * 10) % len(_NOUN)]
        genre = _GENRE[int(buckets @ (2 ** np.arange(len(buckets)))) % len(_GENRE)]
        toks = [tok.sp.sep] + tok.encode(f"{adj} {noun} v{i}")
        toks.append(tok.token_id(f"genre={genre}"))
        item_tokens.append(toks)

    sequences = []
    for u in range(n_users):
        p = rng.normal(size=(latent_dim,)) / np.sqrt(latent_dim)
        m = (seq_len if min_seq_len is None
             else int(rng.integers(min_seq_len, seq_len + 1)))
        items = rng.integers(0, n_items, size=m)
        aff = z[items] @ p * label_scale
        probs = 1.0 / (1.0 + np.exp(-aff))
        labels = (rng.random(m) < probs).astype(np.int64)
        ratings = np.clip(np.round(2.5 + 1.5 * np.tanh(aff)), 1, 5).astype(int)
        sequences.append({"items": items, "ratings": ratings, "labels": labels})

    avg = float(np.mean([len(t) + 1 for t in item_tokens]))  # + rating token
    return CTRDataset(item_tokens, z, sequences, tok, avg)


def split_users(ds: CTRDataset, ratios=(0.8, 0.1, 0.1), seed: int = 1):
    """8:1:1 split along each user's timeline (paper's protocol)."""
    train, val, test = [], [], []
    for u in range(len(ds.sequences)):
        toks, labels = ds.user_prompt_material(u)
        m = len(toks)
        a, b = int(m * ratios[0]), int(m * (ratios[0] + ratios[1]))
        train.append((toks[:a], labels[:a]))
        val.append((toks[:b], labels[:b], a))     # context may reach back
        test.append((toks, labels, b))
    return train, val, test


__all__ = ["CTRDataset", "make_ctr_dataset", "split_users"]
