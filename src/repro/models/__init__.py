"""repro.models — model zoo: decoder LMs (GQA/MLA/MoE), GNN, recsys."""
from repro.models.transformer import (ModelConfig, count_params, forward,
                                      init_params, lm_logits)
