"""RecSys CTR models: MIND, xDeepFM, DIN, SASRec (assigned architectures).

All four share: sparse embedding tables (the hot path — see
``repro.sparse.embedding``), a feature-interaction op (the family signature),
and a small MLP head producing one logit. Pointwise sigmoid-BCE training.

DTI applicability (DESIGN.md §Arch-applicability): SASRec natively trains all
positions in parallel (the k=m limit of DTI); DIN gets a multi-target train
step (`din_forward_multi`) transplanting the paper's idea; MIND / xDeepFM are
non-sequential, implemented without DTI.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (Params, dense, init_layernorm, init_linear,
                                 init_mlp, layernorm, mlp, normal_init)
from repro.sparse.embedding import (embedding_lookup, field_lookup,
                                    init_field_tables, init_table)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str = "recsys"
    kind: str = "din"                     # mind | xdeepfm | din | sasrec
    embed_dim: int = 18
    n_items: int = 1_000_000
    seq_len: int = 100
    # xDeepFM
    field_vocabs: Tuple[int, ...] = ()
    cin_layers: Tuple[int, ...] = (200, 200, 200)
    dnn_dims: Tuple[int, ...] = (400, 400)
    # DIN
    attn_mlp: Tuple[int, ...] = (80, 40)
    head_mlp: Tuple[int, ...] = (200, 80)
    # SASRec
    n_blocks: int = 2
    n_heads: int = 1
    window: int = 0                       # 0 = full causal (DTI option: >0)
    # MIND
    n_interests: int = 4
    capsule_iters: int = 3
    param_dtype: str = "float32"

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


# ===========================================================================
# xDeepFM (arXiv:1803.05170) — CIN + DNN + linear
# ===========================================================================

def init_xdeepfm(rng, cfg: RecsysConfig) -> Params:
    ks = jax.random.split(rng, 6)
    m, d = len(cfg.field_vocabs), cfg.embed_dim
    p: Params = {
        "tables": init_field_tables(ks[0], cfg.field_vocabs, d, dtype=cfg.pdtype),
        "linear": init_field_tables(ks[1], cfg.field_vocabs, 1, dtype=cfg.pdtype),
        "dnn": init_mlp(ks[2], [m * d, *cfg.dnn_dims, 1], dtype=cfg.pdtype),
    }
    h_prev = m
    cin = {}
    for i, h in enumerate(cfg.cin_layers):
        cin[f"w{i}"] = normal_init(ks[3], (h, h_prev, m), (h_prev * m) ** -0.5,
                                   cfg.pdtype)
        h_prev = h
    p["cin"] = cin
    p["cin_out"] = init_linear(ks[4], sum(cfg.cin_layers), 1, bias=True,
                               dtype=cfg.pdtype)
    p["bias"] = jnp.zeros((), cfg.pdtype)
    return p


def xdeepfm_forward(p: Params, cfg: RecsysConfig, ids: jax.Array) -> jax.Array:
    """ids (B, F) -> logit (B,). CIN = outer-product + per-layer compress."""
    x0 = field_lookup(p["tables"], ids)                       # (B, m, D)
    b, m, d = x0.shape

    # linear term: one weight per (field, id)
    lin = field_lookup(p["linear"], ids).sum(axis=(1, 2))     # (B,)

    # CIN
    xk = x0
    pooled = []
    for i in range(len(cfg.cin_layers)):
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)               # (B,Hk,m,D)
        xk = jnp.einsum("bhmd,ohm->bod", z, p["cin"][f"w{i}"])
        pooled.append(xk.sum(axis=-1))                        # (B,Hi)
    cin_logit = dense(p["cin_out"], jnp.concatenate(pooled, axis=-1))[:, 0]

    dnn_logit = mlp(p["dnn"], x0.reshape(b, m * d))[:, 0]
    return lin + cin_logit + dnn_logit + p["bias"]


# ===========================================================================
# DIN (arXiv:1706.06978) — target attention over user history
# ===========================================================================

def init_din(rng, cfg: RecsysConfig) -> Params:
    ks = jax.random.split(rng, 4)
    d = cfg.embed_dim
    return {
        "items": init_table(ks[0], cfg.n_items, d, dtype=cfg.pdtype),
        "attn": init_mlp(ks[1], [4 * d, *cfg.attn_mlp, 1], dtype=cfg.pdtype),
        "head": init_mlp(ks[2], [3 * d, *cfg.head_mlp, 1], dtype=cfg.pdtype),
    }


def _din_attend(p: Params, h: jax.Array, t: jax.Array,
                valid: Optional[jax.Array]) -> jax.Array:
    """h (B,L,D) history embeds, t (B,K,D) targets -> (B,K,D) pooled."""
    b, l, d = h.shape
    k = t.shape[1]
    hh = jnp.broadcast_to(h[:, None], (b, k, l, d))
    tt = jnp.broadcast_to(t[:, :, None], (b, k, l, d))
    feats = jnp.concatenate([hh, tt, hh - tt, hh * tt], axis=-1)
    w = mlp(p["attn"], feats, act=jax.nn.sigmoid)[..., 0]     # (B,K,L)
    if valid is not None:
        w = jnp.where(valid[:, None, :], w, 0.0)
    return jnp.einsum("bkl,bld->bkd", w, h)                   # DIN: no softmax


def din_forward(p: Params, cfg: RecsysConfig, hist: jax.Array,
                target: jax.Array,
                valid: Optional[jax.Array] = None) -> jax.Array:
    """hist (B, L), target (B,) -> logit (B,)."""
    return din_forward_multi(p, cfg, hist, target[:, None], valid)[:, 0]


def din_forward_multi(p: Params, cfg: RecsysConfig, hist: jax.Array,
                      targets: jax.Array,
                      valid: Optional[jax.Array] = None) -> jax.Array:
    """DTI-transplant: k targets share one history embedding pass.

    hist (B, L), targets (B, K) -> logits (B, K). The history lookup +
    embedding gather (the dominant cost at embed_dim*L >> K) is done once
    instead of K times — the same redundancy-elimination the paper applies
    to LLM context encoding.
    """
    h = embedding_lookup(p["items"], hist)                    # (B,L,D)
    t = embedding_lookup(p["items"], targets)                 # (B,K,D)
    user = _din_attend(p, h, t, valid)                        # (B,K,D)
    x = jnp.concatenate([user, t, user * t], axis=-1)
    return mlp(p["head"], x)[..., 0]


# ===========================================================================
# SASRec (arXiv:1808.09781) — causal self-attention sequence model
# ===========================================================================

def init_sasrec(rng, cfg: RecsysConfig) -> Params:
    ks = jax.random.split(rng, 3 + 4 * cfg.n_blocks)
    d = cfg.embed_dim
    p: Params = {
        "items": init_table(ks[0], cfg.n_items, d, dtype=cfg.pdtype),
        "pos": init_table(ks[1], cfg.seq_len, d, scale=0.02, dtype=cfg.pdtype),
        "ln_f": init_layernorm(d, cfg.pdtype),
    }
    for i in range(cfg.n_blocks):
        k0, k1, k2, k3 = ks[3 + 4 * i: 7 + 4 * i]
        p[f"blk{i}"] = {
            "ln1": init_layernorm(d, cfg.pdtype),
            "ln2": init_layernorm(d, cfg.pdtype),
            "q": init_linear(k0, d, d, dtype=cfg.pdtype),
            "k": init_linear(k1, d, d, dtype=cfg.pdtype),
            "v": init_linear(k2, d, d, dtype=cfg.pdtype),
            "ffn": init_mlp(k3, [d, d, d], dtype=cfg.pdtype),
        }
    return p


def sasrec_encode(p: Params, cfg: RecsysConfig, hist: jax.Array,
                  valid: Optional[jax.Array] = None) -> jax.Array:
    """hist (B, L) -> hidden (B, L, D). Causal (optionally windowed) attn.

    SASRec is the k=m limit of DTI: every position is a training target in
    one parallel pass. ``cfg.window > 0`` aligns train/serve context length
    exactly as the paper's windowed causal attention does.
    """
    b, l = hist.shape
    d = cfg.embed_dim
    h = embedding_lookup(p["items"], hist) + p["pos"][None, :l]
    pos = jnp.arange(l)
    causal = pos[:, None] >= pos[None, :]
    if cfg.window > 0:
        causal &= (pos[:, None] - pos[None, :]) <= cfg.window
    mask = causal[None]
    if valid is not None:
        mask = mask & valid[:, None, :]
    nh = cfg.n_heads
    hd = d // nh
    for i in range(cfg.n_blocks):
        blk = p[f"blk{i}"]
        x = layernorm(blk["ln1"], h)
        q = dense(blk["q"], x).reshape(b, l, nh, hd)
        k = dense(blk["k"], x).reshape(b, l, nh, hd)
        v = dense(blk["v"], x).reshape(b, l, nh, hd)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd ** 0.5)
        s = jnp.where(mask[:, None], s, -1e30)
        a = jax.nn.softmax(s, axis=-1)
        h = h + jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, l, d)
        h = h + mlp(blk["ffn"], layernorm(blk["ln2"], h), final_act=False)
    return layernorm(p["ln_f"], h)


def sasrec_forward(p: Params, cfg: RecsysConfig, hist: jax.Array,
                   target: jax.Array,
                   valid: Optional[jax.Array] = None) -> jax.Array:
    """Pointwise CTR logit: dot(last hidden state, target embedding)."""
    h = sasrec_encode(p, cfg, hist, valid)[:, -1]             # (B,D)
    t = embedding_lookup(p["items"], target)                  # (B,D)
    return jnp.sum(h * t, axis=-1)


def sasrec_forward_all(p: Params, cfg: RecsysConfig, hist: jax.Array,
                       targets: jax.Array,
                       valid: Optional[jax.Array] = None) -> jax.Array:
    """All-position training (native DTI): targets (B, L) aligned next items."""
    h = sasrec_encode(p, cfg, hist, valid)                    # (B,L,D)
    t = embedding_lookup(p["items"], targets)
    return jnp.sum(h * t, axis=-1)                            # (B,L)


# ===========================================================================
# MIND (arXiv:1904.08030) — multi-interest dynamic routing capsules
# ===========================================================================

def init_mind(rng, cfg: RecsysConfig) -> Params:
    ks = jax.random.split(rng, 3)
    d = cfg.embed_dim
    return {
        "items": init_table(ks[0], cfg.n_items, d, dtype=cfg.pdtype),
        "s_matrix": normal_init(ks[1], (d, d), d ** -0.5, cfg.pdtype),
        "head": init_mlp(ks[2], [2 * d, 64, 1], dtype=cfg.pdtype),
    }


def _squash(x: jax.Array, axis: int = -1) -> jax.Array:
    n2 = jnp.sum(jnp.square(x), axis=axis, keepdims=True)
    return (n2 / (1 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def mind_interests(p: Params, cfg: RecsysConfig, hist: jax.Array,
                   valid: Optional[jax.Array] = None) -> jax.Array:
    """B2I dynamic routing: hist (B, L) -> interests (B, K, D)."""
    h = embedding_lookup(p["items"], hist)                    # (B,L,D)
    u = h @ p["s_matrix"]                                     # shared bilinear
    b_, l, d = u.shape
    k = cfg.n_interests
    # fixed (deterministic) logit init so routing is reproducible
    blogit = jnp.zeros((b_, k, l), u.dtype)
    interests = jnp.zeros((b_, k, d), u.dtype)
    vmask = None if valid is None else valid[:, None, :]
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(blogit, axis=1)                    # over interests
        if vmask is not None:
            w = jnp.where(vmask, w, 0.0)
        interests = _squash(jnp.einsum("bkl,bld->bkd", w, u))
        blogit = blogit + jnp.einsum("bkd,bld->bkl", interests, u)
    return interests


def mind_forward(p: Params, cfg: RecsysConfig, hist: jax.Array,
                 target: jax.Array,
                 valid: Optional[jax.Array] = None) -> jax.Array:
    """Label-aware max over interests -> MLP head -> logit (B,)."""
    interests = mind_interests(p, cfg, hist, valid)           # (B,K,D)
    t = embedding_lookup(p["items"], target)                  # (B,D)
    score = jnp.einsum("bkd,bd->bk", interests, t)
    att = jax.nn.softmax(score * 2.0, axis=-1)                # label-aware attn (pow~2)
    user = jnp.einsum("bk,bkd->bd", att, interests)
    x = jnp.concatenate([user, t], axis=-1)
    return mlp(p["head"], x)[..., 0]


def mind_retrieval(p: Params, cfg: RecsysConfig, hist: jax.Array,
                   cand_ids: jax.Array,
                   valid: Optional[jax.Array] = None) -> jax.Array:
    """retrieval_cand shape: one user vs n_candidates via batched dot.

    hist (1, L), cand_ids (C,) -> scores (C,). max over interests — no loop,
    one (K, D) x (D, C) matmul against the gathered candidate block.
    """
    interests = mind_interests(p, cfg, hist, valid)[0]        # (K,D)
    cand = embedding_lookup(p["items"], cand_ids)             # (C,D)
    return jnp.max(interests @ cand.T, axis=0)                # (C,)


# ---------------------------------------------------------------------------
# dispatch helpers
# ---------------------------------------------------------------------------

INIT = {"mind": init_mind, "xdeepfm": init_xdeepfm, "din": init_din,
        "sasrec": init_sasrec}


def init_recsys(rng, cfg: RecsysConfig) -> Params:
    return INIT[cfg.kind](rng, cfg)


def recsys_logits(p: Params, cfg: RecsysConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    if cfg.kind == "xdeepfm":
        return xdeepfm_forward(p, cfg, batch["ids"])
    if cfg.kind == "din":
        return din_forward(p, cfg, batch["hist"], batch["target"],
                           batch.get("valid"))
    if cfg.kind == "sasrec":
        return sasrec_forward(p, cfg, batch["hist"], batch["target"],
                              batch.get("valid"))
    if cfg.kind == "mind":
        return mind_forward(p, cfg, batch["hist"], batch["target"],
                            batch.get("valid"))
    raise ValueError(cfg.kind)


def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    z = logits.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


__all__ = ["RecsysConfig", "init_recsys", "recsys_logits", "bce_loss",
           "xdeepfm_forward", "din_forward", "din_forward_multi",
           "sasrec_forward", "sasrec_forward_all", "sasrec_encode",
           "mind_forward", "mind_interests", "mind_retrieval"]
