"""Mixture-of-Experts FFN: shared experts + routed top-k with capacity dispatch.

Dispatch is the gather/scatter formulation (not the GShard dense-one-hot
einsum): tokens are gathered into per-expert capacity slots via indices built
from a token->expert cumsum, experts run as one batched einsum, and results
scatter back weighted by the gate. This wastes zero FLOPs on non-routed pairs
(the one-hot formulation costs O(T * E * C * d) in pure dispatch matmuls) and
under GSPMD the gather lowers to activation all-gathers along the expert axis,
which the roofline pass accounts as collective bytes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense, init_linear, init_swiglu, swiglu


def init_moe(rng, d_model: int, *, n_experts: int, moe_d_ff: int, top_k: int,
             n_shared: int = 0, shared_d_ff: Optional[int] = None,
             dtype=jnp.float32, lora_rank: int = 0) -> Params:
    kr, ke, ks = jax.random.split(rng, 3)
    # experts as stacked weights (E, d, f) so they shard over the expert axis
    kge, kue, kde = jax.random.split(ke, 3)
    scale = d_model ** -0.5
    p: Params = {
        "router": init_linear(kr, d_model, n_experts, dtype=jnp.float32),
        "w_gate": scale * jax.random.normal(kge, (n_experts, d_model, moe_d_ff)),
        "w_up": scale * jax.random.normal(kue, (n_experts, d_model, moe_d_ff)),
        "w_down": (moe_d_ff ** -0.5) * jax.random.normal(kde, (n_experts, moe_d_ff, d_model)),
    }
    p["w_gate"] = p["w_gate"].astype(dtype)
    p["w_up"] = p["w_up"].astype(dtype)
    p["w_down"] = p["w_down"].astype(dtype)
    if n_shared > 0:
        sdf = shared_d_ff or moe_d_ff
        p["shared"] = init_swiglu(ks, d_model, n_shared * sdf, dtype=dtype,
                                  lora_rank=lora_rank)
    return p


def _topk_gates(logits: jax.Array, top_k: int, norm_topk: bool):
    """(T, E) fp32 -> gates (T, k), expert ids (T, k)."""
    gates, ids = jax.lax.top_k(logits, top_k)          # (T,k)
    gates = jax.nn.softmax(gates, axis=-1) if norm_topk else \
        jnp.take_along_axis(jax.nn.softmax(logits, axis=-1), ids, axis=-1)
    return gates, ids


def moe_ffn(p: Params, x: jax.Array, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25, norm_topk: bool = True,
            aux_loss_coef: float = 0.001):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = dense(p["router"], xt.astype(jnp.float32))          # (T, E)
    gates, ids = _topk_gates(logits, top_k, norm_topk)           # (T, k)

    # ---- capacity-slot assignment -------------------------------------
    cap = max(1, int(capacity_factor * t * top_k / n_experts))
    onehot = jax.nn.one_hot(ids, n_experts, dtype=jnp.int32)     # (T,k,E)
    flat = onehot.reshape(t * top_k, n_experts)
    pos_in_e = jnp.cumsum(flat, axis=0) * flat - 1               # (T*k, E)
    pos = pos_in_e.max(axis=-1)                                  # (T*k,)
    eid = ids.reshape(t * top_k)
    keep = (pos >= 0) & (pos < cap)
    slot = jnp.where(keep, eid * cap + pos, t * 0 + n_experts * cap)  # drop slot

    token_of_slot = jnp.full((n_experts * cap + 1,), 0, jnp.int32)
    token_of_slot = token_of_slot.at[slot].set(
        jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k))
    slot_used = jnp.zeros((n_experts * cap + 1,), bool).at[slot].set(keep)
    token_of_slot, slot_used = token_of_slot[:-1], slot_used[:-1]

    # ---- expert compute ------------------------------------------------
    xe = jnp.take(xt, token_of_slot, axis=0).reshape(n_experts, cap, d)
    xe = xe * slot_used.reshape(n_experts, cap, 1).astype(xe.dtype)
    h_g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h_u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h_g * h_u, p["w_down"])      # (E,cap,d)

    # ---- combine back --------------------------------------------------
    # scatter-add each slot's output (pre-scaled by its gate) straight into
    # (T, d): the (T, top_k, d) gather intermediate this replaces costs
    # T*k*d bytes (4 GiB/device at 1M tokens for deepseek-v2) for zero
    # extra information.
    yflat = ye.reshape(n_experts * cap, d)
    gate_flat = (gates.reshape(t * top_k) * keep).astype(yflat.dtype)
    gate_of_slot = jnp.zeros((n_experts * cap + 1,), yflat.dtype
                             ).at[slot].set(gate_flat)[:-1]
    out = jnp.zeros((t, d), yflat.dtype).at[token_of_slot].add(
        yflat * (gate_of_slot * slot_used.astype(yflat.dtype))[:, None])
    # GSPMD replicates data-dependent scatter outputs — re-pin to the token
    # sharding or every MoE layer materialises a full (T, d) copy per device
    # (86 GiB at 1M tokens for qwen2-moe prefill; §Perf log)
    from repro.sharding.act import constrain_tokens
    out = constrain_tokens(out)

    if "shared" in p:
        out = out + swiglu(p["shared"], xt)

    # ---- load-balance auxiliary loss (Switch, arXiv:2101.03961) --------
    probs = jax.nn.softmax(logits, axis=-1)                      # (T,E)
    frac_tokens = onehot.sum(axis=(0, 1)).astype(jnp.float32) / (t * top_k)
    frac_probs = probs.mean(axis=0)
    aux = aux_loss_coef * n_experts * jnp.sum(frac_tokens * frac_probs)

    return out.reshape(b, s, d), aux


__all__ = ["init_moe", "moe_ffn"]
