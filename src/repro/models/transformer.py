"""Decoder-only transformer with ``lax.scan`` over stacked layer params.

One model covers every assigned LM arch: GQA (minicpm-2b, qwen2-1.5b), MLA
(minicpm3-4b, deepseek-v2-236b), MoE (qwen2-moe-a2.7b, deepseek-v2-236b), plus
the paper's own DTI-Llama configuration. DTI training features (streaming
prompts / windowed attention / SUM loss / reset / SUM-ALiBi) are enabled per
forward call via ``DTIAttnOpts`` so the same weights serve both paradigms.

Scan-over-layers keeps the lowered HLO O(1) in depth, which is what makes the
512-device dry-run compiles tractable; it also gives remat a natural unit.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.windowed import ResetConfig
from repro.models import attention as attn_mod
from repro.models.attention import DTIAttnOpts, gqa_attention, init_gqa, init_mla, mla_attention
from repro.models.layers import (Params, dense, init_linear, init_rmsnorm,
                                 init_swiglu, normal_init, rmsnorm, swiglu)
from repro.models.moe import init_moe, moe_ffn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 32000
    head_dim: Optional[int] = None
    attn_type: str = "gqa"              # "gqa" | "mla"
    qkv_bias: bool = False
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_d_ff: Optional[int] = None
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    norm_topk: bool = True
    # positional / attention
    rope_theta: float = 10000.0
    window: int = 0                     # 0 = full causal
    attn_impl: str = "dense"            # "dense" | "blocked" | "pallas"
    attn_q_chunk: int = 4               # q-block chunking (blocked impl)
    # pallas kernel tile; None = autotuned (repro.kernels.autotune)
    attn_block_size: Optional[int] = None
    # DTI
    dti_sum_token: bool = False         # model reserves a [SUM] token
    dti_sum_alibi: bool = True
    dti_sum_isolated: bool = True
    dti_reset: bool = True
    reset_y_min: float = 0.0
    reset_y_max: float = 0.3
    # training
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    lora_rank: int = 0
    remat: bool = True
    remat_policy: str = "nothing"       # "nothing" | "dots" | "none"
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    logits_chunk: int = 0               # 0 = unchunked LM loss

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_kind(self, i: int) -> str:
        if self.moe and i >= self.first_dense_layers:
            return "moe"
        return "dense"

    def reset_config(self, window_tokens: int) -> Optional[ResetConfig]:
        if not self.dti_reset:
            return None
        return ResetConfig(self.reset_y_min, self.reset_y_max,
                           midpoint=window_tokens / 2.0)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(rng, cfg: ModelConfig, kind: str) -> Params:
    ka, kf = jax.random.split(rng)
    if cfg.attn_type == "mla":
        attn = init_mla(ka, cfg.d_model, cfg.n_heads,
                        q_lora_rank=cfg.q_lora_rank, kv_lora_rank=cfg.kv_lora_rank,
                        qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
                        v_head_dim=cfg.v_head_dim, dtype=cfg.pdtype,
                        lora_rank=cfg.lora_rank)
    else:
        attn = init_gqa(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                        qkv_bias=cfg.qkv_bias, dtype=cfg.pdtype,
                        lora_rank=cfg.lora_rank)
    if kind == "moe":
        ffn = init_moe(kf, cfg.d_model, n_experts=cfg.n_experts,
                       moe_d_ff=cfg.moe_d_ff, top_k=cfg.top_k,
                       n_shared=cfg.n_shared_experts, shared_d_ff=cfg.shared_d_ff,
                       dtype=cfg.pdtype, lora_rank=cfg.lora_rank)
    else:
        ffn = init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype=cfg.pdtype,
                          lora_rank=cfg.lora_rank)
    return {"attn": attn, "ffn": ffn,
            "ln_attn": init_rmsnorm(cfg.d_model, cfg.pdtype),
            "ln_ffn": init_rmsnorm(cfg.d_model, cfg.pdtype)}


def init_params(rng, cfg: ModelConfig) -> Params:
    ke, kh, *kl = jax.random.split(rng, 2 + cfg.n_layers)
    p: Params = {"embed": normal_init(ke, (cfg.vocab_size, cfg.d_model), 0.02,
                                      cfg.pdtype),
                 "ln_f": init_rmsnorm(cfg.d_model, cfg.pdtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = init_linear(kh, cfg.d_model, cfg.vocab_size,
                                   scale=0.02, dtype=cfg.pdtype)
    n_dense_pre = cfg.first_dense_layers if cfg.moe else 0
    if n_dense_pre:
        p["prefix"] = _stack([_init_layer(kl[i], cfg, "dense")
                              for i in range(n_dense_pre)])
    kind = "moe" if cfg.moe else "dense"
    p["stack"] = _stack([_init_layer(kl[i], cfg, kind)
                         for i in range(n_dense_pre, cfg.n_layers)])
    return p


def _stack(layers):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_fwd(lp: Params, h: jax.Array, cfg: ModelConfig, kind: str, *,
               positions, window, impl, dti: Optional[DTIAttnOpts],
               valid, cache=None):
    x = rmsnorm(lp["ln_attn"], h, cfg.norm_eps)
    if cfg.attn_block_size is not None:
        block_size = cfg.attn_block_size
    else:
        from repro.kernels.autotune import train_block
        block_size = train_block(x.shape[1], cfg.hd)
    if cfg.attn_type == "mla":
        a, new_cache = mla_attention(
            lp["attn"], x, n_heads=cfg.n_heads, qk_nope_dim=cfg.qk_nope_dim,
            qk_rope_dim=cfg.qk_rope_dim, v_head_dim=cfg.v_head_dim,
            positions=positions, window=window, rope_theta=cfg.rope_theta,
            impl=impl, q_chunk=cfg.attn_q_chunk,
            block_size=block_size, dti=dti, cache=cache,
            valid=valid)
    else:
        a, new_cache = gqa_attention(
            lp["attn"], x, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, positions=positions, window=window,
            rope_theta=cfg.rope_theta, impl=impl, q_chunk=cfg.attn_q_chunk,
            block_size=block_size, dti=dti, cache=cache,
            valid=valid)
    h = h + a
    x = rmsnorm(lp["ln_ffn"], h, cfg.norm_eps)
    if kind == "moe":
        f, aux = moe_ffn(lp["ffn"], x, n_experts=cfg.n_experts, top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor,
                         norm_topk=cfg.norm_topk)
    else:
        f, aux = swiglu(lp["ffn"], x), jnp.zeros((), jnp.float32)
    return h + f, aux, new_cache


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            positions: Optional[jax.Array] = None,
            is_sum: Optional[jax.Array] = None,
            valid: Optional[jax.Array] = None,
            segment_ids: Optional[jax.Array] = None,
            seg_shared: Optional[int] = None,
            dti_enabled: bool = False,
            window: Optional[int] = None,
            caches: Optional[list] = None,
            return_hidden: bool = False,
            ) -> Dict[str, Any]:
    """Run the decoder. Returns dict with 'hidden', 'aux_loss', 'caches'.

    ``segment_ids`` (packed rows, -1 on padding) enforce cross-segment
    isolation in every attention layer; positions are expected to restart
    per segment so RoPE/window/ALiBi/reset distances stay per-prompt.

    ``seg_shared`` marks one segment id (the user context of a multi-target
    serving row) as a shared prefix every other segment may attend;
    candidate segments keep positions continuing after the context instead
    of restarting. Dense attention path only.

    Logits are NOT materialised here — call ``lm_logits`` / the loss fns, so
    CTR training can touch only the two label rows of the vocab matrix.
    """
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    win = cfg.window if window is None else window
    impl = cfg.attn_impl

    from repro.sharding.act import constrain_tokens
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    h = constrain_tokens(h)
    h0 = h

    dti: Optional[DTIAttnOpts] = None
    if (dti_enabled and is_sum is not None) or segment_ids is not None:
        use_sum = dti_enabled and is_sum is not None
        dti = DTIAttnOpts(is_sum=is_sum if use_sum else None, h0=h0,
                          reset=(cfg.reset_config(win)
                                 if use_sum and cfg.dti_reset else None),
                          sum_alibi=cfg.dti_sum_alibi,
                          sum_isolated=cfg.dti_sum_isolated,
                          segment_ids=segment_ids, seg_shared=seg_shared)

    aux_total = jnp.zeros((), jnp.float32)
    new_caches: list = []
    cache_i = 0

    def run_group(h, group: Params, kind: str, aux_total, cache_i):
        nonlocal new_caches
        if caches is not None:
            # decode path: python loop (cache pytrees per layer)
            n = jax.tree_util.tree_leaves(group)[0].shape[0]
            for i in range(n):
                lp = jax.tree_util.tree_map(lambda x: x[i], group)
                h, aux, nc = _layer_fwd(lp, h, cfg, kind, positions=positions,
                                        window=win, impl="dense", dti=dti,
                                        valid=valid, cache=caches[cache_i])
                new_caches.append(nc)
                aux_total = aux_total + aux
                cache_i += 1
            return h, aux_total, cache_i

        def body(carry, lp):
            h, aux_acc = carry
            h, aux, _ = _layer_fwd(lp, h, cfg, kind, positions=positions,
                                   window=win, impl=impl, dti=dti, valid=valid)
            # layer-boundary activation pinning (no-op off-mesh):
            # token-sharded residual stream, features replicated
            h = constrain_tokens(h)
            return (h, aux_acc + aux), None

        if cfg.remat and cfg.remat_policy != "none":
            # "nothing": save only the scan carry per layer (recompute all
            # intermediates in bwd) — the memory-lean default at seq 4k.
            # "dots": save weight-stationary matmul outputs (recompute only
            # attention) — faster bwd, ~8x the activation footprint.
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots" else
                      jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(body, policy=policy)
        (h, aux_total), _ = jax.lax.scan(body, (h, aux_total), group)
        return h, aux_total, cache_i

    if "prefix" in params:
        h, aux_total, cache_i = run_group(h, params["prefix"], "dense",
                                          aux_total, cache_i)
    kind = "moe" if cfg.moe else "dense"
    h, aux_total, cache_i = run_group(h, params["stack"], kind, aux_total, cache_i)

    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    out: Dict[str, Any] = {"hidden": h, "aux_loss": aux_total}
    if caches is not None:
        out["caches"] = new_caches
    return out


def lm_logits(params: Params, cfg: ModelConfig, hidden: jax.Array,
              rows: Optional[jax.Array] = None) -> jax.Array:
    """hidden @ vocab. ``rows`` selects a subset of vocab rows (e.g. yes/no)."""
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]["w"].T
    # w: (V, d) either way after this
    if not cfg.tie_embeddings:
        w = params["lm_head"]["w"].T
    if rows is not None:
        w = jnp.take(w, rows, axis=0)
    return jnp.einsum("...d,vd->...v", hidden, w.astype(hidden.dtype))


def count_params(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, "size"))


__all__ = ["ModelConfig", "init_params", "forward", "lm_logits", "count_params"]
