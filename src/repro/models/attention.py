"""Attention projection modules: GQA (Llama/Qwen-style) and MLA (DeepSeek-style).

These own the parameter layout + RoPE application and delegate score/value
math to ``repro.core.windowed`` so every DTI semantic (window, SUM isolation,
SUM-NoPE+ALiBi, hidden-state reset) lives in exactly one place.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.windowed import ResetConfig, attention
from repro.models.layers import (Params, alibi_slopes, apply_rope, dense,
                                 init_linear, init_rmsnorm, rmsnorm)


@dataclasses.dataclass(frozen=True)
class DTIAttnOpts:
    """Per-call DTI context threaded through the transformer."""
    is_sum: Optional[jax.Array] = None      # (B, S) bool
    h0: Optional[jax.Array] = None          # (B, S, d) initial hidden states
    reset: Optional[ResetConfig] = None
    sum_alibi: bool = True                  # NoPE + ALiBi on SUM rows
    sum_isolated: bool = True
    segment_ids: Optional[jax.Array] = None  # (B, S) int32 packed segments
    seg_shared: Optional[int] = None        # shared-prefix segment id
                                            # (multi-target serving rows)


def _seg_kwargs(kw: Dict[str, Any], dti: Optional["DTIAttnOpts"],
                cache) -> None:
    """Thread packed-row segment ids into the attention mask operands."""
    if dti is None:
        return
    if dti.segment_ids is None:
        assert dti.seg_shared is None, (
            "seg_shared (shared-prefix rows) requires segment_ids")
        return
    if cache is not None:
        raise NotImplementedError(
            "packed segments are a prefill-side feature (no decode cache)")
    kw["seg_q"] = dti.segment_ids
    kw["seg_k"] = dti.segment_ids
    if dti.seg_shared is not None:
        kw["seg_shared"] = dti.seg_shared


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(rng, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
             *, qkv_bias: bool = False, dtype=jnp.float32, lora_rank: int = 0) -> Params:
    kq, kk, kv, ko = jax.random.split(rng, 4)
    return {
        "q": init_linear(kq, d_model, n_heads * head_dim, bias=qkv_bias,
                         dtype=dtype, lora_rank=lora_rank),
        "k": init_linear(kk, d_model, n_kv_heads * head_dim, bias=qkv_bias,
                         dtype=dtype, lora_rank=lora_rank),
        "v": init_linear(kv, d_model, n_kv_heads * head_dim, bias=qkv_bias,
                         dtype=dtype, lora_rank=lora_rank),
        "o": init_linear(ko, n_heads * head_dim, d_model, dtype=dtype,
                         lora_rank=lora_rank),
    }


def gqa_attention(p: Params, x: jax.Array, *, n_heads: int, n_kv_heads: int,
                  head_dim: int, positions: jax.Array, window: int,
                  rope_theta: float, impl: str, q_chunk: int = 4,
                  block_size: int = 256,
                  dti: Optional[DTIAttnOpts] = None,
                  cache: Optional[Dict[str, jax.Array]] = None,
                  valid: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B, S, d). Returns (out, updated_cache)."""
    b, s, _ = x.shape
    q = dense(p["q"], x).reshape(b, s, n_heads, head_dim)
    k = dense(p["k"], x).reshape(b, s, n_kv_heads, head_dim)
    v = dense(p["v"], x).reshape(b, s, n_kv_heads, head_dim)

    q_rot = apply_rope(q, positions, rope_theta)
    k_rot = apply_rope(k, positions, rope_theta)

    kw: Dict[str, Any] = {}
    if dti is not None and dti.is_sum is not None:
        kw["is_sum_q"] = dti.is_sum
        kw["is_sum_k"] = dti.is_sum
        kw["sum_isolated"] = dti.sum_isolated
        if dti.sum_alibi:
            kw["q_nope"], kw["k_nope"] = q, k
            kw["alibi"] = alibi_slopes(n_heads)
        if dti.reset is not None and dti.h0 is not None:
            kw["v0"] = dense(p["v"], dti.h0).reshape(b, s, n_kv_heads, head_dim)
            kw["reset"] = dti.reset
    _seg_kwargs(kw, dti, cache)

    new_cache = None
    if cache is not None:
        k_rot, v, pos_k, valid_k, new_cache = _update_cache(cache, k_rot, v, positions)
        if "k_nope" in kw:
            raise NotImplementedError("DTI SUM rows are a training-time feature")
        out = attention("dense", q_rot, k_rot, v, pos_q=positions, pos_k=pos_k,
                        window=window, valid_k=valid_k, **kw)
    else:
        if impl == "blocked":
            kw["q_chunk"] = q_chunk
        elif impl == "pallas":
            kw["block_size"] = block_size
        out = attention(impl, q_rot, k_rot, v, pos_q=positions, pos_k=positions,
                        window=window, valid_k=valid, **kw)

    out = dense(p["o"], out.reshape(b, s, n_heads * head_dim))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention, arXiv:2405.04434)
# ---------------------------------------------------------------------------

def init_mla(rng, d_model: int, n_heads: int, *, q_lora_rank: int,
             kv_lora_rank: int, qk_nope_dim: int, qk_rope_dim: int,
             v_head_dim: int, dtype=jnp.float32, lora_rank: int = 0) -> Params:
    ks = jax.random.split(rng, 8)
    qk_head = qk_nope_dim + qk_rope_dim
    p: Params = {
        "kv_down": init_linear(ks[0], d_model, kv_lora_rank, dtype=dtype),
        "kv_norm": init_rmsnorm(kv_lora_rank, dtype),
        "kv_up": init_linear(ks[1], kv_lora_rank,
                             n_heads * (qk_nope_dim + v_head_dim), dtype=dtype,
                             lora_rank=lora_rank),
        "k_rope": init_linear(ks[2], d_model, qk_rope_dim, dtype=dtype),
        "o": init_linear(ks[3], n_heads * v_head_dim, d_model, dtype=dtype,
                         lora_rank=lora_rank),
    }
    if q_lora_rank > 0:
        p["q_down"] = init_linear(ks[4], d_model, q_lora_rank, dtype=dtype)
        p["q_norm"] = init_rmsnorm(q_lora_rank, dtype)
        p["q_up"] = init_linear(ks[5], q_lora_rank, n_heads * qk_head,
                                dtype=dtype, lora_rank=lora_rank)
    else:
        p["q"] = init_linear(ks[6], d_model, n_heads * qk_head, dtype=dtype,
                             lora_rank=lora_rank)
    return p


def _mla_qkv(p: Params, x: jax.Array, *, n_heads: int, qk_nope_dim: int,
             qk_rope_dim: int, v_head_dim: int, positions: jax.Array,
             rope_theta: float):
    """Project x -> (q, k, v, q_nope_full, k_nope_full)."""
    b, s, _ = x.shape
    if "q_down" in p:
        qc = rmsnorm(p["q_norm"], dense(p["q_down"], x))
        q = dense(p["q_up"], qc)
    else:
        q = dense(p["q"], x)
    q = q.reshape(b, s, n_heads, qk_nope_dim + qk_rope_dim)
    q_nope, q_pe = q[..., :qk_nope_dim], q[..., qk_nope_dim:]
    q_pe_rot = apply_rope(q_pe, positions, rope_theta)

    c_kv = rmsnorm(p["kv_norm"], dense(p["kv_down"], x))       # (B,S,r_kv)
    kv = dense(p["kv_up"], c_kv).reshape(b, s, n_heads, qk_nope_dim + v_head_dim)
    k_nope, v = kv[..., :qk_nope_dim], kv[..., qk_nope_dim:]
    k_pe = dense(p["k_rope"], x).reshape(b, s, 1, qk_rope_dim)  # shared head
    k_pe_rot = apply_rope(k_pe, positions, rope_theta)
    k_pe_rot = jnp.broadcast_to(k_pe_rot, (b, s, n_heads, qk_rope_dim))
    k_pe_b = jnp.broadcast_to(k_pe, (b, s, n_heads, qk_rope_dim))

    q_full = jnp.concatenate([q_nope, q_pe_rot], axis=-1)
    k_full = jnp.concatenate([k_nope, k_pe_rot], axis=-1)
    # "NoPE" variants for DTI SUM rows: identity rotation on the rope slice.
    q_nope_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    k_nope_full = jnp.concatenate([k_nope, k_pe_b], axis=-1)
    return q_full, k_full, v, q_nope_full, k_nope_full, c_kv


def mla_attention(p: Params, x: jax.Array, *, n_heads: int, qk_nope_dim: int,
                  qk_rope_dim: int, v_head_dim: int, positions: jax.Array,
                  window: int, rope_theta: float, impl: str, q_chunk: int = 4,
                  block_size: int = 256,
                  dti: Optional[DTIAttnOpts] = None,
                  cache: Optional[Dict[str, jax.Array]] = None,
                  valid: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    b, s, _ = x.shape
    qk_head = qk_nope_dim + qk_rope_dim
    q, k, v, q_np, k_np, _ = _mla_qkv(
        p, x, n_heads=n_heads, qk_nope_dim=qk_nope_dim, qk_rope_dim=qk_rope_dim,
        v_head_dim=v_head_dim, positions=positions, rope_theta=rope_theta)

    kw: Dict[str, Any] = {"scale": qk_head ** -0.5}
    if dti is not None and dti.is_sum is not None:
        kw["is_sum_q"] = dti.is_sum
        kw["is_sum_k"] = dti.is_sum
        kw["sum_isolated"] = dti.sum_isolated
        if dti.sum_alibi:
            kw["q_nope"], kw["k_nope"] = q_np, k_np
            kw["alibi"] = alibi_slopes(n_heads)
        if dti.reset is not None and dti.h0 is not None:
            _, _, v0, _, _, _ = _mla_qkv(
                p, dti.h0, n_heads=n_heads, qk_nope_dim=qk_nope_dim,
                qk_rope_dim=qk_rope_dim, v_head_dim=v_head_dim,
                positions=positions, rope_theta=rope_theta)
            kw["v0"] = v0
            kw["reset"] = dti.reset
    _seg_kwargs(kw, dti, cache)

    new_cache = None
    if cache is not None:
        k, v, pos_k, valid_k, new_cache = _update_cache(cache, k, v, positions)
        out = attention("dense", q, k, v, pos_q=positions, pos_k=pos_k,
                        window=window, valid_k=valid_k, **kw)
    else:
        if impl == "blocked":
            kw["q_chunk"] = q_chunk
        elif impl == "pallas":
            kw["block_size"] = block_size
        out = attention(impl, q, k, v, pos_q=positions, pos_k=positions,
                        window=window, valid_k=valid, **kw)

    out = dense(p["o"], out.reshape(b, s, n_heads * v_head_dim))
    return out, new_cache


# ---------------------------------------------------------------------------
# KV cache (full + windowed ring buffer)
# ---------------------------------------------------------------------------

def init_cache(batch: int, capacity: int, n_kv_heads: int, k_dim: int,
               v_dim: int, *, ring: bool, dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """A decode cache. ``ring=True`` -> fixed window ring buffer whose size is
    independent of the logical sequence length (what makes ``long_500k``
    decode O(window) — a direct corollary of DTI's windowed attention)."""
    return {
        "k": jnp.zeros((batch, capacity, n_kv_heads, k_dim), dtype),
        "v": jnp.zeros((batch, capacity, n_kv_heads, v_dim), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
        "cursor": jnp.zeros((batch,), jnp.int32),
        "ring": jnp.asarray(ring),
    }


def _update_cache(cache, k_new, v_new, positions):
    """Insert S_new entries; returns (k_all, v_all, pos_k, valid_k, new_cache).

    Ring mode wraps the write cursor; full mode requires cursor+S <= capacity.
    """
    b, s_new = positions.shape
    cap = cache["k"].shape[1]
    idx = (cache["cursor"][:, None] + jnp.arange(s_new)[None, :])
    idx = jnp.where(cache["ring"], idx % cap, idx)
    bidx = jnp.arange(b)[:, None]
    k = cache["k"].at[bidx, idx].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[bidx, idx].set(v_new.astype(cache["v"].dtype))
    pos = cache["pos"].at[bidx, idx].set(positions)
    new_cache = {"k": k, "v": v, "pos": pos,
                 "cursor": cache["cursor"] + s_new, "ring": cache["ring"]}
    valid = pos >= 0
    return k, v, pos, valid, new_cache


__all__ = ["DTIAttnOpts", "init_gqa", "gqa_attention", "init_mla",
           "mla_attention", "init_cache"]
