"""Shared neural-net building blocks (pure-functional, pytree params).

Every module here follows the same convention:
  init_*(rng, ...) -> params pytree of jnp arrays
  apply fn(params, x, ...) -> output

Params are plain dicts so they stack cleanly under ``jax.lax.scan`` (layer
stacking) and shard cleanly under GSPMD (leaf-path -> PartitionSpec rules in
``repro.sharding.partition``).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def normal_init(rng, shape, scale: float, dtype=jnp.float32):
    return (scale * jax.random.normal(rng, shape, dtype=jnp.float32)).astype(dtype)


def init_linear(rng, d_in: int, d_out: int, *, bias: bool = False,
                scale: Optional[float] = None, dtype=jnp.float32,
                lora_rank: int = 0, lora_alpha: float = 16.0) -> Params:
    """A linear layer, optionally with a LoRA adapter (A: d_in x r, B: r x d_out).

    LoRA follows arXiv:2106.09685: W_eff = W + (alpha / r) * A @ B, with A
    gaussian-initialised and B zero-initialised so training starts at W.
    """
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    k_w, k_a = jax.random.split(rng)
    p: Params = {"w": normal_init(k_w, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    if lora_rank > 0:
        p["lora_a"] = normal_init(k_a, (d_in, lora_rank), 1.0 / math.sqrt(d_in), dtype)
        p["lora_b"] = jnp.zeros((lora_rank, d_out), dtype)
        p["lora_scale"] = jnp.asarray(lora_alpha / lora_rank, dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    """Apply a (possibly LoRA-augmented) linear layer."""
    y = x @ p["w"]
    if "lora_a" in p:
        y = y + (x @ p["lora_a"]) @ p["lora_b"] * p["lora_scale"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# normalisation
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(rng, d_model: int, d_ff: int, *, dtype=jnp.float32,
                lora_rank: int = 0) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "gate": init_linear(k1, d_model, d_ff, dtype=dtype, lora_rank=lora_rank),
        "up": init_linear(k2, d_model, d_ff, dtype=dtype, lora_rank=lora_rank),
        "down": init_linear(k3, d_ff, d_model, dtype=dtype, lora_rank=lora_rank),
    }


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    from repro.sharding.act import constrain_tokens
    h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    return dense(p["down"], constrain_tokens(h, kind="ffn"))


def init_mlp(rng, dims: Sequence[int], *, bias: bool = True, dtype=jnp.float32) -> Params:
    """Plain MLP used by recsys / GNN heads: dims = [in, h1, ..., out]."""
    keys = jax.random.split(rng, len(dims) - 1)
    return {f"fc{i}": init_linear(keys[i], dims[i], dims[i + 1], bias=bias, dtype=dtype)
            for i in range(len(dims) - 1)}


def mlp(p: Params, x: jax.Array, *, act=jax.nn.relu, final_act: bool = False) -> jax.Array:
    n = len(p)
    for i in range(n):
        x = dense(p[f"fc{i}"], x)
        if i < n - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# positional encodings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """Inverse frequencies for RoPE (arXiv:2104.09864)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotate ``x`` [..., S, H, D] by ``positions`` [..., S] (RoPE).

    Uses the (x1, x2) half-split convention (Llama / NeoX style).
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                      # (D/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def alibi_slopes(n_heads: int) -> jax.Array:
    """Standard geometric ALiBi slopes (arXiv:2108.12409)."""
    def pow2_slopes(n):
        start = 2.0 ** (-(2.0 ** -(math.log2(n) - 3)))
        return [start * (start ** i) for i in range(n)]
    if math.log2(n_heads).is_integer():
        s = pow2_slopes(n_heads)
    else:
        closest = 2 ** math.floor(math.log2(n_heads))
        s = pow2_slopes(closest)
        extra = pow2_slopes(2 * closest)[0::2][: n_heads - closest]
        s = s + extra
    return jnp.asarray(s, jnp.float32)


__all__ = [
    "Params", "init_linear", "dense", "init_rmsnorm", "rmsnorm",
    "init_layernorm", "layernorm", "init_swiglu", "swiglu", "init_mlp", "mlp",
    "rope_freqs", "apply_rope", "alibi_slopes", "normal_init",
]
