"""GIN (arXiv:1810.00826) with segment_sum message passing.

JAX sparse is BCOO-only, so message passing is built directly on the
edge-index -> node scatter primitive: gather source features, segment_sum
into destinations. Supports full-graph, sampled-minibatch (see
``repro.data.sampler``), and batched small molecules (graph_ids readout).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Params, init_linear, init_mlp, dense, mlp


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_feat: int = 1433
    n_classes: int = 7
    aggregator: str = "sum"
    learnable_eps: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "float32"   # "bfloat16" halves message/psum bytes
    remat: bool = True          # rematerialize each layer in backward —
    # full-graph cells keep (N, d) activations + (E, d) messages per layer;
    # at ogb_products scale that is the difference between 18 GiB and 6 GiB.

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


def init_gin(rng, cfg: GNNConfig) -> Params:
    ks = jax.random.split(rng, cfg.n_layers + 2)
    d = cfg.d_hidden
    p: Params = {"proj": init_linear(ks[0], cfg.d_feat, d, bias=True,
                                     dtype=cfg.pdtype)}
    for i in range(cfg.n_layers):
        p[f"layer{i}"] = {
            "mlp": init_mlp(ks[i + 1], [d, d, d], dtype=cfg.pdtype),
            "eps": jnp.zeros((), cfg.pdtype),
        }
    p["head"] = init_linear(ks[-1], d, cfg.n_classes, bias=True,
                            dtype=cfg.pdtype)
    return p


def gin_aggregate(h: jax.Array, edge_src: jax.Array, edge_dst: jax.Array,
                  n_nodes: int, aggregator: str = "sum",
                  edge_valid: Optional[jax.Array] = None) -> jax.Array:
    """Message passing primitive: sum_{j in N(i)} h_j via gather+segment.

    ``edge_valid`` masks padding edges (fixed-shape padded subgraphs point
    their pad edges at node 0 — without the mask they would pollute it).
    """
    msgs = jnp.take(h, edge_src, axis=0)
    if edge_valid is not None:
        msgs = msgs * edge_valid[:, None].astype(msgs.dtype)
    if aggregator == "sum":
        return jax.ops.segment_sum(msgs, edge_dst, num_segments=n_nodes)
    if aggregator == "max":
        return jax.ops.segment_max(msgs, edge_dst, num_segments=n_nodes)
    if aggregator == "mean":
        s = jax.ops.segment_sum(msgs, edge_dst, num_segments=n_nodes)
        deg = jax.ops.segment_sum(jnp.ones((edge_dst.shape[0], 1), h.dtype),
                                  edge_dst, num_segments=n_nodes)
        return s / jnp.maximum(deg, 1.0)
    raise ValueError(aggregator)


def gin_forward(p: Params, cfg: GNNConfig, x: jax.Array,
                edge_src: jax.Array, edge_dst: jax.Array, *,
                node_valid: Optional[jax.Array] = None,
                edge_valid: Optional[jax.Array] = None) -> jax.Array:
    """Node classification: x (N, d_feat) -> logits (N, n_classes)."""
    n = x.shape[0]
    h = jax.nn.relu(dense(p["proj"], x)).astype(jnp.dtype(cfg.compute_dtype))

    def layer(lp, h):
        agg = gin_aggregate(h, edge_src, edge_dst, n, cfg.aggregator,
                            edge_valid)
        eps = lp["eps"] if cfg.learnable_eps else 0.0
        h = mlp(lp["mlp"], (1.0 + eps) * h + agg, final_act=True)
        if node_valid is not None:
            h = h * node_valid[:, None].astype(h.dtype)
        return h

    if cfg.remat:
        layer = jax.checkpoint(layer)
    for i in range(cfg.n_layers):
        h = layer(p[f"layer{i}"], h)
    return dense(p["head"], h)


def gin_graph_forward(p: Params, cfg: GNNConfig, x: jax.Array,
                      edge_src: jax.Array, edge_dst: jax.Array,
                      graph_ids: jax.Array, n_graphs: int,
                      edge_valid: Optional[jax.Array] = None) -> jax.Array:
    """Graph classification (molecule shape): sum-readout per graph."""
    n = x.shape[0]
    h = jax.nn.relu(dense(p["proj"], x))
    readout = jnp.zeros((n_graphs, cfg.d_hidden), h.dtype)
    for i in range(cfg.n_layers):
        lp = p[f"layer{i}"]
        agg = gin_aggregate(h, edge_src, edge_dst, n, cfg.aggregator,
                            edge_valid)
        eps = lp["eps"] if cfg.learnable_eps else 0.0
        h = mlp(lp["mlp"], (1.0 + eps) * h + agg, final_act=True)
        readout = readout + jax.ops.segment_sum(h, graph_ids,
                                                num_segments=n_graphs)
    return dense(p["head"], readout)


__all__ = ["GNNConfig", "init_gin", "gin_forward", "gin_graph_forward",
           "gin_aggregate"]
