"""repro.core — the paper's contribution (DTI training paradigm) in JAX."""
from repro.core.windowed import (ResetConfig, attention, attention_blocked,
                                 attention_dense, dti_mask, reset_alpha)
from repro.core.dti import (PromptStats, SpecialTokens, batch_prompts,
                            build_sliding_prompts, build_streaming_prompts,
                            window_tokens)
from repro.core.losses import ctr_logits, ctr_loss, lm_loss
from repro.core.metrics import auc, ctr_metrics, f1, log_loss
from repro.core import flops
