"""FLOPs accounting: the paper's Eq. 3 model and an exact per-step model.

Two granularities:
  * ``sliding_window_flops`` / ``dti_flops`` / ``flops_reduction`` — the
    paper's own approximation (section 3.5), used to validate Eq. 3 and the
    92% claim.
  * ``transformer_step_flops`` — exact matmul counting for an arch config,
    used as MODEL_FLOPS in the roofline analysis (6*N*D for dense LMs,
    6*N_active*D for MoE, attention terms windowed or full).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from typing import TYPE_CHECKING
if TYPE_CHECKING:
    from repro.models.transformer import ModelConfig


# ---------------------------------------------------------------------------
# Paper Eq. 3 (section 3.5)
# ---------------------------------------------------------------------------

def sliding_window_flops(m: int, n: int, N: int, d: int, L: int) -> float:
    """(m - n) prompts x 2L x (N^2 d + N d^2)."""
    return (m - n) * 2 * L * (N * N * d + N * d * d)


def dti_flops(m: int, k: int, N: int, K: int, d: int, L: int) -> float:
    """m/k prompts x 2L x ((N+K) N d + (N+K) d^2)."""
    return (m / k) * 2 * L * ((N + K) * N * d + (N + K) * d * d)


def flops_reduction_exact(m: int, n: int, k: int, N: int, K: int) -> float:
    return (N * k * (m - n)) / (m * (N + K))


def flops_reduction_approx(N: int, K: int, k: int) -> float:
    """Paper Eq. 3: N*k / (N+K)."""
    return N * k / (N + K)


# ---------------------------------------------------------------------------
# Exact per-step model FLOPs (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

@dataclass
class FlopsBreakdown:
    qkv: float
    attn_scores: float
    attn_values: float
    out_proj: float
    ffn: float
    lm_head: float
    embed: float = 0.0

    @property
    def total(self) -> float:
        return (self.qkv + self.attn_scores + self.attn_values
                + self.out_proj + self.ffn + self.lm_head + self.embed)


def _attn_dims(cfg: "ModelConfig"):
    if cfg.attn_type == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        return qk, cfg.v_head_dim
    return cfg.hd, cfg.hd


def transformer_fwd_flops(cfg: "ModelConfig", batch: int, seq: int, *,
                          kv_len: Optional[int] = None,
                          with_lm_head: bool = True,
                          dti_sum_rows: bool = False) -> FlopsBreakdown:
    """Forward matmul FLOPs (2*m*n*k per matmul) for one step.

    kv_len: attended context per query (window or full seq). Defaults to
    full causal (avg seq/2 per query).
    """
    t = batch * seq
    d = cfg.d_model
    qk_d, v_d = _attn_dims(cfg)
    h, hk = cfg.n_heads, cfg.n_kv_heads

    if cfg.attn_type == "mla":
        q_in = (2 * t * d * cfg.q_lora_rank + 2 * t * cfg.q_lora_rank * h * qk_d
                ) if cfg.q_lora_rank else 2 * t * d * h * qk_d
        kv_in = (2 * t * d * cfg.kv_lora_rank
                 + 2 * t * cfg.kv_lora_rank * h * (cfg.qk_nope_dim + v_d)
                 + 2 * t * d * cfg.qk_rope_dim)
        qkv = q_in + kv_in
    else:
        qkv = 2 * t * d * (h * qk_d + 2 * hk * qk_d)

    ctx = kv_len if kv_len is not None else seq / 2.0
    scores = 2 * t * h * qk_d * ctx
    values = 2 * t * h * v_d * ctx
    if dti_sum_rows:
        scores *= 2          # dual (RoPE + NoPE/ALiBi) score matrices
        values *= 2          # reset: second value aggregation
    out = 2 * t * h * v_d * d

    if cfg.moe:
        active = cfg.top_k + cfg.n_shared_experts
        moe_l = cfg.n_layers - cfg.first_dense_layers
        dense_l = cfg.first_dense_layers
        sdf = cfg.shared_d_ff or cfg.moe_d_ff
        ffn = (moe_l * (2 * 3 * t * d * (cfg.top_k * cfg.moe_d_ff
                                         + cfg.n_shared_experts * sdf))
               + dense_l * 2 * 3 * t * d * cfg.d_ff
               + moe_l * 2 * t * d * cfg.n_experts)     # router
        ffn /= cfg.n_layers  # report per layer, scaled back below
    else:
        ffn = 2 * 3 * t * d * cfg.d_ff

    L = cfg.n_layers
    lm = 2 * t * d * cfg.vocab_size if with_lm_head else 0.0
    return FlopsBreakdown(qkv=L * qkv, attn_scores=L * scores,
                          attn_values=L * values, out_proj=L * out,
                          ffn=L * ffn, lm_head=lm)


def train_step_flops(cfg: "ModelConfig", batch: int, seq: int, *,
                     kv_len: Optional[int] = None,
                     dti_sum_rows: bool = False) -> float:
    """fwd + bwd ~= 3x fwd for matmuls (grad wrt inputs and weights)."""
    return 3 * transformer_fwd_flops(cfg, batch, seq, kv_len=kv_len,
                                     dti_sum_rows=dti_sum_rows).total


def param_count_active(cfg: "ModelConfig") -> float:
    """Active (per-token) params, for the 6*N*D rule."""
    d = cfg.d_model
    qk_d, v_d = _attn_dims(cfg)
    h, hk = cfg.n_heads, cfg.n_kv_heads
    if cfg.attn_type == "mla":
        attn = (d * cfg.q_lora_rank + cfg.q_lora_rank * h * qk_d
                if cfg.q_lora_rank else d * h * qk_d)
        attn += (d * cfg.kv_lora_rank
                 + cfg.kv_lora_rank * h * (cfg.qk_nope_dim + v_d)
                 + d * cfg.qk_rope_dim)
    else:
        attn = d * qk_d * (h + 2 * hk)
    attn += h * v_d * d
    if cfg.moe:
        sdf = cfg.shared_d_ff or cfg.moe_d_ff
        moe_l = cfg.n_layers - cfg.first_dense_layers
        ffn_total = (moe_l * 3 * d * (cfg.top_k * cfg.moe_d_ff
                                      + cfg.n_shared_experts * sdf)
                     + cfg.first_dense_layers * 3 * d * cfg.d_ff)
        ffn = ffn_total / cfg.n_layers
    else:
        ffn = 3 * d * cfg.d_ff
    return cfg.n_layers * (attn + ffn) + cfg.vocab_size * d


def param_count_total(cfg: "ModelConfig") -> float:
    d = cfg.d_model
    qk_d, v_d = _attn_dims(cfg)
    h, hk = cfg.n_heads, cfg.n_kv_heads
    if cfg.attn_type == "mla":
        attn = (d * cfg.q_lora_rank + cfg.q_lora_rank * h * qk_d
                if cfg.q_lora_rank else d * h * qk_d)
        attn += (d * cfg.kv_lora_rank
                 + cfg.kv_lora_rank * h * (cfg.qk_nope_dim + v_d)
                 + d * cfg.qk_rope_dim)
    else:
        attn = d * qk_d * (h + 2 * hk)
    attn += h * v_d * d
    if cfg.moe:
        sdf = cfg.shared_d_ff or cfg.moe_d_ff
        moe_l = cfg.n_layers - cfg.first_dense_layers
        ffn_total = (moe_l * (3 * d * cfg.n_experts * cfg.moe_d_ff
                              + 3 * d * cfg.n_shared_experts * sdf
                              + d * cfg.n_experts)
                     + cfg.first_dense_layers * 3 * d * cfg.d_ff)
    else:
        ffn_total = cfg.n_layers * 3 * d * cfg.d_ff
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return cfg.n_layers * attn + ffn_total + emb


__all__ = ["sliding_window_flops", "dti_flops", "flops_reduction_exact",
           "flops_reduction_approx", "transformer_fwd_flops",
           "train_step_flops", "param_count_active", "param_count_total",
           "FlopsBreakdown"]
