"""Losses: the DTI CTR objective (SUM-token yes/no) and chunked LM loss."""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
if TYPE_CHECKING:  # avoid core <-> models import cycle
    from repro.models.transformer import ModelConfig


def ctr_logits(params: Params, cfg: "ModelConfig", hidden: jax.Array,
               yes_id: int, no_id: int) -> jax.Array:
    """Bi-dimensional (yes, no) logits at every position: (B, S, 2).

    Touches only two rows of the vocab matrix — the DTI training step never
    materialises (B, S, V) logits.
    """
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]["w"].T
    rows = jnp.stack([w[yes_id], w[no_id]]).astype(hidden.dtype)   # (2, d)
    return jnp.einsum("bsd,vd->bsv", hidden, rows)


def ctr_loss(params: Params, cfg: "ModelConfig", hidden: jax.Array,
             sum_mask: jax.Array, labels: jax.Array, *,
             yes_id: int, no_id: int) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """DTI objective: cross-entropy of yes/no at each [SUM] position.

    sum_mask: (B, S) bool — [SUM] positions carrying a label.
    labels:   (B, S) {0,1} int — 1 = 'yes' (click), aligned to sum positions.
    Returns (mean loss, dict(probs, mask)) — probs is p(click) per position.
    """
    logits2 = ctr_logits(params, cfg, hidden, yes_id, no_id).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits2, axis=-1)            # (B,S,2)
    lab = labels.astype(jnp.int32)
    nll = -jnp.where(lab == 1, logp[..., 0], logp[..., 1])  # (B,S)
    w = sum_mask.astype(jnp.float32)
    loss = jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
    p_click = jnp.exp(logp[..., 0])
    return loss, {"p_click": p_click, "mask": sum_mask}


def lm_loss(params: Params, cfg: "ModelConfig", hidden: jax.Array,
            targets: jax.Array, valid: Optional[jax.Array] = None) -> jax.Array:
    """Next-token cross entropy. Chunked over the vocab when
    ``cfg.logits_chunk > 0`` so (B, S, V) fp32 logits never exist."""
    w = (params["embed"] if cfg.tie_embeddings else params["lm_head"]["w"].T)
    v, d = w.shape
    h = hidden.astype(jnp.float32)
    wmask = jnp.ones(targets.shape, jnp.float32) if valid is None \
        else valid.astype(jnp.float32)

    if cfg.logits_chunk <= 0 or v % cfg.logits_chunk != 0:
        logits = jnp.einsum("bsd,vd->bsv", h, w.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - tgt) * wmask) / jnp.maximum(jnp.sum(wmask), 1.0)

    c = cfg.logits_chunk
    nc = v // c
    wc = w.reshape(nc, c, d).astype(jnp.float32)

    def body(carry, inp):
        m, s, tgt = carry
        wi, base = inp
        logits = jnp.einsum("bsd,cd->bsc", h, wi)              # (B,S,c)
        mi = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m, mi)
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[..., None]), axis=-1)
        in_chunk = (targets >= base) & (targets < base + c)
        local = jnp.clip(targets - base, 0, c - 1)
        t_val = jnp.take_along_axis(logits, local[..., None], axis=-1)[..., 0]
        tgt = jnp.where(in_chunk, t_val, tgt)
        return (m_new, s, tgt), None

    init = (jnp.full(targets.shape, -jnp.inf, jnp.float32),
            jnp.zeros(targets.shape, jnp.float32),
            jnp.zeros(targets.shape, jnp.float32))
    bases = jnp.arange(nc, dtype=jnp.int32) * c
    (m, s, tgt), _ = jax.lax.scan(body, init, (wc, bases))
    lse = m + jnp.log(s)
    return jnp.sum((lse - tgt) * wmask) / jnp.maximum(jnp.sum(wmask), 1.0)


__all__ = ["ctr_logits", "ctr_loss", "lm_loss"]
