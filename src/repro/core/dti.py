"""DTI prompt formulation: sliding-window (baseline) and streaming prompts.

Data-pipeline side of the paper (sections 3.1, 3.2, 3.4): pure numpy, feeds
the jitted train step with fixed-shape padded batches (the canonical batch
schema shared by every downstream layer — see docs/batch_schema.md):

  tokens      (L,) int32
  positions   (L,) int32   token index, restarting at 0 per segment
  segment_ids (L,) int32   packed-prompt id within the row; -1 on padding
  is_sum      (L,) bool    [SUM] readout positions
  labels      (L,) int32   1='yes' at SUM positions, 0 elsewhere/negative
  valid       (L,) bool    padding mask

The sliding-window builder emits one prompt per target (stride 1); the
streaming builder emits one prompt per k targets (stride k) with a [SUM]
token after each target. ``pack_prompts`` then bin-packs several prompts
into one row (first-fit decreasing); attention layers isolate the segments
via ``segment_ids`` so prompts from different users share a row without
hidden-state leakage. Token budget bookkeeping (`PromptStats`, including
pad-slot accounting) feeds the Eq. 3 validation benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np


@dataclasses.dataclass
class SpecialTokens:
    pad: int = 0
    bos: int = 1
    sum: int = 2
    yes: int = 3
    no: int = 4
    sep: int = 5
    n_reserved: int = 8


@dataclasses.dataclass
class PromptStats:
    n_prompts: int = 0
    n_tokens: int = 0          # non-pad tokens fed to the model
    n_targets: int = 0         # supervised [SUM] positions
    n_rows: int = 0            # physical batch rows (== n_prompts unpacked)
    n_slots: int = 0           # rows * max_len (pad slots included)

    def add(self, tokens: int, targets: int, slots: int = 0):
        self.n_prompts += 1
        self.n_tokens += tokens
        self.n_targets += targets
        if slots:
            self.n_rows += 1
            self.n_slots += slots

    def add_packed_row(self, tokens: int, prompts: int, targets: int,
                       slots: int):
        self.n_prompts += prompts
        self.n_tokens += tokens
        self.n_targets += targets
        self.n_rows += 1
        self.n_slots += slots

    @property
    def pad_fraction(self) -> float:
        """Share of batch slots burnt on pad tokens."""
        if self.n_slots == 0:
            return 0.0
        return 1.0 - self.n_tokens / self.n_slots


def _pad_to(arr: np.ndarray, length: int, fill=0) -> np.ndarray:
    out = np.full((length,), fill, dtype=arr.dtype)
    out[: len(arr)] = arr[:length]
    return out


def _pack(tokens: List[int], is_sum: List[bool], labels: List[int],
          max_len: int, sp: SpecialTokens) -> Dict[str, np.ndarray]:
    n = len(tokens)
    assert n <= max_len, f"prompt length {n} > max_len {max_len}"
    t = _pad_to(np.asarray(tokens, np.int32), max_len, sp.pad)
    s = _pad_to(np.asarray(is_sum, bool), max_len, False)
    l = _pad_to(np.asarray(labels, np.int32), max_len, 0)
    valid = np.zeros((max_len,), bool)
    valid[:n] = True
    seg = np.full((max_len,), -1, np.int32)
    seg[:n] = 0
    return {"tokens": t, "is_sum": s, "labels": l, "valid": valid,
            "positions": np.arange(max_len, dtype=np.int32),
            "segment_ids": seg}


def build_sliding_prompts(
    item_tokens: Sequence[Sequence[int]], labels: Sequence[int], *,
    n_ctx: int, max_len: int, sp: SpecialTokens = SpecialTokens(),
    stats: PromptStats | None = None,
) -> List[Dict[str, np.ndarray]]:
    """One prompt per target interaction i in [n_ctx, m): context =
    interactions [i-n_ctx, i), then the target, then [SUM]."""
    m = len(item_tokens)
    out = []
    for i in range(n_ctx, m):
        toks: List[int] = [sp.bos]
        for j in range(i - n_ctx, i + 1):
            toks.extend(item_tokens[j])
        toks.append(sp.sum)
        is_sum = [False] * (len(toks) - 1) + [True]
        lab = [0] * (len(toks) - 1) + [int(labels[i])]
        if stats is not None:
            stats.add(len(toks), 1, slots=max_len)
        out.append(_pack(toks, is_sum, lab, max_len, sp))
    return out


def build_streaming_prompts(
    item_tokens: Sequence[Sequence[int]], labels: Sequence[int], *,
    n_ctx: int, k: int, max_len: int, sp: SpecialTokens = SpecialTokens(),
    stats: PromptStats | None = None,
) -> List[Dict[str, np.ndarray]]:
    """Stride-k traversal: each prompt = n_ctx context interactions followed
    by up to k (target, [SUM]) groups (paper fig. 1.ii(a), fig. 5)."""
    m = len(item_tokens)
    out = []
    i = n_ctx
    while i < m:
        targets = list(range(i, min(i + k, m)))
        toks: List[int] = [sp.bos]
        for j in range(i - n_ctx, i):
            toks.extend(item_tokens[j])
        is_sum = [False] * len(toks)
        lab = [0] * len(toks)
        for j in targets:
            toks.extend(item_tokens[j])
            is_sum.extend([False] * len(item_tokens[j]))
            lab.extend([0] * len(item_tokens[j]))
            toks.append(sp.sum)
            is_sum.append(True)
            lab.append(int(labels[j]))
        if stats is not None:
            stats.add(len(toks), len(targets), slots=max_len)
        out.append(_pack(toks, is_sum, lab, max_len, sp))
        i += k
    return out


def prompt_length(p: Dict[str, np.ndarray]) -> int:
    """Non-pad length of a built prompt (valid is always a prefix)."""
    return int(p["valid"].sum())


def pack_prompts(prompts: List[Dict[str, np.ndarray]], max_len: int, *,
                 sp: SpecialTokens = SpecialTokens(),
                 stats: PromptStats | None = None,
                 ) -> List[Dict[str, np.ndarray]]:
    """Greedy first-fit-decreasing packing of prompts into shared rows.

    Each output row holds one or more whole prompts back to back (a prompt
    never straddles rows). Per row:

      segment_ids  0,1,2,... per packed prompt, -1 on padding
      positions    restart at 0 at each segment boundary, so RoPE / window /
                   ALiBi / reset distances match the unpacked prompt exactly
      tokens/is_sum/labels/valid  concatenated prompt fields
      target_mask  carried through when present (streaming rows supervise a
                   subset of their [SUM] positions; docs/streaming.md)

    Cross-segment isolation is enforced downstream by the seg_q == seg_k
    term of ``repro.core.windowed.dti_mask`` (and its blocked / Pallas
    equivalents), so rows can mix prompts from different users.
    """
    lengths = [prompt_length(p) for p in prompts]
    for n in lengths:
        assert 0 < n <= max_len, f"prompt length {n} not in (0, {max_len}]"
    order = sorted(range(len(prompts)), key=lambda i: -lengths[i])
    bins: List[List[int]] = []
    free: List[int] = []
    for i in order:
        n = lengths[i]
        for b, cap in enumerate(free):
            if n <= cap:
                bins[b].append(i)
                free[b] = cap - n
                break
        else:
            bins.append([i])
            free.append(max_len - n)

    has_tm = bool(prompts) and "target_mask" in prompts[0]
    assert all(("target_mask" in p) == has_tm for p in prompts), (
        "mixed prompts: target_mask must be present on all rows or none "
        "(a silently dropped mask would re-supervise trained targets)")
    rows = []
    for members in bins:
        t = np.full((max_len,), sp.pad, np.int32)
        pos = np.zeros((max_len,), np.int32)
        seg = np.full((max_len,), -1, np.int32)
        s = np.zeros((max_len,), bool)
        lab = np.zeros((max_len,), np.int32)
        valid = np.zeros((max_len,), bool)
        tm = np.zeros((max_len,), bool)
        off = 0
        for si, i in enumerate(members):
            n = lengths[i]
            p = prompts[i]
            sl = slice(off, off + n)
            t[sl] = p["tokens"][:n]
            pos[sl] = np.arange(n, dtype=np.int32)
            seg[sl] = si
            s[sl] = p["is_sum"][:n]
            lab[sl] = p["labels"][:n]
            valid[sl] = True
            if has_tm:
                tm[sl] = p["target_mask"][:n]
            off += n
        if stats is not None:
            # supervised targets: target_mask when present ([SUM]s re-emitted
            # as context don't count), every [SUM] otherwise
            stats.add_packed_row(off, len(members),
                                 int((tm if has_tm else s).sum()), max_len)
        row = {"tokens": t, "positions": pos, "segment_ids": seg,
               "is_sum": s, "labels": lab, "valid": valid}
        if has_tm:
            row["target_mask"] = tm
        rows.append(row)
    return rows


def build_multi_target_request(
    context_tokens: Sequence[Sequence[int]],
    candidate_tokens: Sequence[Sequence[int]], *, max_len: int,
    sp: SpecialTokens = SpecialTokens(),
    stats: PromptStats | None = None,
) -> Dict[str, np.ndarray]:
    """One serving request — a shared user context + k candidate items —
    laid out as a single canonical-schema row (the serving analog of the
    streaming training prompt):

        [BOS] ctx...              segment 0, positions 0..n-1
        cand_1... [SUM]           segment 1, positions n..n+c_1
        ...
        cand_k... [SUM]           segment k, positions n..n+c_k

    Candidate positions *continue* after the context instead of restarting
    at 0, and the attention mask treats segment 0 as a shared prefix
    (``seg_shared=0``): every candidate attends the context plus itself,
    never another candidate. Each candidate therefore sees exactly the
    token/position geometry of a standalone ``[BOS] ctx cand [SUM]``
    sliding-window prompt, so one prefill over this row reproduces k
    independent prefills — O(n^2 + k·n) attention instead of O(k·n^2).

    Scores are read at the [SUM] slots, in candidate order
    (``candidate_sum_slots``). Labels are zero: serving rows carry no
    supervision.
    """
    toks: List[int] = [sp.bos]
    for it in context_tokens:
        toks.extend(it)
    n = len(toks)
    pos = list(range(n))
    seg = [0] * n
    is_sum = [False] * n
    for j, cand in enumerate(candidate_tokens):
        toks.extend(cand)
        toks.append(sp.sum)
        pos.extend(range(n, n + len(cand) + 1))
        seg.extend([j + 1] * (len(cand) + 1))
        is_sum.extend([False] * len(cand) + [True])
    total = len(toks)
    assert total <= max_len, f"request length {total} > max_len {max_len}"
    if stats is not None:
        stats.add_packed_row(total, len(candidate_tokens),
                            len(candidate_tokens), max_len)
    return {
        "tokens": _pad_to(np.asarray(toks, np.int32), max_len, sp.pad),
        "positions": _pad_to(np.asarray(pos, np.int32), max_len, 0),
        "segment_ids": _pad_to(np.asarray(seg, np.int32), max_len, -1),
        "is_sum": _pad_to(np.asarray(is_sum, bool), max_len, False),
        "labels": np.zeros((max_len,), np.int32),
        "valid": _pad_to(np.ones((total,), bool), max_len, False),
    }


def candidate_sum_slots(row: Dict[str, np.ndarray]) -> np.ndarray:
    """Physical indices of the k [SUM] readouts of a multi-target row, in
    candidate order."""
    return np.flatnonzero(row["is_sum"])


def batch_prompts(prompts: List[Dict[str, np.ndarray]],
                  batch_size: int, *, drop_remainder: bool = False,
                  rng: np.random.Generator | None = None):
    """Yield stacked batches (shuffled if rng given)."""
    idx = np.arange(len(prompts))
    if rng is not None:
        rng.shuffle(idx)
    for s in range(0, len(idx), batch_size):
        sel = idx[s: s + batch_size]
        if len(sel) < batch_size:
            if drop_remainder:
                return
            sel = np.concatenate([sel, idx[: batch_size - len(sel)]])
        yield {key: np.stack([prompts[i][key] for i in sel])
               for key in prompts[0]}


def train_max_len(n_ctx: int, k: int, avg_item_tokens: float) -> int:
    """Fixed-shape training row length for prompts with ``n_ctx`` context
    interactions and ``k`` targets (1 for sliding-window): headroom over the
    expected token count (BOS, one [SUM] per target, margin), rounded up to
    a multiple of 64. The single source of truth shared by the trainer and
    the benchmarks — pad-fraction numbers are only comparable when every
    harness builds rows of this shape."""
    n = int((n_ctx + k) * (avg_item_tokens + 1.5) + 8)
    return ((n + 63) // 64) * 64


def window_tokens(n_ctx: int, avg_item_tokens: float, cap: int = 1024) -> int:
    """Token-level attention window covering n_ctx interactions, capped
    (the paper caps at 1024)."""
    return int(min(cap, round(n_ctx * (avg_item_tokens + 0.5) + 2)))


def effective_window(attn_impl: str, window: int, n_ctx: int,
                     avg_item_tokens: float) -> int:
    """Banded attention paths (blocked / pallas) need a finite window;
    dense treats 0 as unlimited. One rule shared by the trainer CLI and
    the benchmark harness so they always train with the same window."""
    if attn_impl != "dense" and window == 0:
        return window_tokens(n_ctx, avg_item_tokens)
    return window


__all__ = ["SpecialTokens", "PromptStats", "build_sliding_prompts",
           "build_streaming_prompts", "build_multi_target_request",
           "candidate_sum_slots", "pack_prompts", "prompt_length",
           "batch_prompts", "train_max_len", "window_tokens",
           "effective_window"]
