"""DTI prompt formulation: sliding-window (baseline) and streaming prompts.

Data-pipeline side of the paper (sections 3.1, 3.2, 3.4): pure numpy, feeds
the jitted train step with fixed-shape padded batches:

  tokens    (L,) int32
  positions (L,) int32   physical token index (what window masks use)
  is_sum    (L,) bool    [SUM] readout positions
  labels    (L,) int32   1='yes' at SUM positions, 0 elsewhere/negative
  valid     (L,) bool    padding mask

The sliding-window builder emits one prompt per target (stride 1); the
streaming builder emits one prompt per k targets (stride k) with a [SUM]
token after each target. Token budget bookkeeping (`PromptStats`) feeds the
Eq. 3 validation benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np


@dataclasses.dataclass
class SpecialTokens:
    pad: int = 0
    bos: int = 1
    sum: int = 2
    yes: int = 3
    no: int = 4
    sep: int = 5
    n_reserved: int = 8


@dataclasses.dataclass
class PromptStats:
    n_prompts: int = 0
    n_tokens: int = 0          # non-pad tokens fed to the model
    n_targets: int = 0         # supervised [SUM] positions

    def add(self, tokens: int, targets: int):
        self.n_prompts += 1
        self.n_tokens += tokens
        self.n_targets += targets


def _pad_to(arr: np.ndarray, length: int, fill=0) -> np.ndarray:
    out = np.full((length,), fill, dtype=arr.dtype)
    out[: len(arr)] = arr[:length]
    return out


def _pack(tokens: List[int], is_sum: List[bool], labels: List[int],
          max_len: int, sp: SpecialTokens) -> Dict[str, np.ndarray]:
    n = len(tokens)
    assert n <= max_len, f"prompt length {n} > max_len {max_len}"
    t = _pad_to(np.asarray(tokens, np.int32), max_len, sp.pad)
    s = _pad_to(np.asarray(is_sum, bool), max_len, False)
    l = _pad_to(np.asarray(labels, np.int32), max_len, 0)
    valid = np.zeros((max_len,), bool)
    valid[:n] = True
    return {"tokens": t, "is_sum": s, "labels": l, "valid": valid,
            "positions": np.arange(max_len, dtype=np.int32)}


def build_sliding_prompts(
    item_tokens: Sequence[Sequence[int]], labels: Sequence[int], *,
    n_ctx: int, max_len: int, sp: SpecialTokens = SpecialTokens(),
    stats: PromptStats | None = None,
) -> List[Dict[str, np.ndarray]]:
    """One prompt per target interaction i in [n_ctx, m): context =
    interactions [i-n_ctx, i), then the target, then [SUM]."""
    m = len(item_tokens)
    out = []
    for i in range(n_ctx, m):
        toks: List[int] = [sp.bos]
        for j in range(i - n_ctx, i + 1):
            toks.extend(item_tokens[j])
        toks.append(sp.sum)
        is_sum = [False] * (len(toks) - 1) + [True]
        lab = [0] * (len(toks) - 1) + [int(labels[i])]
        if stats is not None:
            stats.add(len(toks), 1)
        out.append(_pack(toks, is_sum, lab, max_len, sp))
    return out


def build_streaming_prompts(
    item_tokens: Sequence[Sequence[int]], labels: Sequence[int], *,
    n_ctx: int, k: int, max_len: int, sp: SpecialTokens = SpecialTokens(),
    stats: PromptStats | None = None,
) -> List[Dict[str, np.ndarray]]:
    """Stride-k traversal: each prompt = n_ctx context interactions followed
    by up to k (target, [SUM]) groups (paper fig. 1.ii(a), fig. 5)."""
    m = len(item_tokens)
    out = []
    i = n_ctx
    while i < m:
        targets = list(range(i, min(i + k, m)))
        toks: List[int] = [sp.bos]
        for j in range(i - n_ctx, i):
            toks.extend(item_tokens[j])
        is_sum = [False] * len(toks)
        lab = [0] * len(toks)
        for j in targets:
            toks.extend(item_tokens[j])
            is_sum.extend([False] * len(item_tokens[j]))
            lab.extend([0] * len(item_tokens[j]))
            toks.append(sp.sum)
            is_sum.append(True)
            lab.append(int(labels[j]))
        if stats is not None:
            stats.add(len(toks), len(targets))
        out.append(_pack(toks, is_sum, lab, max_len, sp))
        i += k
    return out


def batch_prompts(prompts: List[Dict[str, np.ndarray]],
                  batch_size: int, *, drop_remainder: bool = False,
                  rng: np.random.Generator | None = None):
    """Yield stacked batches (shuffled if rng given)."""
    idx = np.arange(len(prompts))
    if rng is not None:
        rng.shuffle(idx)
    for s in range(0, len(idx), batch_size):
        sel = idx[s: s + batch_size]
        if len(sel) < batch_size:
            if drop_remainder:
                return
            sel = np.concatenate([sel, idx[: batch_size - len(sel)]])
        yield {key: np.stack([prompts[i][key] for i in sel])
               for key in prompts[0]}


def window_tokens(n_ctx: int, avg_item_tokens: float, cap: int = 1024) -> int:
    """Token-level attention window covering n_ctx interactions, capped
    (the paper caps at 1024)."""
    return int(min(cap, round(n_ctx * (avg_item_tokens + 0.5) + 2)))


__all__ = ["SpecialTokens", "PromptStats", "build_sliding_prompts",
           "build_streaming_prompts", "batch_prompts", "window_tokens"]
