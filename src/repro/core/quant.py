"""Symmetric int8 quantization — the one contract every quantized surface
shares (serve KV pages, the decode kernel's fused dequant, int8 embedding
tables; see docs/serving.md and docs/kernels.md).

Scheme: per-group symmetric absmax. For a group ``x`` (one reduction axis):

    scale = max(|x|) / 127
    q     = clip(round(x / scale), -127, 127)   int8
    x'    = q * scale                           fp32

Properties the tests pin (tests/test_kv_quant.py):

* **error bound** — ``|x - x'| <= scale / 2`` per element: ``x / scale``
  lies in [-127, 127] by construction, so the only loss is the rounding,
  which is at most half a step. Zero groups quantize to exact zeros.
* **scale locality** — dequantization needs only (q, scale) of the group
  itself. This is what makes quantized KV pages *movable*: a page carries
  its own scales, so cross-row adoption / row steals relocate bytes
  without any requantization (docs/serving.md).
* **linearity** — ``scale`` multiplies out of any linear map of the
  group. In particular RoPE (a per-(token, head) rotation) commutes with
  the per-(token, head) scale: ``rope(q * scale) == rope(q) * scale`` —
  the identity that lets the decode kernel rope raw int8 keys in VMEM and
  apply the scale afterwards, so quantized KV never round-trips through
  bf16 in HBM (repro.kernels.decode_attn).

``-127`` (not -128) keeps the grid symmetric: negating a tensor negates
its quantization exactly.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

#: Largest representable magnitude of the symmetric int8 grid.
Q8_MAX = 127.0


def quantize_q8(x: jax.Array, axis: int = -1) -> Tuple[jax.Array, jax.Array]:
    """Quantize ``x`` to (int8 codes, fp32 scales) with one scale per
    group along ``axis`` (the reduced axis disappears from ``scale``)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=axis) / Q8_MAX
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.round(xf / jnp.expand_dims(safe, axis))
    q = jnp.clip(q, -Q8_MAX, Q8_MAX).astype(jnp.int8)
    return q, scale


def dequantize_q8(q: jax.Array, scale: jax.Array, axis: int = -1) -> jax.Array:
    """Reconstruct fp32 values: ``q * scale`` broadcast along ``axis``."""
    return q.astype(jnp.float32) * jnp.expand_dims(
        scale.astype(jnp.float32), axis)


__all__ = ["Q8_MAX", "quantize_q8", "dequantize_q8"]
