"""CTR evaluation metrics: AUC, Log Loss, F1 (paper section 5.1)."""
from __future__ import annotations

import numpy as np


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Mann-Whitney AUC with tie handling via average ranks."""
    labels = np.asarray(labels).astype(np.int64).ravel()
    scores = np.asarray(scores, dtype=np.float64).ravel()
    n_pos = int(labels.sum())
    n_neg = labels.size - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    order = np.argsort(scores, kind="mergesort")
    s_sorted = scores[order]
    ranks = np.empty_like(s_sorted)
    i = 0
    r = 1.0
    while i < s_sorted.size:
        j = i
        while j + 1 < s_sorted.size and s_sorted[j + 1] == s_sorted[i]:
            j += 1
        ranks[i:j + 1] = (r + r + (j - i)) / 2.0
        r += j - i + 1
        i = j + 1
    rank_of = np.empty_like(ranks)
    rank_of[order] = ranks
    sum_pos = rank_of[labels == 1].sum()
    return float((sum_pos - n_pos * (n_pos + 1) / 2.0) / (n_pos * n_neg))


def log_loss(labels: np.ndarray, scores: np.ndarray, eps: float = 1e-7) -> float:
    labels = np.asarray(labels, dtype=np.float64).ravel()
    p = np.clip(np.asarray(scores, dtype=np.float64).ravel(), eps, 1 - eps)
    return float(-np.mean(labels * np.log(p) + (1 - labels) * np.log(1 - p)))


def f1(labels: np.ndarray, scores: np.ndarray, threshold: float = 0.5) -> float:
    labels = np.asarray(labels).astype(np.int64).ravel()
    pred = (np.asarray(scores).ravel() >= threshold).astype(np.int64)
    tp = int(np.sum((pred == 1) & (labels == 1)))
    fp = int(np.sum((pred == 1) & (labels == 0)))
    fn = int(np.sum((pred == 0) & (labels == 1)))
    if tp == 0:
        return 0.0
    prec = tp / (tp + fp)
    rec = tp / (tp + fn)
    return float(2 * prec * rec / (prec + rec))


def ctr_metrics(labels, scores) -> dict:
    return {"auc": auc(labels, scores), "log_loss": log_loss(labels, scores),
            "f1": f1(labels, scores)}


__all__ = ["auc", "log_loss", "f1", "ctr_metrics"]
