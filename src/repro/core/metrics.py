"""CTR evaluation metrics: AUC, Log Loss, F1 (paper section 5.1)."""
from __future__ import annotations

import numpy as np


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Mann-Whitney AUC with tie handling via average ranks."""
    labels = np.asarray(labels).astype(np.int64).ravel()
    scores = np.asarray(scores, dtype=np.float64).ravel()
    n_pos = int(labels.sum())
    n_neg = labels.size - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    order = np.argsort(scores, kind="mergesort")
    s_sorted = scores[order]
    ranks = np.empty_like(s_sorted)
    i = 0
    r = 1.0
    while i < s_sorted.size:
        j = i
        while j + 1 < s_sorted.size and s_sorted[j + 1] == s_sorted[i]:
            j += 1
        ranks[i:j + 1] = (r + r + (j - i)) / 2.0
        r += j - i + 1
        i = j + 1
    rank_of = np.empty_like(ranks)
    rank_of[order] = ranks
    sum_pos = rank_of[labels == 1].sum()
    return float((sum_pos - n_pos * (n_pos + 1) / 2.0) / (n_pos * n_neg))


def log_loss(labels: np.ndarray, scores: np.ndarray, eps: float = 1e-7) -> float:
    labels = np.asarray(labels, dtype=np.float64).ravel()
    p = np.clip(np.asarray(scores, dtype=np.float64).ravel(), eps, 1 - eps)
    return float(-np.mean(labels * np.log(p) + (1 - labels) * np.log(1 - p)))


def f1(labels: np.ndarray, scores: np.ndarray, threshold: float = 0.5) -> float:
    labels = np.asarray(labels).astype(np.int64).ravel()
    pred = (np.asarray(scores).ravel() >= threshold).astype(np.int64)
    tp = int(np.sum((pred == 1) & (labels == 1)))
    fp = int(np.sum((pred == 1) & (labels == 0)))
    fn = int(np.sum((pred == 0) & (labels == 1)))
    if tp == 0:
        return 0.0
    prec = tp / (tp + fp)
    rec = tp / (tp + fn)
    return float(2 * prec * rec / (prec + rec))


def ctr_metrics(labels, scores) -> dict:
    return {"auc": auc(labels, scores), "log_loss": log_loss(labels, scores),
            "f1": f1(labels, scores)}


# ---------------------------------------------------------------------------
# streaming / mergeable accumulators (online eval; docs/streaming.md)
# ---------------------------------------------------------------------------

class StreamingAUC:
    """Fixed-bin histogram AUC with an ``update`` / ``merge`` / ``value`` API.

    Scores are bucketed into ``n_bins`` equal-width bins over [lo, hi]
    (CTR scores are probabilities, so the default [0, 1] loses nothing);
    per-class counts are all the state, so accumulators from different
    hosts / eval windows merge by addition. ``value`` is the Mann-Whitney
    statistic with in-bin ties counted half — it converges to the exact
    ``auc`` as bins shrink (≤1e-3 off at the default 4096 bins on 10k
    scores; tests/test_stream.py).
    """

    def __init__(self, n_bins: int = 4096, lo: float = 0.0, hi: float = 1.0):
        assert n_bins > 0 and hi > lo
        self.n_bins = n_bins
        self.lo = lo
        self.hi = hi
        self.pos = np.zeros((n_bins,), np.int64)
        self.neg = np.zeros((n_bins,), np.int64)

    def update(self, labels, scores) -> "StreamingAUC":
        labels = np.asarray(labels).astype(np.int64).ravel()
        scores = np.asarray(scores, dtype=np.float64).ravel()
        idx = ((scores - self.lo) / (self.hi - self.lo) * self.n_bins)
        idx = np.clip(idx.astype(np.int64), 0, self.n_bins - 1)
        self.pos += np.bincount(idx[labels == 1], minlength=self.n_bins)
        self.neg += np.bincount(idx[labels != 1], minlength=self.n_bins)
        return self

    def merge(self, other: "StreamingAUC") -> "StreamingAUC":
        assert (self.n_bins, self.lo, self.hi) == \
            (other.n_bins, other.lo, other.hi), "bin layouts differ"
        self.pos += other.pos
        self.neg += other.neg
        return self

    @property
    def n(self) -> int:
        return int(self.pos.sum() + self.neg.sum())

    def value(self) -> float:
        n_pos = int(self.pos.sum())
        n_neg = int(self.neg.sum())
        if n_pos == 0 or n_neg == 0:
            return 0.5
        neg_below = np.cumsum(self.neg) - self.neg      # strictly lower bins
        correct = (self.pos * neg_below).sum() + 0.5 * (self.pos * self.neg).sum()
        return float(correct / (n_pos * n_neg))


class StreamingLogLoss:
    """Running-mean log loss; exact (a sum and a count), trivially mergeable."""

    def __init__(self, eps: float = 1e-7):
        self.eps = eps
        self.total = 0.0
        self.n = 0

    def update(self, labels, scores) -> "StreamingLogLoss":
        labels = np.asarray(labels, dtype=np.float64).ravel()
        p = np.clip(np.asarray(scores, dtype=np.float64).ravel(),
                    self.eps, 1 - self.eps)
        self.total += float(-np.sum(labels * np.log(p)
                                    + (1 - labels) * np.log(1 - p)))
        self.n += labels.size
        return self

    def merge(self, other: "StreamingLogLoss") -> "StreamingLogLoss":
        self.total += other.total
        self.n += other.n
        return self

    def value(self) -> float:
        return self.total / max(self.n, 1)


__all__ = ["auc", "log_loss", "f1", "ctr_metrics", "StreamingAUC",
           "StreamingLogLoss"]
