"""Windowed causal attention with the DTI extensions (the paper's core math).

Three execution paths, all semantically identical (tests assert this):

* ``attention_dense``   — materialises the (Sq, Sk) score matrix. Reference
  path; used for smoke tests, decode steps and as the oracle for the others.
* ``attention_blocked`` — block-local attention: the sequence is split into
  blocks of the window size W and each query block attends to (previous block,
  own block) only. O(S * 2W) time/memory instead of O(S^2). This is the shape
  the Pallas kernel (`repro.kernels.windowed_attn`) implements on TPU and the
  shape used by every large dry-run cell.
* ``repro.kernels.windowed_attn.ops.windowed_attention`` — the fused TPU
  kernel (validated against ``attention_dense`` in interpret mode). It is
  differentiable: a custom VJP pairs the forward (which saves per-row
  logsumexp residuals) with flash-style dq and dk/dv backward kernels, so
  ``attn_impl="pallas"`` trains end-to-end on the kernel path
  (tests/test_kernel_grads.py asserts gradient equivalence to this dense
  reference; docs/kernels.md documents the contract).

DTI semantics implemented here (paper sections 3.3, 4.1, 4.2):

* window mask        — each token attends to at most its ``window`` predecessors.
* SUM isolation      — [SUM] readout tokens are masked out of every *other*
  token's keys: readout states never pollute the stream (they do not exist in
  sliding-window inference prompts).
* SUM NoPE + ALiBi   — rows belonging to [SUM] queries score against the
  *unrotated* (no position id) q/k with a relative ALiBi bias, fixing
  positional-bias overfitting. Non-SUM rows use plain RoPE'd q/k.
* hidden-state reset — for [SUM] query rows the attended value is
  ``(1 - a(d)) * V(h_s) + a(d) * V(h_s_init)`` with the logistic
  ``a(d) = y_min + (y_max - y_min) * sigmoid(d - N/2)``; d = query-key distance.
  Implemented as a second value aggregation with per-(t,s) weights, so each
  target reads its own distance-reset view of the context while the shared
  stream stays untouched ("dynamic target isolation").
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class ResetConfig:
    """Distance-based hidden-state forgetting (paper eq. in section 4.1)."""
    y_min: float = 0.0
    y_max: float = 0.3
    midpoint: float = 512.0   # N/2 in tokens


def reset_alpha(dist: jax.Array, cfg: ResetConfig) -> jax.Array:
    """Logistic interpolation ratio a(d); dist is query_pos - key_pos >= 0."""
    d = dist.astype(jnp.float32)
    return cfg.y_min + (cfg.y_max - cfg.y_min) * jax.nn.sigmoid(d - cfg.midpoint)


def dti_mask(pos_q: jax.Array, pos_k: jax.Array, *, window: int,
             is_sum_k: Optional[jax.Array] = None,
             valid_k: Optional[jax.Array] = None,
             seg_q: Optional[jax.Array] = None,
             seg_k: Optional[jax.Array] = None,
             seg_shared: Optional[int] = None) -> jax.Array:
    """Boolean (..., Sq, Sk) mask: True = attendable.

    causal     : pos_q >= pos_k
    window     : pos_q - pos_k <= window (window == 0 -> unlimited, pure causal)
    SUM-iso    : keys that are [SUM] tokens only attend-able by themselves
    valid_k    : padding mask for keys
    segment    : packed rows — queries only attend keys of their own segment
                 (positions restart per segment, so without this term a later
                 segment's small pos_q would alias into earlier segments)
    seg_shared : multi-target serving rows — keys of segment ``seg_shared``
                 (the user context) are additionally attendable by *every*
                 segment, so k candidate segments share one context prefix
                 while staying isolated from each other. Candidate positions
                 continue after the context (they do not restart at 0), so
                 the causal/window/ALiBi distances equal the ones of a
                 standalone context+candidate prompt.
    """
    d = pos_q[..., :, None] - pos_k[..., None, :]
    m = d >= 0
    if window > 0:
        m = m & (d <= window)
    if is_sum_k is not None:
        m = m & (~is_sum_k[..., None, :] | (d == 0))
    if valid_k is not None:
        m = m & valid_k[..., None, :]
    if seg_q is not None and seg_k is not None:
        same = seg_q[..., :, None] == seg_k[..., None, :]
        if seg_shared is not None:
            same = same | (seg_k[..., None, :] == seg_shared)
        m = m & same
    return m


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Hk, D) -> (B, S, Hk * n_rep, D) by head repetition (GQA)."""
    if n_rep == 1:
        return x
    b, s, hk, dd = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, hk, n_rep, dd)).reshape(b, s, hk * n_rep, dd)


def _scores(q, k):
    """(B,Sq,H,D),(B,Sk,H,D) -> fp32 (B,H,Sq,Sk)."""
    return jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)


def attention_dense(
    q: jax.Array,                      # (B, Sq, H, Dqk)  (RoPE'd)
    k: jax.Array,                      # (B, Sk, Hk, Dqk) (RoPE'd)
    v: jax.Array,                      # (B, Sk, Hk, Dv)
    *,
    pos_q: jax.Array,                  # (B, Sq) int32 token positions
    pos_k: jax.Array,                  # (B, Sk)
    window: int = 0,
    is_sum_q: Optional[jax.Array] = None,   # (B, Sq) bool
    is_sum_k: Optional[jax.Array] = None,   # (B, Sk) bool
    valid_k: Optional[jax.Array] = None,    # (B, Sk) bool
    seg_q: Optional[jax.Array] = None,      # (B, Sq) int32 packed segments
    seg_k: Optional[jax.Array] = None,      # (B, Sk) int32
    seg_shared: Optional[int] = None,       # shared-prefix segment id
    q_nope: Optional[jax.Array] = None,     # unrotated q for SUM rows
    k_nope: Optional[jax.Array] = None,     # unrotated k for SUM rows
    alibi: Optional[jax.Array] = None,      # (H,) slopes for SUM rows
    v0: Optional[jax.Array] = None,         # (B, Sk, Hk, Dv) values of h_init
    reset: Optional[ResetConfig] = None,
    sum_isolated: bool = True,
    scale: Optional[float] = None,
) -> jax.Array:
    """Reference DTI attention. Returns (B, Sq, H, Dv)."""
    b, sq, h, dqk = q.shape
    hk = k.shape[2]
    n_rep = h // hk
    if scale is None:
        scale = dqk ** -0.5

    k_r = _repeat_kv(k, n_rep)
    v_r = _repeat_kv(v, n_rep)

    logits = _scores(q, k_r) * scale                       # (B,H,Sq,Sk) fp32

    use_sum_rows = is_sum_q is not None and (q_nope is not None)
    if use_sum_rows:
        kn_r = _repeat_kv(k_nope, n_rep)
        logits2 = _scores(q_nope, kn_r) * scale
        if alibi is not None:
            d = (pos_q[:, None, :, None] - pos_k[:, None, None, :]).astype(jnp.float32)
            logits2 = logits2 - alibi[None, :, None, None] * d
        logits = jnp.where(is_sum_q[:, None, :, None], logits2, logits)

    mask = dti_mask(pos_q, pos_k, window=window,
                    is_sum_k=is_sum_k if sum_isolated else None,
                    valid_k=valid_k, seg_q=seg_q, seg_k=seg_k,
                    seg_shared=seg_shared)                      # (B,Sq,Sk)
    logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    # rows with no attendable key (padding) -> zero output
    any_ok = jnp.any(mask, axis=-1)[:, None, :, None]
    probs = jnp.where(any_ok, probs, 0.0)

    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v_r.dtype), v_r)

    if reset is not None and v0 is not None and is_sum_q is not None:
        v0_r = _repeat_kv(v0, n_rep)
        dist = jnp.clip(pos_q[:, :, None] - pos_k[:, None, :], 0)   # (B,Sq,Sk)
        a = reset_alpha(dist, reset)[:, None, :, :]                  # (B,1,Sq,Sk)
        probs_a = (probs * a) * is_sum_q[:, None, :, None]
        out = out + jnp.einsum("bhqk,bkhd->bqhd",
                               probs_a.astype(v_r.dtype), (v0_r - v_r))
    return out


# ---------------------------------------------------------------------------
# blocked (O(S * 2W)) path
# ---------------------------------------------------------------------------

def _to_blocks(x: jax.Array, blk: int) -> jax.Array:
    """(B, S, ...) -> (B, nb, blk, ...). S must be divisible by blk."""
    b, s = x.shape[:2]
    return x.reshape(b, s // blk, blk, *x.shape[2:])


def _with_prev(xb: jax.Array) -> jax.Array:
    """(B, nb, blk, ...) -> (B, nb, 2*blk, ...): concat(prev block, own block)."""
    prev = jnp.pad(xb[:, :-1], [(0, 0), (1, 0)] + [(0, 0)] * (xb.ndim - 2))
    return jnp.concatenate([prev, xb], axis=2)


def attention_blocked(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    pos_q: jax.Array, pos_k: jax.Array, window: int,
    is_sum_q: Optional[jax.Array] = None,
    is_sum_k: Optional[jax.Array] = None,
    valid_k: Optional[jax.Array] = None,
    seg_q: Optional[jax.Array] = None,
    seg_k: Optional[jax.Array] = None,
    q_nope: Optional[jax.Array] = None,
    k_nope: Optional[jax.Array] = None,
    alibi: Optional[jax.Array] = None,
    v0: Optional[jax.Array] = None,
    reset: Optional[ResetConfig] = None,
    sum_isolated: bool = True,
    scale: Optional[float] = None,
    q_chunk: int = 4,
) -> jax.Array:
    """Block-local windowed attention; semantics == attention_dense.

    Requires Sq == Sk == S, S % window == 0, window > 0. Each query block i
    attends kv blocks {i-1, i}; the (pos_q - pos_k <= window) mask inside the
    pair keeps semantics exact. Packed rows keep the block-pair invariant:
    positions restart per segment and segments are contiguous, so physical
    distance == positional distance for every same-segment pair, and the
    seg_q == seg_k mask term kills cross-segment aliases inside the pair.

    ``q_chunk``: when the sequence has more than q_chunk blocks, q-block
    chunks are processed sequentially (lax.map) so live fp32 logits stay
    O(q_chunk * H * W * 2W) instead of O(S/W * ...) — at 32k tokens with
    unsharded heads the difference is 19 GiB vs ~2 GiB of temp per device.
    This mirrors the grid schedule of the Pallas kernel.
    """
    assert window > 0, "blocked path needs a window"
    b, s, h, dqk = q.shape
    hk = k.shape[2]
    n_rep = h // hk
    if scale is None:
        scale = dqk ** -0.5
    blk = window
    assert s % blk == 0, f"seq {s} not divisible by window {blk}"
    nb = s // blk

    k_r = _repeat_kv(k, n_rep)
    v_r = _repeat_kv(v, n_rep)

    qb = _to_blocks(q, blk)                             # (B,nb,blk,H,D)
    kb = _with_prev(_to_blocks(k_r, blk))               # (B,nb,2blk,H,D)
    vb = _with_prev(_to_blocks(v_r, blk))
    pq = _to_blocks(pos_q, blk)                         # (B,nb,blk)
    pk = _with_prev(_to_blocks(pos_k, blk))             # (B,nb,2blk)
    # previous-of-block-0 is padding: mark invalid via huge negative position
    pad_valid = _with_prev(_to_blocks(jnp.ones_like(pos_k, dtype=bool)
                                      if valid_k is None else valid_k, blk))
    first = jnp.zeros((1, nb, 1), dtype=bool).at[:, 0, :].set(True)
    blk_pad = jnp.concatenate(
        [jnp.broadcast_to(first, (b, nb, blk)),
         jnp.zeros((b, nb, blk), dtype=bool)], axis=2)
    pad_valid = pad_valid & ~blk_pad

    use_nope = is_sum_q is not None and q_nope is not None
    use_reset = reset is not None and v0 is not None and is_sum_q is not None
    xs = {"qb": qb, "kb": kb, "vb": vb, "pq": pq, "pk": pk,
          "pad_valid": pad_valid}
    if use_nope:
        xs["qnb"] = _to_blocks(q_nope, blk)
        xs["knb"] = _with_prev(_to_blocks(_repeat_kv(k_nope, n_rep), blk))
    if is_sum_q is not None:
        xs["sq_b"] = _to_blocks(is_sum_q, blk)
    if sum_isolated and is_sum_k is not None:
        xs["sk_b"] = _with_prev(_to_blocks(is_sum_k, blk))
    if seg_q is not None and seg_k is not None:
        xs["sgq_b"] = _to_blocks(seg_q, blk)
        # prev-of-block-0 zero padding is already masked via pad_valid
        xs["sgk_b"] = _with_prev(_to_blocks(seg_k, blk))
    if use_reset:
        xs["v0b"] = _with_prev(_to_blocks(_repeat_kv(v0, n_rep), blk))

    def compute(c):
        logits = jnp.einsum("bnqhd,bnkhd->bnhqk", c["qb"], c["kb"],
                            preferred_element_type=jnp.float32) * scale
        if use_nope:
            logits2 = jnp.einsum("bnqhd,bnkhd->bnhqk", c["qnb"], c["knb"],
                                 preferred_element_type=jnp.float32) * scale
            if alibi is not None:
                dd = (c["pq"][:, :, None, :, None]
                      - c["pk"][:, :, None, None, :]).astype(jnp.float32)
                logits2 = logits2 - alibi[None, None, :, None, None] * dd
            logits = jnp.where(c["sq_b"][:, :, None, :, None], logits2,
                               logits)

        d = c["pq"][:, :, :, None] - c["pk"][:, :, None, :]
        mask = (d >= 0) & (d <= window) & c["pad_valid"][:, :, None, :]
        if "sk_b" in c:
            mask = mask & (~c["sk_b"][:, :, None, :] | (d == 0))
        if "sgq_b" in c:
            mask = mask & (c["sgq_b"][:, :, :, None] == c["sgk_b"][:, :, None, :])

        logits = jnp.where(mask[:, :, None, :, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        any_ok = jnp.any(mask, axis=-1)[:, :, None, :, None]
        probs = jnp.where(any_ok, probs, 0.0)

        out = jnp.einsum("bnhqk,bnkhd->bnqhd", probs.astype(c["vb"].dtype),
                         c["vb"])
        if use_reset:
            a = reset_alpha(jnp.clip(d, 0), reset)[:, :, None, :, :]
            probs_a = (probs * a) * c["sq_b"][:, :, None, :, None]
            out = out + jnp.einsum("bnhqk,bnkhd->bnqhd",
                                   probs_a.astype(c["vb"].dtype),
                                   (c["v0b"] - c["vb"]))
        return out

    if q_chunk and nb > q_chunk and nb % q_chunk == 0:
        nc = nb // q_chunk
        # (B, nb, ...) -> (nc, B, q_chunk, ...); lax.map over chunks
        split = jax.tree_util.tree_map(
            lambda t: jnp.moveaxis(
                t.reshape(b, nc, q_chunk, *t.shape[2:]), 1, 0), xs)
        out = jax.lax.map(compute, split)                # (nc,B,qc,blk,H,Dv)
        out = jnp.moveaxis(out, 0, 1).reshape(b, nb, blk, h, v.shape[-1])
    else:
        out = compute(xs)

    return out.reshape(b, s, h, v.shape[-1])


def attention(impl: str, *args, **kwargs) -> jax.Array:
    if impl != "dense" and kwargs.pop("seg_shared", None) is not None:
        # Multi-target serving rows interleave candidate segments whose
        # positions all continue from the context, so physical distance !=
        # positional distance — the block-pair schedule the banded paths
        # rely on does not hold.
        raise NotImplementedError(
            "shared-prefix segments (multi-target serving) require the "
            "dense attention path")
    if impl == "dense":
        return attention_dense(*args, **kwargs)
    if impl == "blocked":
        return attention_blocked(*args, **kwargs)
    if impl == "pallas":
        from repro.kernels.windowed_attn import ops as _ops
        return _ops.windowed_attention(*args, **kwargs)
    raise ValueError(f"unknown attention impl {impl!r}")


__all__ = ["ResetConfig", "reset_alpha", "dti_mask",
           "attention_dense", "attention_blocked", "attention"]
