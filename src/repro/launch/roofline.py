"""Roofline term derivation (TPU v5e constants) from dry-run records.

All three terms are per-device seconds (cost_analysis and the HLO both
describe the post-SPMD per-device program, so no further division by chip
count is needed):

    compute_s    = hlo_flops_per_device      / PEAK_FLOPS      (197 TF bf16)
    memory_s     = hlo_bytes_per_device      / HBM_BW          (819 GB/s)
    collective_s = collective_bytes_per_dev  / ICI_BW          (~50 GB/s/link)

``model_flops_ratio`` = MODEL_FLOPS / (hlo_flops x chips): how much of the
compiled compute is "useful" model math (catches remat recompute, dispatch
overhead, padding waste). MODEL_FLOPS comes from the analytic per-arch
model (6*N*D-style, windowed-attention aware) recorded in the cell meta.
"""
from __future__ import annotations

from typing import Dict

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


def roofline_terms(rec: Dict) -> Dict:
    pd = rec["per_device"]
    compute_s = pd["hlo_flops"] / PEAK_FLOPS
    memory_s = pd["hlo_bytes_accessed"] / HBM_BW
    collective_s = pd["collective_bytes"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(compute_s, memory_s, collective_s)

    model_flops = rec.get("meta", {}).get("model_flops", 0.0)
    n_dev = rec.get("n_devices", 1)
    hlo_global = pd["hlo_flops"] * n_dev
    out = dict(terms)
    out["bottleneck"] = bottleneck.replace("_s", "")
    out["step_time_lb_s"] = step_s
    out["model_flops"] = model_flops
    out["model_flops_ratio"] = (model_flops / hlo_global
                                if hlo_global else 0.0)
    # fraction of the compute roofline actually achieved if the step runs at
    # its bound: useful_flops / (chips * peak * step_time)
    if step_s > 0 and n_dev:
        out["roofline_fraction"] = model_flops / (n_dev * PEAK_FLOPS * step_s)
    else:
        out["roofline_fraction"] = 0.0
    return out


__all__ = ["roofline_terms", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
