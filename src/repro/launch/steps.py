"""Cell builders: (arch, shape, mesh) -> step fn + sharded input specs.

A *cell* is one graded (architecture x input-shape) combination. For each,
``build_cell`` returns the real step function (train step incl. optimizer
update, prefill, decode, serve or retrieval — whatever the shape's kind
dictates) plus ShapeDtypeStruct stand-ins for every input with NamedSharding
attached, so the dry-run can ``jit(...).lower(*args).compile()`` without
allocating anything.

The same builders back the smoke tests (pass ``smoke=True`` + the CPU mesh)
— the dry-run cells and the tests exercise identical code.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import ArchSpec, ShapeSpec
from repro.core.dti import SpecialTokens
from repro.core.flops import (param_count_active, param_count_total,
                              train_step_flops, transformer_fwd_flops)
from repro.core.losses import ctr_loss
from repro.models.gnn import GNNConfig, gin_forward, gin_graph_forward, init_gin
from repro.models.recsys import (RecsysConfig, _din_attend, bce_loss,
                                 init_recsys, mind_retrieval, recsys_logits,
                                 sasrec_encode)
from repro.models.transformer import ModelConfig, forward, init_params
from repro.serve.cache import init_lm_cache
from repro.serve.engine import make_decode_fn, make_prefill_fn
from repro.sharding.partition import (batch_spec, make_param_specs, rules_for,
                                      zero1_specs)
from repro.sparse.embedding import embedding_lookup
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state)
from repro.train.trainer import TrainState

SP = SpecialTokens()


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    step_fn: Callable
    args: Tuple[Any, ...]
    donate: Tuple[int, ...]
    meta: Dict[str, Any]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _attach(shapes: Any, specs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sp),
        shapes, specs)


def _sds(mesh, shape, dtype, *axes) -> jax.ShapeDtypeStruct:
    from repro.sharding.partition import spec_for_shape
    # divisibility-aware: batch=1 cells (long_500k, retrieval queries) drop
    # the data axis instead of failing the explicit input sharding
    spec = spec_for_shape(shape, tuple(axes), mesh)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _replicated_specs(tree: Any, mesh) -> Any:
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def _freeze_non_lora(params):
    """stop_gradient on every non-LoRA leaf: grads for frozen leaves are
    zero and DCE'd (no 2x-param grad buffers for PEFT archs)."""
    def one(path, p):
        from repro.sharding.partition import leaf_path_str
        return p if "lora" in leaf_path_str(path) else jax.lax.stop_gradient(p)
    return jax.tree_util.tree_map_with_path(one, params)


def _train_state_specs(params_shape, ocfg, rules, mesh, *, zero1=True,
                       zero1_axis="data", param_axis=None):
    param_specs = make_param_specs(params_shape, rules, mesh)
    if param_axis is not None:
        # ZeRO-3: shard every param's largest dim over `param_axis`; XLA
        # all-gathers the (bf16) weights per layer inside the scan and
        # reduce-scatters their gradients — no full grad/master tree ever
        # exists on one device.
        param_specs = zero1_specs(params_shape, param_specs, mesh,
                                  axis=param_axis)
    opt_shape = jax.eval_shape(partial(init_opt_state, ocfg), params_shape)
    repl = NamedSharding(mesh, P())

    def opt_tree_specs(tree_shape):
        sp = make_param_specs(tree_shape, rules, mesh)
        return (zero1_specs(tree_shape, sp, mesh, axis=zero1_axis)
                if zero1 else sp)

    opt_specs = type(opt_shape)(
        step=repl,
        mu=opt_tree_specs(opt_shape.mu),
        nu=opt_tree_specs(opt_shape.nu),
        master=(opt_tree_specs(opt_shape.master)
                if opt_shape.master is not None else None),
    )
    state_shape = TrainState(params=params_shape, opt=opt_shape, ef_error=None)
    state_specs = TrainState(params=param_specs, opt=opt_specs, ef_error=None)
    return state_shape, state_specs, opt_specs.mu


def _make_train_step(loss_fn, ocfg, *, grad_accum: int = 1,
                     grad_shardings=None, batch_shardings=None):
    """Train step with optional gradient-accumulation microbatching: the
    global batch is split on axis 0 into ``grad_accum`` microbatches scanned
    sequentially — per-device activation memory scales 1/grad_accum while
    the optimizer still sees the full-batch gradient.

    ``grad_shardings`` (pytree of NamedSharding mirroring params) pins the
    fp32 accumulator's layout: GSPMD does not infer scan-carry shardings, so
    without the constraint the accumulator replicates (2 x params x 4B of
    temp per device — the difference between fitting HBM and not)."""

    from repro.train.optimizer import _trainable_mask

    def constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s)
            if x.ndim else x, tree, grad_shardings)

    def train_step(state: TrainState, batch):
        mask = _trainable_mask(ocfg, state.params)
        if grad_accum <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
            # ZeRO-1: land gradients directly in the optimizer-state layout
            # — reduce-scatter in bf16 FIRST, upcast on the shard (casting
            # before the constraint materialises the full fp32 grad tree,
            # +10.9 GiB/device for minicpm-2b)
            grads = constrain(grads)
            grads = jax.tree_util.tree_map(
                lambda g, m: g.astype(jnp.float32) if m else g, grads, mask)
        else:
            # (B, ...) -> (A, B/A, ...); re-pin the batch sharding onto the
            # new axis 1 — after the reshape GSPMD would otherwise try to
            # shard axis 0 (= A, usually not divisible) and fall back to
            # fully replicated microbatches, silently dropping DP.
            def split(x, ns=None):
                y = x.reshape(grad_accum, x.shape[0] // grad_accum,
                              *x.shape[1:])
                if ns is not None:
                    y = jax.lax.with_sharding_constraint(
                        y, NamedSharding(ns.mesh, P(None, *ns.spec)))
                return y

            if batch_shardings is not None:
                mb = jax.tree_util.tree_map(
                    lambda x, s: split(x, s.sharding), batch,
                    batch_shardings)
            else:
                mb = jax.tree_util.tree_map(split, batch)

            def micro(carry, b):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, b)
                # frozen leaves keep a scalar accumulator (their grads are
                # zero and DCE'd — no 236B fp32 carries for PEFT archs)
                g_acc = jax.tree_util.tree_map(
                    lambda a, gg, m: a + gg.astype(jnp.float32) if m else a,
                    g_acc, g, mask)
                return (constrain(g_acc), l_acc + l), None

            zeros = constrain(jax.tree_util.tree_map(
                lambda p, m: (jnp.zeros(p.shape, jnp.float32) if m
                              else jnp.zeros((), jnp.float32)),
                state.params, mask))
            (grads, loss), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), mb)
            inv = 1.0 / grad_accum
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
            loss = loss * inv
        params, opt, stats = adamw_update(ocfg, grads, state.opt,
                                          state.params,
                                          shard_specs=grad_shardings)
        return TrainState(params, opt, state.ef_error), {
            "loss": loss, **stats}
    return train_step


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_opt_cfg(spec: ArchSpec, profile: str = "tp") -> OptimizerConfig:
    sched = "wsd" if "minicpm-2b" in spec.name else "cosine"
    # dp profile: params replicated in bf16; a separate fp32 master copy
    # forces XLA to materialise/gather full fp32 param-sized buffers around
    # the update (+12 GiB/dev, §Perf log). Without it the update fuses
    # elementwise; mu/nu stay fp32 (sharded ZeRO-1), so the second moment
    # keeps full precision and only the weight storage is bf16.
    return OptimizerConfig(lr=1e-4, schedule=sched, warmup_steps=100,
                           total_steps=10_000, trainable=spec.trainable,
                           master_fp32=(profile != "dp"))


def _lm_batch_specs(mesh, b, s, *, axis="data"):
    return {
        "tokens": _sds(mesh, (b, s), jnp.int32, axis, None),
        "positions": _sds(mesh, (b, s), jnp.int32, axis, None),
        "is_sum": _sds(mesh, (b, s), jnp.bool_, axis, None),
        "labels": _sds(mesh, (b, s), jnp.int32, axis, None),
        "valid": _sds(mesh, (b, s), jnp.bool_, axis, None),
    }


def _lm_train_cell(spec: ArchSpec, shape: ShapeSpec, mesh, cfg: ModelConfig,
                   overrides: Dict[str, Any]) -> Cell:
    p = dict(shape.params)
    b, s, win = p["global_batch"], p["seq_len"], p["window"]
    if "global_batch" in overrides:
        b = overrides["global_batch"]
    grad_accum = overrides.get("grad_accum", p.get("grad_accum", 1))
    grad_accum = max(1, min(grad_accum, b))         # smoke batches are tiny
    if b % grad_accum:
        grad_accum = 1
    ocfg = _lm_opt_cfg(spec, overrides.get("profile", spec.profile))
    lora = spec.trainable == "lora"

    def loss_fn(params, batch):
        if lora:
            params = _freeze_non_lora(params)
        out = forward(params, cfg, batch["tokens"],
                      positions=batch["positions"], is_sum=batch["is_sum"],
                      valid=batch["valid"], dti_enabled=True, window=win)
        loss, _ = ctr_loss(params, cfg, out["hidden"], batch["is_sum"],
                           batch["labels"], yes_id=SP.yes, no_id=SP.no)
        return loss + out["aux_loss"]

    params_shape = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    profile = overrides.get("profile", spec.profile)
    rules = rules_for("lm", "tp" if profile == "zero3" else profile)
    state_shape, state_specs, mu_specs = _train_state_specs(
        params_shape, ocfg, [] if profile == "zero3" else rules, mesh,
        zero1_axis=(("data", "model") if profile in ("dp", "zero3")
                    else "data"),
        param_axis="model" if profile == "zero3" else None)
    # pure DP: the batch spreads over the WHOLE mesh (every device is a
    # data shard) when the microbatch still divides it; otherwise fall back
    # to the data axis + accumulation
    from repro.sharding.partition import _axis_size, _resolve_axis
    batch_axis = "data"
    if profile == "dp":
        full = _axis_size(_resolve_axis(("data", "model"), mesh), mesh)
        if b % full == 0:
            # full-mesh DP: accumulation capped so every microbatch still
            # spans the whole mesh (usually accum=1 at 1 seq/device)
            batch_axis = ("data", "model")
            grad_accum = max(1, min(grad_accum, b // full))
    batch_sds = _lm_batch_specs(mesh, b, s, axis=batch_axis)

    tokens = b * s
    meta = dict(
        tokens_per_step=tokens,
        model_flops=train_step_flops(cfg, b, s, kv_len=win,
                                     dti_sum_rows=True),
        six_nd_flops=6.0 * param_count_active(cfg) * tokens,
        params_total=param_count_total(cfg),
        grad_accum=grad_accum, remat_policy=cfg.remat_policy,
    )
    return Cell(spec.name, shape.name, "train",
                _make_train_step(loss_fn, ocfg, grad_accum=grad_accum,
                                 grad_shardings=mu_specs,
                                 batch_shardings=batch_sds),
                (_attach(state_shape, state_specs), batch_sds),
                donate=(0,), meta=meta)


def _lm_prefill_cell(spec: ArchSpec, shape: ShapeSpec, mesh,
                     cfg: ModelConfig, overrides) -> Cell:
    p = dict(shape.params)
    b, s, win = p["global_batch"], p["seq_len"], p["window"]
    prefill = make_prefill_fn(cfg, yes_id=SP.yes, no_id=SP.no, window=win)
    chunks = overrides.get("prefill_chunks",
                           p.get("prefill_chunks",
                                 spec.shapes[shape.name].params.get(
                                     "prefill_chunks", 1)))
    from repro.sharding.partition import dp_size
    bc = b // chunks
    if chunks > 1 and b % chunks == 0:
        # sequential batch chunks (lax.map) bound the live token count —
        # same lever as grad-accum microbatching, applied to inference.
        # When the chunk batch no longer divides the full dp extent
        # (multi-pod: bc=16 vs pod x data = 32) fall back to the inner
        # "data" axis and accept pod-replicated chunk compute — fitting HBM
        # beats the idle pod (noted per-cell in EXPERIMENTS.md §Dry-run).
        batch_axis = ("data",)
        if bc % dp_size(mesh) == 0:
            batch_axis = ("data",)          # alias resolves to pod+data
            full = True
        else:
            full = False

        def step(params, batch):
            def split(x):
                y = x.reshape(chunks, bc, *x.shape[1:])
                if full:
                    ns = batch_spec(mesh, "data",
                                    *([None] * (x.ndim - 1)))
                    spec = P(None, *ns.spec)
                else:
                    inner = ("data",) if "data" in mesh.axis_names else ()
                    spec = P(None, inner[0] if inner else None,
                             *([None] * (x.ndim - 1)))
                return jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, spec))
            mb = jax.tree_util.tree_map(split, batch)
            out = jax.lax.map(lambda bb: prefill(params, bb), mb)
            return out.reshape(b, *out.shape[2:])
    else:
        step = prefill
    params_shape = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    # inference: "dp" trains pure-DP but serves Megatron-TP (a 32..128
    # request batch cannot spread over 256 devices; sharded weights can)
    prof = "tp" if spec.profile in ("dp", "zero3") else spec.profile
    param_specs = make_param_specs(params_shape, rules_for("lm", prof), mesh)
    batch_sds = {k: v for k, v in _lm_batch_specs(mesh, b, s).items()
                 if k != "labels"}
    meta = dict(
        tokens_per_step=b * s,
        model_flops=transformer_fwd_flops(cfg, b, s, kv_len=win,
                                          with_lm_head=False).total,
        six_nd_flops=2.0 * param_count_active(cfg) * b * s,
        params_total=param_count_total(cfg),
    )
    return Cell(spec.name, shape.name, "prefill", step,
                (_attach(params_shape, param_specs), batch_sds),
                donate=(), meta=meta)


def _lm_decode_cell(spec: ArchSpec, shape: ShapeSpec, mesh, cfg: ModelConfig,
                    overrides, *, ring: bool) -> Cell:
    p = dict(shape.params)
    b, win = p["global_batch"], p["window"]
    capacity = p["ring_capacity"] if ring else p["cache_len"]
    step = make_decode_fn(cfg, window=win, ring=ring,
                          yes_id=SP.yes, no_id=SP.no)

    params_shape = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    prof = "tp" if spec.profile in ("dp", "zero3") else spec.profile
    param_specs = make_param_specs(params_shape, rules_for("lm", prof), mesh)
    cache_shape_ = jax.eval_shape(
        partial(init_lm_cache, cfg, b, capacity))
    # cache: batch over data, sequence (capacity) over model
    def cache_spec(path, leaf):
        from repro.sharding.partition import leaf_path_str, spec_for_shape
        key = leaf_path_str(path)
        if key in ("pos",):
            return NamedSharding(mesh, spec_for_shape(
                leaf.shape, ("data", "model"), mesh))
        if key in ("cursor",):
            return NamedSharding(mesh, spec_for_shape(
                leaf.shape, ("data",), mesh))
        tpl = (None, "data", "model") + (None,) * (len(leaf.shape) - 3)
        return NamedSharding(mesh, spec_for_shape(leaf.shape, tpl, mesh))
    cache_specs = jax.tree_util.tree_map_with_path(cache_spec, cache_shape_)

    tok_sds = _sds(mesh, (b, 1), jnp.int32, "data", None)
    sum_sds = _sds(mesh, (b, 1), jnp.bool_, "data", None)
    meta = dict(
        tokens_per_step=b,
        model_flops=transformer_fwd_flops(
            cfg, b, 1, kv_len=min(win, capacity), with_lm_head=False).total,
        six_nd_flops=2.0 * param_count_active(cfg) * b,
        params_total=param_count_total(cfg),
        cache_capacity=capacity, ring=ring,
        logical_len=p["cache_len"],
    )
    return Cell(spec.name, shape.name, "decode_ring" if ring else "decode",
                step,
                (_attach(params_shape, param_specs),
                 _attach(cache_shape_, cache_specs), tok_sds, tok_sds,
                 sum_sds),
                donate=(1,), meta=meta)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_batch_specs(mesh, cfg: RecsysConfig, b, *, labels: bool):
    out: Dict[str, Any] = {}
    if cfg.kind == "xdeepfm":
        out["ids"] = _sds(mesh, (b, len(cfg.field_vocabs)), jnp.int32,
                          "data", None)
    else:
        out["hist"] = _sds(mesh, (b, cfg.seq_len), jnp.int32, "data", None)
        out["target"] = _sds(mesh, (b,), jnp.int32, "data")
    if labels:
        out["labels"] = _sds(mesh, (b,), jnp.int32, "data")
    return out


def _recsys_flops(cfg: RecsysConfig, b: int) -> float:
    """Rough per-example matmul FLOPs (forward)."""
    d = cfg.embed_dim
    if cfg.kind == "xdeepfm":
        m = len(cfg.field_vocabs)
        cin = 0.0
        h_prev = m
        for h in cfg.cin_layers:
            cin += 2 * h * h_prev * m * d          # compress einsum
            cin += h_prev * m * d                  # outer product
            h_prev = h
        dims = [m * d, *cfg.dnn_dims, 1]
        dnn = sum(2 * a * bb for a, bb in zip(dims[:-1], dims[1:]))
        return b * (cin + dnn)
    if cfg.kind == "din":
        l = cfg.seq_len
        attn_dims = [4 * d, *cfg.attn_mlp, 1]
        attn = l * sum(2 * a * bb for a, bb in zip(attn_dims[:-1],
                                                   attn_dims[1:]))
        head_dims = [3 * d, *cfg.head_mlp, 1]
        head = sum(2 * a * bb for a, bb in zip(head_dims[:-1], head_dims[1:]))
        return b * (attn + head + 2 * l * d)
    if cfg.kind == "sasrec":
        l = cfg.seq_len
        per_blk = 4 * 2 * l * d * d + 2 * 2 * l * l * d + 2 * 2 * l * d * d
        return b * (cfg.n_blocks * per_blk + 2 * d)
    if cfg.kind == "mind":
        l, k = cfg.seq_len, cfg.n_interests
        routing = cfg.capsule_iters * 2 * (2 * k * l * d)
        return b * (2 * l * d * d + routing + 2 * 2 * d * 64)
    raise ValueError(cfg.kind)


def _recsys_train_cell(spec, shape, mesh, cfg: RecsysConfig, overrides) -> Cell:
    b = overrides.get("global_batch", shape.params["batch"])
    ocfg = OptimizerConfig(lr=1e-3, schedule="cosine", total_steps=10_000)

    def loss_fn(params, batch):
        return bce_loss(recsys_logits(params, cfg, batch), batch["labels"])

    params_shape = jax.eval_shape(
        lambda: init_recsys(jax.random.PRNGKey(0), cfg))
    rules = rules_for("recsys")
    state_shape, state_specs, mu_specs = _train_state_specs(
        params_shape, ocfg, rules, mesh)
    batch_sds = _recsys_batch_specs(mesh, cfg, b, labels=True)
    meta = dict(tokens_per_step=b,
                model_flops=3 * _recsys_flops(cfg, b),
                embed_rows=_embed_rows(cfg),
                params_total=_recsys_params(params_shape))
    return Cell(spec.name, shape.name, "train",
                _make_train_step(loss_fn, ocfg),
                (_attach(state_shape, state_specs), batch_sds),
                donate=(0,), meta=meta)


def _embed_rows(cfg: RecsysConfig) -> int:
    if cfg.kind == "xdeepfm":
        return sum(cfg.field_vocabs)
    return cfg.n_items


def _recsys_params(params_shape) -> int:
    return sum(int(jnp.prod(jnp.asarray(l.shape))) if l.shape else 1
               for l in jax.tree_util.tree_leaves(params_shape))


def _recsys_serve_cell(spec, shape, mesh, cfg: RecsysConfig, overrides) -> Cell:
    b = overrides.get("global_batch", shape.params["batch"])

    def step(params, batch):
        return jax.nn.sigmoid(
            recsys_logits(params, cfg, batch).astype(jnp.float32))

    params_shape = jax.eval_shape(
        lambda: init_recsys(jax.random.PRNGKey(0), cfg))
    param_specs = make_param_specs(params_shape, rules_for("recsys"), mesh)
    batch_sds = _recsys_batch_specs(mesh, cfg, b, labels=False)
    meta = dict(tokens_per_step=b, model_flops=_recsys_flops(cfg, b),
                embed_rows=_embed_rows(cfg))
    return Cell(spec.name, shape.name, "serve", step,
                (_attach(params_shape, param_specs), batch_sds),
                donate=(), meta=meta)


RETRIEVAL_CHUNK = 8000


def _recsys_retrieval_cell(spec, shape, mesh, cfg: RecsysConfig,
                           overrides) -> Cell:
    c = shape.params["n_candidates"]
    chunk = overrides.get("retrieval_chunk", RETRIEVAL_CHUNK)

    if cfg.kind == "mind":
        def step(params, batch):
            return mind_retrieval(params, cfg, batch["hist"],
                                  batch["cand_ids"])
    elif cfg.kind == "sasrec":
        def step(params, batch):
            h = sasrec_encode(params, cfg, batch["hist"])[:, -1]   # (1, D)
            cand = embedding_lookup(params["items"], batch["cand_ids"])
            return (cand @ h[0]).astype(jnp.float32)
    elif cfg.kind == "din":
        def step(params, batch):
            from repro.models.layers import mlp
            h = embedding_lookup(params["items"], batch["hist"])   # (1,L,D)

            def score_chunk(ids):
                t = embedding_lookup(params["items"], ids)[None]   # (1,c,D)
                user = _din_attend(params, h, t, None)
                x = jnp.concatenate([user, t, user * t], axis=-1)
                return mlp(params["head"], x)[0, :, 0]

            return jax.lax.map(score_chunk, batch["cand_ids"]).reshape(-1)
    elif cfg.kind == "xdeepfm":
        from repro.models.recsys import xdeepfm_forward
        v0 = cfg.field_vocabs[0]

        def step(params, batch):
            def score_chunk(ids):
                full = jnp.broadcast_to(batch["base_ids"],
                                        (ids.shape[0],
                                         len(cfg.field_vocabs)))
                full = full.at[:, 0].set(ids % v0)
                return xdeepfm_forward(params, cfg, full)

            return jax.lax.map(score_chunk, batch["cand_ids"]).reshape(-1)
    else:
        raise ValueError(cfg.kind)

    params_shape = jax.eval_shape(
        lambda: init_recsys(jax.random.PRNGKey(0), cfg))
    param_specs = make_param_specs(params_shape, rules_for("recsys"), mesh)
    batch_sds: Dict[str, Any] = {}
    if cfg.kind in ("din", "xdeepfm"):
        # chunked scoring: lax.map over the leading chunk index, candidates
        # within each chunk shard over the data axis
        if c % chunk:
            chunk = next(cc for cc in range(chunk, 0, -1) if c % cc == 0)
        batch_sds["cand_ids"] = _sds(mesh, (c // chunk, chunk), jnp.int32,
                                     None, "data")
    else:
        # single-shot scoring: candidates shard over data directly
        batch_sds["cand_ids"] = _sds(mesh, (c,), jnp.int32, "data")
    if cfg.kind == "xdeepfm":
        batch_sds["base_ids"] = _sds(mesh, (1, len(cfg.field_vocabs)),
                                     jnp.int32, None, None)
    else:
        batch_sds["hist"] = _sds(mesh, (1, cfg.seq_len), jnp.int32,
                                 None, None)
    meta = dict(tokens_per_step=c, model_flops=_recsys_flops(cfg, c),
                embed_rows=_embed_rows(cfg))
    return Cell(spec.name, shape.name, "retrieval", step,
                (_attach(params_shape, param_specs), batch_sds),
                donate=(), meta=meta)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _ce_loss(logits, labels, mask=None):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    if mask is None:
        return jnp.mean(nll)
    w = mask.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def _gnn_flops(cfg: GNNConfig, n_nodes: int, n_edges: int) -> float:
    d = cfg.d_hidden
    per_layer = 2 * n_nodes * d * d * 2 + n_edges * d   # MLP + scatter adds
    return (2 * n_nodes * cfg.d_feat * d + cfg.n_layers * per_layer
            + 2 * n_nodes * d * cfg.n_classes)


def _gnn_cell(spec: ArchSpec, shape: ShapeSpec, mesh, overrides,
              cfg_overrides=None) -> Cell:
    from repro.configs.gin_tu import config_for_shape
    p = dict(shape.params)
    cfg = config_for_shape(p)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    ocfg = OptimizerConfig(lr=1e-3, schedule="cosine", total_steps=5_000)
    kind = shape.kind

    if kind in ("graph_full", "graph_sampled"):
        n = p.get("n_nodes")
        e = p.get("n_edges")
        if kind == "graph_sampled":
            seeds = p["batch_nodes"]
            f = p["fanouts"]
            n = seeds * (1 + f[0] + f[0] * f[1])
            e = seeds * (f[0] + f[0] * f[1])

        def loss_fn(params, batch):
            logits = gin_forward(params, cfg, batch["x"], batch["edge_src"],
                                 batch["edge_dst"],
                                 edge_valid=batch["edge_valid"])
            return _ce_loss(logits, batch["labels"], batch["label_mask"])

        batch_sds = {
            "x": _sds(mesh, (n, cfg.d_feat), jnp.float32, None, None),
            "edge_src": _sds(mesh, (e,), jnp.int32, "data"),
            "edge_dst": _sds(mesh, (e,), jnp.int32, "data"),
            "edge_valid": _sds(mesh, (e,), jnp.bool_, "data"),
            "labels": _sds(mesh, (n,), jnp.int32, None),
            "label_mask": _sds(mesh, (n,), jnp.bool_, None),
        }
        meta_tokens = n
    elif kind == "graph_batched":
        bsz, nn, ne = p["batch"], p["n_nodes"], p["n_edges"]
        n, e = bsz * nn, bsz * ne

        def loss_fn(params, batch):
            logits = gin_graph_forward(params, cfg, batch["x"],
                                       batch["edge_src"], batch["edge_dst"],
                                       batch["graph_ids"], bsz,
                                       edge_valid=batch["edge_valid"])
            return _ce_loss(logits, batch["labels"])

        batch_sds = {
            "x": _sds(mesh, (n, cfg.d_feat), jnp.float32, None, None),
            "edge_src": _sds(mesh, (e,), jnp.int32, "data"),
            "edge_dst": _sds(mesh, (e,), jnp.int32, "data"),
            "edge_valid": _sds(mesh, (e,), jnp.bool_, "data"),
            "graph_ids": _sds(mesh, (n,), jnp.int32, None),
            "labels": _sds(mesh, (bsz,), jnp.int32, None),
        }
        meta_tokens = n
    else:
        raise ValueError(kind)

    params_shape = jax.eval_shape(lambda: init_gin(jax.random.PRNGKey(0),
                                                   cfg))
    rules = rules_for("gnn")
    state_shape, state_specs, _ = _train_state_specs(params_shape, ocfg,
                                                     rules, mesh, zero1=False)
    n_e = e if kind != "graph_sampled" else e
    meta = dict(tokens_per_step=meta_tokens,
                model_flops=3 * _gnn_flops(cfg, meta_tokens, n_e))
    return Cell(spec.name, shape.name, kind,
                _make_train_step(loss_fn, ocfg),
                (_attach(state_shape, state_specs), batch_sds),
                donate=(0,), meta=meta)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def build_cell(arch_name: str, shape_name: str, mesh, *,
               smoke: bool = False,
               overrides: Optional[Dict[str, Any]] = None) -> Cell:
    spec = get_arch(arch_name)
    shape = spec.shape(shape_name)
    overrides = dict(overrides or {})
    cfg_overrides = overrides.pop("config", {})
    # activation pinning (repro.sharding.act): measured NET-HARMFUL for
    # tp/fsdp_tp (GSPMD re-reshards around the pins: qwen2-moe prefill
    # 13.2 -> 86.5 GiB/dev, §Perf log) and essential for the dp profile
    # (weight-grad contractions would gather global activations). Default:
    # only the dp profile pins.
    act_shard = overrides.pop(
        "act_shard",
        spec.family == "lm"
        and overrides.get("profile", spec.profile) == "dp")
    cell = _build_cell_inner(spec, shape, mesh, smoke=smoke,
                             overrides=overrides,
                             cfg_overrides=cfg_overrides)
    if act_shard and not smoke:
        from repro.sharding.act import with_activation_mesh
        profile = (overrides.get("profile", spec.profile))
        if profile in ("dp", "zero3") and cell.kind != "train":
            profile = "tp"                      # inference serves TP
        batch_axis = ("data", "model") if profile == "dp" else "data"
        tensor_axis = "model" if profile in ("tp", "fsdp_tp") else None
        cell.step_fn = with_activation_mesh(cell.step_fn, mesh, batch_axis,
                                            tensor_axis)
        cell.meta["act_shard"] = True
    return cell


def _build_cell_inner(spec, shape, mesh, *, smoke, overrides,
                      cfg_overrides) -> Cell:

    if spec.family == "lm":
        cfg = spec.smoke if smoke else spec.config
        if cfg_overrides:
            cfg = dataclasses.replace(cfg, **cfg_overrides)
        if smoke:
            shape = _shrink_lm_shape(shape, cfg)
        if shape.kind == "train":
            return _lm_train_cell(spec, shape, mesh, cfg, overrides)
        if shape.kind == "prefill":
            return _lm_prefill_cell(spec, shape, mesh, cfg, overrides)
        if shape.kind == "decode":
            return _lm_decode_cell(spec, shape, mesh, cfg, overrides,
                                   ring=False)
        if shape.kind == "decode_ring":
            return _lm_decode_cell(spec, shape, mesh, cfg, overrides,
                                   ring=True)
        raise ValueError(shape.kind)

    if spec.family == "recsys":
        cfg = spec.smoke if smoke else spec.config
        if cfg_overrides:
            cfg = dataclasses.replace(cfg, **cfg_overrides)
        if smoke:
            shape = _shrink_recsys_shape(shape)
        if shape.kind == "train":
            return _recsys_train_cell(spec, shape, mesh, cfg, overrides)
        if shape.kind == "serve":
            return _recsys_serve_cell(spec, shape, mesh, cfg, overrides)
        if shape.kind == "retrieval":
            return _recsys_retrieval_cell(spec, shape, mesh, cfg, overrides)
        raise ValueError(shape.kind)

    if spec.family == "gnn":
        if smoke:
            shape = _shrink_gnn_shape(shape)
        return _gnn_cell(spec, shape, mesh, overrides, cfg_overrides)

    raise ValueError(spec.family)


def _shrink_lm_shape(shape: ShapeSpec, cfg: ModelConfig) -> ShapeSpec:
    p = dict(shape.params)
    win = cfg.window or 32
    p["window"] = win
    p["global_batch"] = 2
    if "seq_len" in p:
        p["seq_len"] = 4 * win
    if "cache_len" in p:
        p["cache_len"] = 2 * win
    if "ring_capacity" in p:
        p["ring_capacity"] = 2 * win
    return ShapeSpec(shape.name, shape.kind, p)


def _shrink_recsys_shape(shape: ShapeSpec) -> ShapeSpec:
    p = dict(shape.params)
    if "batch" in p:
        p["batch"] = 8
    if "n_candidates" in p:
        p["n_candidates"] = 64
    return ShapeSpec(shape.name, shape.kind, p)


def _shrink_gnn_shape(shape: ShapeSpec) -> ShapeSpec:
    p = dict(shape.params)
    for k, v in [("n_nodes", 128), ("n_edges", 512), ("batch_nodes", 8),
                 ("batch", 4)]:
        if k in p:
            p[k] = v
    if "fanouts" in p:
        p["fanouts"] = (3, 2)
    if "n_nodes_raw" in p:
        p["n_nodes_raw"], p["n_edges_raw"] = 100, 400
    p["d_feat"] = min(p.get("d_feat", 16), 16)
    p["n_classes"] = min(p.get("n_classes", 4), 4)
    return ShapeSpec(shape.name, shape.kind, p)


__all__ = ["Cell", "build_cell", "RETRIEVAL_CHUNK"]
