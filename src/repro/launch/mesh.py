"""Mesh builders for the production pods.

Functions, not module-level constants: importing this module never touches
jax device state. The dry-run entry point (repro.launch.dryrun) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the 1 real CPU device and uses
``make_cpu_mesh``.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4.37; older jax has neither the enum
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_cpu_mesh() -> Mesh:
    """Degenerate (1, 1) mesh on the host device — lets every sharded code
    path run unchanged in tests on one CPU."""
    return _make_mesh((1, 1), ("data", "model"))


__all__ = ["make_production_mesh", "make_cpu_mesh"]
