"""Mesh builders for the production pods.

Functions, not module-level constants: importing this module never touches
jax device state. The dry-run entry point (repro.launch.dryrun) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the 1 real CPU device and uses
``make_cpu_mesh``.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4.37; older jax has neither the enum
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_cpu_mesh() -> Mesh:
    """Degenerate (1, 1) mesh on the host device — lets every sharded code
    path run unchanged in tests on one CPU."""
    return _make_mesh((1, 1), ("data", "model"))


def make_serve_mesh(dp: int = 2, mp: int = 4) -> Mesh:
    """``(data, model)`` mesh for the sharded serving/stream lane: the
    scheduler's paged KV slot axis shards over ``data`` (each shard owns a
    range of the page pool) and KV heads over ``model`` — see
    ``repro.sharding.partition.cache_specs`` and docs/sharding.md. Raises
    when the runtime has fewer than ``dp * mp`` devices; the forced-CPU CI
    lane provides 8 via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    (which must be set before the first jax import)."""
    need = dp * mp
    have = len(jax.devices())
    if have < need:
        raise ValueError(
            f"make_serve_mesh({dp}, {mp}) needs {need} devices, runtime has "
            f"{have} — set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need} before importing jax (or use make_cpu_mesh)")
    return _make_mesh((dp, mp), ("data", "model"))


__all__ = ["make_production_mesh", "make_cpu_mesh", "make_serve_mesh"]
