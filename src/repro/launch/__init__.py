"""repro.launch — meshes, cell builders, dry-run + training entry points.

NOTE: repro.launch.dryrun must be imported/run as the process entry point
(it sets XLA_FLAGS for 512 placeholder devices before jax loads); nothing
here imports it.
"""
from repro.launch.mesh import make_cpu_mesh, make_production_mesh
from repro.launch.steps import Cell, build_cell
