"""Reduced-config smoke training for every assigned architecture.

``train_smoke(arch)`` instantiates the arch's SMOKE config, generates
matching synthetic data, runs real optimizer steps on CPU, and returns the
loss trajectory + output sanity (shapes, finiteness). Used by the per-arch
smoke tests and by ``repro.launch.train`` for non-LM archs.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.dti import SpecialTokens, batch_prompts, build_streaming_prompts
from repro.core.losses import ctr_loss
from repro.data.recsys_gen import RecsysGenerator
from repro.data.sampler import (make_community_graph, make_molecule_batch,
                                sample_neighbors)
from repro.data.synthetic import make_ctr_dataset
from repro.models.gnn import gin_forward, gin_graph_forward, init_gin
from repro.models.recsys import bce_loss, init_recsys, recsys_logits
from repro.models.transformer import forward, init_params
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import init_train_state, make_train_step

SP = SpecialTokens()


def _ce(logits, labels, mask=None):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    if mask is None:
        return jnp.mean(nll)
    w = mask.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def _run(loss_fn, params, batches, steps, lr) -> Dict:
    ocfg = OptimizerConfig(lr=lr, schedule="const", warmup_steps=1,
                           total_steps=steps)
    state = init_train_state(params, ocfg)
    step_fn = make_train_step(loss_fn, ocfg)
    losses = []
    rng = jax.random.PRNGKey(0)
    for i in range(steps):
        rng, sub = jax.random.split(rng)
        state, m = step_fn(state, next(batches), sub)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), f"non-finite loss: {losses}"
    return {"losses": losses, "first": losses[0], "last": losses[-1],
            "state": state}


def train_smoke(arch: str, *, steps: int = 20, batch: int = 8,
                seed: int = 0, lr: float = 1e-2) -> Dict:
    spec = get_arch(arch)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)

    if spec.family == "lm":
        cfg = spec.smoke
        ds = make_ctr_dataset(n_users=8, n_items=64, seq_len=30,
                              vocab_size=cfg.vocab_size, seed=seed)
        prompts = []
        for u in range(8):
            toks, labels = ds.user_prompt_material(u)
            prompts += build_streaming_prompts(toks, labels, n_ctx=4, k=4,
                                               max_len=4 * max(cfg.window, 32))
        params = init_params(key, cfg)
        win = cfg.window or 0

        def loss_fn(p, b, r):
            out = forward(p, cfg, b["tokens"], positions=b["positions"],
                          is_sum=b["is_sum"], valid=b["valid"],
                          dti_enabled=True, window=win)
            loss, _ = ctr_loss(p, cfg, out["hidden"], b["is_sum"],
                               b["labels"], yes_id=SP.yes, no_id=SP.no)
            return loss + out["aux_loss"], {}

        def batches():
            while True:
                yield from batch_prompts(prompts, batch, rng=rng)

        return {"arch": arch, **_run(loss_fn, params, batches(), steps, lr)}

    if spec.family == "recsys":
        cfg = spec.smoke
        gen = RecsysGenerator(cfg.n_items, seed=seed)

        def batches():
            while True:
                if cfg.kind == "xdeepfm":
                    yield gen.field_batch(batch, cfg.field_vocabs, rng=rng)
                else:
                    yield gen.seq_batch(batch, cfg.seq_len, rng=rng)

        params = init_recsys(key, cfg)

        def loss_fn(p, b, r):
            return bce_loss(recsys_logits(p, cfg, b), b["labels"]), {}

        return {"arch": arch, **_run(loss_fn, params, batches(), steps, lr)}

    if spec.family == "gnn":
        cfg = spec.smoke
        g = make_community_graph(200, 6, cfg.d_feat, cfg.n_classes, seed=seed)
        es, ed = g.edge_list()
        params = init_gin(key, cfg)
        full = {"x": g.x, "edge_src": es, "edge_dst": ed,
                "edge_valid": np.ones(len(es), bool),
                "labels": g.y, "label_mask": np.ones(len(g.y), bool)}

        def loss_fn(p, b, r):
            logits = gin_forward(p, cfg, b["x"], b["edge_src"],
                                 b["edge_dst"], edge_valid=b["edge_valid"])
            return _ce(logits, b["labels"], b["label_mask"]), {}

        def batches():
            while True:
                yield full

        return {"arch": arch, **_run(loss_fn, params, batches(), steps, lr)}

    raise ValueError(spec.family)


__all__ = ["train_smoke"]
