"""End-to-end training driver (CLI).

    PYTHONPATH=src python -m repro.launch.train \
        --arch dti-llama --paradigm dti --k 10 --steps 200

Trains the paper's CTR LLM (the CPU-scale REPRO config by default) on the
synthetic MovieLens-like corpus with either training paradigm:

  * ``--paradigm sw``   — sliding-window baseline (1 target / prompt)
  * ``--paradigm dti``  — streaming prompts with k targets (+ windowed
    causal attention, [SUM] loss, hidden-state reset, SUM NoPE+ALiBi)
  * ``--paradigm dti-`` — DTI without the two bottleneck fixes (ablation)

``--pack`` bin-packs prompts into shared segment-isolated rows (fewer,
denser rows per epoch; docs/batch_schema.md). ``--attn-impl pallas``
trains through the fused windowed-attention kernel's custom VJP
(docs/kernels.md); banded impls get a finite window automatically when
the config's is 0 (``effective_window``).

Non-LM archs (--arch gin-tu / din / ...) train their smoke config on the
matching synthetic generator — every assigned architecture is runnable
end-to-end from this one driver.

Checkpointing (atomic, keep-k, resumable), straggler monitoring and the
full evaluation (AUC / LogLoss / F1) are always on; this is the same
runtime the production mesh would run, minus the mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.dti import (PromptStats, SpecialTokens, batch_prompts,
                            build_sliding_prompts, build_streaming_prompts,
                            effective_window, pack_prompts, train_max_len,
                            window_tokens)
from repro.core.losses import ctr_loss
from repro.core.metrics import ctr_metrics
from repro.data.synthetic import make_ctr_dataset, split_users
from repro.models.transformer import ModelConfig, forward, init_params
from repro.obs.clock import monotonic
from repro.serve.engine import make_prefill_fn
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptimizerConfig
from repro.train.resilience import StragglerMonitor
from repro.train.trainer import (TrainOptions, Trainer, init_train_state,
                                 make_train_step)

SP = SpecialTokens()


# ---------------------------------------------------------------------------
# LM CTR training (the paper)
# ---------------------------------------------------------------------------

def build_prompt_sets(ds, splits, *, paradigm: str, n_ctx: int, k: int,
                      max_len: int):
    """-> (train_prompts, stats), eval prompt builder uses SW always."""
    train, _, test = splits
    stats = PromptStats()
    train_prompts: List[Dict[str, np.ndarray]] = []
    for toks, labels in train:
        if len(toks) <= n_ctx:
            continue
        if paradigm == "sw":
            train_prompts += build_sliding_prompts(
                toks, labels, n_ctx=n_ctx, max_len=max_len, stats=stats)
        else:
            train_prompts += build_streaming_prompts(
                toks, labels, n_ctx=n_ctx, k=k, max_len=max_len, stats=stats)
    test_prompts, test_labels = [], []
    for toks, labels, start in test:
        for i in range(max(start, n_ctx), len(toks)):
            p = build_sliding_prompts(toks[i - n_ctx:i + 1],
                                      labels[i - n_ctx:i + 1],
                                      n_ctx=n_ctx, max_len=max_len)
            test_prompts += p
            test_labels.append(int(labels[i]))
    return train_prompts, test_prompts, np.asarray(test_labels), stats


def make_lm_loss_fn(cfg: ModelConfig, window: int):
    """Loss over the canonical batch schema; consumes packed rows whenever
    the batch carries ``segment_ids`` (cross-segment isolation happens in
    the attention mask, the [SUM] loss itself is position-local)."""
    def loss_fn(params, batch, rng):
        out = forward(params, cfg, batch["tokens"],
                      positions=batch["positions"], is_sum=batch["is_sum"],
                      valid=batch["valid"],
                      segment_ids=batch.get("segment_ids"),
                      dti_enabled=cfg.dti_sum_token, window=window)
        loss, _ = ctr_loss(params, cfg, out["hidden"], batch["is_sum"],
                           batch["labels"], yes_id=SP.yes, no_id=SP.no)
        return loss + out["aux_loss"], {}
    return loss_fn


def evaluate_lm(params, cfg: ModelConfig, window: int, test_prompts,
                test_labels, *, batch_size: int = 32) -> Dict[str, float]:
    prefill = jax.jit(make_prefill_fn(cfg, yes_id=SP.yes, no_id=SP.no,
                                      window=window))
    scores = []
    for batch in batch_prompts(test_prompts, batch_size):
        p = np.asarray(prefill(params, {k: batch[k] for k in
                                        ("tokens", "positions", "is_sum",
                                         "valid")}))
        for i in range(p.shape[0]):
            sums = np.flatnonzero(batch["is_sum"][i])
            scores.append(p[i, sums[-1]] if len(sums) else 0.5)
    scores = np.asarray(scores[: len(test_labels)])
    return ctr_metrics(test_labels, scores)


def run_lm(args) -> Dict:
    arch = get_arch(args.arch)
    cfg = arch.smoke if args.size == "smoke" else arch.config
    if args.paradigm == "sw":
        cfg = dataclasses.replace(cfg, dti_reset=False, dti_sum_alibi=False)
    elif args.paradigm == "dti-":
        cfg = dataclasses.replace(cfg, dti_reset=False, dti_sum_alibi=False)
    if args.attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=args.attn_impl)

    ds = make_ctr_dataset(n_users=args.users, n_items=args.items,
                          seq_len=args.seq, vocab_size=cfg.vocab_size,
                          seed=args.seed)
    splits = split_users(ds)
    n_tok = window_tokens(args.n_ctx, ds.avg_item_tokens)
    window = 0 if cfg.window == 0 else n_tok
    eff = effective_window(cfg.attn_impl, window, args.n_ctx,
                           ds.avg_item_tokens)
    if eff != window:
        print(f"[attn] {cfg.attn_impl} path: window 0 -> {eff} tokens")
        window = eff
    max_len = train_max_len(args.n_ctx,
                            1 if args.paradigm == "sw" else args.k,
                            ds.avg_item_tokens)
    train_prompts, test_prompts, test_labels, stats = build_prompt_sets(
        ds, splits, paradigm=args.paradigm, n_ctx=args.n_ctx, k=args.k,
        max_len=max_len)
    print(f"[data] {stats.n_prompts} train prompts, {stats.n_tokens} tokens, "
          f"{stats.n_targets} targets; window={window} max_len={max_len} "
          f"pad_fraction={stats.pad_fraction:.3f}")
    if args.pack:
        pstats = PromptStats()
        train_prompts = pack_prompts(train_prompts, max_len, stats=pstats)
        print(f"[pack] {pstats.n_prompts} prompts -> {pstats.n_rows} rows, "
              f"pad_fraction {stats.pad_fraction:.3f} -> "
              f"{pstats.pad_fraction:.3f}")
        stats = pstats

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    ocfg = OptimizerConfig(lr=args.lr, schedule="cosine",
                           warmup_steps=max(10, args.steps // 10),
                           total_steps=args.steps)
    loss_fn = make_lm_loss_fn(cfg, window)
    state = init_train_state(params, ocfg)
    step_fn = make_train_step(loss_fn, ocfg)

    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=2,
                                 save_interval=max(50, args.steps // 4))
    trainer = Trainer(step_fn, state, ckpt=ckpt,
                      monitor=StragglerMonitor(1), log_every=args.log_every)
    trainer.resume_if_possible()

    rng = np.random.default_rng(args.seed)

    def batches():
        while True:
            yield from batch_prompts(train_prompts, args.batch, rng=rng,
                                     drop_remainder=False)

    t0 = monotonic()
    trainer.run(batches(), n_steps=args.steps)
    train_time = monotonic() - t0

    metrics = evaluate_lm(trainer.state.params, cfg, window, test_prompts,
                          test_labels)
    # compile-vs-steady split (repro.obs / Trainer.timing): short runs
    # fold the first step's XLA compile into wall time, so headline
    # tok/s comes from the steady half only
    timing = trainer.timing()
    steady_tok_s = (args.batch * max_len * (1 - stats.pad_fraction)
                    / timing["step_s"] if timing["step_s"] else 0.0)
    result = {"paradigm": args.paradigm, "k": args.k,
              "train_time_s": train_time, "steps": trainer.step,
              "compile_s": timing["compile_s"],
              "steady_step_s": timing["step_s"],
              "steady_tokens_per_s": steady_tok_s,
              "prompts": stats.n_prompts, "train_tokens": stats.n_tokens,
              "packed": bool(args.pack),
              "pad_fraction": stats.pad_fraction,
              **metrics}
    print(f"[timing] compile {timing['compile_s']:.2f}s, steady step "
          f"{timing['step_s']*1e3:.0f}ms x {timing['steady_steps']} "
          f"({steady_tok_s:.0f} tok/s)")
    print(f"[result] {result}")
    return result


# ---------------------------------------------------------------------------
# non-LM archs: train the smoke config on synthetic data
# ---------------------------------------------------------------------------

def run_other(args) -> Dict:
    from repro.launch.smoke import train_smoke
    result = train_smoke(args.arch, steps=args.steps, batch=args.batch,
                         seed=args.seed, lr=args.lr)
    print(f"[result] {result}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dti-llama")
    ap.add_argument("--paradigm", default="dti",
                    choices=["sw", "dti", "dti-"])
    ap.add_argument("--size", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--pack", action="store_true",
                    help="bin-pack prompts into shared rows (segment-aware)")
    ap.add_argument("--attn-impl", default=None, dest="attn_impl",
                    choices=["dense", "blocked", "pallas"],
                    help="override the config's attention path (pallas = "
                         "fused kernel, fwd AND bwd via its custom VJP)")
    ap.add_argument("--n-ctx", type=int, default=10, dest="n_ctx")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--users", type=int, default=48)
    ap.add_argument("--items", type=int, default=300)
    ap.add_argument("--seq", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()

    if get_arch(args.arch).family == "lm":
        run_lm(args)
    else:
        run_other(args)


if __name__ == "__main__":
    main()
