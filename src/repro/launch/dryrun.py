import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the process entry point (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above runs before any jax import so jax.make_mesh can build
the 512-placeholder-device production meshes on the one real CPU.

For every cell this prints/records:
  * memory_analysis()  — per-device argument/output/temp bytes (proves fit)
  * cost_analysis()    — per-device HLO FLOPs + bytes accessed
  * collective bytes   — parsed from the post-SPMD HLO (repro.launch.hlo)
  * roofline terms     — compute / memory / collective seconds (v5e consts)

Artifacts land in benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json;
EXPERIMENTS.md §Dry-run and §Roofline are generated from them.
"""
import argparse
import json
import traceback

import jax

from repro.obs.clock import monotonic

from repro.configs import ASSIGNED, all_cells, get_arch
from repro.launch.hlo import analyze_hlo, collective_bytes, xla_cost_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_terms
from repro.launch.steps import build_cell

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             overrides=None, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = monotonic()
    cell = build_cell(arch, shape, mesh, overrides=overrides)
    lowered = jax.jit(cell.step_fn, donate_argnums=cell.donate
                      ).lower(*cell.args)
    t_lower = monotonic() - t0
    compiled = lowered.compile()
    t_compile = monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = xla_cost_analysis(compiled)
    hlo = compiled.as_text()
    # trip-count-aware accounting (repro.launch.hlo.analyze_hlo): XLA's own
    # cost_analysis visits while bodies once, undercounting scanned
    # layers/microbatches by their trip counts
    an = analyze_hlo(hlo, n_devices=n_dev)

    rec = {
        "arch": arch, "shape": shape, "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "n_devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "per_device": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_est": (mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               + mem.temp_size_in_bytes
                               - mem.alias_size_in_bytes),
            "hlo_flops": an["flops"],
            "hlo_bytes_accessed": an["bytes"],
            "collective_bytes": an["collective_bytes"],
            "xla_cost_flops_once": cost.get("flops", 0.0),
            "xla_cost_bytes_once": cost.get("bytes accessed", 0.0),
        },
        "collectives": {k: float(an[k]) for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute")},
        "meta": cell.meta,
    }
    rec["roofline"] = roofline_terms(rec)
    if verbose:
        pd = rec["per_device"]
        r = rec["roofline"]
        print(f"[{rec['mesh']}] {arch} x {shape} ({cell.kind}): "
              f"compile {t_compile:.1f}s | "
              f"mem {pd['peak_bytes_est']/2**30:.2f} GiB/dev | "
              f"flops {pd['hlo_flops']:.3e} | coll {pd['collective_bytes']/2**20:.1f} MiB | "
              f"terms c/m/x = {r['compute_s']:.2e}/{r['memory_s']:.2e}/"
              f"{r['collective_s']:.2e} s -> {r['bottleneck']}",
              flush=True)
    return rec


def save_record(rec: dict):
    d = os.path.join(ARTIFACT_DIR, rec["mesh"])
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{rec['arch']}__{rec['shape']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--include-dti-llama", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED)
    if args.include_dti_llama:
        archs.append("dti-llama")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for multi_pod in meshes:
        for arch in archs:
            spec = get_arch(arch)
            shapes = [args.shape] if args.shape else list(spec.shapes)
            for shape in shapes:
                try:
                    rec = run_cell(arch, shape, multi_pod=multi_pod)
                    save_record(rec)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    failures.append((arch, shape, multi_pod, repr(e)))
                    print(f"FAIL [{multi_pod=}] {arch} x {shape}: {e}",
                          flush=True)
                    traceback.print_exc()
    print(f"\ndry-run complete: {len(failures)} failures")
    for f in failures:
        print("  FAIL:", f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
