"""Summarize a repro trace: ``python -m repro.launch.obs_report TRACE.json``.

Validates the Chrome-trace document against the obs schema first
(:func:`repro.obs.trace.validate_chrome_trace`) and exits nonzero on a
malformed or empty trace — CI runs this on the ``serve_bench --trace``
artifact, so a bench change that breaks trace export fails the job, not
just the viewer.

On a valid trace it prints per-span-name aggregates (count, total /
mean / p99 / max milliseconds, sorted by total), instant-event counts
(admissions, hot-swaps, finishes, watchdog fires) and the last value of
each counter series. ``--json OUT`` additionally writes the summary as
JSON for trend tracking.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

import numpy as np

from repro.obs.trace import validate_chrome_trace


def summarize(doc: Dict) -> Dict:
    """Aggregate a validated trace document into a plain summary dict."""
    spans: Dict[str, List[float]] = {}
    instants: Dict[str, int] = {}
    counters: Dict[str, float] = {}
    for ev in doc.get("traceEvents", []):
        ph, name = ev.get("ph"), ev.get("name")
        if ph == "X":
            spans.setdefault(name, []).append(float(ev["dur"]) / 1e3)
        elif ph == "i":
            instants[name] = instants.get(name, 0) + 1
        elif ph == "C":
            counters[name] = list(ev["args"].values())[0]
    span_stats = {}
    for name, durs in spans.items():
        a = np.asarray(durs)
        span_stats[name] = {
            "count": int(a.size), "total_ms": float(a.sum()),
            "mean_ms": float(a.mean()), "p99_ms": float(np.percentile(a, 99)),
            "max_ms": float(a.max()),
        }
    return {"spans": span_stats, "instants": instants,
            "counters_last": counters,
            "dropped_events": doc.get("otherData", {}).get(
                "dropped_events", 0)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate + summarize a repro Chrome trace")
    ap.add_argument("trace", help="trace JSON path (serve_bench --trace)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write the summary as JSON")
    args = ap.parse_args(argv)

    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"obs_report: cannot load {args.trace}: {e}", file=sys.stderr)
        return 1
    problems = validate_chrome_trace(doc)
    if problems:
        print(f"obs_report: {args.trace} failed schema validation:",
              file=sys.stderr)
        for p in problems[:20]:
            print(f"  - {p}", file=sys.stderr)
        if len(problems) > 20:
            print(f"  ... and {len(problems) - 20} more", file=sys.stderr)
        return 1

    s = summarize(doc)
    print(f"# {args.trace}: "
          f"{sum(v['count'] for v in s['spans'].values())} spans, "
          f"{sum(s['instants'].values())} instants, "
          f"{s['dropped_events']} dropped")
    print(f"{'span':<24}{'count':>8}{'total ms':>12}{'mean ms':>10}"
          f"{'p99 ms':>10}{'max ms':>10}")
    for name, st in sorted(s["spans"].items(),
                           key=lambda kv: -kv[1]["total_ms"]):
        print(f"{name:<24}{st['count']:>8}{st['total_ms']:>12.2f}"
              f"{st['mean_ms']:>10.3f}{st['p99_ms']:>10.3f}"
              f"{st['max_ms']:>10.3f}")
    if s["instants"]:
        print("events: " + "  ".join(
            f"{k}={v}" for k, v in sorted(s["instants"].items())))
    if s["counters_last"]:
        print("counters (last): " + "  ".join(
            f"{k}={v}" for k, v in sorted(s["counters_last"].items())))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(s, f, indent=2)
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
