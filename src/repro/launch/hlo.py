"""HLO text analysis: per-device collective-byte accounting for §Roofline.

``cost_analysis`` has no collective term, so we parse the post-SPMD (= per
device) HLO and sum output-shape bytes of every collective op, weighted by
a ring-algorithm traffic model:

    op                  per-device traffic (output bytes O, group size g)
    all-gather          O * (g-1)/g            (~O)
    all-reduce          2 * O * (g-1)/g        (~2O)
    reduce-scatter      O * (g-1)                (input is O*g)
    all-to-all          O * (g-1)/g            (~O)
    collective-permute  O

Group size comes from ``replica_groups`` when parseable (both the explicit
``{{0,1,..},..}`` and the iota ``[groups,size]<=[n]`` forms), else from the
device count.
"""
from __future__ import annotations

import math
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """'f32[16,128]' or '(f32[4], bf16[8,8])' -> total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)     # iota form
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)      # explicit
    if m:
        return len(m.group(1).split(","))
    return default


def _line_traffic(s: str, n_devices: int):
    """One HLO line -> (kind, modeled per-device bytes) or None."""
    m = re.match(r"%?[\w.\-]+\s*=\s*((?:\([^=]*?\))|\S+)\s+([\w\-]+)\(", s)
    if not m:
        return None
    op = m.group(2)
    base = next((c for c in _COLLECTIVES
                 if op == c or op == c + "-start"), None)
    if base is None:
        return None
    o = _shape_bytes(m.group(1))
    g = _group_size(s, n_devices)
    if g <= 1:
        return None
    if base == "all-gather":
        traffic = o * (g - 1) / g
    elif base == "all-reduce":
        traffic = 2 * o * (g - 1) / g
    elif base == "reduce-scatter":
        traffic = o * (g - 1)
    elif base == "all-to-all":
        traffic = o * (g - 1) / g
    else:                                   # collective-permute
        traffic = o
    return base, traffic


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{")
_CALL_SINGLE_RE = re.compile(
    r"(?:condition|body|calls|to_apply)=%([\w.\-]+)")
_CALL_LIST_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')


def collective_bytes(hlo_text: str, *, n_devices: int = 1) -> Dict[str, float]:
    """Sum modeled per-device collective traffic by op kind (bytes).

    Collectives inside while bodies (lax.scan / lax.map -- grad accumulation,
    layer scans, chunked prefill) execute once per iteration: the walk below
    multiplies each computation's direct traffic by the product of enclosing
    whiles' ``known_trip_count`` annotations (XLA stamps these for
    statically-counted loops; unannotated loops conservatively count 1)."""
    comps = {}
    entry = None
    current = None
    for raw in hlo_text.splitlines():
        s = raw.strip()
        m = _COMP_RE.match(s)
        if m and s.endswith("{"):
            current = m.group(1)
            comps[current] = []
            if s.startswith("ENTRY"):
                entry = current
            continue
        if s == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(s)
    if not comps:                       # bare op list (tests, fragments)
        comps = {"__flat__": [l.strip() for l in hlo_text.splitlines()]}
        entry = "__flat__"

    direct = {}
    calls = {}
    counts = {}
    for name, lines in comps.items():
        d = {c: 0.0 for c in _COLLECTIVES}
        n = 0
        edges = []
        for s in lines:
            t = _line_traffic(s, n_devices)
            if t is not None:
                d[t[0]] += t[1]
                n += 1
            trip = 1
            if " while(" in s:
                tm = _TRIP_RE.search(s)
                trip = int(tm.group(1)) if tm else 1
            for cm in _CALL_SINGLE_RE.finditer(s):
                edges.append((cm.group(1), trip))
            for cm in _CALL_LIST_RE.finditer(s):
                for callee in re.split(r",\s*", cm.group(1)):
                    edges.append((callee.lstrip("%"), trip))
        direct[name] = d
        counts[name] = n
        calls[name] = edges

    out = {c: 0.0 for c in _COLLECTIVES}
    out["count"] = 0
    stack = set()

    def walk(name, mult):
        if name not in comps or name in stack:
            return
        stack.add(name)
        for c in _COLLECTIVES:
            out[c] += direct[name][c] * mult
        out["count"] += counts[name]
        for callee, trip in calls[name]:
            walk(callee, mult * trip)
        stack.discard(name)

    if entry is None and comps:
        entry = next(iter(comps))
    if entry is not None:
        walk(entry, 1.0)
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"=\s*(?:\([^)]*\)|\S+)\s+{re.escape(opname)}\(",
                          hlo_text))


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalised across jax versions: older
    jax returns a one-element list of dicts, newer jax the dict itself."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


__all__ = ["collective_bytes", "analyze_hlo", "count_op",
           "xla_cost_analysis"]


# ===========================================================================
# Full-module analysis: trip-count-aware FLOPs + HBM bytes + collectives
# ===========================================================================
#
# XLA's HloCostAnalysis (what compiled.cost_analysis() exposes) visits each
# while BODY ONCE — a 40-layer lax.scan with 4-way grad accumulation under-
# counts flops/bytes 160x. analyze_hlo() re-derives all three roofline
# inputs from the post-SPMD text with the call-graph walk multiplying by
# known_trip_count:
#   * flops  — 2 * |out| * contracted_size per dot/convolution line
#              (elementwise flops ignored: matmuls dominate every cell)
#   * bytes  — per instruction: output + operand bytes, fusions counted as
#              ONE op (their internals live in registers/VMEM), free ops
#              (parameter/tuple/gte/bitcast/constant) skipped
#   * collective traffic — ring model, as collective_bytes()

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^=]*?\))|\S+)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CDIM_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "iota"}


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def analyze_hlo(hlo_text: str, *, n_devices: int = 1) -> Dict[str, float]:
    comps: Dict[str, list] = {}
    entry = None
    current = None
    symbols: Dict[str, str] = {}           # instr name -> type string
    for raw in hlo_text.splitlines():
        s = raw.strip()
        m = _COMP_RE.match(s)
        if m and s.endswith("{"):
            current = m.group(1)
            comps[current] = []
            if s.startswith("ENTRY"):
                entry = current
            continue
        if s == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(s)
            dm = _DEF_RE.match(s)
            if dm:
                symbols[dm.group(1)] = dm.group(2)
    if not comps:
        comps = {"__flat__": [l.strip() for l in hlo_text.splitlines()]}
        entry = "__flat__"
        for s in comps["__flat__"]:
            dm = _DEF_RE.match(s)
            if dm:
                symbols[dm.group(1)] = dm.group(2)

    # which computations are fusion bodies / scalar appliers (skip bytes)
    fused: set = set()
    for lines in comps.values():
        for s in lines:
            if re.search(r"\bfusion\(", s):
                for cm in re.finditer(r"calls=%([\w.\-]+)", s):
                    fused.add(cm.group(1))
            for cm in re.finditer(r"to_apply=%([\w.\-]+)", s):
                fused.add(cm.group(1))

    per: Dict[str, Dict[str, float]] = {}
    calls: Dict[str, list] = {}
    for name, lines in comps.items():
        flops = bytes_ = coll = 0.0
        ckinds = {c: 0.0 for c in _COLLECTIVES}
        edges = []
        for s in lines:
            dm = _DEF_RE.match(s)
            op = dm.group(3) if dm else ""
            out_type = dm.group(2) if dm else ""
            if op in ("dot", "convolution"):
                out_elems = 1
                dims = _shape_dims(out_type) or []
                for d in dims:
                    out_elems *= d
                contracted = 1
                ops_ = _OPERAND_RE.findall(s[s.index("("):])
                cd = _CDIM_RE.search(s)
                if cd and ops_:
                    lhs_type = symbols.get(ops_[0], "")
                    lhs_dims = _shape_dims(lhs_type)
                    if lhs_dims:
                        for di in cd.group(1).split(","):
                            if di:
                                contracted *= lhs_dims[int(di)]
                flops += 2.0 * out_elems * max(contracted, 1)
            if dm and op not in _FREE_OPS and name not in fused:
                b = _shape_bytes(out_type)
                for oname in _OPERAND_RE.findall(s[s.index("("):])[:8]:
                    b += _shape_bytes(symbols.get(oname, ""))
                bytes_ += b
            t = _line_traffic(s, n_devices)
            if t is not None:
                ckinds[t[0]] += t[1]
                coll += t[1]
            trip = 1
            if " while(" in s:
                tm = _TRIP_RE.search(s)
                trip = int(tm.group(1)) if tm else 1
            for cm in _CALL_SINGLE_RE.finditer(s):
                edges.append((cm.group(1), trip))
            for cm in _CALL_LIST_RE.finditer(s):
                for callee in re.split(r",\s*", cm.group(1)):
                    edges.append((callee.lstrip("%"), trip))
        per[name] = {"flops": flops, "bytes": bytes_, "coll": coll,
                     **ckinds}
        calls[name] = edges

    out = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
           **{c: 0.0 for c in _COLLECTIVES}}
    stack = set()

    def walk(name, mult):
        if name not in comps or name in stack:
            return
        stack.add(name)
        out["flops"] += per[name]["flops"] * mult
        out["bytes"] += per[name]["bytes"] * mult
        out["collective_bytes"] += per[name]["coll"] * mult
        for c in _COLLECTIVES:
            out[c] += per[name][c] * mult
        for callee, trip in calls[name]:
            walk(callee, mult * trip)
        stack.discard(name)

    if entry is not None:
        walk(entry, 1.0)
    return out
