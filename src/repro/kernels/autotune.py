"""Block-size autotuning for the Pallas attention kernels.

The decode kernel's kv tile (``block_size`` in
``repro.kernels.decode_attn``) and the training kernel's band tile
(``ModelConfig.attn_block_size``) used to be fixed constants (64 / 256).
Both are now resolved here, from two sources consulted in order:

1. **measured table** — ``measure_decode`` / ``measure_train`` sweep the
   candidate tiles with real timed kernel calls and memoize the winner.
   Sweeps only ever *measure* on TPU: interpret-mode wall time profiles
   the Pallas interpreter, not the kernel, so off-TPU the sweep functions
   report the table default and store nothing. Benchmarks
   (``benchmarks.kernels_micro``) run the sweeps and publish the table.
2. **built-in defaults** — a small geometry-keyed heuristic. On TPU the
   kv tile wants to be a multiple of the 128 lane width and bounded by
   what (k + v + nope) tiles fit comfortably in VMEM; in interpret mode
   tile size has no perf meaning, so the defaults reproduce the historic
   constants exactly (decode 64, train 256) and CPU tests/benches are
   byte-for-byte unchanged.

Lookups are pure host arithmetic plus a dict probe — safe to call inside
a jit trace (the engine resolves ``block_size=None`` at trace time from
the static cache capacity). Only the ``measure_*`` entry points execute
device code, and they are called from benchmarks / startup paths, never
from inside a trace.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence, Tuple

from repro.kernels import default_interpret

#: measured winners: key -> block size (populated by measure_* on TPU)
_MEASURED: Dict[Tuple, int] = {}

#: VMEM budget the kv-side tiles of one grid step may occupy (bytes).
#: Conservative: k + v (+ nope k) tiles in fp32 plus scratch must fit in
#: ~16 MB/core alongside double buffering.
_VMEM_TILE_BUDGET = 1 << 20

DECODE_CANDIDATES: Sequence[int] = (64, 128, 256, 512)
TRAIN_CANDIDATES: Sequence[int] = (128, 256, 512)


def _pow2_le(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _decode_key(cap: int, dqk: int, dv: int) -> Tuple:
    return ("decode", _pow2_le(max(cap, 1)), int(dqk), int(dv))


def _train_key(seq: int, head_dim: int) -> Tuple:
    return ("train", _pow2_le(max(seq, 1)), int(head_dim))


def decode_block(cap: int, *, dqk: int = 64, dv: int = 64,
                 interpret: Optional[bool] = None) -> int:
    """kv tile for the decode-attention kernel over a ``cap``-slot cache.

    Interpret mode returns the historic 64 (tile size is semantics-free
    there — the kernel pads cap to a block multiple either way). On TPU:
    the largest lane-aligned candidate that the capacity warrants and the
    VMEM budget admits, unless a measured sweep recorded a winner.
    """
    interpret = default_interpret(interpret)
    if interpret:
        return 64
    hit = _MEASURED.get(_decode_key(cap, dqk, dv))
    if hit is not None:
        return hit
    # ~3 fp32 tiles of width (dqk + dqk + dv) stream per block step
    vmem_cap = _VMEM_TILE_BUDGET // max((2 * dqk + dv) * 4, 1)
    best = DECODE_CANDIDATES[0]
    for c in DECODE_CANDIDATES:
        if c <= max(_pow2_le(cap), 128) and c <= vmem_cap:
            best = c
    return best


def train_block(seq: int, head_dim: int, *,
                interpret: Optional[bool] = None) -> int:
    """Band tile for the windowed training kernel at sequence ``seq``.

    Interpret mode returns the historic 256 (``choose_block`` degrades it
    toward a divisor of ragged lengths downstream, exactly as before). On
    TPU: measured winner if any, else the largest candidate the sequence
    and VMEM budget warrant.
    """
    interpret = default_interpret(interpret)
    if interpret:
        return 256
    hit = _MEASURED.get(_train_key(seq, head_dim))
    if hit is not None:
        return hit
    vmem_cap = _VMEM_TILE_BUDGET // max(3 * head_dim * 4, 1)
    best = TRAIN_CANDIDATES[0]
    for c in TRAIN_CANDIDATES:
        if c <= max(_pow2_le(seq), 128) and c <= vmem_cap:
            best = c
    return best


def _time_best_of(fn, *args, iters: int = 5) -> float:
    import jax
    jax.block_until_ready(fn(*args))          # compile outside the clock
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def measure_decode(cap: int, *, s: int = 8, hq: int = 8, hk: int = 2,
                   dqk: int = 64, dv: int = 64,
                   candidates: Optional[Sequence[int]] = None,
                   iters: int = 5,
                   interpret: Optional[bool] = None) -> Dict:
    """Sweep decode kv tiles with timed kernel calls; memoize the winner.

    Returns ``{"block", "measured", "timings_us"}``. Off-TPU (interpret)
    nothing is timed or stored — the report carries the table default so
    callers (kernels_micro) can still publish what a config resolves to.
    """
    import jax
    import jax.numpy as jnp
    from repro.kernels.decode_attn.ops import decode_attention

    interpret = default_interpret(interpret)
    if interpret:
        return {"block": decode_block(cap, dqk=dqk, dv=dv,
                                      interpret=interpret),
                "measured": False, "timings_us": None}
    kk = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kk[0], (1, s, hq, dqk), jnp.float32)
    k = jax.random.normal(kk[1], (1, cap, hk, dqk), jnp.float32)
    v = jax.random.normal(kk[2], (1, cap, hk, dv), jnp.float32)
    pos_q = jnp.full((1, s), cap - 1, jnp.int32)
    pos_k = jnp.arange(cap, dtype=jnp.int32)[None]
    timings = {}
    for blk in (candidates or DECODE_CANDIDATES):
        fn = jax.jit(lambda q, k, v, b=blk: decode_attention(
            q, k, v, pos_q, pos_k, window=0, block_size=b,
            interpret=interpret))
        timings[blk] = _time_best_of(fn, q, k, v, iters=iters) * 1e6
    best = min(timings, key=timings.get)
    _MEASURED[_decode_key(cap, dqk, dv)] = int(best)
    return {"block": int(best), "measured": True, "timings_us": timings}


def measure_train(seq: int, *, head_dim: int = 64, heads: int = 4,
                  window: int = 128,
                  candidates: Optional[Sequence[int]] = None,
                  iters: int = 5,
                  interpret: Optional[bool] = None) -> Dict:
    """Sweep the windowed training kernel's band tile; memoize the winner
    (TPU only, as in ``measure_decode``)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.windowed_attn.ops import windowed_attention

    interpret = default_interpret(interpret)
    if interpret:
        return {"block": train_block(seq, head_dim, interpret=interpret),
                "measured": False, "timings_us": None}
    kk = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kk[0], (1, seq, heads, head_dim), jnp.float32)
    k = jax.random.normal(kk[1], (1, seq, heads, head_dim), jnp.float32)
    v = jax.random.normal(kk[2], (1, seq, heads, head_dim), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (1, seq))
    timings = {}
    for blk in (candidates or TRAIN_CANDIDATES):
        fn = jax.jit(lambda q, k, v, b=blk: windowed_attention(
            q, k, v, pos_q=pos, pos_k=pos, window=window, block_size=b,
            interpret=interpret))
        timings[blk] = _time_best_of(fn, q, k, v, iters=iters) * 1e6
    best = min(timings, key=timings.get)
    _MEASURED[_train_key(seq, head_dim)] = int(best)
    return {"block": int(best), "measured": True, "timings_us": timings}


def measured_table() -> Dict[str, int]:
    """Snapshot of the measured winners (JSON-friendly keys), for
    benchmark artifacts."""
    return {"/".join(str(p) for p in k): v for k, v in _MEASURED.items()}


__all__ = ["DECODE_CANDIDATES", "TRAIN_CANDIDATES", "decode_block",
           "train_block", "measure_decode", "measure_train",
           "measured_table"]
