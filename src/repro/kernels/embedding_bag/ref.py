"""Pure-jnp oracle for the EmbeddingBag kernel: the substrate implementation
in ``repro.sparse.embedding`` (take + masked sum) IS the reference."""
from repro.sparse.embedding import embedding_bag as reference_embedding_bag

__all__ = ["reference_embedding_bag"]
