"""jit'd wrapper for the EmbeddingBag kernel (sum / mean, masked)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.embedding_bag.embedding_bag import embedding_bag_pallas


def embedding_bag(table: jax.Array, ids: jax.Array,
                  valid: Optional[jax.Array] = None, *,
                  mode: str = "sum",
                  weights: Optional[jax.Array] = None,
                  table_scale: Optional[jax.Array] = None,
                  interpret: Optional[bool] = None) -> jax.Array:
    """ids (B, H) -> (B, D); masked, optionally weighted, sum or mean.

    ``table_scale (V,)`` supports int8-quantized tables: row ``r`` of
    ``table`` holds int8 codes that dequantize as ``codes * table_scale[r]``
    (``repro.core.quant.quantize_q8`` over the row axis). The bag is a
    weighted sum, so the per-row scale folds *exactly* into the gather
    weights — ``w[b, j] *= table_scale[ids[b, j]]`` — and the kernel runs
    unchanged on the codes cast to fp32; no dequantized table ever
    materialises in HBM.
    """
    interpret = default_interpret(interpret)
    b, h = ids.shape
    w = jnp.ones((b, h), jnp.float32) if weights is None \
        else weights.astype(jnp.float32)
    if valid is not None:
        w = w * valid.astype(jnp.float32)
    if mode == "mean":
        n = (valid.sum(axis=-1, keepdims=True).astype(jnp.float32)
             if valid is not None else jnp.full((b, 1), float(h)))
        w = w / jnp.maximum(n, 1.0)
    elif mode != "sum":
        raise ValueError(f"kernel supports sum/mean, got {mode!r}")
    # masked ids may be out of range: clamp (their weight is already 0)
    ids = jnp.clip(ids, 0, table.shape[0] - 1)
    out_dtype = table.dtype
    if table_scale is not None:
        w = w * table_scale.astype(jnp.float32)[ids]
        table = table.astype(jnp.float32)
        out_dtype = jnp.float32
    return embedding_bag_pallas(table, ids, w,
                                interpret=interpret).astype(out_dtype)


__all__ = ["embedding_bag"]
