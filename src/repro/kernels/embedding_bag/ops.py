"""jit'd wrapper for the EmbeddingBag kernel (sum / mean, masked)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.embedding_bag.embedding_bag import embedding_bag_pallas


def embedding_bag(table: jax.Array, ids: jax.Array,
                  valid: Optional[jax.Array] = None, *,
                  mode: str = "sum",
                  weights: Optional[jax.Array] = None,
                  interpret: Optional[bool] = None) -> jax.Array:
    """ids (B, H) -> (B, D); masked, optionally weighted, sum or mean."""
    interpret = default_interpret(interpret)
    b, h = ids.shape
    w = jnp.ones((b, h), jnp.float32) if weights is None \
        else weights.astype(jnp.float32)
    if valid is not None:
        w = w * valid.astype(jnp.float32)
    if mode == "mean":
        n = (valid.sum(axis=-1, keepdims=True).astype(jnp.float32)
             if valid is not None else jnp.full((b, 1), float(h)))
        w = w / jnp.maximum(n, 1.0)
    elif mode != "sum":
        raise ValueError(f"kernel supports sum/mean, got {mode!r}")
    # masked ids may be out of range: clamp (their weight is already 0)
    ids = jnp.clip(ids, 0, table.shape[0] - 1)
    return embedding_bag_pallas(table, ids, w,
                                interpret=interpret).astype(table.dtype)


__all__ = ["embedding_bag"]
