"""Pallas TPU kernel: EmbeddingBag (gather + weighted segment-reduce).

JAX has no nn.EmbeddingBag; the jnp path (repro.sparse.embedding) lowers to
take + sum. This kernel implements the op the TPU-native way: the bag ids
are **scalar-prefetched** into SMEM so each grid step's BlockSpec index_map
can address the embedding-table row *directly in HBM* — the row DMA
HBM->VMEM is the gather, no (B, H, D) intermediate ever exists.

  grid = (B * H,)   (bag-major; "arbitrary" — out block revisited H times)
  table BlockSpec (1, D): index_map i -> (ids[i], 0)   <- the gather
  out   BlockSpec (1, D): index_map i -> (i // H, 0)   <- the reduce

Weights (per-sample scale, or validity 0/1) ride SMEM alongside the ids.
Modes: sum / mean (mean = sum with 1/n weights, done in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(ids_ref, w_ref, table_ref, o_ref, *, bag: int):
    i = pl.program_id(0)

    @pl.when(i % bag == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[i]
    o_ref[...] += table_ref[...].astype(jnp.float32) * w


def embedding_bag_pallas(table: jax.Array,      # (V, D)
                         ids: jax.Array,        # (B, H) int32
                         weights: jax.Array,    # (B, H) f32 (0 masks)
                         *, interpret: bool = False) -> jax.Array:
    b, bag = ids.shape
    v, d = table.shape
    grid = (b * bag,)
    out = pl.pallas_call(
        functools.partial(_kernel, bag=bag),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, d),
                             lambda i, ids, w: (ids[i], 0)),   # table row
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, ids, w: (i // bag, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(ids.reshape(-1).astype(jnp.int32),
      weights.reshape(-1).astype(jnp.float32), table)
    return out


__all__ = ["embedding_bag_pallas"]
