# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared kernel-package helpers.

Every kernel wrapper defaults ``interpret`` the same way: compile to Mosaic
on TPU, fall back to the Pallas interpreter elsewhere so the kernel *body*
(not a jnp re-implementation) is what runs — and is tested — on CPU.
"""
from __future__ import annotations

from typing import Optional

import jax


def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # noqa: BLE001
        return False


def default_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve a tri-state ``interpret`` argument (None = auto)."""
    return (not on_tpu()) if interpret is None else bool(interpret)


__all__ = ["on_tpu", "default_interpret"]
