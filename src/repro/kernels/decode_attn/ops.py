"""Public decode-attention op: cache-layout operands, custom Pallas lowering.

``decode_attention`` is the serving analogue of
``repro.kernels.windowed_attn.ops.windowed_attention``: it normalises the
optional serve operands (SUM flags, in-burst segments, NoPE stream) to
concrete arrays plus hashable statics and lowers to the fused Pallas
kernel in ``decode_attn.py``. Differences from the training op:

* operands stay in the serving cache layout — queries ``(B, s, H, Dqk)``,
  cache-side tensors ``(B, cap, Hk, D)`` — and the index maps do the GQA
  head-group addressing, so no transposes or head replication happen in
  memory;
* no VJP: decode never trains, so the op is forward-only (scores are read
  under ``jax.lax.stop_gradient`` semantics by construction);
* the capacity axis is padded to a kv-block multiple with ``pos = -1``
  slots, which the kernel's occupancy skip drops — arbitrary scheduler
  capacities stay legal without degrading the block size;
* paged KV reaches this op already gathered: the engine resolves each
  row's page table to a logical-slot-ordered ``(B, cap, ...)`` view
  before calling (``repro.serve.cache.physical_slots``), so the op's
  contract — and its outputs — are identical for paged and contiguous
  caches.

``interpret=None`` auto-resolves via ``repro.kernels.default_interpret``
(Mosaic on TPU, the Pallas interpreter elsewhere so the kernel *body* is
what CPU tests exercise).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import default_interpret
from repro.kernels.autotune import decode_block
from repro.kernels.decode_attn.decode_attn import (
    decode_attention_bshd, prepare_decode_inputs)


def decode_attention(
    q: jax.Array,                  # (B, s, H, Dqk)   RoPE'd queries
    k: jax.Array,                  # (B, cap, Hk, Dqk) read-time-RoPE'd keys
                                   #   (int8 unroped codes when quantized)
    v: jax.Array,                  # (B, cap, Hk, Dv)
    pos_q: jax.Array,              # (B, s) int32 query positions
    pos_k: jax.Array,              # (B, cap) int32 slot positions; -1 empty
    *,
    window: int,                   # 0 = unlimited (decode convention)
    is_sum_q: Optional[jax.Array] = None,   # (B, s) flags
    q_nope: Optional[jax.Array] = None,     # (B, s, H, Dqk)
    k_nope: Optional[jax.Array] = None,     # (B, cap, Hk, Dqk) unroped
    alibi: Optional[jax.Array] = None,      # (H,) f32
    seg_q: Optional[jax.Array] = None,      # (B, s) int32; -1 = shared
    seg_k: Optional[jax.Array] = None,      # (B, cap) int32; -1 = shared
    scale: Optional[float] = None,
    block_size: Optional[int] = None,       # None = autotuned
    interpret: Optional[bool] = None,
    k_scale: Optional[jax.Array] = None,    # (B, cap, Hk, G) fp32; int8 KV
    v_scale: Optional[jax.Array] = None,    # (B, cap, Hk) fp32
    rope_start: int = 0,                    # first roped key dim (quant)
    rope_theta: float = 10000.0,
) -> jax.Array:
    """Fused burst attention into the batched KV cache -> (B, s, H, Dv).

    ``block_size=None`` resolves the kv tile via
    ``repro.kernels.autotune.decode_block`` (measured winner on TPU when
    one exists, geometry table otherwise; the historic 64 in interpret
    mode). ``k_scale`` switches to the quantized-KV contract: ``k``/``v``
    are raw int8 cache codes and dequant + RoPE (span ``[rope_start:]``,
    base ``rope_theta``) happen inside the kernel — see docs/kernels.md.
    """
    interpret = default_interpret(interpret)
    if block_size is None:
        block_size = decode_block(k.shape[1], dqk=q.shape[-1],
                                  dv=v.shape[-1], interpret=interpret)
    use_nope = q_nope is not None and is_sum_q is not None
    rope_inv = None
    if k_scale is not None:
        from repro.models.layers import rope_freqs
        rope_inv = rope_freqs(q.shape[-1] - rope_start, rope_theta)
    st, arrays = prepare_decode_inputs(
        q, k, v, pos_q, pos_k, window=window,
        sum_q=is_sum_q if use_nope else None,
        seg_q=seg_q, seg_k=seg_k,
        q_nope=q_nope if use_nope else None,
        k_nope=k_nope if use_nope else None,
        alibi=alibi if use_nope else None,
        scale=scale, block_size=block_size, interpret=interpret,
        k_scale=k_scale, v_scale=v_scale, rope_inv=rope_inv,
        rope_start=rope_start)
    return decode_attention_bshd(st, *arrays)


__all__ = ["decode_attention"]
