"""Dense reference for the decode-attention kernel.

Mirrors the serving engine's dense decode math (`repro.serve.engine`:
``_decode_mask`` + ``_decode_attend``) on the kernel's operand layout so
`tests/test_decode_attn.py` can assert kernel == oracle without standing
up a full model. Semantics:

* attendable iff the cache slot is filled (``pos_k >= 0``), causal
  (``pos_q >= pos_k``), within ``window`` when ``window > 0`` (0 =
  unlimited — the decode convention), and segment-compatible
  (``seg_k < 0`` shared, else ``seg_k == seg_q``);
* rows flagged ``sum_q`` replace the RoPE scores with the NoPE stream
  minus ``alibi * distance``;
* rows with no attendable key output exactly zero.

With ``k_scale`` set the quantized-KV contract applies: ``k``/``v`` are
raw int8 cache codes, dequantized here (per-slot/per-head scales, two
groups split at ``rope_start`` when ``k_scale`` has a trailing axis of
2) and the key span ``[rope_start:]`` is roped at read time from
``max(pos_k, 0)``. The NoPE stream is the *same* codes dequantized
without rotation, so ``k_nope`` must be None on the quant path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.windowed import NEG_INF
from repro.models.layers import apply_rope


def decode_attention_ref(
    q: jax.Array,                  # (B, s, H, Dqk)
    k: jax.Array,                  # (B, cap, Hk, Dqk)
    v: jax.Array,                  # (B, cap, Hk, Dv)
    pos_q: jax.Array,              # (B, s) int32
    pos_k: jax.Array,              # (B, cap) int32; -1 = empty
    *,
    window: int,
    sum_q: Optional[jax.Array] = None,
    seg_q: Optional[jax.Array] = None,
    seg_k: Optional[jax.Array] = None,
    q_nope: Optional[jax.Array] = None,
    k_nope: Optional[jax.Array] = None,
    alibi: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    k_scale: Optional[jax.Array] = None,    # (B, cap, Hk, G) fp32
    v_scale: Optional[jax.Array] = None,    # (B, cap, Hk) fp32
    rope_start: int = 0,
    rope_theta: float = 10000.0,
) -> jax.Array:
    b, s, h, d = q.shape
    hk = k.shape[2]
    n_rep = h // hk
    if scale is None:
        scale = d ** -0.5

    if k_scale is not None:
        assert k_nope is None, "quant path derives NoPE from the codes"
        kf = k.astype(jnp.float32)
        if k_scale.shape[-1] == 1:
            sc_vec = k_scale
        else:                      # two groups split at rope_start
            idx = jnp.arange(d)[None, None, None, :]
            sc_vec = jnp.where(idx < rope_start,
                               k_scale[..., 0:1], k_scale[..., 1:2])
        kd = kf * sc_vec           # unroped dequant == the NoPE stream
        p = jnp.maximum(pos_k, 0)  # empty slots masked out later anyway
        roped = apply_rope(kd[..., rope_start:], p, rope_theta)
        k = jnp.concatenate([kd[..., :rope_start], roped], axis=-1) \
            if rope_start else roped
        if q_nope is not None and sum_q is not None:
            k_nope = kd
        v = v.astype(jnp.float32) * v_scale[..., None]

    def rep(t):                    # (B, cap, Hk, D) -> (B, cap, H, D)
        if n_rep == 1:
            return t
        bb, cap, _, dd = t.shape
        return jnp.broadcast_to(
            t[:, :, :, None, :], (bb, cap, hk, n_rep, dd)
        ).reshape(bb, cap, h, dd)

    sc = jnp.einsum("bshd,bkhd->bhsk", q, rep(k),
                    preferred_element_type=jnp.float32) * scale
    dist = (pos_q[:, None, :, None] - pos_k[:, None, None, :]
            ).astype(jnp.float32)
    if q_nope is not None and sum_q is not None:
        kn = k_nope if k_nope.shape[2] == hk else jnp.broadcast_to(
            k_nope, (b, k.shape[1], hk, k_nope.shape[-1]))
        sn = jnp.einsum("bshd,bkhd->bhsk", q_nope, rep(kn),
                        preferred_element_type=jnp.float32) * scale
        sn = sn - alibi[None, :, None, None] * dist
        sc = jnp.where(sum_q[:, None, :, None], sn, sc)

    mask = ((pos_k[:, None, :] >= 0)
            & (pos_q[:, :, None] >= pos_k[:, None, :]))
    if window > 0:
        mask &= (pos_q[:, :, None] - pos_k[:, None, :]) <= window
    if seg_q is not None and seg_k is not None:
        mask &= ((seg_k[:, None, :] < 0)
                 | (seg_k[:, None, :] == seg_q[:, :, None]))

    sc = jnp.where(mask[:, None, :, :], sc, NEG_INF)
    probs = jax.nn.softmax(sc, axis=-1)
    any_ok = jnp.any(mask, axis=-1)[:, None, :, None]
    probs = jnp.where(any_ok, probs, 0.0)
    out = jnp.einsum("bhsk,bkhd->bshd", probs.astype(q.dtype), rep(v))
    return out


__all__ = ["decode_attention_ref"]
