"""Pallas TPU kernel: fused decode attention for the serving burst path.

The scheduler's hot loop (`repro.serve.scheduler`) feeds one work unit per
busy cache row per step: a chunk of ``s <= bucket`` queries per row
attending into that row's slice of the batched KV cache — context-prefill
chunks and non-committing candidate bursts ride the same call. The dense
path in `repro.serve.engine` materialises two (B, H, s, cap) score tensors
(RoPE + NoPE), a (B, s, cap) mask and the full probability tensor per
layer; this kernel fuses the whole thing into one online-softmax pass over
the cache, so scores/probabilities never touch HBM and cost scales with
cache *occupancy* rather than capacity.

Schedule:

    grid = (B, H, n_kv)        n_kv = cap_padded // blk_kv

The kv axis is "arbitrary": each (row, head) walks the row's cache blocks
left to right carrying an online-softmax accumulator (m, l, acc) in VMEM
scratch. Two structural wins over the dense decode path:

* **occupancy skip** — a cache block whose every slot is empty
  (``pos_k < 0``) is skipped entirely (`pl.when`): a mostly-empty
  high-capacity cache costs what its occupancy costs, while the dense
  einsums always pay full capacity;
* **no (s, cap) materialisation** — mask terms (filled slot, causal,
  window, in-burst segment) are index arithmetic against the staged
  (blk,) ``pos``/``seg`` tiles.

Cache-native layout: K/V tiles are staged directly from the serving cache
layout ``(B, cap, Hk, D)`` via index maps (query head h reads kv head
``h // n_rep``) — no transpose or head replication in memory, mirroring
the windowed training kernel. MLA runs through the same kernel in absorbed
MQA form (Hk=1): the engine folds q through W_UK and concatenates the
latent/rope streams so ``Dqk = r_kv + d_rope`` while values stay in the
latent (``Dv = r_kv != Dqk``); see `repro.serve.engine._mla_decode_layer`.

The full serve feature set is fused:

* per-row cursors / right-padded chunks — empty and padded slots carry
  ``pos = -1`` and are never attendable (the ``valid`` operand of
  ``make_decode_fn`` writes them that way);
* ``commit=False`` scoring bursts — no kernel-side difference: the burst's
  own tokens are already written into the cache tensors for the step, the
  kernel just attends what ``pos_k``/``seg_k`` describe;
* in-burst candidate isolation — ``seg_k >= 0`` entries are attendable
  only by queries of the same segment; ``seg_k < 0`` (committed context +
  shared suffix) by everyone;
* ring/window semantics — the mask is purely positional, so a ring cache
  (wrapped physical slots, monotone logical positions) needs no special
  handling; ``window == 0`` means unlimited (decode convention, matching
  ``_decode_mask``), ``window > 0`` bounds the attendable distance;
* SUM NoPE+ALiBi — rows flagged ``is_sum_q`` score a second (q_nope,
  k_nope) stream with the ALiBi distance bias instead of the RoPE'd
  stream, fused as a second matmul on the same tiles;
* GQA head groups and MLA ``Dv != Dqk`` — value tiles block on ``Dv``,
  score tiles on ``Dqk``.

Queries with no attendable key (fully padded rows) produce exactly zero
output, matching the dense path's ``any_ok`` guard. All index/flag
operands are int32 (no sub-byte loads); scores accumulate in fp32.

**Paged caches need no kernel changes.** When the scheduler runs the
paged KV layout (`repro.serve.cache` with a page table), the engine
gathers each row's pages into logical-slot order *before* this op —
``k``/``v``/``pos_k``/``seg_k`` arrive as the same per-row ``(B, cap,
...)`` views a contiguous cache would produce, holding identical values
at identical logical slots (RoPE is applied per-row positions on the
gathered view, so it cannot move inside the kernel). The kernel
therefore computes bit-identical outputs for paged and contiguous
layouts; see ``make_decode_fn`` and tests/test_paged_cache.py.

**Quantized KV (int8 codes + fp32 scale sidecar) is dequantized in the
kernel body.** On the quant path (``k_scale`` operand present) the k/v
tiles are staged as raw int8 codes straight from the cache — unroped,
undequantized — so quantized KV never round-trips through bf16 in HBM.
Per kv block the kernel: casts codes to fp32 in VMEM, RoPEs the
``[rope_start:]`` span using the staged slot positions (GQA rotates the
whole head dim, ``rope_start = 0``; absorbed MLA only the ``kpe`` tail,
``rope_start = r_kv``), then multiplies in the per-(slot, head) scale —
legal in either order because the rotation stays inside one scale group
(see ``repro.core.quant``). Two scale groups (``k_scale[..., 2]``) split
at ``rope_start`` cover MLA's separately-quantized latent/rope streams.
The NoPE stream needs no second cache operand when quantized: it is the
same codes dequantized without rotation, halving the kernel's
full-capacity HBM traffic vs the bf16 NoPE path.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.windowed_attn.windowed_attn import NEG_INF

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = (getattr(pltpu, "CompilerParams", None)
                   or pltpu.TPUCompilerParams)


class DecodeStatics(NamedTuple):
    """Hashable per-call configuration of the decode kernel."""
    window: int          # 0 = unlimited (decode convention)
    scale: float
    block: int           # kv block size (divides the padded capacity)
    use_seg: bool        # in-burst candidate isolation active
    use_nope: bool       # SUM rows score the NoPE+ALiBi stream
    quant: bool          # int8 KV codes + fp32 scales; dequant in VMEM
    rope_start: int      # first key dim RoPE rotates (quant path only)
    interpret: bool


def _kernel(pos_q_ref, pos_k_ref, sum_q_ref, seg_q_ref, seg_k_ref, alibi_ref,
            q_ref, k_ref, v_ref, qn_ref, kn_ref, ks_ref, vs_ref, rinv_ref,
            o_ref,
            m_ref, l_ref, acc_ref,
            *, n_kv: int, window: int, scale: float,
            use_seg: bool, use_nope: bool, quant: bool, rope_start: int):
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos_k = pos_k_ref[0]                                   # (blk,) int32

    # occupancy skip: an all-empty cache block (padding, or capacity the
    # row never reached) contributes nothing — skip its matmuls entirely
    @pl.when(jnp.any(pos_k >= 0))
    def _block():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (s, Dqk)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (blk, Dqk)
        kn = None
        if quant:
            # int8 path: the staged k tile is raw *unroped* codes. Build
            # the per-dim scale row (one scale per head group; two groups
            # when the latent/rope streams of absorbed MLA are separately
            # quantized, split at rope_start), dequantize for the NoPE
            # stream, and RoPE the [rope_start:] span in VMEM. Scales are
            # per (slot, head), so rope-then-scale == scale-then-rope (the
            # rotation is within the group) — scaling last keeps one
            # multiply off the trig path.
            dk = k.shape[-1]
            sc = ks_ref[0, :, 0, :]                        # (blk, G)
            if sc.shape[-1] == 1:
                sc_vec = sc
            else:
                col = jax.lax.broadcasted_iota(jnp.int32, (1, dk), 1)
                sc_vec = jnp.where(col < rope_start,
                                   sc[:, 0:1], sc[:, 1:2])
            if use_nope:
                kn = k * sc_vec                            # unroped dequant
            p = jnp.maximum(pos_k, 0).astype(jnp.float32)
            ang = p[:, None] * rinv_ref[...][None, :]      # (blk, span/2)
            cosv, sinv = jnp.cos(ang), jnp.sin(ang)
            span = k[:, rope_start:]
            half = span.shape[-1] // 2
            x1, x2 = span[:, :half], span[:, half:]
            rot = jnp.concatenate([x1 * cosv - x2 * sinv,
                                   x1 * sinv + x2 * cosv], axis=-1)
            if rope_start:
                rot = jnp.concatenate([k[:, :rope_start], rot], axis=-1)
            k = rot * sc_vec
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        pos_q = pos_q_ref[0]                               # (s,) int32
        d = pos_q[:, None] - pos_k[None, :]                # (s, blk)
        if use_nope:
            qn = qn_ref[0, :, 0, :].astype(jnp.float32)
            if not quant:
                kn = kn_ref[0, :, 0, :].astype(jnp.float32)
            sn = jax.lax.dot_general(qn, kn, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            sn = sn * scale - alibi_ref[0] * d.astype(jnp.float32)
            s = jnp.where((sum_q_ref[0] != 0)[:, None], sn, s)

        # mask: filled slot + causal (+ window) (+ in-burst segment)
        mask = (pos_k >= 0)[None, :] & (d >= 0)
        if window > 0:
            mask &= d <= window
        if use_seg:
            seg_k = seg_k_ref[0]
            mask &= ((seg_k < 0)[None, :]
                     | (seg_k[None, :] == seg_q_ref[0][:, None]))
        s = jnp.where(mask, s, NEG_INF)

        # online softmax across the kv blocks
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        w = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(w, axis=-1)
        m_ref[:, 0] = m_new

        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (blk, Dv)
        if quant:
            v = v * vs_ref[0, :, 0, :]                     # (blk, 1) scale
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            w, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(ikv == n_kv - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l > 0, l, 1.0)
        # rows with no attendable key output exactly 0 (dense any_ok guard)
        o_ref[0, :, 0, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def _pad_cap(x: jax.Array, cap_pad: int, fill) -> jax.Array:
    """Pad the capacity axis (axis 1) of a cache-side operand to cap_pad."""
    cap = x.shape[1]
    if cap == cap_pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, cap_pad - cap)
    return jnp.pad(x, widths, constant_values=fill)


def prepare_decode_inputs(
    q: jax.Array,                 # (B, s, H, Dqk)   RoPE'd queries
    k: jax.Array,                 # (B, cap, Hk, Dqk) read-time-RoPE'd keys
                                  #   (int8 unroped codes on the quant path)
    v: jax.Array,                 # (B, cap, Hk, Dv)
    pos_q: jax.Array,             # (B, s) int32
    pos_k: jax.Array,             # (B, cap) int32; -1 = empty slot
    *,
    window: int,
    sum_q: Optional[jax.Array],
    seg_q: Optional[jax.Array],
    seg_k: Optional[jax.Array],
    q_nope: Optional[jax.Array],
    k_nope: Optional[jax.Array],
    alibi: Optional[jax.Array],
    scale: Optional[float],
    block_size: int,
    interpret: bool,
    k_scale: Optional[jax.Array] = None,    # (B, cap, Hk, G) fp32, G in {1,2}
    v_scale: Optional[jax.Array] = None,    # (B, cap, Hk) fp32
    rope_inv: Optional[jax.Array] = None,   # ((Dqk - rope_start)/2,) fp32
    rope_start: int = 0,
) -> Tuple[DecodeStatics, Tuple[jax.Array, ...]]:
    """Normalise optional operands to concrete arrays + hashable statics.

    Pads the capacity axis to a multiple of the kv block (padding slots
    carry ``pos = -1`` so the occupancy skip drops them for free) — the
    scheduler's ``capacity = ctx + bucket`` need not be block-aligned.

    ``k_scale`` switches the kernel to the quantized-KV contract
    (docs/kernels.md): ``k``/``v`` are raw int8 codes straight from the
    cache — unroped, undequantized — and the kernel dequantizes and RoPEs
    ([``rope_start``:] span, inverse frequencies ``rope_inv``) in VMEM.
    The NoPE stream then needs no separate ``k_nope`` operand: it is the
    same codes dequantized without rotation.
    """
    b, s_len, h, d = q.shape
    cap = k.shape[1]
    if scale is None:
        scale = d ** -0.5
    # unlike choose_block (which degrades towards gcd -> 1 on ragged
    # lengths), pad the cache operands up to a block multiple: the
    # scheduler's capacity is arbitrary and padding slots are skipped
    blk = min(block_size, cap)
    cap_pad = ((cap + blk - 1) // blk) * blk

    quant = k_scale is not None
    if quant:
        assert v_scale is not None and rope_inv is not None, \
            "quantized decode needs k_scale, v_scale and rope_inv together"
        assert k_nope is None, \
            "quantized decode derives the NoPE stream from the codes"
    use_nope = q_nope is not None and sum_q is not None
    use_seg = seg_q is not None and seg_k is not None
    i32 = functools.partial(jnp.asarray, dtype=jnp.int32)
    sum_q_i = i32(sum_q if sum_q is not None else jnp.zeros((b, s_len)))
    seg_q_i = i32(seg_q if use_seg else jnp.zeros((b, s_len)))
    seg_k_i = i32(seg_k if use_seg else jnp.zeros((b, cap)))
    alibi_f = (alibi if alibi is not None
               else jnp.zeros((h,))).astype(jnp.float32)
    # without the NoPE stream the kernel never reads qn/kn: stage single-
    # element placeholders (their BlockSpecs shrink to match) instead of a
    # full-capacity zero tensor per layer per step
    qn = q_nope if use_nope else jnp.zeros((b, 1, 1, 1), q.dtype)
    use_kn = use_nope and not quant
    kn = k_nope if use_kn else jnp.zeros((b, 1, 1, 1), k.dtype)
    # scale sidecars: padded slots get scale 0 (their pos = -1 already
    # makes them unattendable; 0-scale dequant is exact zeros either way)
    ks = (k_scale.astype(jnp.float32) if quant
          else jnp.zeros((b, 1, 1, 1), jnp.float32))
    vs = (v_scale.astype(jnp.float32)[..., None] if quant
          else jnp.zeros((b, 1, 1, 1), jnp.float32))
    rinv = (rope_inv.astype(jnp.float32) if quant
            else jnp.zeros((1,), jnp.float32))

    arrays = (pos_q.astype(jnp.int32),
              _pad_cap(pos_k.astype(jnp.int32), cap_pad, -1),
              sum_q_i, seg_q_i, _pad_cap(seg_k_i, cap_pad, -1),
              alibi_f, q, _pad_cap(k, cap_pad, 0), _pad_cap(v, cap_pad, 0),
              qn, _pad_cap(kn, cap_pad, 0) if use_kn else kn,
              _pad_cap(ks, cap_pad, 0) if quant else ks,
              _pad_cap(vs, cap_pad, 0) if quant else vs,
              rinv)
    st = DecodeStatics(window=int(window), scale=float(scale), block=blk,
                       use_seg=use_seg, use_nope=use_nope,
                       quant=quant, rope_start=int(rope_start),
                       interpret=bool(interpret))
    return st, arrays


def decode_attention_bshd(st: DecodeStatics, pos_q, pos_k, sum_q, seg_q,
                          seg_k, alibi, q, k, v, qn, kn, ks, vs,
                          rinv) -> jax.Array:
    """Normalised forward over prepared operands: returns o (B, s, H, Dv)."""
    b, s_len, h, d = q.shape
    cap = k.shape[1]
    hk = k.shape[2]
    dv = v.shape[-1]
    n_rep = h // hk
    blk = st.block
    assert cap % blk == 0, f"cap={cap} not divisible by block {blk}"
    n_kv = cap // blk

    def q_idx(bi, hi, ki):
        return (bi, 0, hi, 0)

    def kv_idx(bi, hi, ki):
        return (bi, ki, hi // n_rep, 0)

    def kvh_idx(bi, hi, ki):              # for (B, cap, 1, D) nope caches
        return (bi, ki, 0, 0)

    one = lambda bi, hi, ki: (bi, 0, 0, 0)    # single-element placeholders
    use_kn = st.use_nope and not st.quant
    qn_map = q_idx if st.use_nope else one
    kn_map = one if not use_kn else (
        kv_idx if kn.shape[2] == hk else kvh_idx)
    qn_spec = ((1, s_len, 1, qn.shape[-1]) if st.use_nope else (1, 1, 1, 1))
    kn_spec = ((1, blk, 1, kn.shape[-1]) if use_kn else (1, 1, 1, 1))
    # quant sidecars ride the same kv-block schedule as k/v; the rope
    # inverse-frequency row is tiny and staged whole per grid step
    ks_map = kv_idx if st.quant else one
    vs_map = kv_idx if st.quant else one
    ks_spec = ((1, blk, 1, ks.shape[-1]) if st.quant else (1, 1, 1, 1))
    vs_spec = ((1, blk, 1, 1) if st.quant else (1, 1, 1, 1))

    grid = (b, h, n_kv)
    out = pl.pallas_call(
        functools.partial(_kernel, n_kv=n_kv, window=st.window,
                          scale=st.scale, use_seg=st.use_seg,
                          use_nope=st.use_nope, quant=st.quant,
                          rope_start=st.rope_start),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s_len), lambda bi, hi, ki: (bi, 0)),   # pos_q
            pl.BlockSpec((1, blk), lambda bi, hi, ki: (bi, ki)),    # pos_k
            pl.BlockSpec((1, s_len), lambda bi, hi, ki: (bi, 0)),   # sum_q
            pl.BlockSpec((1, s_len), lambda bi, hi, ki: (bi, 0)),   # seg_q
            pl.BlockSpec((1, blk), lambda bi, hi, ki: (bi, ki)),    # seg_k
            pl.BlockSpec((1,), lambda bi, hi, ki: (hi,)),           # alibi
            pl.BlockSpec((1, s_len, 1, d), q_idx),                  # q
            pl.BlockSpec((1, blk, 1, d), kv_idx),                   # k
            pl.BlockSpec((1, blk, 1, dv), kv_idx),                  # v
            pl.BlockSpec(qn_spec, qn_map),                          # qn
            pl.BlockSpec(kn_spec, kn_map),                          # kn
            pl.BlockSpec(ks_spec, ks_map),                          # k scales
            pl.BlockSpec(vs_spec, vs_map),                          # v scales
            pl.BlockSpec((rinv.shape[0],), lambda bi, hi, ki: (0,)),  # rinv
        ],
        out_specs=pl.BlockSpec((1, s_len, 1, dv), q_idx),
        out_shape=jax.ShapeDtypeStruct((b, s_len, h, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((s_len, 1), jnp.float32),      # m (row max)
            pltpu.VMEM((s_len, 1), jnp.float32),      # l (row denom)
            pltpu.VMEM((s_len, dv), jnp.float32),     # acc (value accum)
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=st.interpret,
    )(pos_q, pos_k, sum_q, seg_q, seg_k, alibi, q, k, v, qn, kn, ks, vs,
      rinv)
    return out


__all__ = ["DecodeStatics", "prepare_decode_inputs", "decode_attention_bshd"]
