"""Fused Pallas decode-attention for the serving burst path.

Modules mirror ``windowed_attn/``: ``decode_attn.py`` (kernel + schedule),
``ops.py`` (public op with the custom Pallas lowering), ``ref.py`` (dense
oracle for tests). Entry point: ``repro.kernels.decode_attn.ops
.decode_attention``; wired into serving via
``repro.serve.engine.make_decode_fn(..., attn_impl="pallas")``.
"""
from repro.kernels.decode_attn.ops import decode_attention

__all__ = ["decode_attention"]
