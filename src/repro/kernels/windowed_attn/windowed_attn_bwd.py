"""Flash-style backward Pallas kernels for the windowed DTI attention.

Two passes over the same window-banded block schedule as the forward
(docs/kernels.md has the full contract):

* **dq pass** — grid ``(B, H, n_q, n_kv)``, identical banding to the
  forward: each q block walks its kv band, recomputes the probabilities
  from the saved per-row logsumexp (``p = exp(s - lse)``, no S x S tensor),
  and accumulates ``dq`` (RoPE stream) and ``dq_nope`` (SUM rows) in VMEM
  scratch, writing once at the end of the band.

* **dk/dv pass** — grid ``(B, H, n_kv_j, band)``: for kv block j the
  attending q blocks are ``i = j .. j+n_kv-1``; the kernel accumulates
  ``dk``/``dv`` (and ``dk_nope``/``dv0`` when those streams are live) per
  *query* head, and the wrapper reduces query-head groups onto kv heads
  (GQA) outside — K/V are never repeated in memory, matching the forward.

DTI semantics and where their gradients flow:

* mask terms (causal window, ``valid_k``, SUM isolation, packed segments)
  are recomputed from index arithmetic — pure zero/one gates, no grads;
* SUM NoPE+ALiBi rows took their score from the (q_nope, k_nope) matmul,
  so their ``ds`` flows to dq_nope/dk_nope and contributes *nothing* to
  dq/dk (and vice versa for non-SUM rows); the ALiBi bias is additive in a
  position constant, so it has no input gradient (slopes are non-learned);
* the hidden-state reset output o = sum p * (v + a(d)*sigma * (v0 - v))
  modifies the *per-pair value*, not the normalisation, so the classic
  flash identity D_i = sum_j p_ij dp_ij = <do_i, o_i> still holds;
  dv picks up the (1 - a*sigma) weight and dv0 the a*sigma weight.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.windowed_attn.windowed_attn import (AttnStatics,
                                                       _CompilerParams,
                                                       n_kv_blocks)

_f32 = jnp.float32


def _dot(a, b, dims):
    return jax.lax.dot_general(a, b, (dims, ((), ())),
                               preferred_element_type=_f32)


def _recompute_tile(pos_q, pos_k, sum_q, sum_k, valid_k, seg_q, seg_k,
                    alibi, q, k, qn, kn, v, v0, do, lse, delta, band_ok,
                    *, window, scale, sum_isolated, use_seg, use_nope,
                    use_reset, y_min, y_max, midpoint):
    """Shared (q-block, kv-block) tile math for both backward passes.

    Returns (p, ds_rope, ds_nope, asig): probabilities, the score gradient
    split by stream (RoPE rows vs SUM NoPE rows), and the reset weight
    a(d)*sigma (None unless the reset stream is live). All fp32.
    """
    s = _dot(q, k, ((1,), (1,))) * scale                  # (blk_q, blk_k)
    d = pos_q[:, None] - pos_k[None, :]
    sum_row = sum_q != 0
    if use_nope:
        sn = _dot(qn, kn, ((1,), (1,))) * scale
        sn = sn - alibi * d.astype(_f32)
        s = jnp.where(sum_row[:, None], sn, s)

    mask = (d >= 0) & (d <= window) & (valid_k != 0)[None, :]
    if sum_isolated:
        mask &= (sum_k == 0)[None, :] | (d == 0)
    if use_seg:
        mask &= seg_q[:, None] == seg_k[None, :]
    mask &= band_ok

    # p == softmax probs exactly: lse = m + log(l) (or +1e30 on empty rows,
    # in which case every exp underflows to 0 and so does delta)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)

    dp = _dot(do, v, ((1,), (1,)))                        # do . v_j
    asig = None
    if use_reset:
        a = y_min + (y_max - y_min) * jax.nn.sigmoid(
            d.astype(_f32) - midpoint)
        asig = a * sum_row[:, None].astype(_f32)
        dp = dp + asig * _dot(do, v0 - v, ((1,), (1,)))
    ds = p * (dp - delta[:, None])
    if use_nope:
        ds_nope = ds * sum_row[:, None].astype(_f32)
        ds_rope = ds - ds_nope
    else:
        ds_rope, ds_nope = ds, None
    return p, ds_rope, ds_nope, asig


def _load_tile(pos_q_ref, pos_k_ref, sum_q_ref, sum_k_ref, valid_k_ref,
               seg_q_ref, seg_k_ref, alibi_ref, q_ref, k_ref, v_ref,
               qn_ref, kn_ref, v0_ref, do_ref, lse_ref, delta_ref):
    return dict(
        pos_q=pos_q_ref[0], pos_k=pos_k_ref[0], sum_q=sum_q_ref[0],
        sum_k=sum_k_ref[0], valid_k=valid_k_ref[0], seg_q=seg_q_ref[0],
        seg_k=seg_k_ref[0], alibi=alibi_ref[0],
        q=q_ref[0, 0].astype(_f32), k=k_ref[0, 0].astype(_f32),
        qn=qn_ref[0, 0].astype(_f32), kn=kn_ref[0, 0].astype(_f32),
        v=v_ref[0, 0].astype(_f32), v0=v0_ref[0, 0].astype(_f32),
        do=do_ref[0, 0].astype(_f32), lse=lse_ref[0, 0],
        delta=delta_ref[0, 0])


def _dq_kernel(*refs, n_kv: int, use_nope: bool, scale: float, math_kw):
    ins, refs = refs[:17], refs[17:]
    if use_nope:
        dq_ref, dqn_ref, dq_acc, dqn_acc = refs
    else:
        (dq_ref, dq_acc), dqn_ref, dqn_acc = refs, None, None
    ikv = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)
        if use_nope:
            dqn_acc[...] = jnp.zeros_like(dqn_acc)

    t = _load_tile(*ins)
    band_ok = (iq - (n_kv - 1) + ikv) >= 0                # clamped kv block
    _, ds_rope, ds_nope, _ = _recompute_tile(
        t["pos_q"], t["pos_k"], t["sum_q"], t["sum_k"], t["valid_k"],
        t["seg_q"], t["seg_k"], t["alibi"], t["q"], t["k"], t["qn"],
        t["kn"], t["v"], t["v0"], t["do"], t["lse"], t["delta"], band_ok,
        **math_kw)
    dq_acc[...] += scale * _dot(ds_rope, t["k"], ((1,), (0,)))
    if use_nope:
        dqn_acc[...] += scale * _dot(ds_nope, t["kn"], ((1,), (0,)))

    @pl.when(ikv == n_kv - 1)
    def _finish():
        dq_ref[0, 0, ...] = dq_acc[...].astype(dq_ref.dtype)
        if use_nope:
            dqn_ref[0, 0, ...] = dqn_acc[...].astype(dqn_ref.dtype)


def _dkv_kernel(*refs, n_kv: int, n_q: int, use_nope: bool,
                use_reset: bool, scale: float, math_kw):
    ins, refs = refs[:17], refs[17:]
    n_out = 2 + int(use_nope) + int(use_reset)
    outs, accs = refs[:n_out], refs[n_out:]
    dk_ref, dv_ref = outs[0], outs[1]
    dk_acc, dv_acc = accs[0], accs[1]
    dkn_ref = outs[2] if use_nope else None
    dkn_acc = accs[2] if use_nope else None
    dv0_ref = outs[2 + int(use_nope)] if use_reset else None
    dv0_acc = accs[2 + int(use_nope)] if use_reset else None
    ib = pl.program_id(3)                                  # band position
    j = pl.program_id(2)                                   # kv block

    @pl.when(ib == 0)
    def _init():
        for acc in accs:
            acc[...] = jnp.zeros_like(acc)

    t = _load_tile(*ins)
    band_ok = (j + ib) <= (n_q - 1)                        # clamped q block
    p, ds_rope, ds_nope, asig = _recompute_tile(
        t["pos_q"], t["pos_k"], t["sum_q"], t["sum_k"], t["valid_k"],
        t["seg_q"], t["seg_k"], t["alibi"], t["q"], t["k"], t["qn"],
        t["kn"], t["v"], t["v0"], t["do"], t["lse"], t["delta"], band_ok,
        **math_kw)
    pv = p if not use_reset else p * (1.0 - asig)
    dv_acc[...] += _dot(pv, t["do"], ((0,), (0,)))
    if use_reset:
        dv0_acc[...] += _dot(p * asig, t["do"], ((0,), (0,)))
    dk_acc[...] += scale * _dot(ds_rope, t["q"], ((0,), (0,)))
    if use_nope:
        dkn_acc[...] += scale * _dot(ds_nope, t["qn"], ((0,), (0,)))

    @pl.when(ib == n_kv - 1)
    def _finish():
        for ref, acc in zip(outs, accs):
            ref[0, 0, ...] = acc[...].astype(ref.dtype)


def _head_sum(x: jax.Array, n_out: int) -> jax.Array:
    """Reduce per-query-head grads (B, H, S, D) onto n_out kv heads."""
    b, h, s, d = x.shape
    if n_out == h:
        return x
    if n_out == 1:
        return x.sum(axis=1, keepdims=True)
    return x.reshape(b, n_out, h // n_out, s, d).sum(axis=2)


def windowed_attention_bwd_bhsd(
        st: AttnStatics, q, k, v, qn, kn, v0, alibi,
        pos_q, pos_k, sum_q, sum_k, valid_k, seg_q, seg_k,
        o, lse, do) -> Tuple[jax.Array, ...]:
    """Backward over normalised operands. Returns (dq, dk, dv, dqn, dkn,
    dv0); streams that are not live come back as zeros of the dummy
    operand's shape (dropped by the caller)."""
    b, h, s, d = q.shape
    dv_d = v.shape[-1]                  # value dim (MLA: != qk dim)
    hk = k.shape[1]
    n_rep = h // hk
    blk = st.block
    n_q = s // blk
    n_kv = n_kv_blocks(st.window, blk, n_q)
    kn_heads = kn.shape[1]

    # flash delta: D_i = <do_i, o_i> (holds with the reset stream too)
    delta = jnp.sum(o.astype(_f32) * do.astype(_f32), axis=-1)  # (B,H,S)

    math_kw = dict(window=st.window, scale=st.scale,
                   sum_isolated=st.sum_isolated, use_seg=st.use_seg,
                   use_nope=st.use_nope, use_reset=st.use_reset,
                   y_min=st.y_min, y_max=st.y_max, midpoint=st.midpoint)
    sem = _CompilerParams(dimension_semantics=("parallel", "parallel",
                                               "parallel", "arbitrary"))
    grid = (b, h, n_q, n_kv)

    # ---- dq pass: q-block major, walk the kv band (same maps as fwd) ----
    def kv_idx(bi, hi, qi, ki):
        j = qi - (n_kv - 1) + ki
        return (bi, hi // n_rep, jnp.maximum(j, 0), 0)

    def kvh_idx(bi, hi, qi, ki):
        j = qi - (n_kv - 1) + ki
        return (bi, 0, jnp.maximum(j, 0), 0)

    def q_idx(bi, hi, qi, ki):
        return (bi, hi, qi, 0)

    def seq_q_idx(bi, hi, qi, ki):
        return (bi, qi)

    def seq_k_idx(bi, hi, qi, ki):
        j = qi - (n_kv - 1) + ki
        return (bi, jnp.maximum(j, 0))

    def row_q_idx(bi, hi, qi, ki):
        return (bi, hi, qi)

    kn_map = kv_idx if st.use_nope and kn_heads == hk else kvh_idx
    qn_map = q_idx if st.use_nope else kvh_idx
    v0_map = kv_idx if st.use_reset else kvh_idx

    def in_specs(sq, sk, qm, km, vm, qnm, knm, v0m, rowm):
        return [
            pl.BlockSpec((1, blk), sq),                     # pos_q
            pl.BlockSpec((1, blk), sk),                     # pos_k
            pl.BlockSpec((1, blk), sq),                     # sum_q
            pl.BlockSpec((1, blk), sk),                     # sum_k
            pl.BlockSpec((1, blk), sk),                     # valid_k
            pl.BlockSpec((1, blk), sq),                     # seg_q
            pl.BlockSpec((1, blk), sk),                     # seg_k
            pl.BlockSpec((1,), lambda bi, hi, qi, ki: (hi,)),  # alibi
            pl.BlockSpec((1, 1, blk, d), qm),               # q
            pl.BlockSpec((1, 1, blk, d), km),               # k
            pl.BlockSpec((1, 1, blk, dv_d), vm),            # v
            pl.BlockSpec((1, 1, blk, d), qnm),              # qn
            pl.BlockSpec((1, 1, blk, d), knm),              # kn
            pl.BlockSpec((1, 1, blk, dv_d), v0m),           # v0
            pl.BlockSpec((1, 1, blk, dv_d), qm),            # do
            pl.BlockSpec((1, 1, blk), rowm),                # lse
            pl.BlockSpec((1, 1, blk), rowm),                # delta
        ]

    operands = (pos_q, pos_k, sum_q, sum_k, valid_k, seg_q, seg_k, alibi,
                q, k, v, qn, kn, v0, do, lse, delta)

    dq_outs = [jax.ShapeDtypeStruct((b, h, s, d), q.dtype)]
    dq_specs = [pl.BlockSpec((1, 1, blk, d), q_idx)]
    dq_scratch = [pltpu.VMEM((blk, d), _f32)]
    if st.use_nope:
        dq_outs.append(jax.ShapeDtypeStruct((b, h, s, d), qn.dtype))
        dq_specs.append(pl.BlockSpec((1, 1, blk, d), q_idx))
        dq_scratch.append(pltpu.VMEM((blk, d), _f32))
    res = pl.pallas_call(
        functools.partial(_dq_kernel, n_kv=n_kv, use_nope=st.use_nope,
                          scale=st.scale, math_kw=math_kw),
        grid=grid,
        in_specs=in_specs(seq_q_idx, seq_k_idx, q_idx, kv_idx, kv_idx,
                          qn_map, kn_map, v0_map, row_q_idx),
        out_specs=dq_specs, out_shape=dq_outs, scratch_shapes=dq_scratch,
        compiler_params=sem, interpret=st.interpret,
    )(*operands)
    dq = res[0]
    dqn = res[1] if st.use_nope else jnp.zeros_like(qn)

    # ---- dk/dv pass: kv-block major, walk the attending q blocks --------
    # for kv block j the forward visited it from q blocks j .. j+n_kv-1
    def b_q_idx(bi, hi, j, ib):
        return (bi, hi, jnp.minimum(j + ib, n_q - 1), 0)

    def b_qh_idx(bi, hi, j, ib):
        return (bi, 0, jnp.minimum(j + ib, n_q - 1), 0)

    def b_seq_q_idx(bi, hi, j, ib):
        return (bi, jnp.minimum(j + ib, n_q - 1))

    def b_seq_k_idx(bi, hi, j, ib):
        return (bi, j)

    def b_kv_idx(bi, hi, j, ib):
        return (bi, hi // n_rep, j, 0)

    def b_kvh_idx(bi, hi, j, ib):
        return (bi, 0, j, 0)

    def b_row_idx(bi, hi, j, ib):
        return (bi, hi, jnp.minimum(j + ib, n_q - 1))

    def b_out_idx(bi, hi, j, ib):
        return (bi, hi, j, 0)

    b_kn_map = b_kv_idx if st.use_nope and kn_heads == hk else b_kvh_idx
    b_qn_map = b_q_idx if st.use_nope else b_kvh_idx
    b_v0_map = b_kv_idx if st.use_reset else b_kvh_idx

    # outputs: dk (qk dim), dv (value dim), then dkn / dv0 when live
    out_dims = [d, dv_d] + ([d] if st.use_nope else []) \
        + ([dv_d] if st.use_reset else [])
    dkv_outs = [jax.ShapeDtypeStruct((b, h, s, dd), _f32)
                for dd in out_dims]
    dkv_specs = [pl.BlockSpec((1, 1, blk, dd), b_out_idx)
                 for dd in out_dims]
    dkv_scratch = [pltpu.VMEM((blk, dd), _f32) for dd in out_dims]
    res = pl.pallas_call(
        functools.partial(_dkv_kernel, n_kv=n_kv, n_q=n_q,
                          use_nope=st.use_nope, use_reset=st.use_reset,
                          scale=st.scale, math_kw=math_kw),
        grid=grid,
        in_specs=in_specs(b_seq_q_idx, b_seq_k_idx, b_q_idx, b_kv_idx,
                          b_kv_idx, b_qn_map, b_kn_map, b_v0_map,
                          b_row_idx),
        out_specs=dkv_specs, out_shape=dkv_outs, scratch_shapes=dkv_scratch,
        compiler_params=sem, interpret=st.interpret,
    )(*operands)
    dk = _head_sum(res[0], hk).astype(k.dtype)
    dv = _head_sum(res[1], hk).astype(v.dtype)
    dkn = (_head_sum(res[2], kn_heads).astype(kn.dtype)
           if st.use_nope else jnp.zeros_like(kn))
    dv0 = (_head_sum(res[2 + int(st.use_nope)], hk).astype(v0.dtype)
           if st.use_reset else jnp.zeros_like(v0))
    return dq, dk, dv, dqn, dkn, dv0


__all__ = ["windowed_attention_bwd_bhsd"]
