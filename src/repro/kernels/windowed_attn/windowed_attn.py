"""Pallas TPU kernel: flash-style windowed causal attention with the DTI
semantics fused (SUM isolation, SUM NoPE+ALiBi dual scores, distance-based
hidden-state reset) — the compute hot-spot of the paper's training step.

TPU adaptation (DESIGN.md §3): the paper's GPU implementation is a masked
SDPA; here the window becomes a *blocked local* schedule tuned for the MXU
and VMEM:

  grid = (B, H, n_q_blocks, n_kv_blocks)     n_kv = window//blk + 1

Each (q-block, kv-block) step stages (blk, D) tiles HBM->VMEM, runs the
score matmul on the MXU in fp32, applies every DTI mask term via index
arithmetic (no S x S mask ever materialises), and maintains an online-
softmax accumulator in VMEM scratch across the kv dimension (declared
"arbitrary" so the accumulator carries). The hidden-state reset rides the
same pass as a second value stream: acc_r += w * a(d) * (v0 - v), folded
into the final normalisation — zero extra HBM traffic for the reset beyond
reading v0.

All mask/positional inputs are int32 (pos) / int32 (flags) so the kernel
has no sub-byte loads. GQA is handled by index-mapping query head h onto
kv head h // n_rep — K/V are never repeated in memory.

The forward also emits the per-row softmax logsumexp (B, H, S) — the flash
residual the backward kernels (``windowed_attn_bwd``) use to recompute
probabilities blockwise instead of storing them; see docs/kernels.md for
the fwd/bwd contract.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


class AttnStatics(NamedTuple):
    """Hashable per-call configuration shared by the fwd and bwd kernels
    (the ``nondiff`` argument of the custom_vjp in ops.py)."""
    window: int
    scale: float
    block: int
    sum_isolated: bool
    use_seg: bool
    use_nope: bool
    use_reset: bool
    y_min: float
    y_max: float
    midpoint: float
    interpret: bool


def choose_block(s: int, block_size: int) -> int:
    """Largest block <= block_size that divides S. Falls back to
    gcd(S, block_size) so arbitrary row lengths stay legal (correctness
    fallback — pick 128-aligned S on real TPUs)."""
    blk = min(block_size, s)
    if s % blk:
        blk = math.gcd(s, blk)
    return blk


def n_kv_blocks(window: int, blk: int, n_q: int) -> int:
    """KV-band depth: how many kv blocks each q block attends (window plus
    in-block causal tail, +1 when the window is not block-aligned)."""
    n_kv = min(window // blk + 1, n_q) + (0 if window % blk == 0 else 1)
    return min(max(n_kv, 1), n_q)


def _kernel(pos_q_ref, pos_k_ref, sum_q_ref, sum_k_ref, valid_k_ref,
            seg_q_ref, seg_k_ref,
            alibi_ref,
            q_ref, k_ref, v_ref, qn_ref, kn_ref, v0_ref,
            o_ref, lse_ref,
            m_ref, l_ref, acc_ref,
            *, blk: int, n_kv: int, window: int, scale: float,
            sum_isolated: bool, use_seg: bool, use_nope: bool,
            use_reset: bool, y_min: float, y_max: float, midpoint: float):
    ikv = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # (blk, D)
    k = k_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    pos_q = pos_q_ref[0]                                  # (blk,) int32
    pos_k = pos_k_ref[0]
    d = pos_q[:, None] - pos_k[None, :]                   # (blk, blk)
    sum_q = sum_q_ref[0] != 0                             # (blk,)

    if use_nope:
        qn = qn_ref[0, 0].astype(jnp.float32)
        kn = kn_ref[0, 0].astype(jnp.float32)
        sn = jax.lax.dot_general(qn, kn, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
        sn = sn - alibi_ref[0] * d.astype(jnp.float32)
        s = jnp.where(sum_q[:, None], sn, s)

    # mask: causal + window + key-padding (+ SUM isolation) (+ same packed
    # segment) + real kv block
    mask = (d >= 0) & (d <= window) & (valid_k_ref[0] != 0)[None, :]
    if sum_isolated:
        mask &= (sum_k_ref[0] == 0)[None, :] | (d == 0)
    if use_seg:
        mask &= seg_q_ref[0][:, None] == seg_k_ref[0][None, :]
    j_actual = iq - (n_kv - 1) + ikv
    mask &= j_actual >= 0                                  # clamped block
    s = jnp.where(mask, s, NEG_INF)

    # online softmax
    m_prev = m_ref[:, 0]                                   # (blk,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    w = jnp.exp(s - m_new[:, None])
    w = jnp.where(mask, w, 0.0)
    l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(w, axis=-1)
    m_ref[:, 0] = m_new

    v = v_ref[0, 0].astype(jnp.float32)
    acc = acc_ref[...] * alpha[:, None]
    acc += jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    if use_reset:
        a = y_min + (y_max - y_min) * jax.nn.sigmoid(
            d.astype(jnp.float32) - midpoint)
        wr = w * a * sum_q[:, None].astype(jnp.float32)
        dv = v0_ref[0, 0].astype(jnp.float32) - v
        acc += jax.lax.dot_general(wr, dv, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(ikv == n_kv - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0, ...] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)
        # flash residual: rows with no attendable key get +1e30 so the bwd
        # recompute exp(s - lse) underflows to exactly 0 for every key
        lse_ref[0, 0, :] = jnp.where(l > 0, m_ref[:, 0] + jnp.log(safe),
                                     -NEG_INF)


def prepare_inputs(
    q: jax.Array,                 # (B, H, S, D)
    k: jax.Array,                 # (B, Hk, S, D)
    v: jax.Array,
    pos_q: jax.Array,             # (B, S) int32
    pos_k: jax.Array,
    *,
    window: int,
    sum_q: Optional[jax.Array],
    sum_k: Optional[jax.Array],
    valid_k: Optional[jax.Array],
    seg_q: Optional[jax.Array],
    seg_k: Optional[jax.Array],
    q_nope: Optional[jax.Array],
    k_nope: Optional[jax.Array],
    alibi: Optional[jax.Array],
    v0: Optional[jax.Array],
    reset: Optional[tuple],
    sum_isolated: bool,
    scale: Optional[float],
    block_size: int,
    interpret: bool,
) -> Tuple[AttnStatics, Tuple[jax.Array, ...]]:
    """Normalise optional operands to concrete arrays + hashable statics.

    The array tuple is exactly the differentiable-argument order of the
    custom_vjp in ops.py: (q, k, v, qn, kn, v0, alibi, pos_q, pos_k,
    sum_q, sum_k, valid_k, seg_q, seg_k).
    """
    b, h, s, d = q.shape
    blk = choose_block(s, block_size)
    if scale is None:
        scale = d ** -0.5

    use_nope = q_nope is not None
    use_reset = reset is not None and v0 is not None
    use_seg = seg_q is not None and seg_k is not None
    i32 = functools.partial(jnp.asarray, dtype=jnp.int32)
    sum_q_i = i32(sum_q if sum_q is not None else jnp.zeros((b, s)))
    sum_k_i = i32(sum_k if sum_k is not None else jnp.zeros((b, s)))
    valid_i = i32(valid_k if valid_k is not None else jnp.ones((b, s)))
    seg_q_i = i32(seg_q if use_seg else jnp.zeros((b, s)))
    seg_k_i = i32(seg_k if use_seg else jnp.zeros((b, s)))
    alibi_f = (alibi if alibi is not None
               else jnp.zeros((h,))).astype(jnp.float32)
    # value dim may differ from the qk dim (MLA: v_head_dim != qk_head)
    zero_qk = jnp.zeros((b, 1, s, d), q.dtype)
    qn = q_nope if use_nope else zero_qk
    kn = k_nope if use_nope else zero_qk
    v0_ = v0 if use_reset else jnp.zeros((b, 1, s, v.shape[-1]), q.dtype)
    y_min, y_max, midpoint = reset if use_reset else (0.0, 0.0, 0.0)

    st = AttnStatics(window=int(window), scale=float(scale), block=blk,
                     sum_isolated=bool(sum_isolated), use_seg=use_seg,
                     use_nope=use_nope, use_reset=use_reset,
                     y_min=float(y_min), y_max=float(y_max),
                     midpoint=float(midpoint), interpret=bool(interpret))
    arrays = (q, k, v, qn, kn, v0_, alibi_f,
              pos_q.astype(jnp.int32), pos_k.astype(jnp.int32),
              sum_q_i, sum_k_i, valid_i, seg_q_i, seg_k_i)
    return st, arrays


def windowed_attention_fwd_bhsd(
        st: AttnStatics, q, k, v, qn, kn, v0, alibi,
        pos_q, pos_k, sum_q, sum_k, valid_k, seg_q, seg_k,
) -> Tuple[jax.Array, jax.Array]:
    """Normalised forward: returns (o (B,H,S,Dv), lse (B,H,S) fp32)."""
    b, h, s, d = q.shape
    dv = v.shape[-1]
    hk = k.shape[1]
    n_rep = h // hk
    blk = st.block
    assert s % blk == 0, f"S={s} not divisible by block {blk}"
    n_q = s // blk
    n_kv = n_kv_blocks(st.window, blk, n_q)

    def kv_idx(bi, hi, qi, ki):
        j = qi - (n_kv - 1) + ki
        return (bi, hi // n_rep, jnp.maximum(j, 0), 0)

    def kvh_idx(bi, hi, qi, ki):          # for arrays already (B,1,S,D)
        j = qi - (n_kv - 1) + ki
        return (bi, 0, jnp.maximum(j, 0), 0)

    def seq_q_idx(bi, hi, qi, ki):
        return (bi, qi)

    def seq_k_idx(bi, hi, qi, ki):
        j = qi - (n_kv - 1) + ki
        return (bi, jnp.maximum(j, 0))

    kn_map = kv_idx if st.use_nope and kn.shape[1] == hk else kvh_idx
    qn_map = ((lambda bi, hi, qi, ki: (bi, hi, qi, 0))
              if st.use_nope else kvh_idx)
    v0_map = kv_idx if st.use_reset else kvh_idx

    grid = (b, h, n_q, n_kv)
    out, lse = pl.pallas_call(
        functools.partial(
            _kernel, blk=blk, n_kv=n_kv, window=st.window, scale=st.scale,
            sum_isolated=st.sum_isolated, use_seg=st.use_seg,
            use_nope=st.use_nope, use_reset=st.use_reset, y_min=st.y_min,
            y_max=st.y_max, midpoint=st.midpoint),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk), seq_q_idx),                  # pos_q
            pl.BlockSpec((1, blk), seq_k_idx),                  # pos_k
            pl.BlockSpec((1, blk), seq_q_idx),                  # sum_q
            pl.BlockSpec((1, blk), seq_k_idx),                  # sum_k
            pl.BlockSpec((1, blk), seq_k_idx),                  # valid_k
            pl.BlockSpec((1, blk), seq_q_idx),                  # seg_q
            pl.BlockSpec((1, blk), seq_k_idx),                  # seg_k
            pl.BlockSpec((1,), lambda bi, hi, qi, ki: (hi,)),   # alibi
            pl.BlockSpec((1, 1, blk, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),  # q
            pl.BlockSpec((1, 1, blk, d), kv_idx),               # k
            pl.BlockSpec((1, 1, blk, dv), kv_idx),              # v
            pl.BlockSpec((1, 1, blk, d), qn_map),               # qn
            pl.BlockSpec((1, 1, blk, d), kn_map),               # kn
            pl.BlockSpec((1, 1, blk, dv), v0_map),              # v0
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk, dv),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, blk), lambda bi, hi, qi, ki: (bi, hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, dv), q.dtype),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk, 1), jnp.float32),      # m (row max)
            pltpu.VMEM((blk, 1), jnp.float32),      # l (row denom)
            pltpu.VMEM((blk, dv), jnp.float32),     # acc (value accum)
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=st.interpret,
    )(pos_q, pos_k, sum_q, sum_k, valid_k, seg_q, seg_k, alibi, q, k, v,
      qn, kn, v0)
    return out, lse


def windowed_attention_bhsd(
    q: jax.Array,                 # (B, H, S, D)   RoPE'd queries
    k: jax.Array,                 # (B, Hk, S, D)  RoPE'd keys
    v: jax.Array,                 # (B, Hk, S, D)
    pos_q: jax.Array,             # (B, S) int32
    pos_k: jax.Array,             # (B, S) int32
    *,
    window: int,
    sum_q: Optional[jax.Array] = None,     # (B, S) int32 flags
    sum_k: Optional[jax.Array] = None,
    valid_k: Optional[jax.Array] = None,
    seg_q: Optional[jax.Array] = None,     # (B, S) int32 packed segments
    seg_k: Optional[jax.Array] = None,
    q_nope: Optional[jax.Array] = None,    # (B, H, S, D)
    k_nope: Optional[jax.Array] = None,    # (B, Hk, S, D)
    alibi: Optional[jax.Array] = None,     # (H,) f32
    v0: Optional[jax.Array] = None,        # (B, Hk, S, D)
    reset: Optional[tuple] = None,         # (y_min, y_max, midpoint)
    sum_isolated: bool = True,
    scale: Optional[float] = None,
    block_size: int = 256,
    interpret: bool = False,
    return_residuals: bool = False,
):
    """Raw forward (no VJP) — ``ops.windowed_attention`` is the trainable
    entry point. ``return_residuals=True`` also returns the per-row lse."""
    st, arrays = prepare_inputs(
        q, k, v, pos_q, pos_k, window=window, sum_q=sum_q, sum_k=sum_k,
        valid_k=valid_k, seg_q=seg_q, seg_k=seg_k, q_nope=q_nope,
        k_nope=k_nope, alibi=alibi, v0=v0, reset=reset,
        sum_isolated=sum_isolated, scale=scale, block_size=block_size,
        interpret=interpret)
    out, lse = windowed_attention_fwd_bhsd(st, *arrays)
    return (out, lse) if return_residuals else out


__all__ = ["AttnStatics", "choose_block", "n_kv_blocks", "prepare_inputs",
           "windowed_attention_fwd_bhsd", "windowed_attention_bhsd"]
