"""jit'd public wrapper: same signature as ``repro.core.windowed.attention_dense``.

Transposes (B, S, H, D) -> (B, H, S, D) for the kernel's tiling, forwards
every DTI option, and untransposes. ``interpret=True`` by default off-TPU so
the kernel body runs (and is tested) on CPU; on TPU it compiles to Mosaic.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.windowed import ResetConfig
from repro.kernels.windowed_attn.windowed_attn import windowed_attention_bhsd


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # noqa: BLE001
        return False


def windowed_attention(
    q: jax.Array,                      # (B, Sq, H, D)
    k: jax.Array,                      # (B, Sk, Hk, D)
    v: jax.Array,                      # (B, Sk, Hk, Dv)
    *,
    pos_q: jax.Array,
    pos_k: jax.Array,
    window: int,
    is_sum_q: Optional[jax.Array] = None,
    is_sum_k: Optional[jax.Array] = None,
    valid_k: Optional[jax.Array] = None,
    seg_q: Optional[jax.Array] = None,
    seg_k: Optional[jax.Array] = None,
    q_nope: Optional[jax.Array] = None,
    k_nope: Optional[jax.Array] = None,
    alibi: Optional[jax.Array] = None,
    v0: Optional[jax.Array] = None,
    reset: Optional[ResetConfig] = None,
    sum_isolated: bool = True,
    scale: Optional[float] = None,
    block_size: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    assert window > 0, "pallas path needs a window"
    if interpret is None:
        interpret = not _on_tpu()
    t = lambda x: None if x is None else jnp.swapaxes(x, 1, 2)
    use_nope = q_nope is not None and is_sum_q is not None
    out = windowed_attention_bhsd(
        t(q), t(k), t(v), pos_q, pos_k, window=window,
        sum_q=is_sum_q, sum_k=is_sum_k, valid_k=valid_k,
        seg_q=seg_q, seg_k=seg_k,
        q_nope=t(q_nope) if use_nope else None,
        k_nope=t(k_nope) if use_nope else None,
        alibi=alibi if use_nope else None,
        v0=t(v0) if (reset is not None and v0 is not None) else None,
        reset=((reset.y_min, reset.y_max, reset.midpoint)
               if reset is not None and v0 is not None else None),
        sum_isolated=sum_isolated and is_sum_k is not None,
        scale=scale, block_size=block_size, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


__all__ = ["windowed_attention"]
