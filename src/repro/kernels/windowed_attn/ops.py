"""jit'd public wrapper: same signature as ``repro.core.windowed.attention_dense``.

Transposes (B, S, H, D) -> (B, H, S, D) for the kernel's tiling, forwards
every DTI option, and untransposes. ``interpret=True`` by default off-TPU so
the kernel body runs (and is tested) on CPU; on TPU it compiles to Mosaic.

The op is differentiable: a ``jax.custom_vjp`` pairs the forward kernel
(which saves the per-row softmax logsumexp) with the flash-style backward
kernels in ``windowed_attn_bwd`` — dq and dk/dv passes over the same
window-banded block schedule, recomputing probabilities from the residual.
Gradients flow to q/k/v, q_nope/k_nope (SUM rows) and v0 (reset stream);
positions, flags, segment ids and the (non-learned) ALiBi slopes get zero
cotangents. See docs/kernels.md.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.windowed import ResetConfig
from repro.kernels import default_interpret
from repro.kernels.windowed_attn.windowed_attn import (
    AttnStatics, prepare_inputs, windowed_attention_fwd_bhsd)
from repro.kernels.windowed_attn.windowed_attn_bwd import (
    windowed_attention_bwd_bhsd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _attn(st: AttnStatics, q, k, v, qn, kn, v0, alibi,
          pos_q, pos_k, sum_q, sum_k, valid_k, seg_q, seg_k):
    out, _ = windowed_attention_fwd_bhsd(
        st, q, k, v, qn, kn, v0, alibi, pos_q, pos_k, sum_q, sum_k,
        valid_k, seg_q, seg_k)
    return out


def _attn_fwd(st: AttnStatics, q, k, v, qn, kn, v0, alibi,
              pos_q, pos_k, sum_q, sum_k, valid_k, seg_q, seg_k):
    out, lse = windowed_attention_fwd_bhsd(
        st, q, k, v, qn, kn, v0, alibi, pos_q, pos_k, sum_q, sum_k,
        valid_k, seg_q, seg_k)
    return out, (q, k, v, qn, kn, v0, alibi, pos_q, pos_k, sum_q, sum_k,
                 valid_k, seg_q, seg_k, out, lse)


def _attn_bwd(st: AttnStatics, res, do):
    (q, k, v, qn, kn, v0, alibi, pos_q, pos_k, sum_q, sum_k, valid_k,
     seg_q, seg_k, out, lse) = res
    dq, dk, dv, dqn, dkn, dv0 = windowed_attention_bwd_bhsd(
        st, q, k, v, qn, kn, v0, alibi, pos_q, pos_k, sum_q, sum_k,
        valid_k, seg_q, seg_k, out, lse, do)
    # ALiBi slopes are head constants (alibi_slopes(n_heads)), not params
    return (dq, dk, dv, dqn, dkn, dv0, jnp.zeros_like(alibi),
            None, None, None, None, None, None, None)


_attn.defvjp(_attn_fwd, _attn_bwd)


def windowed_attention(
    q: jax.Array,                      # (B, Sq, H, D)
    k: jax.Array,                      # (B, Sk, Hk, D)
    v: jax.Array,                      # (B, Sk, Hk, Dv)
    *,
    pos_q: jax.Array,
    pos_k: jax.Array,
    window: int,
    is_sum_q: Optional[jax.Array] = None,
    is_sum_k: Optional[jax.Array] = None,
    valid_k: Optional[jax.Array] = None,
    seg_q: Optional[jax.Array] = None,
    seg_k: Optional[jax.Array] = None,
    q_nope: Optional[jax.Array] = None,
    k_nope: Optional[jax.Array] = None,
    alibi: Optional[jax.Array] = None,
    v0: Optional[jax.Array] = None,
    reset: Optional[ResetConfig] = None,
    sum_isolated: bool = True,
    scale: Optional[float] = None,
    block_size: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    assert window > 0, "pallas path needs a window"
    interpret = default_interpret(interpret)
    t = lambda x: None if x is None else jnp.swapaxes(x, 1, 2)
    use_nope = q_nope is not None and is_sum_q is not None
    use_reset = reset is not None and v0 is not None
    st, arrays = prepare_inputs(
        t(q), t(k), t(v), pos_q, pos_k, window=window,
        sum_q=is_sum_q, sum_k=is_sum_k, valid_k=valid_k,
        seg_q=seg_q, seg_k=seg_k,
        q_nope=t(q_nope) if use_nope else None,
        k_nope=t(k_nope) if use_nope else None,
        alibi=alibi if use_nope else None,
        v0=t(v0) if use_reset else None,
        reset=((reset.y_min, reset.y_max, reset.midpoint)
               if use_reset else None),
        sum_isolated=sum_isolated and is_sum_k is not None,
        scale=scale, block_size=block_size, interpret=interpret)
    return jnp.swapaxes(_attn(st, *arrays), 1, 2)


__all__ = ["windowed_attention"]
