"""Pure-jnp oracle for the windowed_attn kernel.

The reference is ``repro.core.windowed.attention_dense`` — the exact DTI
attention the paper defines (window mask, SUM isolation, SUM NoPE+ALiBi,
distance-based reset), materialising the full (Sq, Sk) score matrix. The
kernel tests sweep shapes/dtypes/feature-flags and assert allclose against
this function.
"""
from repro.core.windowed import attention_dense as reference_attention

__all__ = ["reference_attention"]
