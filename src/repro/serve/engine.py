"""Serving: prefill scoring + incremental decode with stacked-layer caches.

Inference follows the paper (section 3.6): sliding-window prompts with one
[SUM] readout at the end, scored by bi-dimensional softmax over yes/no. Two
execution paths:

* ``make_prefill_fn``   — full forward over the prompt (the paper's actual
  inference procedure). SUM rows keep their training-time semantics
  (NoPE + ALiBi, isolation) but **no hidden-state reset** — the reset is a
  training-only regularizer that mimics inference, inference itself is
  untouched.
* ``make_decode_fn``    — one-token incremental step against a KV cache
  (decode_32k / long_500k shapes). The cache stores **unroped** keys plus
  their logical positions; RoPE is applied at read time, which lets a [SUM]
  query score the same cache with NoPE+ALiBi while regular tokens see
  standard RoPE — one cache serves both semantics. MLA runs in absorbed
  form against the latent cache (q_nope folded through W_UK, values decoded
  through W_UV after aggregation).

``lax.scan`` over (stacked layer params, stacked cache layers) keeps the
lowered HLO O(1) in depth for the 512-device dry-run compiles.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.losses import ctr_logits
from repro.core.windowed import NEG_INF
from repro.models.layers import alibi_slopes, apply_rope, dense, rmsnorm
from repro.models.moe import moe_ffn
from repro.models.transformer import ModelConfig, forward
from repro.serve.cache import Cache, slot_indices

Params = Dict[str, Any]


# ===========================================================================
# prefill
# ===========================================================================

def make_prefill_fn(cfg: ModelConfig, *, yes_id: int = 3, no_id: int = 4,
                    window: Optional[int] = None) -> Callable:
    """(params, batch) -> p_click (B, S); valid only at [SUM] positions."""

    def prefill(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        # inference-time DTI: SUM NoPE+ALiBi + isolation, no reset
        icfg = dataclasses.replace(cfg, dti_reset=False)
        out = forward(params, icfg, batch["tokens"],
                      positions=batch["positions"], is_sum=batch["is_sum"],
                      valid=batch["valid"], dti_enabled=True, window=window)
        logits2 = ctr_logits(params, cfg, out["hidden"], yes_id, no_id)
        p = jax.nn.softmax(logits2.astype(jnp.float32), axis=-1)[..., 0]
        return jnp.where(batch["is_sum"], p, 0.0)

    return prefill


# ===========================================================================
# decode
# ===========================================================================

def _rope_read(k: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rope cached (unroped) keys with their stored positions; slots with
    pos < 0 are masked later, rope them at 0."""
    return apply_rope(k, jnp.maximum(pos, 0), theta)


def _decode_attend(scores_rope, scores_nope, alibi, d, mask, is_sum_q, v_agg):
    """Shared score->prob->value logic. scores_* are (B, H, s, cap) fp32."""
    if scores_nope is not None:
        biased = scores_nope - alibi[None, :, None, None] * d
        scores = jnp.where(is_sum_q[:, None, :, None], biased, scores_rope)
    else:
        scores = scores_rope
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    any_ok = jnp.any(mask, axis=-1)[:, None, :, None]
    return v_agg(jnp.where(any_ok, probs, 0.0))


def _gqa_decode_layer(lp: Params, h, kc, vc, *, cfg: ModelConfig, slots,
                      pos_buf, positions, is_sum, window, kind):
    b, s, _ = h.shape
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    n_rep = hq // hk
    x = rmsnorm(lp["ln_attn"], h, cfg.norm_eps)
    q = dense(lp["attn"]["q"], x).reshape(b, s, hq, hd)
    k_new = dense(lp["attn"]["k"], x).reshape(b, s, hk, hd)
    v_new = dense(lp["attn"]["v"], x).reshape(b, s, hk, hd)

    bidx = jnp.arange(b)[:, None]
    kc = kc.at[bidx, slots].set(k_new.astype(kc.dtype))      # unroped keys
    vc = vc.at[bidx, slots].set(v_new.astype(vc.dtype))

    q_rope = apply_rope(q, positions, cfg.rope_theta)
    k_rope = _rope_read(kc, pos_buf, cfg.rope_theta)

    def rep(t):  # (B, cap, Hk, D) -> (B, cap, Hq, D)
        if n_rep == 1:
            return t
        bb, cap, _, dd = t.shape
        return jnp.broadcast_to(t[:, :, :, None, :],
                                (bb, cap, hk, n_rep, dd)).reshape(bb, cap, hq, dd)

    scale = hd ** -0.5
    sc_rope = jnp.einsum("bshd,bkhd->bhsk", q_rope, rep(k_rope),
                         preferred_element_type=jnp.float32) * scale
    sc_nope = None
    if cfg.dti_sum_alibi:
        sc_nope = jnp.einsum("bshd,bkhd->bhsk", q, rep(kc),
                             preferred_element_type=jnp.float32) * scale

    d = (positions[:, None, :, None] - pos_buf[:, None, None, :]
         ).astype(jnp.float32)
    mask = ((pos_buf[:, None, :] >= 0)
            & (positions[:, :, None] >= pos_buf[:, None, :])
            & ((positions[:, :, None] - pos_buf[:, None, :]) <= window))
    out = _decode_attend(sc_rope, sc_nope, alibi_slopes(hq), d, mask, is_sum,
                         lambda p: jnp.einsum("bhsk,bkhd->bshd",
                                              p.astype(h.dtype), rep(vc)))
    h = h + dense(lp["attn"]["o"], out.reshape(b, s, hq * hd))
    h, aux = _ffn(lp, h, cfg, kind)
    return h, kc, vc, aux


def _mla_decode_layer(lp: Params, h, ckv_c, kpe_c, *, cfg: ModelConfig,
                      slots, pos_buf, positions, is_sum, window, kind):
    """Absorbed-MLA decode: scores and values against the latent cache."""
    b, s, _ = h.shape
    hq = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ap = lp["attn"]
    x = rmsnorm(lp["ln_attn"], h, cfg.norm_eps)

    if "q_down" in ap:
        qc = rmsnorm(ap["q_norm"], dense(ap["q_down"], x))
        q = dense(ap["q_up"], qc)
    else:
        q = dense(ap["q"], x)
    q = q.reshape(b, s, hq, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe_rope = apply_rope(q_pe, positions, cfg.rope_theta)

    c_new = rmsnorm(ap["kv_norm"], dense(ap["kv_down"], x))         # (B,s,r)
    kpe_new = dense(ap["k_rope"], x)                                # (B,s,dr)

    bidx = jnp.arange(b)[:, None]
    ckv_c = ckv_c.at[bidx, slots].set(c_new.astype(ckv_c.dtype))
    kpe_c = kpe_c.at[bidx, slots].set(kpe_new.astype(kpe_c.dtype))

    # absorb W_UK into the query, W_UV into the output
    w_up = ap["kv_up"]["w"].reshape(cfg.kv_lora_rank, hq, dn + dv)
    w_uk, w_uv = w_up[..., :dn], w_up[..., dn:]
    q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)              # (B,s,H,r)

    kpe_rope = _rope_read(kpe_c[:, :, None, :], pos_buf,
                          cfg.rope_theta)[:, :, 0, :]               # (B,cap,dr)
    scale = (dn + dr) ** -0.5
    sc_rope = (jnp.einsum("bshr,bkr->bhsk", q_abs, ckv_c,
                          preferred_element_type=jnp.float32)
               + jnp.einsum("bshd,bkd->bhsk", q_pe_rope, kpe_rope,
                            preferred_element_type=jnp.float32)) * scale
    sc_nope = None
    if cfg.dti_sum_alibi:
        sc_nope = (jnp.einsum("bshr,bkr->bhsk", q_abs, ckv_c,
                              preferred_element_type=jnp.float32)
                   + jnp.einsum("bshd,bkd->bhsk", q_pe, kpe_c,
                                preferred_element_type=jnp.float32)) * scale

    d = (positions[:, None, :, None] - pos_buf[:, None, None, :]
         ).astype(jnp.float32)
    mask = ((pos_buf[:, None, :] >= 0)
            & (positions[:, :, None] >= pos_buf[:, None, :])
            & ((positions[:, :, None] - pos_buf[:, None, :]) <= window))

    def v_agg(p):
        o_lat = jnp.einsum("bhsk,bkr->bshr", p.astype(h.dtype), ckv_c)
        return jnp.einsum("bshr,rhd->bshd", o_lat, w_uv)

    out = _decode_attend(sc_rope, sc_nope, alibi_slopes(hq), d, mask, is_sum,
                         v_agg)
    h = h + dense(ap["o"], out.reshape(b, s, hq * dv))
    h, aux = _ffn(lp, h, cfg, kind)
    return h, ckv_c, kpe_c, aux


def _ffn(lp: Params, h, cfg: ModelConfig, kind: str):
    from repro.models.layers import swiglu
    x = rmsnorm(lp["ln_ffn"], h, cfg.norm_eps)
    if kind == "moe":
        f, aux = moe_ffn(lp["ffn"], x, n_experts=cfg.n_experts,
                         top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                         norm_topk=cfg.norm_topk)
    else:
        f, aux = swiglu(lp["ffn"], x), jnp.zeros((), jnp.float32)
    return h + f, aux


def make_decode_fn(cfg: ModelConfig, *, window: int, ring: bool,
                   yes_id: int = 3, no_id: int = 4) -> Callable:
    """(params, cache, tokens (B,s), positions (B,s), is_sum (B,s))
    -> (p_click (B, s), new_cache)."""
    mla = cfg.attn_type == "mla"
    keys = ("ckv", "kpe") if mla else ("k", "v")
    layer_fn = _mla_decode_layer if mla else _gqa_decode_layer

    def decode(params: Params, cache: Cache, tokens: jax.Array,
               positions: jax.Array, is_sum: jax.Array
               ) -> Tuple[jax.Array, Cache]:
        b, s = tokens.shape
        slots = slot_indices(cache, s, ring=ring)
        bidx = jnp.arange(b)[:, None]
        pos_buf = cache["pos"].at[bidx, slots].set(positions)
        new_cache = dict(cache, pos=pos_buf,
                         cursor=cache["cursor"] + s)

        h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)

        n_prefix = cfg.first_dense_layers if cfg.moe else 0

        # The (L, B, cap, ...) cache tensors ride the scan CARRY and are
        # updated per layer with dynamic_update_index_in_dim: XLA keeps
        # while-loop carries in place, so the donated cache is mutated with
        # no xs/ys double buffer (which would cost a full extra cache).
        def run_group(h, ca_all, cb_all, group: Params, kind: str, lo: int):
            n = jax.tree_util.tree_leaves(group)[0].shape[0]

            def body(carry, xs):
                hc, ca_full, cb_full = carry
                lp, li = xs
                ca = jax.lax.dynamic_index_in_dim(ca_full, li, 0,
                                                  keepdims=False)
                cb = jax.lax.dynamic_index_in_dim(cb_full, li, 0,
                                                  keepdims=False)
                hh, ca, cb, aux = layer_fn(
                    lp, hc, ca, cb, cfg=cfg, slots=slots, pos_buf=pos_buf,
                    positions=positions, is_sum=is_sum, window=window,
                    kind=kind)
                ca_full = jax.lax.dynamic_update_index_in_dim(
                    ca_full, ca.astype(ca_full.dtype), li, 0)
                cb_full = jax.lax.dynamic_update_index_in_dim(
                    cb_full, cb.astype(cb_full.dtype), li, 0)
                return (hh, ca_full, cb_full), None

            idx = lo + jnp.arange(n, dtype=jnp.int32)
            (h, ca_all, cb_all), _ = jax.lax.scan(
                body, (h, ca_all, cb_all), (group, idx))
            return h, ca_all, cb_all

        ca_all, cb_all = cache[keys[0]], cache[keys[1]]
        if "prefix" in params:
            h, ca_all, cb_all = run_group(h, ca_all, cb_all,
                                          params["prefix"], "dense", 0)
        h, ca_all, cb_all = run_group(h, ca_all, cb_all, params["stack"],
                                      "moe" if cfg.moe else "dense",
                                      n_prefix)
        new_cache[keys[0]], new_cache[keys[1]] = ca_all, cb_all

        h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
        logits2 = ctr_logits(params, cfg, h, yes_id, no_id)
        p = jax.nn.softmax(logits2.astype(jnp.float32), axis=-1)[..., 0]
        return p, new_cache

    return decode


# ===========================================================================
# batched CTR scoring server (example-facing)
# ===========================================================================

@dataclasses.dataclass
class CTRServer:
    """Batched pointwise CTR scorer over sliding-window prompts.

    Pads requests to a fixed (batch, seq) grid, scores the [SUM] position of
    each, returns p(click). One jitted prefill per (batch, seq) bucket.
    """
    params: Params
    cfg: ModelConfig
    max_len: int
    yes_id: int = 3
    no_id: int = 4

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill_fn(
            self.cfg, yes_id=self.yes_id, no_id=self.no_id))

    def score(self, prompts) -> "list[float]":
        import numpy as np
        b = len(prompts)
        batch = {k: np.stack([p[k] for p in prompts])
                 for k in ("tokens", "positions", "is_sum", "valid")}
        p = np.asarray(self._prefill(self.params, batch))
        out = []
        for i in range(b):
            sums = np.flatnonzero(batch["is_sum"][i])
            out.append(float(p[i, sums[-1]]) if len(sums) else 0.5)
        return out


__all__ = ["make_prefill_fn", "make_decode_fn", "CTRServer"]
