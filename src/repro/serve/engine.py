"""Serving: prefill scoring + incremental decode with stacked-layer caches.

Inference follows the paper (section 3.6): sliding-window prompts with one
[SUM] readout at the end, scored by bi-dimensional softmax over yes/no. Two
execution paths:

* ``make_prefill_fn``   — full forward over the prompt (the paper's actual
  inference procedure). SUM rows keep their training-time semantics
  (NoPE + ALiBi, isolation) but **no hidden-state reset** — the reset is a
  training-only regularizer that mimics inference, inference itself is
  untouched.
* ``make_decode_fn``    — one-token incremental step against a KV cache
  (decode_32k / long_500k shapes). The cache stores **unroped** keys plus
  their logical positions; RoPE is applied at read time, which lets a [SUM]
  query score the same cache with NoPE+ALiBi while regular tokens see
  standard RoPE — one cache serves both semantics. MLA runs in absorbed
  form against the latent cache (q_nope folded through W_UK, values decoded
  through W_UV after aggregation).

``lax.scan`` over (stacked layer params, stacked cache layers) keeps the
lowered HLO O(1) in depth for the 512-device dry-run compiles.

Beyond the paper's one-prompt-per-candidate procedure, two shared-context
paths score a whole candidate slate against one user context (the serving
analog of the training paradigm; docs/serving.md):

* ``make_multi_target_prefill_fn`` — one prefill over a
  context-segment + k-isolated-candidate-segments row
  (``repro.core.dti.build_multi_target_request``);
* ``make_decode_fn``'s ``valid``/``commit``/``seg`` operands — chunked
  context prefill into the cache once, then non-committing segment-isolated
  candidate bursts against it (driven by ``repro.serve.scheduler``).
  Committed KV depends only on (token, logical position) — never on which
  step wrote it — so a context committed in budget-cut chunks of any size
  is byte-identical to a monolithic commit; this is what lets the
  scheduler cut chunk boundaries freely for tail latency.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.losses import ctr_logits
from repro.core.quant import dequantize_q8, quantize_q8
from repro.core.windowed import NEG_INF
from repro.kernels.decode_attn.ops import decode_attention
from repro.models.layers import alibi_slopes, apply_rope, dense, rmsnorm
from repro.models.moe import moe_ffn
from repro.models.transformer import ModelConfig, forward
from repro.serve.cache import (Cache, is_paged, kv_keys, physical_slots,
                               slot_indices)

Params = Dict[str, Any]


# ===========================================================================
# prefill
# ===========================================================================

def make_prefill_fn(cfg: ModelConfig, *, yes_id: int = 3, no_id: int = 4,
                    window: Optional[int] = None,
                    multi_target: bool = False) -> Callable:
    """(params, batch) -> p_click (B, S); valid only at [SUM] positions.

    ``multi_target=True`` scores shared-context rows instead of one-prompt
    rows: the batch must carry ``segment_ids`` and segment 0 is treated as
    a shared prefix (``seg_shared=0``); forces the dense attention path.
    """

    def prefill(params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        # inference-time DTI: SUM NoPE+ALiBi + isolation, no reset
        icfg = dataclasses.replace(cfg, dti_reset=False)
        kw: Dict[str, Any] = {}
        if multi_target:
            icfg = dataclasses.replace(icfg, attn_impl="dense")
            kw = dict(segment_ids=batch["segment_ids"], seg_shared=0)
        out = forward(params, icfg, batch["tokens"],
                      positions=batch["positions"], is_sum=batch["is_sum"],
                      valid=batch["valid"], dti_enabled=True, window=window,
                      **kw)
        logits2 = ctr_logits(params, cfg, out["hidden"], yes_id, no_id)
        p = jax.nn.softmax(logits2.astype(jnp.float32), axis=-1)[..., 0]
        return jnp.where(batch["is_sum"], p, 0.0)

    return prefill


def make_multi_target_prefill_fn(cfg: ModelConfig, *, yes_id: int = 3,
                                 no_id: int = 4,
                                 window: Optional[int] = None) -> Callable:
    """(params, batch) -> p_click (B, S) for multi-target serving rows.

    ``batch`` rows come from ``repro.core.dti.build_multi_target_request``:
    one shared user context (segment 0) plus k [SUM]-terminated candidate
    segments whose positions continue after the context. Segment 0 is a
    shared prefix (``seg_shared=0``), so one prefill scores all k candidates
    with the context encoded once — O(n^2 + k·n) attention instead of the
    O(k·n^2) of k independent sliding-window prefills — and each [SUM]
    probability equals the standalone-prompt score exactly.

    Forces the dense attention path: the banded/Pallas schedules assume
    physical distance == positional distance, which the interleaved
    candidate segments break.
    """
    return make_prefill_fn(cfg, yes_id=yes_id, no_id=no_id, window=window,
                           multi_target=True)


# ===========================================================================
# decode
# ===========================================================================

def _rope_read(k: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rope cached (unroped) keys with their stored positions; slots with
    pos < 0 are masked later, rope them at 0."""
    return apply_rope(k, jnp.maximum(pos, 0), theta)


def _decode_mask(pos_buf, positions, window: int, seg_q=None, seg_buf=None):
    """(B, s, cap) attendability: filled slot, causal, and — matching
    ``dti_mask`` — the window term only when window > 0 (0 = pure causal).

    ``seg_q``/``seg_buf`` implement multi-candidate bursts: committed cache
    entries (the shared user context) carry segment -1 and are attendable by
    everyone; in-flight burst tokens carry their candidate index and only
    attend context + their own candidate — k candidates score in one step
    without seeing each other."""
    m = ((pos_buf[:, None, :] >= 0)
         & (positions[:, :, None] >= pos_buf[:, None, :]))
    if window > 0:
        m = m & ((positions[:, :, None] - pos_buf[:, None, :]) <= window)
    if seg_q is not None:
        m = m & ((seg_buf[:, None, :] < 0)
                 | (seg_buf[:, None, :] == seg_q[:, :, None]))
    return m


def _cache_write(buf, slots, new, *, bidx, write_idx):
    """Scatter freshly produced KV into the cache.

    Contiguous layout (``write_idx=None``): ``buf (B, cap, ...)`` is
    indexed per row at logical ``slots``. Paged layout: ``buf`` is the
    global ``(n_total, ...)`` pool and ``write_idx (B, s)`` carries the
    physical slot of each token (-1 where the logical slot's page is
    unmapped or past capacity — those writes drop). Either way writes only
    land on the row's private (never shared) slots; see docs/serving.md.
    """
    if write_idx is None:
        return buf.at[bidx, slots].set(new.astype(buf.dtype), mode="drop")
    b, s = write_idx.shape
    flat = new.astype(buf.dtype).reshape((b * s,) + new.shape[2:])
    # -1 sentinels must map PAST the pool, not onto its last slot: jax
    # wraps negative indices numpy-style before mode="drop" applies, so a
    # raw -1 would silently clobber the highest physical slot (a live page
    # once the pool fills).
    idx = write_idx.reshape(-1)
    idx = jnp.where(idx >= 0, idx, buf.shape[0])
    return buf.at[idx].set(flat, mode="drop")


def _cache_view(buf, read_idx):
    """Row-major read view of the cache: identity for the contiguous
    layout, page-index gather for the paged layout (``read_idx (B, cap)``
    physical slots, already clamped — unmapped entries gather arbitrary
    pool bytes that ``pos = -1`` masking keeps unattendable)."""
    return buf if read_idx is None else buf[read_idx]


def _decode_attend(scores_rope, scores_nope, alibi, d, mask, is_sum_q, v_agg):
    """Shared score->prob->value logic. scores_* are (B, H, s, cap) fp32."""
    if scores_nope is not None:
        biased = scores_nope - alibi[None, :, None, None] * d
        scores = jnp.where(is_sum_q[:, None, :, None], biased, scores_rope)
    else:
        scores = scores_rope
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    any_ok = jnp.any(mask, axis=-1)[:, None, :, None]
    return v_agg(jnp.where(any_ok, probs, 0.0))


def _gqa_decode_layer(lp: Params, h, kv: Params, *, cfg: ModelConfig, slots,
                      pos_buf, positions, is_sum, window, kind,
                      seg_q=None, seg_buf=None, impl="dense",
                      block_size=None, interpret=None,
                      write_idx=None, read_idx=None):
    b, s, _ = h.shape
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    n_rep = hq // hk
    quant = "k_scale" in kv
    x = rmsnorm(lp["ln_attn"], h, cfg.norm_eps)
    q = dense(lp["attn"]["q"], x).reshape(b, s, hq, hd)
    k_new = dense(lp["attn"]["k"], x).reshape(b, s, hk, hd)
    v_new = dense(lp["attn"]["v"], x).reshape(b, s, hk, hd)

    bidx = jnp.arange(b)[:, None]
    kv = dict(kv)
    # mode="drop": padded-to-bucket chunks may point past capacity; those
    # writes must vanish, not clamp onto the last slot (see decode docstring)
    if quant:
        # quantize-on-write: int8 codes and their per-(slot, head) scales
        # land on the same slots in one step, so pages stay self-describing
        k_new, k_sv = quantize_q8(k_new)
        v_new, v_sv = quantize_q8(v_new)
        kv["k_scale"] = _cache_write(kv["k_scale"], slots, k_sv,
                                     bidx=bidx, write_idx=write_idx)
        kv["v_scale"] = _cache_write(kv["v_scale"], slots, v_sv,
                                     bidx=bidx, write_idx=write_idx)
    kv["k"] = _cache_write(kv["k"], slots, k_new, bidx=bidx,
                           write_idx=write_idx)
    kv["v"] = _cache_write(kv["v"], slots, v_new, bidx=bidx,
                           write_idx=write_idx)
    k_raw = _cache_view(kv["k"], read_idx)
    v_raw = _cache_view(kv["v"], read_idx)

    q_rope = apply_rope(q, positions, cfg.rope_theta)
    scale = hd ** -0.5
    nope = cfg.dti_sum_alibi

    if impl == "pallas" and quant:
        # quantized-KV contract: hand the kernel the raw int8 codes plus
        # scale sidecars; dequant + read-time RoPE happen in VMEM, and the
        # NoPE stream is the same codes dequantized without rotation
        out = decode_attention(
            q_rope, k_raw, v_raw, positions, pos_buf, window=window,
            is_sum_q=is_sum if nope else None,
            q_nope=q if nope else None, k_nope=None,
            alibi=alibi_slopes(hq) if nope else None,
            seg_q=seg_q, seg_k=seg_buf, scale=scale,
            block_size=block_size, interpret=interpret,
            k_scale=_cache_view(kv["k_scale"], read_idx)[..., None],
            v_scale=_cache_view(kv["v_scale"], read_idx),
            rope_start=0, rope_theta=cfg.rope_theta).astype(h.dtype)
        h = h + dense(lp["attn"]["o"], out.reshape(b, s, hq * hd))
        h, aux = _ffn(lp, h, cfg, kind)
        return h, kv, aux

    if quant:
        # dense oracle path: dequantize the row-major views up front
        k_raw = dequantize_q8(k_raw, _cache_view(kv["k_scale"], read_idx))
        v_raw = dequantize_q8(v_raw, _cache_view(kv["v_scale"], read_idx))
    k_rope = _rope_read(k_raw, pos_buf, cfg.rope_theta)

    if impl == "pallas":
        # fused burst attention into the cache: the kernel reads the
        # row-major cache view directly (contiguous storage, or the paged
        # page-index gather) via index maps, applies every mask term via
        # index arithmetic and keeps the softmax online — no (B,H,s,cap)
        # score/prob tensors, empty cache blocks skipped
        out = decode_attention(
            q_rope, k_rope, v_raw, positions, pos_buf, window=window,
            is_sum_q=is_sum if nope else None,
            q_nope=q if nope else None, k_nope=k_raw if nope else None,
            alibi=alibi_slopes(hq) if nope else None,
            seg_q=seg_q, seg_k=seg_buf, scale=scale,
            block_size=block_size, interpret=interpret).astype(h.dtype)
        h = h + dense(lp["attn"]["o"], out.reshape(b, s, hq * hd))
        h, aux = _ffn(lp, h, cfg, kind)
        return h, kv, aux

    def rep(t):  # (B, cap, Hk, D) -> (B, cap, Hq, D)
        if n_rep == 1:
            return t
        bb, cap, _, dd = t.shape
        return jnp.broadcast_to(t[:, :, :, None, :],
                                (bb, cap, hk, n_rep, dd)).reshape(bb, cap, hq, dd)

    sc_rope = jnp.einsum("bshd,bkhd->bhsk", q_rope, rep(k_rope),
                         preferred_element_type=jnp.float32) * scale
    sc_nope = None
    if cfg.dti_sum_alibi:
        sc_nope = jnp.einsum("bshd,bkhd->bhsk", q, rep(k_raw),
                             preferred_element_type=jnp.float32) * scale

    d = (positions[:, None, :, None] - pos_buf[:, None, None, :]
         ).astype(jnp.float32)
    mask = _decode_mask(pos_buf, positions, window, seg_q, seg_buf)
    out = _decode_attend(sc_rope, sc_nope, alibi_slopes(hq), d, mask, is_sum,
                         lambda p: jnp.einsum("bhsk,bkhd->bshd",
                                              p.astype(h.dtype), rep(v_raw)))
    h = h + dense(lp["attn"]["o"], out.reshape(b, s, hq * hd))
    h, aux = _ffn(lp, h, cfg, kind)
    return h, kv, aux


def _mla_decode_layer(lp: Params, h, kv: Params, *, cfg: ModelConfig,
                      slots, pos_buf, positions, is_sum, window, kind,
                      seg_q=None, seg_buf=None, impl="dense",
                      block_size=None, interpret=None,
                      write_idx=None, read_idx=None):
    """Absorbed-MLA decode: scores and values against the latent cache."""
    b, s, _ = h.shape
    hq = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    quant = "ckv_scale" in kv
    ap = lp["attn"]
    x = rmsnorm(lp["ln_attn"], h, cfg.norm_eps)

    if "q_down" in ap:
        qc = rmsnorm(ap["q_norm"], dense(ap["q_down"], x))
        q = dense(ap["q_up"], qc)
    else:
        q = dense(ap["q"], x)
    q = q.reshape(b, s, hq, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe_rope = apply_rope(q_pe, positions, cfg.rope_theta)

    c_new = rmsnorm(ap["kv_norm"], dense(ap["kv_down"], x))         # (B,s,r)
    kpe_new = dense(ap["k_rope"], x)                                # (B,s,dr)

    bidx = jnp.arange(b)[:, None]
    kv = dict(kv)
    if quant:
        # latent and rope streams quantize separately: per-token scales,
        # written on the same slots as their codes (self-describing pages)
        c_new, c_sv = quantize_q8(c_new)
        kpe_new, p_sv = quantize_q8(kpe_new)
        kv["ckv_scale"] = _cache_write(kv["ckv_scale"], slots, c_sv,
                                       bidx=bidx, write_idx=write_idx)
        kv["kpe_scale"] = _cache_write(kv["kpe_scale"], slots, p_sv,
                                       bidx=bidx, write_idx=write_idx)
    kv["ckv"] = _cache_write(kv["ckv"], slots, c_new, bidx=bidx,
                             write_idx=write_idx)
    kv["kpe"] = _cache_write(kv["kpe"], slots, kpe_new, bidx=bidx,
                             write_idx=write_idx)
    ckv_v = _cache_view(kv["ckv"], read_idx)
    kpe_v = _cache_view(kv["kpe"], read_idx)

    # absorb W_UK into the query, W_UV into the output
    w_up = ap["kv_up"]["w"].reshape(cfg.kv_lora_rank, hq, dn + dv)
    w_uk, w_uv = w_up[..., :dn], w_up[..., dn:]
    q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)              # (B,s,H,r)

    scale = (dn + dr) ** -0.5
    nope = cfg.dti_sum_alibi

    if quant:
        c_sv_view = _cache_view(kv["ckv_scale"], read_idx)          # (B,cap)
        p_sv_view = _cache_view(kv["kpe_scale"], read_idx)
        if impl == "pallas":
            # quantized MQA form: concatenated int8 codes with a 2-group
            # scale row split at rope_start = r_kv (latent | rope stream);
            # the kernel dequantizes and ropes the kpe tail in VMEM
            q_eff = jnp.concatenate([q_abs, q_pe_rope], axis=-1)
            k_codes = jnp.concatenate([ckv_v, kpe_v], axis=-1)[:, :, None, :]
            k_sc = jnp.stack([c_sv_view, p_sv_view],
                             axis=-1)[:, :, None, :]                # (B,cap,1,2)
            qn_eff = (jnp.concatenate([q_abs, q_pe], axis=-1)
                      if nope else None)
            o_lat = decode_attention(
                q_eff, k_codes, ckv_v[:, :, None, :], positions, pos_buf,
                window=window, is_sum_q=is_sum if nope else None,
                q_nope=qn_eff, k_nope=None,
                alibi=alibi_slopes(hq) if nope else None,
                seg_q=seg_q, seg_k=seg_buf, scale=scale,
                block_size=block_size, interpret=interpret,
                k_scale=k_sc, v_scale=c_sv_view[:, :, None],
                rope_start=cfg.kv_lora_rank, rope_theta=cfg.rope_theta)
            out = jnp.einsum("bshr,rhd->bshd", o_lat.astype(h.dtype), w_uv)
            h = h + dense(ap["o"], out.reshape(b, s, hq * dv))
            h, aux = _ffn(lp, h, cfg, kind)
            return h, kv, aux
        ckv_v = dequantize_q8(ckv_v, c_sv_view)
        kpe_v = dequantize_q8(kpe_v, p_sv_view)

    kpe_rope = _rope_read(kpe_v[:, :, None, :], pos_buf,
                          cfg.rope_theta)[:, :, 0, :]               # (B,cap,dr)

    if impl == "pallas":
        # absorbed MLA as MQA for the fused kernel (Hk=1): concatenate the
        # latent and rope streams so one score matmul covers both terms —
        # q_eff . k_eff == q_abs . ckv + q_pe_rope . kpe_rope — and keep
        # values in the latent (Dv = r_kv != Dqk); W_UV folds after.
        q_eff = jnp.concatenate([q_abs, q_pe_rope], axis=-1)
        k_eff = jnp.concatenate([ckv_v, kpe_rope], axis=-1)[:, :, None, :]
        qn_eff = (jnp.concatenate([q_abs, q_pe], axis=-1) if nope else None)
        kn_eff = (jnp.concatenate([ckv_v, kpe_v], axis=-1)[:, :, None, :]
                  if nope else None)
        o_lat = decode_attention(
            q_eff, k_eff, ckv_v[:, :, None, :], positions, pos_buf,
            window=window, is_sum_q=is_sum if nope else None,
            q_nope=qn_eff, k_nope=kn_eff,
            alibi=alibi_slopes(hq) if nope else None,
            seg_q=seg_q, seg_k=seg_buf, scale=scale,
            block_size=block_size, interpret=interpret)
        out = jnp.einsum("bshr,rhd->bshd", o_lat.astype(h.dtype), w_uv)
        h = h + dense(ap["o"], out.reshape(b, s, hq * dv))
        h, aux = _ffn(lp, h, cfg, kind)
        return h, kv, aux

    sc_rope = (jnp.einsum("bshr,bkr->bhsk", q_abs, ckv_v,
                          preferred_element_type=jnp.float32)
               + jnp.einsum("bshd,bkd->bhsk", q_pe_rope, kpe_rope,
                            preferred_element_type=jnp.float32)) * scale
    sc_nope = None
    if cfg.dti_sum_alibi:
        sc_nope = (jnp.einsum("bshr,bkr->bhsk", q_abs, ckv_v,
                              preferred_element_type=jnp.float32)
                   + jnp.einsum("bshd,bkd->bhsk", q_pe, kpe_v,
                                preferred_element_type=jnp.float32)) * scale

    d = (positions[:, None, :, None] - pos_buf[:, None, None, :]
         ).astype(jnp.float32)
    mask = _decode_mask(pos_buf, positions, window, seg_q, seg_buf)

    def v_agg(p):
        o_lat = jnp.einsum("bhsk,bkr->bshr", p.astype(h.dtype), ckv_v)
        return jnp.einsum("bshr,rhd->bshd", o_lat, w_uv)

    out = _decode_attend(sc_rope, sc_nope, alibi_slopes(hq), d, mask, is_sum,
                         v_agg)
    h = h + dense(ap["o"], out.reshape(b, s, hq * dv))
    h, aux = _ffn(lp, h, cfg, kind)
    return h, kv, aux


def _ffn(lp: Params, h, cfg: ModelConfig, kind: str):
    from repro.models.layers import swiglu
    x = rmsnorm(lp["ln_ffn"], h, cfg.norm_eps)
    if kind == "moe":
        f, aux = moe_ffn(lp["ffn"], x, n_experts=cfg.n_experts,
                         top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                         norm_topk=cfg.norm_topk)
    else:
        f, aux = swiglu(lp["ffn"], x), jnp.zeros((), jnp.float32)
    return h + f, aux


def make_decode_fn(cfg: ModelConfig, *, window: int, ring: bool,
                   yes_id: int = 3, no_id: int = 4,
                   attn_impl: Optional[str] = None,
                   block_size: Optional[int] = None,
                   interpret: Optional[bool] = None) -> Callable:
    """(params, cache, tokens (B,s), positions (B,s), is_sum (B,s)[,
    valid (B,s), commit (B,), seg (B,s)]) -> (p_click (B, s), new_cache).

    ``attn_impl`` selects the per-layer attention path:

    * ``"dense"``  — masked einsums over the full cache capacity (the
      semantic oracle; also the fallback when ``attn_impl=None`` and the
      model config doesn't train on the kernel path).
    * ``"pallas"`` — the fused decode-attention kernel
      (``repro.kernels.decode_attn``): one online-softmax pass over the
      cache with every serve mask term fused, occupancy-skipping empty
      cache blocks. Covers the full operand set below (valid/commit/seg),
      GQA and absorbed MLA, ring and windowed caches.
    * ``None``     — inherit the model's training-time choice:
      ``"pallas"`` when ``cfg.attn_impl == "pallas"``, else ``"dense"``
      (so a config that trains on the kernel path serves on it too).

    ``block_size``/``interpret`` tune the kernel path only (interpret
    auto-resolves off-TPU, see ``repro.kernels.default_interpret``;
    ``block_size=None`` defers to ``repro.kernels.autotune.decode_block``).

    Quantized caches (``init_lm_cache(kv_dtype="int8")``) are detected from
    the cache structure: layers quantize KV on write (codes + scale
    sidecars land on the same slots), the dense path dequantizes the
    row-major view up front, and the Pallas path hands the kernel raw int8
    codes with their scales so dequant happens in VMEM (docs/kernels.md).

    The three optional operands are what the continuous-batching scheduler
    (repro.serve.scheduler) runs on:

    * ``valid``  — right-padded chunks: invalid slots are written with
      position -1 (never attendable) and the per-row cursor advances by the
      number of *valid* tokens only, so rows of different real lengths share
      one padded-to-bucket jit shape.
    * ``commit`` — per-row bool. A row with ``commit=False`` is a *scoring
      burst*: its tokens attend the row's committed cache (the shared user
      context) plus themselves, but the returned cache keeps the row's
      ``pos``/``cursor`` unchanged, so the next burst sees the pristine
      context again — candidate k+1 never reads candidate k's KV. This is
      the decode-side shared-context reuse; it requires ``ring=False``
      (a wrapped burst write would orphan old positions onto burst KV).
    * ``seg``    — per-token segment for multi-candidate bursts: -1 = shared
      (context chunks), 0..k-1 = candidate index. Committed cache entries
      are shared by construction; burst tokens attend context + their own
      segment only, so one burst step scores a whole candidate slate — the
      decode-side analog of the training paradigm's k isolated targets.

    Paged caches (``init_lm_cache(page_size=...)``) are detected from the
    cache structure: reads and writes go through the page-index gather
    maps of ``repro.serve.cache.physical_slots``, everything else —
    including the Pallas kernel, which consumes the gathered row-major
    view — is unchanged. Since a gathered view holds the same values at
    the same logical slots as contiguous storage and unmapped slots carry
    ``pos = -1``, paged and contiguous decode are byte-identical
    (tests/test_paged_cache.py). Paged requires ``ring=False``.
    """
    mla = cfg.attn_type == "mla"
    layer_fn = _mla_decode_layer if mla else _gqa_decode_layer
    if attn_impl is None:
        attn_impl = "pallas" if cfg.attn_impl == "pallas" else "dense"
    assert attn_impl in ("dense", "pallas"), f"unknown decode attn_impl {attn_impl!r}"

    def decode(params: Params, cache: Cache, tokens: jax.Array,
               positions: jax.Array, is_sum: jax.Array,
               valid: Optional[jax.Array] = None,
               commit: Optional[jax.Array] = None,
               seg: Optional[jax.Array] = None,
               ) -> Tuple[jax.Array, Cache]:
        b, s = tokens.shape
        slots = slot_indices(cache, s, ring=ring)
        bidx = jnp.arange(b)[:, None]
        write_idx = read_idx = None
        if is_paged(cache):
            # page-index gather maps (docs/serving.md): flat (B, cap) is
            # logical->physical; reads gather a row-major view through it,
            # writes scatter at each token's physical slot. Unmapped pages
            # (flat == -1) drop writes and gather arbitrary pool bytes that
            # pos = -1 keeps unattendable.
            assert not ring, "paged caches are non-ring"
            cap = cache["pos"].shape[1]
            flat = physical_slots(cache)
            write_idx = jnp.take_along_axis(
                flat, jnp.clip(slots, 0, cap - 1), axis=1)
            write_idx = jnp.where(slots < cap, write_idx, -1)
            read_idx = jnp.maximum(flat, 0)
        pos_write = (positions if valid is None
                     else jnp.where(valid, positions, -1))
        # mode="drop": a chunk right-padded to its bucket may index past
        # capacity when a row's cursor sits near the top; dropping those
        # writes (instead of XLA's default clamp onto the last slot) keeps
        # the scheduler's "real tokens always fit" invariant sufficient.
        pos_buf = cache["pos"].at[bidx, slots].set(pos_write, mode="drop")
        seg_buf = None
        if seg is not None:
            cap = cache["pos"].shape[1]
            seg_buf = jnp.full((b, cap), -1, jnp.int32).at[bidx, slots].set(
                seg, mode="drop")
        n_new = (s if valid is None
                 else valid.sum(axis=-1).astype(jnp.int32))
        if commit is None:
            new_cache = dict(cache, pos=pos_buf,
                             cursor=cache["cursor"] + n_new)
        else:
            assert not ring, "non-committing bursts require ring=False"
            new_cache = dict(
                cache,
                pos=jnp.where(commit[:, None], pos_buf, cache["pos"]),
                cursor=jnp.where(commit, cache["cursor"] + n_new,
                                 cache["cursor"]))

        h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)

        n_prefix = cfg.first_dense_layers if cfg.moe else 0

        # The (L, B, cap, ...) cache tensors ride the scan CARRY and are
        # updated per layer with dynamic_update_index_in_dim: XLA keeps
        # while-loop carries in place, so the donated cache is mutated with
        # no xs/ys double buffer (which would cost a full extra cache).
        # The carry is a tuple over kv_keys(cache) — codes plus any
        # quantization-scale sidecars — so int8 caches thread their scales
        # through the scan without a second code path.
        kv_names = kv_keys(cache)

        def run_group(h, kv_all, group: Params, kind: str, lo: int):
            n = jax.tree_util.tree_leaves(group)[0].shape[0]

            def body(carry, xs):
                hc, full = carry
                lp, li = xs
                layer_kv = {nm: jax.lax.dynamic_index_in_dim(
                    t, li, 0, keepdims=False)
                    for nm, t in zip(kv_names, full)}
                hh, layer_kv, aux = layer_fn(
                    lp, hc, layer_kv, cfg=cfg, slots=slots, pos_buf=pos_buf,
                    positions=positions, is_sum=is_sum, window=window,
                    kind=kind, seg_q=seg, seg_buf=seg_buf, impl=attn_impl,
                    block_size=block_size, interpret=interpret,
                    write_idx=write_idx, read_idx=read_idx)
                full = tuple(jax.lax.dynamic_update_index_in_dim(
                    t, layer_kv[nm].astype(t.dtype), li, 0)
                    for nm, t in zip(kv_names, full))
                return (hh, full), None

            idx = lo + jnp.arange(n, dtype=jnp.int32)
            (h, kv_all), _ = jax.lax.scan(body, (h, kv_all), (group, idx))
            return h, kv_all

        kv_all = tuple(cache[nm] for nm in kv_names)
        if "prefix" in params:
            h, kv_all = run_group(h, kv_all, params["prefix"], "dense", 0)
        h, kv_all = run_group(h, kv_all, params["stack"],
                              "moe" if cfg.moe else "dense", n_prefix)
        for nm, t in zip(kv_names, kv_all):
            new_cache[nm] = t

        h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
        logits2 = ctr_logits(params, cfg, h, yes_id, no_id)
        p = jax.nn.softmax(logits2.astype(jnp.float32), axis=-1)[..., 0]
        return p, new_cache

    return decode


# ===========================================================================
# batched CTR scoring server (example-facing)
# ===========================================================================

@dataclasses.dataclass
class CTRServer:
    """Batched pointwise CTR scorer over prefill rows.

    Two entry points, both scoring a stacked batch of ``max_len``-padded
    rows in one jitted prefill call:

    * ``score``              — one sliding-window prompt per candidate (the
      paper's inference procedure; re-encodes the context per candidate).
    * ``score_multi_target`` — one multi-target row per *request* (shared
      context + k isolated candidate segments); the context is encoded once
      per request. Same scores, O(n^2 + k·n) instead of O(k·n^2).

    The seq dim is fixed (``max_len``) but the batch dim is whatever the
    caller passes — each distinct batch size jit-compiles once, so feed
    fixed-size groups in steady state. For sustained traffic with
    admission/eviction, bucketed shapes and decode-side context KV reuse,
    use ``repro.serve.scheduler.ServeScheduler`` instead.
    """
    params: Params
    cfg: ModelConfig
    max_len: int
    yes_id: int = 3
    no_id: int = 4

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill_fn(
            self.cfg, yes_id=self.yes_id, no_id=self.no_id))
        self._mt_prefill = jax.jit(make_multi_target_prefill_fn(
            self.cfg, yes_id=self.yes_id, no_id=self.no_id))

    def update_params(self, params) -> None:
        """Hot-swap serving weights (e.g. from a continual-training
        ``ParamPublisher``); params are a jit argument, so no recompile."""
        self.params = params

    def score(self, prompts) -> "list[float]":
        import numpy as np
        b = len(prompts)
        batch = {k: np.stack([p[k] for p in prompts])
                 for k in ("tokens", "positions", "is_sum", "valid")}
        p = np.asarray(self._prefill(self.params, batch))
        out = []
        for i in range(b):
            sums = np.flatnonzero(batch["is_sum"][i])
            out.append(float(p[i, sums[-1]]) if len(sums) else 0.5)
        return out

    def score_multi_target(self, requests) -> "list[list[float]]":
        """``requests``: (context_tokens, candidate_tokens) pairs, each a
        list of per-interaction / per-candidate token lists. Returns the k
        candidate scores per request, in candidate order."""
        import numpy as np
        from repro.core.dti import (build_multi_target_request,
                                    candidate_sum_slots)
        rows = [build_multi_target_request(ctx, cands, max_len=self.max_len)
                for ctx, cands in requests]
        batch = {k: np.stack([r[k] for r in rows]) for k in
                 ("tokens", "positions", "segment_ids", "is_sum", "valid")}
        p = np.asarray(self._mt_prefill(self.params, batch))
        return [[float(p[i, s]) for s in candidate_sum_slots(rows[i])]
                for i in range(len(rows))]


__all__ = ["make_prefill_fn", "make_multi_target_prefill_fn",
           "make_decode_fn", "CTRServer"]
