"""Host-side page pool for the paged KV cache.

The paged cache (``repro.serve.cache`` with ``page_size`` set) stores KV on
a single global slot axis of ``n_pages * page_size`` physical slots; rows
address it through a per-row ``page_table (B, max_pages) int32`` of pool
page ids (-1 = unmapped). ``PagePool`` owns the allocation state for those
pages: a free list and a per-page reference count. It is deliberately
host-only — allocation decisions never need a device sync, and the device
never sees refcounts, only the page tables the scheduler publishes.

Refcount invariant (checked by tests/test_paged_cache.py):

    ref[p] == (# row page-table entries mapping p)
              + (1 if the radix prefix index holds p)

A page with ``ref > 1`` is *shared*: it is fully committed in every view
that maps it and is never written again (writers only touch private
``ref == 1`` pages — partial boundary pages are never published, so a
shared page can only ever be read). A page returns to the free list when
its last reference drops.

Eviction is not the pool's job: when ``alloc`` comes up short the caller
(the scheduler) reclaims pages from the radix index via
``RadixTree.evict_pages`` — least-recently-used pages held only by the
index — releases them here, and retries. ``evictions`` counts pages
reclaimed that way for telemetry/benchmarks.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry


class PagePool:
    """Fixed-size page allocator: free list + per-page refcounts.

    ``token_bytes`` is the KV cost of one token slot (codes + any
    quantization scale sidecar, summed over layers —
    ``repro.serve.cache.kv_token_bytes``); the scheduler stamps it at
    construction so capacity questions have one answer in tokens
    (``capacity_tokens``) and one in bytes (``pool_bytes``). With int8 KV
    the sidecar is part of a page's footprint — a page moves with its
    scales — so the byte accounting stays honest across dtypes, which is
    what lets benchmarks size quantized and bf16 pools to *equal bytes*
    rather than equal page counts.
    """

    def __init__(self, n_pages: int, page_size: int,
                 *, token_bytes: float = 0.0,
                 metrics: Optional[MetricsRegistry] = None):
        assert n_pages > 0 and page_size > 0
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.token_bytes = float(token_bytes)
        self.ref = np.zeros(self.n_pages, np.int32)
        # stack: pop() hands out low page ids first
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        # counters live in a metrics registry (the scheduler passes its
        # own, so pool counters ride scheduler snapshots); the plain
        # attribute API (`pool.evictions`, `pool.evictions = 0`) is kept
        # as properties over the registry
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_evictions = self.metrics.counter("pool.evictions")
        self._c_alloc_total = self.metrics.counter("pool.alloc_total")

    @property
    def evictions(self) -> int:
        """Pages reclaimed from the prefix index."""
        return self._c_evictions.value

    @evictions.setter
    def evictions(self, v: int) -> None:
        self._c_evictions.set(int(v))

    @property
    def alloc_total(self) -> int:
        """Pages ever handed out."""
        return self._c_alloc_total.value

    @alloc_total.setter
    def alloc_total(self, v: int) -> None:
        self._c_alloc_total.set(int(v))

    def free_count(self) -> int:
        return len(self._free)

    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def capacity_tokens(self) -> int:
        """Total token slots the pool can hold across all rows."""
        return self.n_pages * self.page_size

    def pool_bytes(self) -> int:
        """Total KV bytes backing the pool (0 when ``token_bytes`` was
        never stamped)."""
        return int(self.capacity_tokens() * self.token_bytes)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Hand out ``n`` pages with ``ref = 1`` each, or ``None`` (and no
        state change) if the free list is short — the caller evicts from
        the prefix index and retries."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            assert self.ref[p] == 0, f"page {p} on free list with ref set"
            self.ref[p] = 1
        self._c_alloc_total.inc(n)
        return out

    def incref(self, pages) -> None:
        for p in pages:
            assert self.ref[p] > 0, f"incref on unallocated page {p}"
            self.ref[p] += 1

    def decref(self, pages) -> None:
        """Drop one reference per page; pages reaching zero return to the
        free list."""
        for p in pages:
            assert self.ref[p] > 0, f"decref on unallocated page {p}"
            self.ref[p] -= 1
            if self.ref[p] == 0:
                self._free.append(int(p))

    def note_evictions(self, n: int) -> None:
        self._c_evictions.inc(int(n))


__all__ = ["PagePool"]
