"""repro.serve — prefill/decode serving engine with windowed ring caches."""
from repro.serve.cache import Cache, cache_shape, init_lm_cache, slot_indices
from repro.serve.engine import CTRServer, make_decode_fn, make_prefill_fn
