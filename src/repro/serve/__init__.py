"""repro.serve — serving: prefill + decode engine (dense or fused Pallas
decode attention), refcounted GQA/MLA/ring KV caches, multi-target scoring
and the continuous-batching scheduler with cross-request prefix sharing
(docs/serving.md)."""
from repro.serve.cache import (Cache, cache_shape, free_slots, init_lm_cache,
                               retain_slots, slot_indices, trim_slots)
from repro.serve.engine import (CTRServer, make_decode_fn,
                                make_multi_target_prefill_fn, make_prefill_fn)
from repro.serve.scheduler import RequestResult, ServeScheduler
