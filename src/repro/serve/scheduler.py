"""Continuous-batching CTR serving with shared-context KV reuse.

The paper's training trick — isolate k targets against one shared context
instead of re-encoding the context k times — applied at inference. A request
is one user context plus k candidate items; the end-to-end LLM-ranker
deployment shape (one user, many candidates per page view). Per request the
scheduler:

  1. prefills the context once into the request's cache rows (chunked,
     committed decode steps — decode == prefill, see tests/test_serve.py);
  2. scores candidates as *non-committing bursts*: a burst attends the
     cached context plus itself, reads p(click) at each [SUM] slot, and
     leaves the cache's pos/cursor untouched — the next burst sees the
     pristine context again. As many candidates as fit the largest bucket
     ride one burst, isolated from each other by in-burst segment ids
     (the decode-side analog of the training paradigm's k isolated
     targets), so a whole slate usually costs one decode step.

Continuous batching: a fixed-capacity batched cache (``n_slots`` rows x
``capacity`` token slots); requests are admitted into free rows as they
arrive and evicted the moment their last candidate is scored, so short
requests never wait for long ones. Every step feeds one work unit per busy
row, right-padded to a fixed bucket length — the jitted decode step only
ever sees ``len(buckets)`` shapes, so steady-state serving never recompiles.

Cost: per request O(n^2 + k·n·s) attention reads instead of the O(k·n^2) of
re-prefilling the context per candidate; ``RequestResult.cached_tokens``
tracks the prompt tokens served from the shared cache instead of recomputed.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dti import SpecialTokens
from repro.models.transformer import ModelConfig
from repro.serve.cache import free_slots, init_lm_cache
from repro.serve.engine import make_decode_fn


@dataclasses.dataclass
class RequestResult:
    rid: int
    scores: List[float]                # p(click) per candidate, in order
    latency_s: float                   # submit -> last candidate scored
    context_tokens: int                # tokens prefilled once (incl. BOS)
    burst_tokens: int                  # candidate+[SUM] tokens scored
    cached_tokens: int                 # context re-encodes avoided: (k-1)*n
    logical_tokens: int                # what k independent prefills compute

    @property
    def cache_hit_fraction(self) -> float:
        """Fraction of the logical prompt tokens (k x context+candidate)
        that were read from the shared-context cache instead of recomputed."""
        return self.cached_tokens / max(self.logical_tokens, 1)


@dataclasses.dataclass
class _Unit:
    """One fixed-shape step's worth of work for one slot."""
    tokens: np.ndarray                 # (n,) int32
    positions: np.ndarray              # (n,) int32
    is_sum: np.ndarray                 # (n,) bool
    seg: np.ndarray                    # (n,) int32; -1 shared, else candidate
    commit: bool                       # context chunk (True) vs burst (False)
    score_at: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
                                       # (candidate idx, offset) per [SUM]


@dataclasses.dataclass
class _Slot:
    rid: int
    units: deque
    scores: List[Optional[float]]
    submit_t: float
    context_tokens: int
    burst_tokens: int
    n_candidates: int


class ServeScheduler:
    """Continuous-batching multi-target CTR scorer.

    ``submit`` enqueues a request (context = per-interaction token lists,
    candidates = per-candidate token lists); ``run`` drains queue and slots
    and returns {rid: RequestResult}. ``step`` advances one batched decode
    step (exposed for tests). The decode step is jitted once per bucket
    length; admission/eviction are O(rows) host bookkeeping plus an int32
    pos/cursor reset on the freed rows.
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 8,
                 capacity: int = 256, window: Optional[int] = None,
                 buckets: Sequence[int] = (8, 16, 32, 64),
                 sp: SpecialTokens = SpecialTokens(),
                 yes_id: int = 3, no_id: int = 4, cache_dtype=jnp.float32):
        if window is None:
            window = cfg.window          # match make_prefill_fn's default
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.capacity = capacity
        self.buckets = tuple(sorted(buckets))
        self.sp = sp
        self._decode = jax.jit(
            make_decode_fn(cfg, window=window, ring=False,
                           yes_id=yes_id, no_id=no_id))
        self._free = jax.jit(free_slots)
        self.cache = init_lm_cache(cfg, n_slots, capacity, dtype=cache_dtype)
        self._queue: deque = deque()
        self._slots: List[Optional[_Slot]] = [None] * n_slots
        self._results: Dict[int, RequestResult] = {}
        self._next_rid = 0
        self.n_steps = 0
        self._param_source = None
        self._poll_every = 1
        self._poll_tick = 0
        self.params_version: Optional[int] = None

    # -- weight hot-swap -----------------------------------------------------

    def attach_param_source(self, source, *, poll_every: int = 8) -> None:
        """``source()`` -> None or (version, params) — e.g.
        ``repro.stream.publish.ParamSubscriber(...).poll``. Polled at the
        top of ``step``, every ``poll_every``-th call: the source may hit a
        filesystem/object store, so the default keeps that I/O off the
        per-step decode hot path (weights change every ~publish_every
        trainer steps; sub-step freshness buys nothing). Freshly published
        weights land between decode steps without dropping in-flight slots
        (their cached context KV stays; a request straddling a swap is
        scored under mixed versions — see docs/streaming.md for the
        staleness contract)."""
        assert poll_every >= 1
        self._param_source = source
        self._poll_every = poll_every

    def update_params(self, params, version: Optional[int] = None) -> None:
        """Swap serving weights in place. Params are a jit argument, so the
        bucketed decode step does not recompile; queued requests and busy
        slots are untouched."""
        self.params = params
        if version is not None:
            self.params_version = version

    # -- request intake ------------------------------------------------------

    def submit(self, context: Sequence[Sequence[int]],
               candidates: Sequence[Sequence[int]],
               rid: Optional[int] = None) -> int:
        assert len(candidates) > 0, "a request needs at least one candidate"
        if rid is None:
            rid = self._next_rid
        assert (rid not in self._results
                and all(q[0] != rid for q in self._queue)
                and all(s is None or s.rid != rid for s in self._slots)), (
            f"request id {rid} already pending")
        self._next_rid = max(self._next_rid, rid + 1)
        ctx = [self.sp.bos]
        for it in context:
            ctx.extend(it)
        longest = max(len(c) + 1 for c in candidates)
        assert longest <= self.buckets[-1], (
            f"candidate burst {longest} > largest bucket {self.buckets[-1]}")
        assert len(ctx) + longest <= self.capacity, (
            f"context {len(ctx)} + burst {longest} > capacity {self.capacity}")
        self._queue.append((rid, ctx, [list(c) for c in candidates],
                            time.perf_counter()))
        return rid

    def _admit(self, row: int, rid: int, ctx: List[int],
               candidates: List[List[int]], t0: float) -> None:
        units: deque = deque()
        chunk = self.buckets[-1]
        for lo in range(0, len(ctx), chunk):
            part = ctx[lo: lo + chunk]
            units.append(_Unit(
                tokens=np.asarray(part, np.int32),
                positions=np.arange(lo, lo + len(part), dtype=np.int32),
                is_sum=np.zeros(len(part), bool),
                seg=np.full(len(part), -1, np.int32), commit=True))
        n = len(ctx)
        burst_total = 0
        # Greedy-fill candidates into shared bursts: each candidate+[SUM]
        # group carries its index as an in-burst segment, so one decode step
        # scores as many candidates as fit in the largest bucket. A burst
        # also writes (unreachable) KV at slots n..n+len-1, so it must stay
        # within the cache rows left above the context.
        burst_cap = min(chunk, self.capacity - n)
        toks: List[int] = []
        pos: List[int] = []
        is_sum: List[bool] = []
        seg: List[int] = []
        score_at: List[Tuple[int, int]] = []

        def flush():
            if toks:
                units.append(_Unit(
                    tokens=np.asarray(toks, np.int32),
                    positions=np.asarray(pos, np.int32),
                    is_sum=np.asarray(is_sum),
                    seg=np.asarray(seg, np.int32),
                    commit=False, score_at=list(score_at)))
            for l in (toks, pos, is_sum, seg, score_at):
                l.clear()

        for j, cand in enumerate(candidates):
            group = list(cand) + [self.sp.sum]
            burst_total += len(group)
            if toks and len(toks) + len(group) > burst_cap:
                flush()
            toks.extend(group)
            pos.extend(range(n, n + len(group)))   # every candidate restarts
            is_sum.extend([False] * len(cand) + [True])
            seg.extend([j] * len(group))
            score_at.append((j, len(toks) - 1))
        flush()
        self._slots[row] = _Slot(
            rid=rid, units=units, scores=[None] * len(candidates),
            submit_t=t0, context_tokens=n, burst_tokens=burst_total,
            n_candidates=len(candidates))

    # -- the batched step ----------------------------------------------------

    def step(self) -> bool:
        """Admit into free rows, run one batched decode step over every busy
        row's next work unit, harvest scores, evict finished rows. Returns
        False when queue and slots are both empty (nothing happened)."""
        if self._param_source is not None:
            # dedicated counter: n_steps stalls on idle calls, which would
            # either re-poll every call or never poll again
            if self._poll_tick % self._poll_every == 0:
                update = self._param_source()
                if update is not None:
                    self.update_params(update[1], update[0])
            self._poll_tick += 1
        admitted = np.zeros((self.n_slots,), bool)
        for row in range(self.n_slots):
            if self._slots[row] is None and self._queue:
                self._admit(row, *self._queue.popleft())
                admitted[row] = True
        if admitted.any():
            self.cache = self._free(self.cache, jnp.asarray(admitted))

        work = [(row, slot.units.popleft())
                for row, slot in enumerate(self._slots)
                if slot is not None and slot.units]
        if not work:
            return False
        need = max(len(u.tokens) for _, u in work)
        s = next(b for b in self.buckets if b >= need)

        tokens = np.zeros((self.n_slots, s), np.int32)
        positions = np.zeros((self.n_slots, s), np.int32)
        is_sum = np.zeros((self.n_slots, s), bool)
        valid = np.zeros((self.n_slots, s), bool)
        seg = np.full((self.n_slots, s), -1, np.int32)
        commit = np.zeros((self.n_slots,), bool)
        for row, u in work:
            n = len(u.tokens)
            tokens[row, :n] = u.tokens
            positions[row, :n] = u.positions
            is_sum[row, :n] = u.is_sum
            seg[row, :n] = u.seg
            valid[row, :n] = True
            commit[row] = u.commit

        p, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(positions), jnp.asarray(is_sum),
            jnp.asarray(valid), jnp.asarray(commit), jnp.asarray(seg))
        self.n_steps += 1
        p = np.asarray(p)

        now = time.perf_counter()
        for row, u in work:
            slot = self._slots[row]
            for j, off in u.score_at:
                slot.scores[j] = float(p[row, off])
            if not slot.units:                       # evict: request done
                c, b = slot.context_tokens, slot.burst_tokens
                k = slot.n_candidates
                self._results[slot.rid] = RequestResult(
                    rid=slot.rid, scores=list(slot.scores),
                    latency_s=now - slot.submit_t,
                    context_tokens=c, burst_tokens=b,
                    cached_tokens=(k - 1) * c,
                    logical_tokens=k * c + b)
                self._slots[row] = None
        return True

    def run(self) -> Dict[int, RequestResult]:
        """Drain queue and slots; returns results for every request scored
        since the last ``run``."""
        while self.step():
            pass
        out, self._results = self._results, {}
        return out


__all__ = ["ServeScheduler", "RequestResult"]
