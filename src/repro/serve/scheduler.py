"""Continuous-batching CTR serving with shared-context KV reuse,
cross-request prefix sharing, token-budgeted chunked prefill and
one-step-ahead overlap scheduling.

The paper's training trick — isolate k targets against one shared context
instead of re-encoding the context k times — applied at inference. A request
is one user context plus k candidate items; the end-to-end LLM-ranker
deployment shape (one user, many candidates per page view). Per request the
scheduler:

  1. prefills the context once into the request's cache rows (committed
     decode chunks — decode == prefill, see tests/test_serve.py);
  2. scores candidates as *non-committing bursts*: a burst attends the
     cached context plus itself, reads p(click) at each [SUM] slot, and
     leaves the cache's pos/cursor untouched — the next burst sees the
     pristine context again. As many candidates as fit the largest bucket
     ride one burst, isolated from each other by in-burst segment ids
     (the decode-side analog of the training paradigm's k isolated
     targets), so a whole slate usually costs one decode step.

On top of the per-request reuse, **cross-request prefix sharing** reuses
context KV *between* requests (``share_prefix=True``): committed context
blocks are refcounted (`repro.serve.cache`), indexed by a context-hash
trie (`repro.data.requests.ContextTrie`), and retained after their request
finishes instead of being freed. Admission matches an incoming context
against the trie and reuses the best block — see ``_try_place`` for the
exact policy ladder. Two users with a common context prefix (or one user
paging through result slates) then share one KV copy; step 1 shrinks to
the unshared suffix, or disappears entirely.

Continuous batching: a fixed-capacity batched cache (``n_slots`` rows x
``capacity`` token slots); requests are admitted into rows as they arrive
and a row returns to the reusable pool the moment its last candidate is
scored, so short requests never wait for long ones. Every step feeds one
work unit per busy row, right-padded to a fixed bucket length — the jitted
decode step only ever sees ``len(buckets)`` shapes, so steady-state serving
never recompiles. ``attn_impl="pallas"`` runs every step through the fused
decode-attention kernel (`repro.kernels.decode_attn`) instead of the dense
einsums.

Two hot-path policies keep the batched step latency-uniform under
mixed-length traffic (the tail-latency killer: one long user history
stalling every co-batched short slate):

* **Token-budgeted chunked prefill.** Pending context commits are held as
  *resumable* per-slot prefill state (`_Prefill`), not pre-cut chunks.
  Each step packs decode bursts first — they alone pick the wave's bucket
  — then cuts prefill chunks to whatever fits ``min(bucket,
  prefill_budget)``. A long prefill therefore rides along a few tokens at
  a time without ever inflating the wave's jit shape, and resumes
  mid-context on the next step. (``monolithic_prefill=True`` restores the
  pre-budget behaviour — largest-bucket chunks that drag every
  co-scheduled burst into the largest jit shape — as a reference mode for
  `benchmarks/serve_bench.py`.)
* **One-step-ahead overlap.** The decode step is dispatched async; its
  scores are *not* synced before the next step is built and dispatched
  from already-decided host state. Harvest (the only
  ``np.asarray``/device sync) runs one step behind, so admission, unit
  packing and row bookkeeping overlap the device step instead of
  serializing with it. Correctness rides on the cache being threaded
  through every decode call: step t+1's dispatch consumes step t's output
  cache, so device-side ordering (commit-before-burst, trim-before-
  recommit) is a data dependency, never a host sync.

Cost: per request O(n^2 + k·n·s) attention reads instead of the O(k·n^2) of
re-prefilling the context per candidate — less again whatever prefix
sharing removes; ``RequestResult.cached_tokens`` tracks the prompt tokens
served from cache (own-context reuse + shared prefixes) instead of
recomputed. ``telemetry()`` reports queue depth, per-bucket step counts,
prefill-budget utilization and watchdog state; ``RequestResult`` splits
latency into ``queue_s`` (submit → admitted) and ``service_s`` (admitted →
last score) so tail regressions are attributable.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from contextlib import nullcontext
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dti import SpecialTokens
from repro.data.requests import RadixTree
from repro.models.transformer import ModelConfig
from repro.obs import profile as obs_profile
from repro.obs.clock import monotonic
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.serve.cache import (adopt_slots, free_slots, init_lm_cache,
                               kv_cache_bytes, kv_token_bytes, retain_slots,
                               trim_slots)
from repro.serve.engine import make_decode_fn
from repro.serve.pages import PagePool
from repro.sharding.partition import cache_specs, serve_param_specs

_NULLCTX = nullcontext()

#: Lifecycle schema of every key ``telemetry()`` may report. ``kind``:
#: ``counter`` (accumulates, zeroed by ``reset_telemetry``), ``derived``
#: (computed from counters, falls to its documented reset value),
#: ``state`` (live cache/pool occupancy — reset does not touch it),
#: ``config`` (construction-time constant). ``reset`` is the exact
#: post-``reset_telemetry()`` value for resettable keys
#: ("zero_map" = dict with every value 0).  tests/test_obs.py checks
#: (a) every reported key appears here — a new counter cannot be added
#: without declaring its reset behaviour — and (b) resettable keys
#: really do come back as their documented zero.
TELEMETRY_SCHEMA: Dict[str, Dict[str, Any]] = {
    "steps": {"kind": "counter", "reset": 0},
    "overlap": {"kind": "config"},
    "bucket_steps": {"kind": "counter", "reset": "zero_map"},
    "queue_depth_mean": {"kind": "derived", "reset": 0.0},
    "queue_depth_max": {"kind": "counter", "reset": 0},
    "prefill_budget": {"kind": "config"},
    "prefill_tokens": {"kind": "counter", "reset": 0},
    "prefill_steps": {"kind": "counter", "reset": 0},
    "budget_utilization": {"kind": "derived", "reset": None},
    "prefill_starved_steps": {"kind": "counter", "reset": 0},
    "watchdog_fired": {"kind": "counter", "reset": 0},
    "watchdog_rows": {"kind": "counter", "reset": []},
    "watchdog_stuck_rids": {"kind": "counter", "reset": []},
    "paged": {"kind": "config"},
    "cross_row_hits": {"kind": "counter", "reset": 0},
    "cross_row_tokens": {"kind": "counter", "reset": 0},
    "prefix_hit_rate": {"kind": "derived", "reset": 0.0},
    "kv_dtype": {"kind": "config"},
    "kv_bytes": {"kind": "state"},
    "kv_token_bytes": {"kind": "config"},
    "kv_bytes_committed": {"kind": "counter", "reset": 0},
    "page_size": {"kind": "config"},
    "pages_in_use": {"kind": "state"},
    "pages_free": {"kind": "state"},
    "page_evictions": {"kind": "counter", "reset": 0},
    "radix_pages": {"kind": "state"},
    "pool_capacity_tokens": {"kind": "config"},
    "pool_bytes": {"kind": "config"},
    "mesh": {"kind": "config"},
    "drain_before_swap": {"kind": "config"},
    "swap_drains": {"kind": "counter", "reset": 0},
    "swap_drain_steps": {"kind": "counter", "reset": 0},
}


@dataclasses.dataclass
class RequestResult:
    rid: int
    scores: List[float]                # p(click) per candidate, in order
    latency_s: float                   # submit -> last candidate scored
                                       # (== queue_s + service_s)
    queue_s: float                     # submit -> admitted onto a row
    service_s: float                   # admitted -> last candidate scored
    context_tokens: int                # logical context length n (incl. BOS)
    prefill_tokens: int                # context tokens this request committed
    burst_tokens: int                  # tokens fed in non-committing bursts
                                       # (candidates + [SUM] + suffix copies)
    shared_prefix_tokens: int          # context prefix reused from another
                                       # request's committed block
    cached_tokens: int                 # logical prompt tokens served from
                                       # cache: logical - (prefill + burst)
    logical_tokens: int                # what k independent prefills compute
    params_versions: List[Optional[int]] = dataclasses.field(
        default_factory=list)          # every weight version some work unit
                                       # of this request was dispatched
                                       # under, sorted; len > 1 means the
                                       # request straddled a hot-swap (never
                                       # happens with drain_before_swap)

    @property
    def cache_hit_fraction(self) -> float:
        """Fraction of the logical prompt tokens (k x context+candidate)
        that were read from cache instead of recomputed — own-context
        reuse across the k candidates plus any cross-request shared
        prefix."""
        return self.cached_tokens / max(self.logical_tokens, 1)


@dataclasses.dataclass
class _Unit:
    """One fixed-shape step's worth of work for one slot."""
    tokens: np.ndarray                 # (n,) int32
    positions: np.ndarray              # (n,) int32
    is_sum: np.ndarray                 # (n,) bool
    seg: np.ndarray                    # (n,) int32; -1 shared, else candidate
    commit: bool                       # context chunk (True) vs burst (False)
    score_at: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
                                       # (candidate idx, offset) per [SUM]


@dataclasses.dataclass
class _Prefill:
    """Resumable committed-context work: ``tokens`` land at positions
    ``start .. start+len-1``; ``done`` of them have already been cut into
    dispatched chunks. Chunk size is decided per step (`_build_wave`) from
    the wave's bucket and the prefill token budget — never fixed at
    admission — so a long context commits across many small steps."""
    tokens: List[int]
    start: int
    done: int = 0

    @property
    def remaining(self) -> int:
        return len(self.tokens) - self.done


@dataclasses.dataclass
class _Slot:
    """One in-flight request (possibly one of several sharing a row)."""
    rid: int
    row: int
    units: deque                       # its remaining burst _Units, FIFO
    prefill: Optional[_Prefill]        # resumable context commit (None when
                                       # nothing to commit)
    context: List[int]                 # full flattened context incl. [BOS]
                                       # (kept for mid-prefill restart on a
                                       # weight hot-swap)
    scores: List[Optional[float]]
    submit_t: float
    admit_t: float                     # when the request landed on its row
    n_context: int                     # logical context length n
    prefill_tokens: int
    burst_tokens: int                  # all non-commit feeds (suffix copies
                                       # included)
    slate_tokens: int                  # sum(len(cand) + 1) — the logical
                                       # candidate+[SUM] feed
    shared_prefix_tokens: int
    n_candidates: int
    versions: set = dataclasses.field(default_factory=set)
                                       # params versions its dispatches ran
                                       # under (RequestResult.params_versions)


@dataclasses.dataclass
class _Row:
    """Host-side state of one cache row (one batch index of the KV cache).

    ``committed`` is the row's context block — the token sequence whose KV
    occupies slots ``0..len-1`` once ``pending_commit`` reaches 0 (the
    number of active slots whose prefill has not fully dispatched; a row
    is *sharable* only at ``pending_commit == 0``, enforced by
    ``_try_place``). ``active`` are the requests currently scoring bursts
    against the block; ``retained`` marks an inactive row whose block is
    kept (and refcounted) for future prefix reuse. The cache-side refcount
    invariant is ``ref == len(active) + retained``.
    """
    committed: List[int] = dataclasses.field(default_factory=list)
    pending_commit: int = 0
    active: List[_Slot] = dataclasses.field(default_factory=list)
    retained: bool = False
    stale: bool = False                # KV predates a weight swap: keep
                                       # serving in-flight readers, never
                                       # share with or retain for new ones
    last_used: int = 0                 # step counter, for LRU steal
    last_progress: int = 0             # step counter, for the watchdog
    rr: int = 0                        # round-robin pointer over active


class ServeScheduler:
    """Continuous-batching multi-target CTR scorer.

    ``submit`` enqueues a request (context = per-interaction token lists,
    candidates = per-candidate token lists); ``run`` drains queue and rows
    and returns {rid: RequestResult}. ``step`` advances one batched decode
    step (exposed for tests). The decode step is jitted once per bucket
    length; admission/eviction are O(rows) host bookkeeping plus int32
    refcount/pos/cursor updates on the touched rows (never KV traffic).

    ``share_prefix=True`` (default) enables cross-request prefix sharing:
    finished contexts are retained and refcounted, and admission reuses
    the longest matching committed prefix (`_try_place`). Shared requests
    score bit-identically to unshared ones — sharing changes which cache
    row a burst reads, never what the burst attends. ``min_shared_prefix``
    sets the shortest prefix worth reusing (every context starts with
    [BOS], so a floor of 1 would "share" almost nothing of value while
    trimming away retained blocks).

    ``attn_impl`` picks the decode attention path ("dense", "pallas", or
    None = follow ``cfg.attn_impl``); see ``make_decode_fn``.

    Scheduling policy knobs:

    * ``prefill_budget`` — max committed context tokens dispatched per
      step, across all rows (None = one largest-bucket worth,
      ``buckets[-1]``). Decode bursts are packed first and alone size the
      wave's bucket; prefill chunks are then cut to
      ``min(bucket, budget remaining)``, so prefill progress rides along
      without inflating any co-scheduled burst's jit shape.
    * ``monolithic_prefill`` — restore the pre-budget behaviour (context
      chunks cut at ``buckets[-1]``, inflating the whole wave's bucket)
      as a reference/baseline mode; ``prefill_budget`` is ignored.
    * ``overlap`` — keep one decode step in flight: dispatch step t+1
      before syncing step t's scores (default True). Commit gating, row
      op ordering and hot-swap invalidation stay correct because the
      cache threads every call (device-order data dependency); the only
      observable difference is that row reuse and admission run one step
      behind request completion.
    * ``watchdog_steps`` — a row holding undispatchable backlog for more
      than this many steps (or a request still unfinished when ``run``
      drains) increments ``watchdog_fired`` and is recorded in
      ``telemetry()`` — a stalled/never-draining row is a scheduler bug
      surfaced rather than a silent hang.

    Multi-device knobs (docs/sharding.md):

    * ``mesh`` — a ``(data, model)`` ``jax.sharding.Mesh`` (e.g.
      ``repro.launch.mesh.make_serve_mesh``). The KV cache is committed
      with ``repro.sharding.partition.cache_specs`` layouts (paged global
      slot axis over ``data``, KV heads over ``model``, bookkeeping
      replicated) and params with the whole-head-granular serving TP
      layout (``serve_param_specs``); the donated decode
      chain preserves the shardings step over step, so steady-state
      serving is GSPMD-partitioned with zero per-step resharding. Scores
      are within reduction-order noise of the unsharded scheduler
      (tests/test_multihost.py pins <= 1e-4 across the whole
      dense/pallas x GQA/MLA x contiguous/paged x bf16/int8 matrix).
    * ``drain_before_swap`` — make ``update_params`` *drain* in-flight
      work first: admission is suppressed, the pipeline and every active
      row run to completion under the old weights, and only then do the
      new weights land. Every request is then scored under exactly one
      weight version (``RequestResult.params_versions``) — the
      version-purity contract a fleet-wide hot-swap needs — at the cost
      of a fleet-visible drain bubble (``swap_drain_steps`` in
      ``telemetry()``). Default False keeps the documented mixed-version
      straddle (zero dropped traffic, bounded staleness).
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 8,
                 capacity: int = 256, window: Optional[int] = None,
                 buckets: Sequence[int] = (8, 16, 32, 64),
                 sp: SpecialTokens = SpecialTokens(),
                 yes_id: int = 3, no_id: int = 4, cache_dtype=jnp.float32,
                 kv_dtype: Optional[str] = None,
                 attn_impl: Optional[str] = None,
                 share_prefix: bool = True, min_shared_prefix: int = 4,
                 prefill_budget: Optional[int] = None,
                 monolithic_prefill: bool = False,
                 overlap: bool = True,
                 watchdog_steps: int = 256,
                 paged: bool = True, page_size: int = 16,
                 n_pages: Optional[int] = None,
                 mesh=None, drain_before_swap: bool = False,
                 tracer=None):
        if window is None:
            window = cfg.window          # match make_prefill_fn's default
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.capacity = capacity
        self.kv_dtype = kv_dtype
        self.buckets = tuple(sorted(buckets))
        self.sp = sp
        self.attn_impl = attn_impl
        self.share_prefix = share_prefix
        self.min_shared_prefix = max(int(min_shared_prefix), 1)
        if prefill_budget is None:
            prefill_budget = self.buckets[-1]
        assert prefill_budget >= 1, "prefill_budget must be >= 1"
        self.prefill_budget = int(prefill_budget)
        self.monolithic_prefill = bool(monolithic_prefill)
        self.overlap = bool(overlap)
        self.watchdog_steps = int(watchdog_steps)
        self.paged = bool(paged)
        self.mesh = mesh
        self.drain_before_swap = bool(drain_before_swap)
        self._in_swap = False
        # observability: a tracer (default no-op) plus the metrics
        # registry backing every counter telemetry() reports. The public
        # counter attributes (`n_steps`, `shared_admissions`, ...) are
        # read-only properties over these — same names, same values.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = MetricsRegistry()
        m = self.metrics
        self._c_steps = m.counter("serve.steps")
        self._c_shared_admissions = m.counter("serve.shared_admissions")
        self._c_cross_row_hits = m.counter("serve.cross_row_hits")
        self._c_cross_row_tokens = m.counter("serve.cross_row_tokens")
        self._c_watchdog_fired = m.counter("serve.watchdog_fired")
        self._c_budget_used = m.counter("serve.prefill_tokens")
        self._c_budget_avail = m.counter("serve.prefill_budget_avail")
        self._c_kv_committed = m.counter("serve.kv_bytes_committed")
        self._c_starved = m.counter("serve.prefill_starved_steps")
        self._c_prefill_steps = m.counter("serve.prefill_steps")
        self._c_swap_drains = m.counter("serve.swap_drains")
        self._c_swap_drain_steps = m.counter("serve.swap_drain_steps")
        self._c_ctx_done = m.counter("serve.ctx_tokens_done")
        self._c_shared_done = m.counter("serve.shared_tokens_done")
        self._c_bucket = {int(b): m.counter(f"serve.bucket_steps.{int(b)}")
                          for b in self.buckets}
        self._h_qdepth = m.histogram("serve.queue_depth")
        if self.paged:
            # each row addresses the global page pool through its page
            # table; the pool defaults to the same total slot count as the
            # contiguous layout, so pages freed by short contexts fund
            # radix-index retention instead of sitting idle in long rows
            cap_eff = -(-capacity // page_size) * page_size
            self.page_size = int(page_size)
            max_pages = cap_eff // page_size
            if n_pages is None:
                n_pages = n_slots * max_pages
            self._pool = PagePool(n_pages, page_size, metrics=self.metrics)
            # host mirror of the device page tables (authoritative copy;
            # synced to the cache dict whenever dirty)
            self._tables = np.full((n_slots, max_pages), -1, np.int32)
            self._tables_dirty = False
        else:
            cap_eff = capacity
            self.page_size = None
            self._pool = None
        # the cache is donated to every jitted op that rewrites it: KV
        # tensors alias straight through (bookkeeping ops touch int32 only)
        # instead of being copied per call — the scheduler always rebinds
        # ``self.cache`` from the op's return, so the stale reference is
        # never read
        self._decode = jax.jit(
            make_decode_fn(cfg, window=window, ring=False,
                           yes_id=yes_id, no_id=no_id, attn_impl=attn_impl),
            donate_argnums=(1,))
        self._free = jax.jit(free_slots, donate_argnums=(0,))
        self._retain = jax.jit(retain_slots, donate_argnums=(0,))
        # the scheduler's caches are never rings; threading the flag makes
        # trim_slots' non-ring-only contract enforced, not just documented
        self._trim = jax.jit(lambda c, m, k: trim_slots(c, m, k, ring=False),
                             donate_argnums=(0,))
        self._adopt = jax.jit(adopt_slots, donate_argnums=(0,))
        self.cache = init_lm_cache(
            cfg, n_slots, cap_eff, dtype=cache_dtype, kv_dtype=kv_dtype,
            page_size=self.page_size,
            n_pages=n_pages if self.paged else None)
        # per-token KV footprint (codes + scale sidecars, all layers):
        # stamped on the pool so capacity can be asked in bytes — what lets
        # benchmarks size int8 and bf16 pools to equal HBM budgets
        self._kv_token_bytes = kv_token_bytes(self.cache)
        if self.paged:
            self._pool.token_bytes = self._kv_token_bytes
        # multi-device placement: commit the cache under the serving layout
        # (paged slot axis over data, KV heads over model — `cache_specs`)
        # and params under the whole-head-granular serving TP layout
        # (`serve_param_specs`). Donation keeps the layouts across the step
        # chain; host->device uploads that rebind a cache leaf
        # (`_flush_row_ops`'s page-table sync) must re-commit with the
        # same sharding or every sync would change the jit signature and
        # recompile the decode step.
        self._cache_shardings = None
        self._param_specs = None
        if mesh is not None:
            self._cache_shardings = cache_specs(self.cache, mesh)
            self.cache = jax.device_put(self.cache, self._cache_shardings)
            self._param_specs = serve_param_specs(self.params, cfg, mesh)
            self.params = jax.device_put(self.params, self._param_specs)
        self._queue: deque = deque()
        self._rows: List[_Row] = [_Row() for _ in range(n_slots)]
        self._trie = RadixTree(page_size=self.page_size or 0)
        # host shadow of the device per-row refcounts: lets the row-op
        # batcher detect double-frees (`_flush_row_ops`) and the paged path
        # unmap pages exactly when a row resets, without a device sync
        self._row_ref = np.zeros((n_slots,), np.int32)
        self._pending = self._fresh_pending()
        self._results: Dict[int, RequestResult] = {}
        self._next_rid = 0
        self._inflight: deque = deque()  # dispatched, un-harvested steps
        self._prefill_rr = 0             # rotates budget priority over rows
        self._param_source = None
        self._poll_every = 1
        self._poll_tick = 0
        self.params_version: Optional[int] = None
        self.reset_stats()

    # -- telemetry -----------------------------------------------------------

    # registry-backed views keeping the historic attribute API
    # (`sched.n_steps`, benchmarks, tests — reads and writes) intact
    # post-migration
    @property
    def n_steps(self) -> int:
        return self._c_steps.value

    @n_steps.setter
    def n_steps(self, v: int) -> None:
        self._c_steps.set(int(v))

    @property
    def shared_admissions(self) -> int:
        """Requests that reused a prefix."""
        return self._c_shared_admissions.value

    @shared_admissions.setter
    def shared_admissions(self, v: int) -> None:
        self._c_shared_admissions.set(int(v))

    @property
    def cross_row_hits(self) -> int:
        """Admissions served from the radix page index (pages another
        row or no row currently holds)."""
        return self._c_cross_row_hits.value

    @cross_row_hits.setter
    def cross_row_hits(self, v: int) -> None:
        self._c_cross_row_hits.set(int(v))

    @property
    def cross_row_tokens(self) -> int:
        return self._c_cross_row_tokens.value

    @cross_row_tokens.setter
    def cross_row_tokens(self, v: int) -> None:
        self._c_cross_row_tokens.set(int(v))

    @property
    def watchdog_fired(self) -> int:
        return self._c_watchdog_fired.value

    @watchdog_fired.setter
    def watchdog_fired(self, v: int) -> None:
        self._c_watchdog_fired.set(int(v))

    def reset_stats(self) -> None:
        """Zero the step/telemetry counters (benchmarks call this after
        warmup so compile steps don't pollute the measured run). In-flight
        state, retained blocks and results are untouched — and so are the
        one-shot ``jit.*`` compile gauges (``jit_stats()``), which live
        outside the ``serve.``/``pool.`` reset scopes."""
        self.metrics.reset(prefix="serve.")
        self.watchdog_stuck_rids: List[int] = []
        self._watchdog_rows: set = set()
        if self.paged:
            self._pool.evictions = 0
        for r in self._rows:
            r.last_used = 0
            r.last_progress = 0

    def reset_telemetry(self) -> None:
        """Documented alias of ``reset_stats`` — clears every counter
        ``telemetry()`` reports, including the watchdog state
        (``_watchdog_rows`` / ``watchdog_stuck_rids``)."""
        self.reset_stats()

    def telemetry(self) -> Dict[str, Any]:
        """Scheduler-health counters since construction / ``reset_stats``:

        * ``bucket_steps``        — decode steps per jit bucket shape (the
          tail-latency fingerprint: monolithic prefill piles steps into
          the largest bucket, the token budget keeps burst waves small);
        * ``queue_depth_mean/max``— submitted-but-unadmitted requests,
          sampled once per dispatched step after admission;
        * ``prefill_budget`` / ``prefill_tokens`` / ``budget_utilization``
          — the per-step budget, committed tokens actually dispatched and
          dispatched / available-under-demand (None when
          ``monolithic_prefill`` disables the budget);
        * ``prefill_starved_steps`` — steps where some row's prefill got
          nothing because the budget ran out (rotation keeps this fair);
        * ``watchdog_fired`` / ``watchdog_rows`` / ``watchdog_stuck_rids``
          — stalled-row detections (see ``watchdog_steps``).
        """
        # guard the burst-only / zero-prefill case: with no prefill steps
        # dispatched there is no budget demand to divide by — report None,
        # never a ZeroDivisionError
        util = (self._c_budget_used.value / self._c_budget_avail.value
                if self._c_budget_avail.value else None)
        qd = self._h_qdepth
        out = {
            "steps": int(self.n_steps),
            "overlap": bool(self.overlap),
            "bucket_steps": {b: int(c.value)
                             for b, c in sorted(self._c_bucket.items())},
            "queue_depth_mean": qd.mean if qd.count else 0.0,
            "queue_depth_max": int(qd.vmax) if qd.count else 0,
            "prefill_budget": (None if self.monolithic_prefill
                               else int(self.prefill_budget)),
            "prefill_tokens": int(self._c_budget_used.value),
            "prefill_steps": int(self._c_prefill_steps.value),
            "budget_utilization": (None if self.monolithic_prefill else util),
            "prefill_starved_steps": int(self._c_starved.value),
            "watchdog_fired": int(self.watchdog_fired),
            "watchdog_rows": sorted(int(i) for i in self._watchdog_rows),
            "watchdog_stuck_rids": list(self.watchdog_stuck_rids),
            "paged": bool(self.paged),
            "cross_row_hits": int(self.cross_row_hits),
            "cross_row_tokens": int(self.cross_row_tokens),
            "prefix_hit_rate": (self._c_shared_done.value
                                / self._c_ctx_done.value
                                if self._c_ctx_done.value else 0.0),
            # KV footprint: dtype, whole-cache bytes, per-token bytes
            # (codes + any scale sidecar) and bytes landed by commits —
            # the equal-HBM-budget axis of the quantized-vs-bf16 benches
            "kv_dtype": self.kv_dtype or "native",
            "kv_bytes": int(kv_cache_bytes(self.cache)),
            "kv_token_bytes": float(self._kv_token_bytes),
            "kv_bytes_committed": int(self._c_kv_committed.value),
            # multi-device: the serving mesh's axis sizes (None when
            # unsharded) and the hot-swap drain policy + its cost
            "mesh": (None if self.mesh is None
                     else {str(k): int(v)
                           for k, v in self.mesh.shape.items()}),
            "drain_before_swap": bool(self.drain_before_swap),
            "swap_drains": int(self._c_swap_drains.value),
            "swap_drain_steps": int(self._c_swap_drain_steps.value),
        }
        if self.paged:
            out.update({
                "page_size": int(self.page_size),
                "pages_in_use": int(self._pool.pages_in_use()),
                "pages_free": int(self._pool.free_count()),
                "page_evictions": int(self._pool.evictions),
                "radix_pages": int(self._trie.held_pages()),
                "pool_capacity_tokens": int(self._pool.capacity_tokens()),
                "pool_bytes": int(self._pool.pool_bytes()),
            })
        return out

    def warmup(self) -> None:
        """Pre-compile the decode step for every bucket shape with an
        all-invalid, non-committing wave. No row state changes (invalid
        slots write pos −1 that ``commit=False`` discards), so serving
        traffic never hits a compile mid-request.

        Because this is the one place every jit bucket is entered cold
        and off the hot path, it also measures per-bucket compile-vs-
        execute time (first call = compile + execute, second = execute)
        into the ``jit.*`` gauges — see ``jit_stats()``. The blocking
        calls here are warmup-only; the serving hot path stays at its
        single harvest sync."""
        for s in self.buckets:
            z = np.zeros((self.n_slots, s), np.int32)
            f = np.zeros((self.n_slots, s), bool)
            args = (jnp.asarray(z), jnp.asarray(z), jnp.asarray(f),
                    jnp.asarray(f),
                    jnp.asarray(np.zeros((self.n_slots,), bool)),
                    jnp.asarray(np.full((self.n_slots, s), -1, np.int32)))
            t0 = monotonic()
            p, self.cache = self._decode(self.params, self.cache, *args)
            jax.block_until_ready(p)
            t1 = monotonic()
            p, self.cache = self._decode(self.params, self.cache, *args)
            jax.block_until_ready(p)
            t2 = monotonic()
            first, execute = t1 - t0, t2 - t1
            pre = f"jit.bucket{int(s)}"
            self.metrics.gauge(pre + ".first_s").set(first)
            self.metrics.gauge(pre + ".execute_s").set(execute)
            self.metrics.gauge(pre + ".compile_s").set(
                max(0.0, first - execute))
        # the row-op jits too (no-op masks/counts), so the first real
        # admission/eviction doesn't pay their compiles mid-run
        none = jnp.asarray(np.zeros((self.n_slots,), bool))
        zc = jnp.asarray(np.zeros((self.n_slots,), np.int32))
        self.cache = self._free(self.cache, zc)
        self.cache = self._trim(self.cache, none, zc)
        self.cache = self._adopt(self.cache, none, zc)
        self.cache = self._retain(self.cache, zc)
        jax.block_until_ready(self.cache["pos"])

    def jit_stats(self) -> Dict[int, Dict[str, float]]:
        """Per-jit-bucket compile-vs-execute timing measured by
        ``warmup()``: ``{bucket: {compile_s, execute_s, first_s}}``.
        Empty before warmup. Survives ``reset_stats`` (the gauges sit
        under the un-reset ``jit.`` prefix), so benchmarks that reset
        after warmup still report what the compiles cost."""
        out: Dict[int, Dict[str, float]] = {}
        for s in self.buckets:
            pre = f"jit.bucket{int(s)}"
            g = self.metrics.gauge(pre + ".first_s")
            if g.seq:
                out[int(s)] = {
                    "compile_s": self.metrics.gauge(pre + ".compile_s").value,
                    "execute_s": self.metrics.gauge(pre + ".execute_s").value,
                    "first_s": g.value,
                }
        return out

    # -- weight hot-swap -----------------------------------------------------

    def attach_param_source(self, source, *, poll_every: int = 8) -> None:
        """``source()`` -> None or (version, params) — e.g.
        ``repro.stream.publish.ParamSubscriber(...).poll``. Polled at the
        top of ``step``, every ``poll_every``-th call: the source may hit a
        filesystem/object store, so the default keeps that I/O off the
        per-step decode hot path (weights change every ~publish_every
        trainer steps; sub-step freshness buys nothing). Freshly published
        weights land between decode steps without dropping in-flight slots
        (their cached context KV stays; a request straddling a swap is
        scored under mixed versions — see docs/streaming.md for the
        staleness contract)."""
        assert poll_every >= 1
        self._param_source = source
        self._poll_every = poll_every

    def update_params(self, params, version: Optional[int] = None) -> None:
        """Swap serving weights in place. Params are a jit argument, so the
        bucketed decode step does not recompile; queued requests and busy
        rows are untouched.

        Retained context blocks are **invalidated**: their KV encodes the
        old weights, so sharing them with post-swap requests would score
        fresh traffic against stale context. Idle retained blocks are
        freed and deregistered immediately; blocks with in-flight readers
        keep serving them (the documented mixed-version contract for
        requests straddling a swap, docs/streaming.md) but are flagged
        ``stale`` — never matched for new sharing, and freed instead of
        retained when their last reader leaves.

        A row whose context is **still committing** when the swap lands is
        *restarted* instead: mixing weight versions inside one context
        block would make the block's KV internally inconsistent (worse
        than the documented whole-version straddle), so the row's slots
        are rolled back to empty (``trim_slots`` at keep=0 — enqueued
        after any in-flight chunk, the cache data dependency orders it)
        and the committer re-commits its full context from position 0
        under the new weights. Chunked and monolithic prefill therefore
        score identically across a mid-prefill swap.

        With ``drain_before_swap=True`` none of the straddle/restart
        machinery is reachable: in-flight work is drained first (admission
        suppressed, queued requests wait), so the swap lands on idle rows
        and every request's KV — and every score — comes from exactly one
        weight version."""
        if self.drain_before_swap and not self._in_swap and (
                self._inflight or any(r.active for r in self._rows)):
            self._in_swap = True       # suppress admission + source polling
            try:
                drained = 0
                while self._inflight or any(r.active for r in self._rows):
                    if not self.step():
                        break
                    drained += 1
                self._c_swap_drains.inc()
                self._c_swap_drain_steps.inc(drained)
                self.tracer.instant("swap_drain", steps=drained)
            finally:
                self._in_swap = False
        self.tracer.instant("hot_swap", version=version)
        if self._param_specs is not None:
            params = jax.device_put(params, self._param_specs)
        self.params = params
        if version is not None:
            self.params_version = version
        if self.paged:
            # the radix page index holds pre-swap KV: flush it before any
            # restart re-allocates, so freed pages fund the recommits
            dropped = self._trie.drop_all_pages()
            if dropped:
                self._pool.decref(dropped)
        for i, r in enumerate(self._rows):
            committer = self._committer(r) if r.pending_commit > 0 else None
            if committer is not None:
                n = len(committer.context)
                committer.prefill = _Prefill(tokens=list(committer.context),
                                             start=0)
                # accounting restarts with the prefill: the request now
                # commits its full context itself (any shared prefix it
                # had borrowed predates the swap)
                committer.prefill_tokens = n
                committer.shared_prefix_tokens = 0
                self._mark("trim", i, keep=0)
                if self.paged:
                    # radix-adopted pages may be shared with other rows —
                    # a full recommit must write only private pages
                    self._unmap_row(i)
                    if not self._ensure_pages(i, min(self.capacity,
                                                     n + self.buckets[-1]),
                                              exclude={i}):
                        raise RuntimeError(
                            f"page pool exhausted re-committing row {i} "
                            f"after a weight hot-swap")
                continue
            if not self.share_prefix or not r.committed:
                continue
            if r.active:
                r.stale = True
            else:                              # idle retention hold
                self._trie.remove(r.committed, i)
                r.committed, r.retained = [], False
                self._mark("free", i)
                if self.paged:
                    self._unmap_row(i)

    # -- request intake ------------------------------------------------------

    def submit(self, context: Sequence[Sequence[int]],
               candidates: Sequence[Sequence[int]],
               rid: Optional[int] = None) -> int:
        assert len(candidates) > 0, "a request needs at least one candidate"
        if rid is None:
            rid = self._next_rid
        assert (rid not in self._results
                and all(q[0] != rid for q in self._queue)
                and all(s.rid != rid for r in self._rows
                        for s in r.active)), (
            f"request id {rid} already pending")
        self._next_rid = max(self._next_rid, rid + 1)
        ctx = [self.sp.bos]
        for it in context:
            ctx.extend(it)
        j_long = max(range(len(candidates)),
                     key=lambda j: len(candidates[j]))
        longest = len(candidates[j_long]) + 1
        if longest > self.buckets[-1]:
            raise ValueError(
                f"request {rid}: candidate {j_long} burst {longest} tokens "
                f"> largest bucket {self.buckets[-1]}")
        # explicit capacity-overflow rejection: non-ring `slot_indices`
        # never wraps or clamps, so a commit running past capacity would
        # silently scatter-drop KV (mode="drop") and score garbage — the
        # overflow must be refused here, with the offending lengths named,
        # before any row state is touched
        if len(ctx) + longest > self.capacity:
            raise ValueError(
                f"request {rid}: context {len(ctx)} + candidate {j_long} "
                f"burst {longest} tokens overflow capacity {self.capacity} "
                f"(commits past capacity would be silently dropped)")
        self._queue.append((rid, ctx, [list(c) for c in candidates],
                            monotonic()))
        if self.tracer.enabled:
            self.tracer.instant("submit", rid=rid, context=len(ctx),
                                k=len(candidates))
        return rid

    def prewarm(self, context: Sequence[Sequence[int]]) -> Optional[int]:
        """Enqueue a candidate-less request that commits ``context`` into
        the cache (and, on a paged cache, publishes its full pages into
        the radix index) without scoring anything — so a user's *next*
        real request admits against an already-resident prefix. The
        stream pipeline calls this for hot users on hot-swap-free ticks
        (`repro.stream.prewarm`).

        Prewarms ride the normal admission ladder and prefill budget, so
        they never preempt scoring traffic's jit shapes; the context is
        clamped to leave one largest-bucket of burst headroom for the
        real request that follows. Returns the rid (its RequestResult
        has ``scores == []``), or None when sharing is off, the usable
        context is shorter than ``min_shared_prefix``, or the prefix is
        already fully resident (nothing to warm)."""
        if not self.share_prefix:
            return None
        ctx = [self.sp.bos]
        for it in context:
            ctx.extend(it)
        ctx = ctx[:max(0, self.capacity - self.buckets[-1])]
        if len(ctx) < self.min_shared_prefix:
            return None
        end_d, _, _, _ = self._trie.match(ctx)
        if end_d >= len(ctx):
            return None
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append((rid, ctx, [], monotonic()))
        if self.tracer.enabled:
            self.tracer.instant("submit", rid=rid, context=len(ctx),
                                k=0, prewarm=True)
        return rid

    # -- unit construction ---------------------------------------------------

    def _burst_units(self, candidates: List[List[int]], n: int,
                     suffix: List[int], burst_cap: int
                     ) -> Tuple[List[_Unit], int]:
        """Non-committing scoring bursts: greedy-fill candidate+[SUM]
        groups into shared bursts; each group carries its candidate index
        as an in-burst segment, so one decode step scores as many
        candidates as fit. A burst also writes (unreachable) KV after the
        committed block, so it must stay within ``burst_cap`` slots.

        ``suffix`` is the request's uncommitted context tail (nonempty
        only when sharing a busy row's shorter committed prefix): it rides
        at the head of **every** burst as shared (seg −1) tokens at
        positions ``n−len(suffix)..n−1``, re-creating the request's full
        context without writing to the shared block. Candidate positions
        restart at ``n`` either way — identical to the unshared layout.

        Returns (units, total burst tokens incl. suffix copies).
        """
        units: List[_Unit] = []
        total = 0
        toks: List[int] = []
        pos: List[int] = []
        is_sum: List[bool] = []
        seg: List[int] = []
        score_at: List[Tuple[int, int]] = []

        def begin():
            toks.extend(suffix)
            pos.extend(range(n - len(suffix), n))
            is_sum.extend([False] * len(suffix))
            seg.extend([-1] * len(suffix))

        def flush():
            nonlocal total
            if len(toks) > len(suffix) or (toks and not suffix):
                units.append(_Unit(
                    tokens=np.asarray(toks, np.int32),
                    positions=np.asarray(pos, np.int32),
                    is_sum=np.asarray(is_sum),
                    seg=np.asarray(seg, np.int32),
                    commit=False, score_at=list(score_at)))
                total += len(toks)
            for l in (toks, pos, is_sum, seg, score_at):
                l.clear()

        begin()
        for j, cand in enumerate(candidates):
            group = list(cand) + [self.sp.sum]
            if len(toks) > len(suffix) and len(toks) + len(group) > burst_cap:
                flush()
                begin()
            toks.extend(group)
            pos.extend(range(n, n + len(group)))   # every candidate restarts
            is_sum.extend([False] * len(cand) + [True])
            seg.extend([j] * len(group))
            score_at.append((j, len(toks) - 1))
        flush()
        return units, total

    # -- paged-cache page management (host-side, no device syncs) ------------

    def _unmap_row(self, row: int, from_page: int = 0) -> None:
        """Drop the row's page-table references from ``from_page`` on.
        Pages whose last reference this was return to the pool; pages the
        radix index still holds stay resident (and matchable) rowlessly."""
        tbl = self._tables[row]
        pids = tbl[from_page:]
        pids = pids[pids >= 0]
        if len(pids):
            self._pool.decref([int(p) for p in pids])
            tbl[from_page:] = -1
            self._tables_dirty = True

    def _alloc_pages(self, n: int, exclude=()) -> Optional[List[int]]:
        """Allocate ``n`` private pages, reclaiming under pressure: first
        LRU pages held only by the radix index, then whole LRU retained
        rows (their trie entries drop, like a steal). ``exclude`` protects
        rows the current admission is about to use. None when the pool is
        truly exhausted (every page pinned by an active or excluded row)."""
        if n == 0:
            return []
        while True:
            pids = self._pool.alloc(n)
            if pids is not None:
                return pids
            short = n - self._pool.free_count()
            ev = self._trie.evict_pages(short, self._pool.ref)
            if ev:
                self._pool.note_evictions(len(ev))
                self._pool.decref(ev)
                continue
            victims = [i for i, r in enumerate(self._rows)
                       if i not in exclude and not r.active and r.retained
                       and r.pending_commit == 0]
            if not victims:
                return None
            row = min(victims, key=lambda i: self._rows[i].last_used)
            r = self._rows[row]
            self._trie.remove(r.committed, row)
            r.committed, r.retained = [], False
            self._mark("free", row)
            self._unmap_row(row)

    def _mapped_pages(self, row: int) -> int:
        """Mapped page-table prefix length (mappings are always a
        contiguous prefix: adopt/extend grow it, trim/free shrink it)."""
        return int((self._tables[row] >= 0).sum())

    def _ensure_pages(self, row: int, upto_tokens: int, exclude=()) -> bool:
        """Grow ``row``'s mapped prefix to cover ``upto_tokens`` logical
        slots (committed context plus the burst-scratch extent). New pages
        are private (ref 1, owned by the row)."""
        need = -(-min(upto_tokens, self.capacity) // self.page_size)
        have = self._mapped_pages(row)
        if need <= have:
            return True
        pids = self._alloc_pages(need - have, exclude=exclude)
        if pids is None:
            return False
        self._tables[row, have:need] = pids
        self._tables_dirty = True
        return True

    def _publish_pages(self, row: int) -> None:
        """Index the row's full committed pages in the radix tree (the
        index takes one pool reference per newly adopted page), so the
        prefix stays reusable by *any* row even after this one is stolen."""
        r = self._rows[row]
        full = len(r.committed) // self.page_size
        if full == 0:
            return
        pids = [int(p) for p in self._tables[row, :full]]
        assert all(p >= 0 for p in pids)
        new = self._trie.attach_pages(r.committed, pids)
        if new:
            self._pool.incref(new)

    def _max_burst_extent(self, candidates: List[List[int]],
                          suffix_len: int, burst_cap: int) -> int:
        """Largest slot extent any single burst unit will write past the
        committed block — mirrors ``_burst_units``'s greedy packing."""
        cur, out = suffix_len, 0
        for c in candidates:
            g = len(c) + 1
            if cur > suffix_len and cur + g > burst_cap:
                cur = suffix_len
            cur += g
            out = max(out, cur)
        return out

    # -- admission -----------------------------------------------------------

    def _mark(self, which: str, row: int, keep: int = 0) -> None:
        """Queue a refcount/trim/adopt update for ``row``; applied in one
        batched jitted call per phase (`_flush_row_ops`) instead of per
        event — per-event dispatch would dominate the step at small model
        sizes. Retain/free marks are *counts*, not flags: several requests
        can take (or drop) references on the same row within one wave."""
        if which == "trim":
            self._pending["trim"][row] = True
            self._pending["trim_keep"][row] = keep
        elif which == "adopt":
            self._pending["adopt"][row] = True
            self._pending["adopt_len"][row] = keep
        else:
            self._pending[which][row] += 1

    def _flush_row_ops(self) -> None:
        """Apply queued row ops in dependency order: free (steal resets)
        -> trim (roll back retained blocks) -> adopt (install radix-mapped
        prefixes) -> retain (new references). The phases touch disjoint
        rows within one flush except steal, which queues free+retain (and
        possibly adopt) on the same row — exactly the order applied.

        Before applying, the free counts are audited against the host
        shadow refcounts: freeing more references than a row holds is a
        scheduler accounting bug that the device op would silently
        *saturate* (resetting ``pos``/``cursor`` under a still-active
        sharer mid-burst), so it fails loudly here with the row and its
        active rids named instead.
        """
        p = self._pending
        over = p["free"] > self._row_ref
        if over.any():
            parts = []
            for row in np.flatnonzero(over):
                rids = sorted(s.rid for s in self._rows[row].active)
                parts.append(
                    f"row {int(row)}: freeing {int(p['free'][row])} ref(s) "
                    f"but only {int(self._row_ref[row])} held "
                    f"(active rids {rids})")
            raise RuntimeError("double-free in row-op batch — " +
                               "; ".join(parts))
        self._row_ref += p["retain"] - p["free"]
        if p["free"].any():
            self.cache = self._free(self.cache, jnp.asarray(p["free"]))
        if p["trim"].any():
            self.cache = self._trim(self.cache, jnp.asarray(p["trim"]),
                                    jnp.asarray(p["trim_keep"]))
        if p["adopt"].any():
            self.cache = self._adopt(self.cache, jnp.asarray(p["adopt"]),
                                     jnp.asarray(p["adopt_len"]))
        if p["retain"].any():
            self.cache = self._retain(self.cache, jnp.asarray(p["retain"]))
        if self.paged and self._tables_dirty:
            # re-upload under the committed sharding: an uncommitted
            # asarray would change the decode jit's input-sharding
            # signature and force a recompile every sync
            pt = (jnp.asarray(self._tables)
                  if self._cache_shardings is None else
                  jax.device_put(self._tables,
                                 self._cache_shardings["page_table"]))
            self.cache = dict(self.cache, page_table=pt)
            self._tables_dirty = False
        self._pending = self._fresh_pending()

    def _fresh_pending(self) -> Dict[str, np.ndarray]:
        return {"free": np.zeros((self.n_slots,), np.int32),
                "trim": np.zeros((self.n_slots,), bool),
                "retain": np.zeros((self.n_slots,), np.int32),
                "trim_keep": np.zeros((self.n_slots,), np.int32),
                "adopt": np.zeros((self.n_slots,), bool),
                "adopt_len": np.zeros((self.n_slots,), np.int32)}

    def _admit(self, row: int, rid: int, ctx: List[int],
               candidates: List[List[int]], t0: float, *,
               shared_depth: int, commit_from: int,
               suffix_in_burst: bool, rung: int = 0) -> None:
        """Build the request's work on ``row``: resumable prefill state for
        the context tokens no committed block covers, plus its burst queue.

        ``shared_depth``   — context prefix reused from the row's block;
        ``commit_from``    — first context index this request commits
                             (== len(ctx) when nothing is committed);
        ``suffix_in_burst``— True when the row is busy with other readers,
                             so the unshared tail ``ctx[shared_depth:]``
                             must ride each burst instead of extending the
                             shared block;
        ``rung``           — which admission-ladder rung placed it
                             (1..4, see ``_try_place``; trace-only).
        """
        n = len(ctx)
        r = self._rows[row]
        to_commit = ctx[commit_from:]
        prefill = None
        if to_commit:
            prefill = _Prefill(tokens=list(to_commit), start=commit_from)
            r.pending_commit += 1
            if r.committed:
                self._trie.remove(r.committed, row)
            r.committed = list(ctx)
            self._trie.insert(r.committed, row)
        elif not r.committed:
            r.committed = list(ctx)
            self._trie.insert(r.committed, row)
        suffix = ctx[shared_depth:] if suffix_in_burst else []
        committed_len = shared_depth if suffix_in_burst else n
        burst_cap = min(self.buckets[-1], self.capacity - committed_len)
        bursts, burst_total = self._burst_units(candidates, n, suffix,
                                                burst_cap)
        slot = _Slot(rid=rid, row=row, units=deque(bursts), prefill=prefill,
                     context=list(ctx),
                     scores=[None] * len(candidates), submit_t=t0,
                     admit_t=monotonic(),
                     n_context=n, prefill_tokens=len(to_commit),
                     burst_tokens=burst_total,
                     slate_tokens=sum(len(c) + 1 for c in candidates),
                     shared_prefix_tokens=shared_depth,
                     n_candidates=len(candidates))
        r.active.append(slot)
        if shared_depth > 0:
            self._c_shared_admissions.inc()
        if self.tracer.enabled:
            self.tracer.instant("admission", rid=rid, row=row, rung=rung,
                                shared=shared_depth,
                                commit=len(to_commit))
        if prefill is None and not slot.units:
            # a prewarm whose context is already fully resident: nothing
            # to dispatch, the request completes at admission
            self._finish(slot, monotonic())

    def _try_place(self, rid: int, ctx: List[int],
                   candidates: List[List[int]], t0: float) -> bool:
        """Place one queued request onto a cache row, preferring the most
        reusable committed context block. The policy ladder (first match
        wins; every rung needs a non-stale block with a usable prefix of
        >= ``min_shared_prefix`` tokens; rungs 1 and 3 mutate the block so
        they additionally need its commits drained):

        1. **extend a retained block** — an inactive row whose full
           committed context is a prefix of ``ctx``: commit only the
           suffix (the block grows; its trie entry is re-keyed). Exact
           matches commit nothing.
        2. **read a busy block** — an active row whose full committed
           context is a prefix of ``ctx``: take a reference and ride the
           unshared suffix inside each burst (the block itself is
           immutable while others read it). Needs suffix + largest
           candidate to fit one bucket. The block may still be committing
           (a same-wave admission): the sharer's bursts are gated behind
           the commits by ``_build_wave``.
        3. **trim a retained block** — an inactive row sharing only a
           proper prefix: roll the block back to the shared prefix
           (`trim_slots`), then commit the rest, as in 1. Paged caches
           trim at a page boundary when the boundary page is shared
           (writing the recommit into it would corrupt its other
           readers); a private boundary page trims at the exact depth.
        4. **fresh row / steal** — a never-used/reset row, else steal the
           least-recently-used retained row (`free_slots` drops the
           retention reference, resetting it). On a paged cache this rung
           first consults the radix **page index**: a prefix another row
           committed — even one whose row has since been stolen — is
           mapped straight into the new row's page table (`adopt_slots`
           installs the bookkeeping; zero KV recompute, zero KV copy) and
           only the tail is committed. These are the *cross-row* hits a
           per-slot contiguous cache cannot serve.

        On a paged cache every rung first maps enough pages to cover the
        committed block plus the burst-scratch extent; a rung whose pages
        cannot be allocated (pool exhausted even after evicting
        index-only pages and stealing retained rows) is skipped.

        Returns False when nothing can host the request (all rows busy).
        """
        n = len(ctx)
        max_group = max((len(c) + 1 for c in candidates), default=0)

        def extent(committed_len: int, suffix_len: int) -> int:
            cap = min(self.buckets[-1], self.capacity - committed_len)
            return committed_len + self._max_burst_extent(
                candidates, suffix_len, cap)

        if self.share_prefix:
            end_d, end_rows, thr_d, thr_rows = self._trie.match(ctx)
            ok = lambda i: (self._rows[i].pending_commit == 0
                            and not self._rows[i].stale)
            if end_d >= self.min_shared_prefix:
                idle = [i for i in sorted(end_rows)
                        if ok(i) and not self._rows[i].active]
                # a busy block may still have commits in flight (its
                # committer was admitted this very wave): sharers can be
                # placed anyway — their bursts are gated behind the
                # commits by `_build_wave`, never reading a half-written
                # block
                busy = [i for i in sorted(end_rows)
                        if not self._rows[i].stale and self._rows[i].active]
                if idle:
                    row = idle[0]
                    if not self.paged or self._ensure_pages(
                            row, extent(n, 0), exclude={row}):
                        self._rows[row].retained = False  # hold transfers
                        self._admit(row, rid, ctx, candidates, t0,
                                    shared_depth=end_d, commit_from=end_d,
                                    suffix_in_burst=False, rung=1)
                        return True
                # the suffix-fits check depends only on the request: all
                # rows in `busy` share the same committed length end_d
                if busy and (n - end_d) + max_group <= min(
                        self.buckets[-1], self.capacity - end_d):
                    row = busy[0]
                    if not self.paged or self._ensure_pages(
                            row, extent(end_d, n - end_d), exclude={row}):
                        self._mark("retain", row)
                        self._admit(row, rid, ctx, candidates, t0,
                                    shared_depth=end_d, commit_from=n,
                                    suffix_in_burst=True, rung=2)
                        return True
            if thr_d >= self.min_shared_prefix:
                trimmable = [i for i in sorted(thr_rows)
                             if ok(i) and not self._rows[i].active
                             and self._rows[i].retained
                             and len(self._rows[i].committed) > thr_d]
                if trimmable:
                    row = min(trimmable,
                              key=lambda i: self._rows[i].last_used)
                    r = self._rows[row]
                    keep = thr_d
                    usable = True
                    if self.paged:
                        ps = self.page_size
                        bp, rem = divmod(thr_d, ps)
                        bref = (int(self._pool.ref[self._tables[row, bp]])
                                if rem else 1)
                        if bref == 2:
                            # the boundary page's only other holder is the
                            # index (a second *row* would imply ref >= 3,
                            # since adoption keeps the index's hold):
                            # un-index it — and the deeper pages behind
                            # it, unreachable once the boundary is gone —
                            # so the recommit writes a private page
                            dropped = self._trie.drop_pages(r.committed, bp)
                            if dropped:
                                self._pool.decref(dropped)
                        elif bref > 2:
                            # another row is reading the boundary page —
                            # fall back to the aligned prefix, or skip
                            # the rung if too short
                            keep = bp * ps
                            usable = keep >= self.min_shared_prefix
                        if usable:
                            self._unmap_row(row, from_page=-(-keep // ps))
                            if not self._ensure_pages(row, extent(n, 0),
                                                      exclude={row}):
                                # pool exhausted mid-trim: the tail pages
                                # are already gone, so reset the row to
                                # fresh rather than leave its committed
                                # block partially unmapped
                                self._trie.remove(r.committed, row)
                                r.committed, r.retained = [], False
                                self._mark("free", row)
                                self._unmap_row(row)
                                usable = False
                    if usable:
                        self._trie.remove(r.committed, row)
                        r.committed = []
                        r.retained = False             # hold transfers
                        self._mark("trim", row, keep=keep)
                        self._admit(row, rid, ctx, candidates, t0,
                                    shared_depth=keep, commit_from=keep,
                                    suffix_in_burst=False, rung=3)
                        return True
        row = None
        fresh = [i for i, r in enumerate(self._rows)
                 if not r.active and not r.retained and not r.committed]
        if fresh:
            row = fresh[0]
            self._mark("retain", row)
        else:
            stealable = [i for i, r in enumerate(self._rows)
                         if not r.active and r.retained
                         and r.pending_commit == 0]
            if stealable:
                row = min(stealable, key=lambda i: self._rows[i].last_used)
                r = self._rows[row]
                self._trie.remove(r.committed, row)
                r.committed, r.retained = [], False
                self._mark("free", row)                # drop hold -> reset
                self._mark("retain", row)
                if self.paged:
                    self._unmap_row(row)
        if row is None:
            return False
        if not self.paged:
            self._admit(row, rid, ctx, candidates, t0,
                        shared_depth=0, commit_from=0, suffix_in_burst=False,
                        rung=4)
            return True
        # paged rung 4: adopt any radix-indexed prefix pages (shared KV
        # that survives row steals), then allocate private pages for the
        # remainder. Shared pages take their reference *before* the
        # private allocation so the allocator's eviction sweep cannot
        # reclaim them out from under the admission.
        depth = 0
        adopted: List[int] = []
        if self.share_prefix:
            covered, pages = self._trie.match_pages(ctx)
            if covered >= self.min_shared_prefix:
                self._pool.incref(pages)
                adopted, depth = list(pages), covered
        need = -(-min(extent(n, 0), self.capacity) // self.page_size)
        priv = self._alloc_pages(need - len(adopted), exclude={row})
        if priv is None and adopted:
            # not enough private pages alongside the shared prefix: give
            # the prefix back and retry as a plain admission
            self._pool.decref(adopted)
            adopted, depth = [], 0
            priv = self._alloc_pages(need, exclude={row})
        if priv is None:
            # the pool cannot host this request at all right now — undo
            # this rung's reference mark and leave it queued
            self._pending["retain"][row] -= 1
            return False
        self._tables[row, :len(adopted)] = adopted
        self._tables[row, len(adopted):need] = priv
        self._tables_dirty = True
        if depth:
            self._mark("adopt", row, keep=depth)
            self._c_cross_row_hits.inc()
            self._c_cross_row_tokens.inc(depth)
        self._admit(row, rid, ctx, candidates, t0,
                    shared_depth=depth, commit_from=depth,
                    suffix_in_burst=False, rung=4)
        return True

    # -- the batched step ----------------------------------------------------

    @staticmethod
    def _committer(r: _Row) -> Optional[_Slot]:
        """The row's active slot with prefill still to dispatch (at most
        one: only idle-row admissions commit)."""
        for s in r.active:
            if s.prefill is not None and s.prefill.remaining > 0:
                return s
        return None

    def _next_unit(self, r: _Row) -> Optional[Tuple[_Slot, _Unit]]:
        """Round-robin the row's active requests' burst queues. Only called
        on rows with no commits in flight (``pending_commit == 0``): while
        a context is still committing, ``_build_wave`` schedules prefill
        chunks instead, so a sharer admitted onto a mid-commit block waits
        there rather than bursting against a half-written context."""
        if not r.active:
            return None
        for off in range(len(r.active)):
            slot = r.active[(r.rr + off) % len(r.active)]
            if not slot.units:
                continue
            r.rr = (r.rr + off + 1) % len(r.active)
            return slot, slot.units.popleft()
        return None

    def _build_wave(self) -> Optional[Tuple[List[Tuple[int, _Slot, _Unit]],
                                            int]]:
        """Pack one batched step: decode bursts first (they alone pick the
        wave's bucket unless ``monolithic_prefill``), then cut resumable
        prefill chunks into the remaining rows under the token budget.
        Advances prefill cursors and pops burst units — callers must
        dispatch exactly what is returned. None when nothing can run."""
        work: List[Tuple[int, _Slot, _Unit]] = []
        pending: List[Tuple[int, _Slot]] = []
        for i, r in enumerate(self._rows):
            if r.pending_commit > 0:
                c = self._committer(r)
                if c is not None:
                    pending.append((i, c))
                continue                   # bursts wait for the block
            picked = self._next_unit(r)
            if picked is not None:
                work.append((i, picked[0], picked[1]))
        if not work and not pending:
            return None
        if pending:
            # rotate which row gets budget first, so a tight budget
            # round-robins across competing prefills instead of starving
            # the highest-numbered rows
            self._prefill_rr += 1
            off = self._prefill_rr % len(pending)
            pending = pending[off:] + pending[:off]
        if self.monolithic_prefill:
            # pre-budget behaviour: prefill chunks are largest-bucket
            # sized and inflate the whole wave's jit shape
            budget = None
            need = max([len(u.tokens) for _, _, u in work]
                       + [min(c.prefill.remaining, self.buckets[-1])
                          for _, c in pending])
        else:
            budget = self.prefill_budget
            if work:
                need = max(len(u.tokens) for _, _, u in work)
            else:
                # prefill-only wave: no burst to keep small, so every
                # pending row fills a chunk — the budget caps the bucket
                # (and so the chunk), not the wave's total tokens, else a
                # drained pipeline would commit slower than monolithic
                # for no latency benefit
                need = min(max(c.prefill.remaining for _, c in pending),
                           budget)
        s = next(b for b in self.buckets
                 if b >= min(need, self.buckets[-1]))
        left = s * len(pending) if (budget is None or not work) else budget
        cap0 = left
        used = demand = 0
        starved = False
        for i, c in pending:
            pf = c.prefill
            demand += pf.remaining
            take = min(pf.remaining, s, left)
            if take <= 0:
                starved = True
                continue
            work.append((i, c, _Unit(
                tokens=np.asarray(pf.tokens[pf.done:pf.done + take],
                                  np.int32),
                positions=np.arange(pf.start + pf.done,
                                    pf.start + pf.done + take,
                                    dtype=np.int32),
                is_sum=np.zeros(take, bool),
                seg=np.full(take, -1, np.int32), commit=True)))
            pf.done += take
            left -= take
            used += take
            if pf.remaining == 0:
                self._rows[i].pending_commit -= 1
        if pending:
            self._c_budget_used.inc(used)
            self._c_kv_committed.inc(int(used * self._kv_token_bytes))
            if budget is not None:
                self._c_budget_avail.inc(min(cap0, demand))
                if starved:
                    self._c_starved.inc()
        return work, s

    def _finish(self, slot: _Slot, now: float) -> None:
        """Harvested the request's last [SUM]: record the result and drop
        its cache reference. The row's context block outlives the request
        when sharing is on — the last departing reader flips the row to
        ``retained`` (keeping the reference as the retention hold) instead
        of freeing, so the block stays matchable in the trie until stolen
        or trimmed.

        Accounting: ``logical_tokens`` is what k standalone prefills would
        compute (k·n context re-encodes + the slate); ``computed`` is what
        this scheduler actually fed (committed prefill + burst tokens,
        suffix copies included); ``cached_tokens`` is the difference — the
        prompt tokens served from cache, whether by own-context reuse
        across the k candidates or by a cross-request shared prefix."""
        r = self._rows[slot.row]
        n, k = slot.n_context, slot.n_candidates
        computed = slot.prefill_tokens + slot.burst_tokens
        # a prewarm (k == 0) has no logical k-prefill equivalent: its
        # logical cost is exactly what it computed (cached_tokens = 0)
        logical_tokens = (k * n + slot.slate_tokens) if k else computed
        if k:
            self._c_ctx_done.inc(n)
            self._c_shared_done.inc(slot.shared_prefix_tokens)
        self._results[slot.rid] = RequestResult(
            rid=slot.rid, scores=list(slot.scores),
            latency_s=now - slot.submit_t,
            queue_s=slot.admit_t - slot.submit_t,
            service_s=now - slot.admit_t,
            context_tokens=n, prefill_tokens=slot.prefill_tokens,
            burst_tokens=slot.burst_tokens,
            shared_prefix_tokens=slot.shared_prefix_tokens,
            cached_tokens=logical_tokens - computed,
            logical_tokens=logical_tokens,
            params_versions=sorted(slot.versions,
                                   key=lambda v: (v is not None, v)))
        if self.tracer.enabled:
            self.tracer.instant("finish", rid=slot.rid, row=slot.row)
        r.active.remove(slot)
        if self.share_prefix:
            if r.active:
                self._mark("free", slot.row)           # drop reader ref
            elif r.stale:                              # pre-swap KV: drop it
                self._trie.remove(r.committed, slot.row)
                r.committed, r.retained, r.stale = [], False, False
                self._mark("free", slot.row)
                if self.paged:
                    self._unmap_row(slot.row)
            else:
                r.retained = True                      # ref becomes the hold
                if self.paged:
                    # index the block's full pages so the prefix outlives
                    # even a steal of this row (rung-4 radix map-in)
                    self._publish_pages(slot.row)
        else:
            if r.committed and not r.active:
                self._trie.remove(r.committed, slot.row)
                r.committed = []
            self._mark("free", slot.row)
            if self.paged and not r.active:
                self._unmap_row(slot.row)

    def _harvest_one(self) -> bool:
        """Sync the oldest in-flight step's scores (the only host<->device
        sync on the hot path), record them, retire finished requests and
        flush their reference drops. Returns False when nothing was in
        flight."""
        if not self._inflight:
            return False
        with self.tracer.span("harvest"):
            self._harvest_body()
        return True

    def _harvest_body(self) -> None:
        p, work, _ = self._inflight.popleft()
        p = np.asarray(p)
        now = monotonic()
        for row, slot, u in work:
            for j, off in u.score_at:
                slot.scores[j] = float(p[row, off])
            # a slot finishes on the harvest that fills its last score —
            # never on queue emptiness, which overlap races (units are
            # popped at dispatch, one step ahead of this harvest)
            if u.score_at and all(sc is not None for sc in slot.scores):
                self._finish(slot, now)
            elif (slot.n_candidates == 0 and u.commit
                  and slot.prefill.remaining == 0
                  and slot in self._rows[row].active):
                # a prewarm has no [SUM] to score: it finishes when its
                # last committed chunk has been dispatched and a chunk
                # harvested after that (device order makes the block
                # fully written before any adopter reads it)
                self._finish(slot, now)
        self._flush_row_ops()          # departing readers' refs drop once

    def _watchdog_scan(self, scheduled: set) -> None:
        """Flag rows holding backlog that has not dispatched for more than
        ``watchdog_steps`` steps — a stall (gating bug, corrupted row
        state) surfaced as a counter instead of a silent hang."""
        for i, r in enumerate(self._rows):
            backlog = any(s.units or (s.prefill is not None
                                      and s.prefill.remaining > 0)
                          for s in r.active)
            if not backlog or i in scheduled:
                r.last_progress = self.n_steps
            elif (self.n_steps - r.last_progress > self.watchdog_steps
                  and i not in self._watchdog_rows):
                self._watchdog_rows.add(i)
                self._c_watchdog_fired.inc()
                self.tracer.instant("watchdog", row=i)

    def step(self) -> bool:
        """Admit queued requests (strict FIFO, as many as place), dispatch
        one batched decode step over every busy row's next work unit, and
        harvest scores — one step behind the dispatch when ``overlap`` is
        on, immediately otherwise. Returns False when queue, rows and the
        in-flight pipeline are all drained (nothing happened).

        With a tracer attached each step emits one ``scheduler.step``
        span with nested ``admit`` / ``build_wave`` / per-unit
        ``prefill_chunk``/``burst`` / ``dispatch`` / ``harvest`` child
        spans; the tracer touches only host clocks + a ring append, so
        the step's device-sync profile is identical traced or not
        (asserted by tests/test_obs.py)."""
        sp = self.tracer.span("scheduler.step")
        with sp:
            return self._step_impl(sp)

    def _step_impl(self, sp) -> bool:
        if self._param_source is not None and not self._in_swap:
            # dedicated counter: n_steps stalls on idle calls, which would
            # either re-poll every call or never poll again. Polling is
            # suppressed inside a drain-before-swap (its steps run under
            # the old weights by construction).
            if self._poll_tick % self._poll_every == 0:
                update = self._param_source()
                if update is not None:
                    self.update_params(update[1], update[0])
            self._poll_tick += 1
        # un-lag the pipeline when it pays: harvest an in-flight step
        # before admission if (a) it's free — the device already finished
        # it — or (b) requests are queued and the step is known (at
        # dispatch time) to finish a request, so harvesting releases a row
        # this wave's admission can use. (b) trades one step of overlap
        # for a row exactly when rows are the bottleneck; under light load
        # the pipeline stays a full step ahead.
        while self._inflight and (
                self._inflight[0][0].is_ready()
                or (self._queue and self._inflight[0][2])):
            self._harvest_one()
        if self._queue and not self._in_swap:   # drains admit nothing
            with self.tracer.span("admit"):
                while self._queue:
                    rid, ctx, cands, t0 = self._queue[0]
                    if not self._try_place(rid, ctx, cands, t0):
                        break
                    self._queue.popleft()
        self._flush_row_ops()          # steals/trims land before the decode

        with self.tracer.span("build_wave"):
            wave = self._build_wave()
        if wave is None:
            return self._harvest_one()     # drain the pipeline tail
        work, s = wave
        tr = self.tracer

        tokens = np.zeros((self.n_slots, s), np.int32)
        positions = np.zeros((self.n_slots, s), np.int32)
        is_sum = np.zeros((self.n_slots, s), bool)
        valid = np.zeros((self.n_slots, s), bool)
        seg = np.full((self.n_slots, s), -1, np.int32)
        commit = np.zeros((self.n_slots,), bool)
        for row, slot, u in work:
            # the version whose weights compute this unit — what
            # RequestResult.params_versions reports (a one-element list
            # under drain_before_swap, the purity assertion in tests)
            slot.versions.add(self.params_version)
            with tr.span("prefill_chunk" if u.commit else "burst",
                         row=row, rid=slot.rid,
                         tokens=int(len(u.tokens))) if tr.enabled \
                    else _NULLCTX:
                m = len(u.tokens)
                tokens[row, :m] = u.tokens
                positions[row, :m] = u.positions
                is_sum[row, :m] = u.is_sum
                seg[row, :m] = u.seg
                valid[row, :m] = True
                commit[row] = u.commit

        # async dispatch: p stays on device until this step is harvested
        ann = (obs_profile.annotate(f"decode.b{int(s)}")
               if tr.jax_annotate else _NULLCTX)
        with tr.span("dispatch", bucket=int(s), rows=len(work)), ann:
            p, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(positions), jnp.asarray(is_sum),
                jnp.asarray(valid), jnp.asarray(commit), jnp.asarray(seg))
        self._c_steps.inc()
        self._c_bucket[int(s)].inc()
        if any(u.commit for _, _, u in work):
            self._c_prefill_steps.inc()
        qd = len(self._queue)
        self._h_qdepth.observe(qd)
        if tr.enabled:
            tr.counter("queue_depth", qd)
            sp.set(bucket=int(s), rows=len(work))
        scheduled = set()
        for row, _, _u in work:
            self._rows[row].last_used = self.n_steps
            scheduled.add(row)
        self._watchdog_scan(scheduled)
        # decidable at dispatch (units pop at dispatch): does this step
        # carry some request's final [SUM]? drives the queued-harvest rule
        finishes = any(u.score_at and not slot.units
                       and (slot.prefill is None
                            or slot.prefill.remaining == 0)
                       for _, slot, u in work)
        self._inflight.append((p, work, finishes))
        if not self.overlap or len(self._inflight) > 1:
            self._harvest_one()
        return True

    def run(self) -> Dict[int, RequestResult]:
        """Drain queue, rows and the in-flight pipeline; returns results
        for every request scored since the last ``run``. Retained context
        blocks survive across ``run`` calls, so later traffic still shares
        them. A request left unfinished after the drain (a stalled row —
        scheduler bug or corrupted state) fires the watchdog instead of
        hanging; its rid is recorded in ``telemetry()``."""
        while self.step():
            pass
        stuck = sorted([s.rid for r in self._rows for s in r.active]
                       + [q[0] for q in self._queue])
        if stuck:
            self._c_watchdog_fired.inc()
            self.watchdog_stuck_rids = stuck
            self.tracer.instant("watchdog", stuck_rids=stuck)
        out, self._results = self._results, {}
        return out


__all__ = ["ServeScheduler", "RequestResult", "TELEMETRY_SCHEMA"]
