"""Decode KV caches: full-length and ring-buffer (windowed), GQA and MLA,
with refcounted context blocks for cross-request prefix sharing, in two
layouts — contiguous per-row capacity, or a global page pool addressed
through per-row page tables (``init_lm_cache(page_size=...)``; allocation
state lives host-side in ``repro.serve.pages.PagePool``, the prefix index
in ``repro.data.requests.RadixTree``; see docs/serving.md).

Layout: per-layer tensors are stacked on a leading L dim so the decode step
can ``lax.scan`` over (layer params, layer cache) — HLO stays O(1) in depth.
Slot bookkeeping (``pos``, ``cursor``, ``ref``) is shared across layers
(every layer writes the same slots).

* GQA cache: k/v per head — ``k (L, B, cap, Hk, dk)``, ``v (L, B, cap, Hk, dv)``.
* MLA cache: the **latent** per token — ``ckv (L, B, cap, r_kv)``,
  ``kpe (L, B, cap, d_rope)``. Caching the latent instead of expanded heads
  is what makes deepseek-v2 decode storable (0.58 KB/token/layer instead of
  ~82 KB); attention runs in absorbed form (see repro.serve.engine).

Ring mode (``ring=True``): capacity is a constant independent of the logical
position — the windowed causal attention the paper trains with guarantees no
query ever needs a key older than ``window``, so ``long_500k`` decode is
O(window) in both memory and FLOPs. ``ring`` is static (baked into the
jitted step), not a traced value.

Refcounted context blocks (``ref (B,)``): a row's committed prefix (the
tokens at slots ``0..cursor-1``) is a *context block* that more than one
request may score bursts against — cross-request prefix sharing, see
``repro.serve.scheduler`` and docs/serving.md. ``retain_slots`` takes a
reference on a row, ``free_slots`` drops one; a row's ``pos``/``cursor``
reset only when its last reference is dropped. The invariant the scheduler
maintains is ``ref[row] == (#active requests on the row) + (1 if the row's
context is retained for future reuse else 0)`` — so a finished request's
context survives eviction exactly as long as something (an in-flight
sharer, or the retention policy) still holds a reference.

The cache is donated through every jitted op that rewrites it, and the
scheduler always rebinds from the op's return — a single linear chain of
cache values. That chain is also what makes one-step-ahead overlap
dispatch safe: step t+1 consumes step t's output cache on device, so step
ordering is a data dependency, not a host-side sync.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.models.transformer import ModelConfig

#: A decode cache is a flat dict pytree. Per-layer KV tensors are stacked
#: on a leading layer dim (``k``/``v`` for GQA, ``ckv``/``kpe`` for MLA);
#: three per-row bookkeeping arrays are shared by every layer:
#:
#: * ``pos (B, cap) int32``    — the logical position held by each physical
#:   slot; ``-1`` marks an empty/unreachable slot (never attendable). This
#:   is the single source of truth for attendability — KV bytes are never
#:   cleared, they become unreachable via ``pos = -1`` and are overwritten
#:   by the next occupant.
#: * ``cursor (B,) int32``     — the next physical slot a committed write
#:   lands in (equivalently: the row's committed context length when the
#:   cache is not a ring).
#: * ``ref (B,) int32``        — reference count on the row's committed
#:   context block (see module docstring).
Cache = Dict[str, Any]


def init_lm_cache(cfg: ModelConfig, batch: int, capacity: int,
                  *, dtype=jnp.bfloat16, kv_dtype: str = None,
                  page_size: int = None, n_pages: int = None) -> Cache:
    """Allocate a decode cache.

    Contiguous layout (``page_size=None``): KV tensors carry a per-row
    capacity axis — ``(L, B, cap, ...)`` — and a row's committed context
    lives at physical slots ``0..cursor-1`` of its own row.

    Paged layout (``page_size`` set): KV tensors carry one **global** slot
    axis of ``n_pages * page_size`` physical slots shared by every row —
    ``(L, n_pages * page_size, ...)`` — and each row addresses it through
    ``page_table (B, max_pages) int32`` of pool page ids (-1 = unmapped,
    ``max_pages = capacity // page_size``). Logical slot ``j`` of a row
    lives at physical slot ``page_table[row, j // ps] * ps + j % ps`` (see
    ``physical_slots``). ``pos``/``cursor``/``ref`` keep their contiguous
    meaning — they are logical-per-row either way — so the scheduler's
    bookkeeping ops are layout-agnostic. Allocation/refcounting of the
    global pages is host-side state (``repro.serve.pages.PagePool``); the
    device only ever sees the page tables.

    Quantized layout (``kv_dtype="int8"``): KV tensors are stored as int8
    codes plus a small fp32 **scale sidecar** on the same slot axis —
    ``k_scale/v_scale (L, ..., cap, Hk)`` for GQA (one symmetric absmax
    scale per (token slot, kv head), the group RoPE rotates within),
    ``ckv_scale/kpe_scale (L, ..., cap)`` for MLA (the latent has no head
    axis — one scale per token slot per stream). Keeping the scales
    slot-resident rather than literally per-page means incremental
    chunked writes never requantize a neighbour token, and — because the
    sidecar rides the same global slot axis as the codes — a page *is*
    self-describing: adoption, steals and LRU eviction move codes and
    scales together with zero extra bookkeeping (the scale-invariance
    property tests/test_kv_quant.py pins). ``dtype`` is ignored for the
    KV tensors when ``kv_dtype`` is set; dequantization happens at read
    time (dense path) or inside the decode kernel (pallas path).
    """
    l = cfg.n_layers
    assert kv_dtype in (None, "int8"), f"unsupported kv_dtype {kv_dtype!r}"
    quant = kv_dtype == "int8"
    kv_store = jnp.int8 if quant else dtype
    if page_size is not None:
        assert capacity % page_size == 0, (
            f"paged capacity {capacity} must be a multiple of "
            f"page_size {page_size}")
        assert n_pages is not None and n_pages > 0
        kv_rows, kv_cap = 1, n_pages * page_size     # global slot axis
    else:
        kv_rows, kv_cap = batch, capacity
    if cfg.attn_type == "mla":
        tensors = {
            "ckv": jnp.zeros((l, kv_rows, kv_cap, cfg.kv_lora_rank),
                             kv_store),
            "kpe": jnp.zeros((l, kv_rows, kv_cap, cfg.qk_rope_dim),
                             kv_store),
        }
        if quant:
            tensors["ckv_scale"] = jnp.zeros((l, kv_rows, kv_cap),
                                             jnp.float32)
            tensors["kpe_scale"] = jnp.zeros((l, kv_rows, kv_cap),
                                             jnp.float32)
    else:
        hk, dk = cfg.n_kv_heads, cfg.hd
        tensors = {
            "k": jnp.zeros((l, kv_rows, kv_cap, hk, dk), kv_store),
            "v": jnp.zeros((l, kv_rows, kv_cap, hk, dk), kv_store),
        }
        if quant:
            tensors["k_scale"] = jnp.zeros((l, kv_rows, kv_cap, hk),
                                           jnp.float32)
            tensors["v_scale"] = jnp.zeros((l, kv_rows, kv_cap, hk),
                                           jnp.float32)
    if page_size is not None:
        tensors = {k: v[:, 0] for k, v in tensors.items()}   # (L, n_tot, ...)
        tensors["page_table"] = jnp.full((batch, capacity // page_size), -1,
                                         jnp.int32)
    tensors["pos"] = jnp.full((batch, capacity), -1, jnp.int32)
    tensors["cursor"] = jnp.zeros((batch,), jnp.int32)
    tensors["ref"] = jnp.zeros((batch,), jnp.int32)
    return tensors


def is_paged(cache: Cache) -> bool:
    """True when the cache uses the global page-pool layout."""
    return "page_table" in cache


#: Bookkeeping keys present in every cache layout; everything else in the
#: dict is a per-layer KV tensor (codes or scale sidecar).
BOOK_KEYS = ("pos", "cursor", "ref", "page_table")


def kv_keys(cache: Cache):
    """The per-layer KV tensor keys of ``cache`` (codes + scale sidecars),
    in a deterministic order — the order the decode step's scan carry
    threads them."""
    return tuple(k for k in ("k", "v", "k_scale", "v_scale",
                             "ckv", "kpe", "ckv_scale", "kpe_scale")
                 if k in cache)


def is_quantized(cache: Cache) -> bool:
    """True when KV is stored as int8 codes + fp32 scale sidecar."""
    return "k_scale" in cache or "ckv_scale" in cache


def kv_cache_bytes(cache: Cache) -> int:
    """Total bytes of the KV tensors (codes + scale sidecar; bookkeeping
    arrays excluded) — works on concrete caches and ``cache_shape`` specs."""
    total = 0
    for key in kv_keys(cache):
        t = cache[key]
        n = 1
        for d in t.shape:
            n *= d
        total += n * jnp.dtype(t.dtype).itemsize
    return int(total)


def kv_token_bytes(cache: Cache) -> float:
    """KV bytes per token slot, summed over layers (codes + scales): the
    per-token cost a pool budget buys — ``serve_bench`` sizes its
    equal-byte quantized-vs-bf16 pools with this."""
    ref = cache["ckv"] if "ckv" in cache else cache["k"]
    n_slots = ref.shape[1]          # global slot axis (paged) or B... cap
    if not is_paged(cache):
        n_slots = ref.shape[1] * ref.shape[2]
    return kv_cache_bytes(cache) / n_slots


def page_size_of(cache: Cache) -> int:
    """Static page size of a paged cache (tokens per page)."""
    cap = cache["pos"].shape[1]
    return cap // cache["page_table"].shape[1]


def physical_slots(cache: Cache):
    """Logical→physical slot map of a paged cache: (B, cap) int32 into the
    global KV slot axis, -1 where the logical slot's page is unmapped.

    This is the gather map both the dense decode path and the Pallas
    decode kernel read KV through: gathering the KV pool with (the
    clamped) map yields the same per-row ``(B, cap, ...)`` view the
    contiguous layout stores directly, and ``pos = -1`` masking makes the
    unmapped entries unattendable exactly like empty contiguous slots.
    """
    pt = cache["page_table"]
    ps = page_size_of(cache)
    batch = pt.shape[0]
    base = pt[:, :, None] * ps + jnp.arange(ps, dtype=jnp.int32)[None, None]
    flat = jnp.where(pt[:, :, None] < 0, -1, base)
    return flat.reshape(batch, -1)


def cache_shape(cfg: ModelConfig, batch: int, capacity: int,
                *, dtype=jnp.bfloat16, kv_dtype: str = None,
                page_size: int = None, n_pages: int = None) -> Dict[str, tuple]:
    """Shapes/dtypes without allocation (dry-run input specs)."""
    import jax
    return jax.eval_shape(lambda: init_lm_cache(cfg, batch, capacity,
                                                dtype=dtype,
                                                kv_dtype=kv_dtype,
                                                page_size=page_size,
                                                n_pages=n_pages))


def slot_indices(cache: Cache, s_new: int, *, ring: bool):
    """Logical slots the next ``s_new`` tokens occupy: (B, s_new) int32.

    Non-ring indices are *not* wrapped or clamped: a commit that would run
    past ``capacity`` must be rejected at admission time (the scheduler
    raises with the rid and lengths named — see ``ServeScheduler.submit``)
    rather than relying on out-of-bounds scatter writes being dropped.
    """
    cap = cache["pos"].shape[1]
    idx = cache["cursor"][:, None] + jnp.arange(s_new, dtype=jnp.int32)[None]
    return idx % cap if ring else idx


def retain_slots(cache: Cache, counts) -> Cache:
    """Take references on rows: ``counts`` is (B,) bool (one reference per
    True row) or int32 (that many references per row — several requests
    admitted onto one row in the same scheduling wave).

    Each reference is one reason the row's committed context must stay
    readable: an active request scoring bursts against it, or the
    scheduler retaining a finished request's context for future prefix
    reuse. Purely int32 bookkeeping — no KV traffic.
    """
    return dict(cache, ref=cache["ref"] + counts.astype(jnp.int32))


def free_slots(cache: Cache, counts) -> Cache:
    """Drop references on rows — ``counts`` is (B,) bool or int32, as in
    ``retain_slots`` — and reset the touched rows whose count reaches
    zero.

    With prefix sharing a row's committed context may be in use by several
    requests (and/or retained for reuse), so freeing **decrements** instead
    of unconditionally resetting: only when the last reference is dropped
    does the row's position buffer go to -1 (nothing attendable) and its
    cursor to 0. KV bytes are left in place even then — ``pos = -1``
    already makes them unreachable and the next occupant overwrites them —
    so eviction/admission stays O(B·cap) int32 work, no KV traffic.

    A ``free_slots`` on a zero-ref row (the pre-sharing idiom: "reset this
    row now") still resets it: the count saturates at zero rather than
    going negative. Used by the continuous-batching scheduler when a
    request completes, when a retained context is stolen for a new
    admission, and on (re-)admission of rows the legacy way.
    """
    counts = counts.astype(jnp.int32)
    ref = cache["ref"] - counts
    reset = (counts > 0) & (ref <= 0)
    pos = jnp.where(reset[:, None], -1, cache["pos"])
    cursor = jnp.where(reset, 0, cache["cursor"])
    return dict(cache, pos=pos, cursor=cursor, ref=jnp.maximum(ref, 0))


def trim_slots(cache: Cache, mask, keep, *, ring: bool = False) -> Cache:
    """Roll the rows selected by ``mask`` (B,) bool back to their first
    ``keep`` (B,) int32 committed tokens.

    Used when a retained context is reused by a request that shares only a
    *proper* prefix: slots at logical index >= ``keep`` become
    unreachable (``pos = -1``) and the cursor drops to ``keep``, so the
    next committed write extends the shared prefix. Only valid on rows
    with no active readers (the scheduler trims retained rows only) and on
    non-ring caches, where slot index == committed order — on a ring the
    slot holding committed token ``j`` depends on how often the row
    wrapped, so "first ``keep`` tokens" is not an index range and a trim
    would corrupt attendability. ``ring`` is the static flag the caller
    built its cache with; passing ``ring=True`` raises.
    """
    if ring:
        raise ValueError(
            "trim_slots on a ring cache: slot index != committed order, "
            "trimming would corrupt attendability (non-ring caches only)")
    cap = cache["pos"].shape[1]
    idx = jnp.arange(cap, dtype=jnp.int32)[None]
    drop = mask[:, None] & (idx >= keep[:, None])
    pos = jnp.where(drop, -1, cache["pos"])
    cursor = jnp.where(mask, jnp.minimum(cache["cursor"], keep),
                       cache["cursor"])
    return dict(cache, pos=pos, cursor=cursor)


def adopt_slots(cache: Cache, mask, length) -> Cache:
    """Install an already-populated shared prefix on the rows selected by
    ``mask`` (B,) bool: logical slots ``0..length-1`` become attendable at
    positions ``0..length-1`` and the cursor moves to ``length`` (B,)
    int32, *without writing any KV bytes*.

    Paged-cache admission uses this after mapping radix-indexed pages into
    a row's page table: the pages already hold the prefix's KV (committed
    context positions are always ``0..n-1``), so adoption is pure int32
    bookkeeping — the page-table gather makes the bytes reachable and
    ``adopt_slots`` makes them attendable. Slots at and beyond ``length``
    are reset to -1 (the row is assumed freshly reset or stolen).
    Non-ring only, like ``trim_slots``.
    """
    cap = cache["pos"].shape[1]
    idx = jnp.arange(cap, dtype=jnp.int32)[None]
    take = mask[:, None] & (idx < length[:, None])
    pos = jnp.where(take, idx, cache["pos"])
    pos = jnp.where(mask[:, None] & (idx >= length[:, None]), -1, pos)
    cursor = jnp.where(mask, length, cache["cursor"])
    return dict(cache, pos=pos, cursor=cursor)


__all__ = ["Cache", "init_lm_cache", "cache_shape", "slot_indices",
           "retain_slots", "free_slots", "trim_slots", "adopt_slots",
           "is_paged", "page_size_of", "physical_slots",
           "is_quantized", "kv_keys", "kv_cache_bytes", "kv_token_bytes",
           "BOOK_KEYS"]
