"""Decode KV caches: full-length and ring-buffer (windowed), GQA and MLA.

Layout: per-layer tensors are stacked on a leading L dim so the decode step
can ``lax.scan`` over (layer params, layer cache) — HLO stays O(1) in depth.
Slot bookkeeping (``pos``, ``cursor``) is shared across layers (every layer
writes the same slots).

* GQA cache: k/v per head — ``k (L, B, cap, Hk, dk)``, ``v (L, B, cap, Hk, dv)``.
* MLA cache: the **latent** per token — ``ckv (L, B, cap, r_kv)``,
  ``kpe (L, B, cap, d_rope)``. Caching the latent instead of expanded heads
  is what makes deepseek-v2 decode storable (0.58 KB/token/layer instead of
  ~82 KB); attention runs in absorbed form (see repro.serve.engine).

Ring mode (``ring=True``): capacity is a constant independent of the logical
position — the windowed causal attention the paper trains with guarantees no
query ever needs a key older than ``window``, so ``long_500k`` decode is
O(window) in both memory and FLOPs. ``ring`` is static (baked into the
jitted step), not a traced value.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.models.transformer import ModelConfig

Cache = Dict[str, Any]


def init_lm_cache(cfg: ModelConfig, batch: int, capacity: int,
                  *, dtype=jnp.bfloat16) -> Cache:
    l = cfg.n_layers
    if cfg.attn_type == "mla":
        tensors = {
            "ckv": jnp.zeros((l, batch, capacity, cfg.kv_lora_rank), dtype),
            "kpe": jnp.zeros((l, batch, capacity, cfg.qk_rope_dim), dtype),
        }
    else:
        hk, dk = cfg.n_kv_heads, cfg.hd
        tensors = {
            "k": jnp.zeros((l, batch, capacity, hk, dk), dtype),
            "v": jnp.zeros((l, batch, capacity, hk, dk), dtype),
        }
    tensors["pos"] = jnp.full((batch, capacity), -1, jnp.int32)
    tensors["cursor"] = jnp.zeros((batch,), jnp.int32)
    return tensors


def cache_shape(cfg: ModelConfig, batch: int, capacity: int,
                *, dtype=jnp.bfloat16) -> Dict[str, tuple]:
    """Shapes/dtypes without allocation (dry-run input specs)."""
    import jax
    return jax.eval_shape(lambda: init_lm_cache(cfg, batch, capacity,
                                                dtype=dtype))


def slot_indices(cache: Cache, s_new: int, *, ring: bool):
    """Slots the next ``s_new`` tokens occupy: (B, s_new) int32."""
    cap = cache["pos"].shape[1]
    idx = cache["cursor"][:, None] + jnp.arange(s_new, dtype=jnp.int32)[None]
    return idx % cap if ring else idx


def free_slots(cache: Cache, mask) -> Cache:
    """Reset the batch rows selected by ``mask`` (B,) bool: position buffer
    to -1 (nothing attendable), cursor to 0. KV bytes are left in place —
    pos -1 already makes them unreachable and the next occupant overwrites
    them — so eviction/admission is O(B·cap) int32 work, no KV traffic.
    Used by the continuous-batching scheduler when a request completes and
    its slot is re-admitted."""
    pos = jnp.where(mask[:, None], -1, cache["pos"])
    cursor = jnp.where(mask, 0, cache["cursor"])
    return dict(cache, pos=pos, cursor=cursor)


__all__ = ["Cache", "init_lm_cache", "cache_shape", "slot_indices",
           "free_slots"]
