"""Hot-user prefix prewarming: stream -> serve cache priming.

The paged serving cache (`repro.serve.scheduler.ServeScheduler` with
``paged=True``) keeps committed context prefixes alive in a radix page
index even after their cache row is reused, so *any* later request that
shares the prefix maps the pages back in with zero recompute. That only
pays off if the prefix is resident when the request arrives. This module
closes the loop from the streaming side: the stream pipeline already
holds every active user's recent interaction history
(`repro.stream.incremental.IncrementalDTI` per-user state), which is
exactly the context the serving fleet will be asked to score next — so
between training ticks it *prewarms* the scheduler with the histories of
the currently hottest users.

Prewarms are ordinary candidate-less requests (``ServeScheduler.
prewarm``): they ride the admission ladder and the prefill token budget,
never inflating a scoring wave's jit shape, and publish their full pages
into the radix index on completion. ``tick(swapped=True)`` skips a tick:
a weight hot-swap just invalidated every cached prefix, and the swap
tick itself is the worst moment to add prefill load — warming resumes on
the next quiet tick, repopulating the index under the new weights.

Hotness is an exponentially-decayed event count, so a user's priority
follows their recent activity rather than lifetime volume; users are
re-warmed only after new events arrive (``_warmed_at`` tracks the
history length last published — re-enqueueing an unchanged prefix is
free at admission, but skipping it saves queue churn).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from repro.stream.incremental import IncrementalDTI


class PrefixPrewarmer:
    """Publishes hot users' history prefixes into a serving scheduler.

    ``dti`` supplies per-user histories (its buffered suffix — the same
    items future prompts reference); ``scheduler`` is anything with a
    ``prewarm(context) -> Optional[rid]`` method. ``top_k`` users are
    warmed per tick, ranked by decayed event count; ``min_events``
    gates users too cold to be worth a row.
    """

    def __init__(self, dti: IncrementalDTI, scheduler, *, top_k: int = 4,
                 min_events: float = 2.0, decay: float = 0.5):
        assert top_k >= 1 and 0.0 < decay <= 1.0
        self.dti = dti
        self.scheduler = scheduler
        self.top_k = int(top_k)
        self.min_events = float(min_events)
        self.decay = float(decay)
        self._heat: Dict[int, float] = {}
        self._warmed_at: Dict[int, int] = {}
        self.warmed = 0                 # prewarm requests actually enqueued
        self.skipped_swap_ticks = 0

    def observe(self, events: Iterable[Dict]) -> None:
        """Credit each event's user with one (decaying) unit of heat.
        Call with the same event batches the pipeline feeds the DTI."""
        for ev in events:
            u = int(ev["user"])
            self._heat[u] = self._heat.get(u, 0.0) + 1.0

    def tick(self, *, swapped: bool = False) -> List[int]:
        """Warm the hottest users' prefixes; returns the enqueued rids.

        ``swapped=True`` marks a tick on which a weight hot-swap landed:
        nothing is warmed (the index was just flushed and the new
        weights' first scoring wave should not queue behind prewarm
        prefill), but every warmed-length marker is dropped so the same
        prefixes re-warm — under the new weights — on the next tick."""
        for u in list(self._heat):
            self._heat[u] *= self.decay
            if self._heat[u] < 1e-3:
                del self._heat[u]
        if swapped:
            self.skipped_swap_ticks += 1
            self._warmed_at.clear()
            return []
        hot = sorted((u for u, h in self._heat.items()
                      if h >= self.min_events),
                     key=lambda u: (-self._heat[u], u))
        rids: List[int] = []
        for u in hot[:self.top_k]:
            st = self.dti._users.get(u)
            if st is None or not st.items:
                continue
            if self._warmed_at.get(u) == st.m:
                continue                 # nothing new since the last warm
            rid: Optional[int] = self.scheduler.prewarm(st.items)
            self._warmed_at[u] = st.m
            if rid is not None:
                rids.append(rid)
                self.warmed += 1
        return rids


__all__ = ["PrefixPrewarmer"]
