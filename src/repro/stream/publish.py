"""Weight publication: online trainer -> serving fleet, no restarts.

Transport is the checkpoint store (``repro.train.checkpoint``): the
publisher writes params-only versions with the same atomic
``tmp.<v>`` -> ``os.replace`` -> ``step_<v>`` protocol, so a subscriber
polling the directory only ever sees complete versions — a crash mid-write
never publishes a torn checkpoint. Versions are the online trainer's step
numbers: monotonic, so ``poll`` is a single ``latest_step`` check.

Consumers:

* ``ServeScheduler.attach_param_source(sub.poll)`` — the continuous-
  batching scheduler polls between decode steps and swaps params in place.
  In-flight slots are NOT dropped: their already-cached context KV stays
  (computed under the old weights), only subsequent steps use the new
  ones, so a request straddling a swap is scored under mixed versions —
  bounded staleness traded for zero dropped traffic (docs/streaming.md).
* ``CTRServer.update_params`` — prefill-path hot-swap; params are a jit
  *argument*, so swapping triggers no recompilation in either consumer.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

from repro.train.checkpoint import CheckpointManager


class ParamPublisher:
    """Writes versioned params; ``keep`` old versions survive so slow
    subscribers never watch their version vanish mid-restore."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.mgr = CheckpointManager(directory, keep=keep, save_interval=1,
                                     async_write=False)

    def publish(self, version: int, params: Any) -> None:
        self.mgr.save(version, params, meta={"version": version}, block=True)

    def latest_version(self) -> Optional[int]:
        return self.mgr.latest_step()


class ParamSubscriber:
    """Polls a publisher directory; returns ``(version, params)`` when a
    newer version than the last one seen exists, else None. ``template``
    pins the expected pytree structure/shapes (shape drift is rejected by
    the checkpoint layer, not silently loaded)."""

    def __init__(self, directory: str, template: Any, *,
                 version: Optional[int] = None):
        self.mgr = CheckpointManager(directory, save_interval=1,
                                     async_write=False)
        self.template = template
        self.version = -1 if version is None else version

    def poll(self) -> Optional[Tuple[int, Any]]:
        latest = self.mgr.latest_step()
        if latest is None or latest <= self.version:
            return None
        params = self.mgr.restore(self.template, step=latest)
        self.version = latest
        self.template = params
        return latest, params


__all__ = ["ParamPublisher", "ParamSubscriber"]
