"""Weight publication: online trainer -> serving fleet, no restarts.

Transport is an ``ObjectStore`` — a minimal versioned-blob interface with
one backend today (``LocalDirStore``, over ``repro.train.checkpoint``'s
atomic ``tmp.<v>`` -> ``os.replace`` -> ``step_<v>`` protocol) and room for
remote stores later; the publisher/subscriber pair never touches paths
directly, so swapping the backend swaps the fleet's transport. Versions are
the online trainer's step numbers: monotonic, so ``poll`` is one listing.

Fleet semantics (docs/sharding.md):

* **one store, many subscribers** — every serving shard runs its own
  ``ParamSubscriber`` over the shared store (``replicated_subscribers``),
  each with an independent cursor, so shards converge on the newest
  version without coordinating with each other.
* **fault tolerance** — ``poll`` *skips* unreadable versions instead of
  raising: a torn/partial write (only reachable if the backend loses the
  atomic-replace guarantee, e.g. a copied-in checkpoint or a crashed
  remote store) or a version GC'd between listing and read falls back to
  the next-newest good version, or to None (keep serving the current
  weights). Skipped versions are remembered (``skipped``) and never
  re-read. A *gap* in the version sequence is not an error — subscribers
  only care about the newest readable version.

Consumers:

* ``ServeScheduler.attach_param_source(sub.poll)`` — the continuous-
  batching scheduler polls between decode steps and swaps params in place.
  By default in-flight slots are NOT dropped: their already-cached context
  KV stays (computed under the old weights), so a request straddling a
  swap is scored under mixed versions — bounded staleness traded for zero
  dropped traffic (docs/streaming.md). ``drain_before_swap=True`` trades
  a drain bubble for version purity instead (docs/sharding.md).
* ``CTRServer.update_params`` — prefill-path hot-swap; params are a jit
  *argument*, so swapping triggers no recompilation in either consumer.
"""
from __future__ import annotations

from typing import Any, List, Optional, Tuple, Union

from repro.train.checkpoint import CheckpointManager


class ObjectStore:
    """Versioned object store: integer versions -> pytrees of arrays.

    ``put`` must be atomic (a reader never sees a half-written version) and
    ``versions`` must list only complete versions — the two properties the
    subscriber protocol rides on. ``get`` may raise on a version that is
    corrupt or vanished (GC race); callers are expected to fall back.
    """

    def put(self, version: int, obj: Any) -> None:
        raise NotImplementedError

    def get(self, template: Any, version: int) -> Any:
        raise NotImplementedError

    def versions(self) -> List[int]:
        raise NotImplementedError

    def latest(self) -> Optional[int]:
        vs = self.versions()
        return vs[-1] if vs else None


class LocalDirStore(ObjectStore):
    """Local-directory backend over ``CheckpointManager``: atomic writes
    via tmp-dir + ``os.replace``, ``keep`` newest versions retained so slow
    subscribers never watch their version vanish mid-restore."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.mgr = CheckpointManager(directory, keep=keep, save_interval=1,
                                     async_write=False)

    def put(self, version: int, obj: Any) -> None:
        self.mgr.save(version, obj, meta={"version": version}, block=True)

    def get(self, template: Any, version: int) -> Any:
        return self.mgr.restore(template, step=version)

    def versions(self) -> List[int]:
        return self.mgr.all_steps()


def _as_store(store: Union[str, ObjectStore], **kw) -> ObjectStore:
    return store if isinstance(store, ObjectStore) else \
        LocalDirStore(store, **kw)


class ParamPublisher:
    """Writes versioned params to an ``ObjectStore`` (or a directory path,
    the historical constructor — wrapped in a ``LocalDirStore``)."""

    def __init__(self, store: Union[str, ObjectStore], *, keep: int = 3):
        self.store = _as_store(store, keep=keep) \
            if isinstance(store, str) else store

    def publish(self, version: int, params: Any) -> None:
        self.store.put(version, params)

    def latest_version(self) -> Optional[int]:
        return self.store.latest()


class ParamSubscriber:
    """Polls an ``ObjectStore``; returns ``(version, params)`` when a newer
    *readable* version than the last one seen exists, else None.
    ``template`` pins the expected pytree structure/shapes (shape drift is
    rejected by the store's codec, not silently loaded).

    ``poll`` never raises on store-side faults: unreadable versions land in
    ``skipped`` and the scan falls back toward the newest good version —
    a serving shard keeps scoring under its current weights rather than
    crashing on a bad publish."""

    def __init__(self, store: Union[str, ObjectStore], template: Any, *,
                 version: Optional[int] = None):
        self.store = _as_store(store)
        self.template = template
        self.version = -1 if version is None else version
        self.skipped: List[int] = []
        self._bad: set = set()

    def poll(self) -> Optional[Tuple[int, Any]]:
        try:
            vs = self.store.versions()
        except OSError:
            return None                    # store unreachable: keep serving
        for v in reversed(vs):
            if v <= self.version:
                break
            if v in self._bad:
                continue
            try:
                params = self.store.get(self.template, v)
            except Exception:              # torn write / GC race: skip it
                self._bad.add(v)
                self.skipped.append(v)
                continue
            self.version = v
            self.template = params
            return v, params
        return None


def replicated_subscribers(store: Union[str, ObjectStore], template: Any,
                           n: int, *, version: Optional[int] = None
                           ) -> List[ParamSubscriber]:
    """``n`` independent subscribers over one shared store — one per
    serving shard. Each keeps its own cursor (and its own restored copy of
    the params), so a fleet-wide publish reaches every shard on its next
    poll without any cross-shard coordination; pair with
    ``ServeScheduler(drain_before_swap=True)`` for a fleet-wide
    version-pure swap."""
    st = _as_store(store)
    return [ParamSubscriber(st, template, version=version)
            for _ in range(n)]


__all__ = ["ObjectStore", "LocalDirStore", "ParamPublisher",
           "ParamSubscriber", "replicated_subscribers"]
