"""Online trainer loop: continual fine-tuning over streaming batches.

Wraps ``repro.train.make_train_step`` — the same jitted runtime the batch
trainer uses — around a stream of incremental batches:

* **warm start**: optimizer state is initialised fresh around the serving
  params (or restored wholesale from a checkpoint via ``resume``), so a
  deployed model keeps training where it left off instead of restarting;
* **streaming eval**: the loss fn returns pre-update p(click); supervised
  positions feed mergeable ``StreamingAUC`` / ``StreamingLogLoss``
  accumulators (progressive validation — every target is scored *before*
  the step that trains on it). Accumulators roll into fixed-size drift
  windows (``eval_windows``) so freshness regressions show up as a window-
  over-window AUC/logloss drift, plus lifetime aggregates;
* **publication**: every ``publish_every`` steps (and at the end of a run)
  the current params go to a ``ParamPublisher`` — the serving fleet picks
  them up between decode steps (``repro.stream.publish``) — and optionally
  to a ``CheckpointManager`` for crash-resume.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Deque, Dict, Iterable, List, Optional

import jax
import numpy as np

from repro.core.losses import ctr_loss
from repro.core.metrics import StreamingAUC, StreamingLogLoss
from repro.models.transformer import ModelConfig, forward
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import (TrainOptions, init_train_state,
                                 make_train_step)


def make_stream_loss_fn(cfg: ModelConfig, window: int, *,
                        yes_id: int = 3, no_id: int = 4) -> Callable:
    """Stream analog of the trainer's LM loss: the forward sees ``is_sum``
    (every [SUM] keeps its training-time geometry — NoPE+ALiBi, isolation,
    reset distances), the loss masks on ``target_mask`` so already-trained
    targets re-emitted as context get zero weight. Returns pre-update
    p(click) for progressive validation.

    Masking is exact for the CTR objective; ``out["aux_loss"]`` (MoE
    load balancing) is batch-global by construction, so on MoE configs the
    aux term — like the batch trainer's under wrap-around padding — still
    depends on batch composition (padding rows, re-emitted context). The
    grad-identical-to-rebuild guarantee is therefore exact end-to-end on
    dense configs and CTR-loss-exact on MoE."""
    def loss_fn(params, batch, rng):
        out = forward(params, cfg, batch["tokens"],
                      positions=batch["positions"], is_sum=batch["is_sum"],
                      valid=batch["valid"],
                      segment_ids=batch.get("segment_ids"),
                      dti_enabled=cfg.dti_sum_token, window=window)
        mask = batch.get("target_mask", batch["is_sum"])
        loss, aux = ctr_loss(params, cfg, out["hidden"], mask,
                             batch["labels"], yes_id=yes_id, no_id=no_id)
        return loss + out["aux_loss"], {"p_click": aux["p_click"]}
    return loss_fn


@dataclasses.dataclass
class EvalWindow:
    """One closed drift window of progressive-validation metrics."""
    auc: float
    log_loss: float
    n_targets: int
    step_lo: int
    step_hi: int


class OnlineTrainer:
    """Continual training with streaming eval and periodic publication."""

    def __init__(self, loss_fn: Callable, params: Any,
                 opt_cfg: OptimizerConfig, *,
                 options: TrainOptions = TrainOptions(),
                 ckpt: Optional[CheckpointManager] = None,
                 publisher=None, publish_every: int = 50,
                 window_targets: int = 256,
                 history_limit: int = 1000,
                 log_every: int = 0,
                 log_fn: Callable[[str], None] = print,
                 tracer=None,
                 metrics: Optional[MetricsRegistry] = None):
        assert options.grad_accum == 1, (
            "OnlineTrainer needs per-batch p_click for streaming eval; "
            "make_train_step drops aux metrics when grad_accum > 1")
        self.state = init_train_state(params, opt_cfg, options)
        self.step_fn = make_train_step(loss_fn, opt_cfg, options)
        self.ckpt = ckpt
        self.publisher = publisher
        self.publish_every = publish_every
        self.window_targets = window_targets
        self.log_every = log_every
        self.log_fn = log_fn
        self.step = 0
        self.published_version: Optional[int] = None
        self._last_publish_step: Optional[int] = None
        # obs: the registry mirrors what the EvalWindow list / drift()
        # already expose (the compatibility shim — those APIs stay), in
        # the mergeable form multi-shard aggregation needs
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_steps = self.metrics.counter("online.steps")
        self._c_targets = self.metrics.counter("online.targets")
        self._c_windows = self.metrics.counter("online.windows")
        self._c_publishes = self.metrics.counter("online.publishes")
        self._g_auc = self.metrics.gauge("online.window_auc")
        self._g_ll = self.metrics.gauge("online.window_log_loss")
        self._g_dauc = self.metrics.gauge("online.d_auc")
        self._g_dll = self.metrics.gauge("online.d_log_loss")
        self.eval_windows: List[EvalWindow] = []
        self.lifetime_auc = StreamingAUC()
        self.lifetime_log_loss = StreamingLogLoss()
        self._win_auc = StreamingAUC()
        self._win_ll = StreamingLogLoss()
        self._win_lo = 0
        # the stream never ends, so per-step records are ring-buffered;
        # long-horizon signals live in the (compact) windows/accumulators
        self.history: Deque[Dict] = deque(maxlen=history_limit)

    # -- persistence ----------------------------------------------------------

    def resume_if_possible(self) -> bool:
        """Warm start from the latest checkpoint (full TrainState: params,
        optimizer moments, EF residual)."""
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        self.state = self.ckpt.restore(self.state)
        self.step = self.ckpt.restore_meta()["meta"]["step"]
        self._win_lo = self.step        # drift windows restart here
        return True

    def publish(self) -> None:
        if self._last_publish_step == self.step:
            return                      # already published this step
        if self.ckpt is not None:
            self.ckpt.save(self.step, self.state, meta={"step": self.step},
                           block=True)
        if self.publisher is not None:
            self.publisher.publish(self.step, self.state.params)
            self.published_version = self.step
        self._last_publish_step = self.step
        self._c_publishes.inc()
        self.tracer.instant("publish", step=self.step)

    # -- metrics --------------------------------------------------------------

    def _observe(self, batch, p_click: np.ndarray) -> None:
        mask = np.asarray(batch.get("target_mask", batch["is_sum"]))
        if not mask.any():
            return
        labels = np.asarray(batch["labels"])[mask]
        scores = p_click[mask]
        for acc in (self.lifetime_auc, self._win_auc):
            acc.update(labels, scores)
        for acc in (self.lifetime_log_loss, self._win_ll):
            acc.update(labels, scores)
        self._c_targets.inc(int(len(labels)))
        if self._win_auc.n >= self.window_targets:
            self._roll_window()

    def _roll_window(self) -> None:
        if self._win_auc.n == 0:
            return
        self.eval_windows.append(EvalWindow(
            auc=self._win_auc.value(), log_loss=self._win_ll.value(),
            n_targets=self._win_auc.n, step_lo=self._win_lo,
            step_hi=self.step))
        self._c_windows.inc()
        self._g_auc.set(self.eval_windows[-1].auc)
        self._g_ll.set(self.eval_windows[-1].log_loss)
        d = self.drift()
        if d is not None:
            self._g_dauc.set(d["d_auc"])
            self._g_dll.set(d["d_log_loss"])
        self.tracer.instant("window_roll", step=self.step,
                            auc=self.eval_windows[-1].auc)
        self._win_auc = StreamingAUC()
        self._win_ll = StreamingLogLoss()
        self._win_lo = self.step

    def flush_windows(self) -> None:
        """Close the in-progress drift window (shorter than
        ``window_targets``) — call at shutdown so tail targets reach
        ``eval_windows``. Windows otherwise roll only when full, and the
        open window survives across ``run`` calls, so per-tick ``run``
        usage still produces fixed-size windows."""
        self._roll_window()

    def drift(self) -> Optional[Dict[str, float]]:
        """AUC / logloss movement between the last two closed windows —
        the freshness alarm an operator pages on."""
        if len(self.eval_windows) < 2:
            return None
        a, b = self.eval_windows[-2], self.eval_windows[-1]
        return {"d_auc": b.auc - a.auc, "d_log_loss": b.log_loss - a.log_loss}

    # -- the loop -------------------------------------------------------------

    def run(self, batches: Iterable, *, n_steps: Optional[int] = None,
            rng=None) -> Deque[Dict]:
        """Consume ``batches`` (e.g. ``StreamPipeline.batches()``) until the
        stream ends or ``n_steps`` is hit; publishes at the end.

        The step-budget check runs *before* pulling the next batch, so
        hitting ``n_steps`` never dequeues (and silently discards) work:
        the remaining batches stay queued, and a later ``run`` over the
        same iterator resumes exactly where this one stopped."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        it = iter(batches)
        while True:
            if n_steps is not None and self.step >= n_steps:
                break
            try:
                batch = next(it)
            except StopIteration:
                break
            rng, sub = jax.random.split(rng)
            with self.tracer.span("online.step", step=self.step + 1):
                self.state, metrics = self.step_fn(self.state, batch, sub)
                p = np.asarray(metrics["p_click"])
            self.step += 1
            self._c_steps.inc()
            self._observe(batch, p)
            rec = {"step": self.step, "loss": float(metrics["loss"])}
            self.history.append(rec)
            if self.log_every and self.step % self.log_every == 0:
                self.log_fn(f"[online {self.step}] loss={rec['loss']:.4f} "
                            f"auc={self.lifetime_auc.value():.4f}")
            if self.publish_every and self.step % self.publish_every == 0:
                self.publish()
        self.publish()
        return self.history


__all__ = ["OnlineTrainer", "EvalWindow", "make_stream_loss_fn"]
