"""Incremental DTI prompt construction over growing user histories.

The paper's cost argument is really about *retraining*: sliding-window
training costs O(m·n²) tokens for a user with m interactions, and
production histories never stop growing. Batch DTI cuts one full pass to
O(m·n); this module applies the same k-target packing *incrementally*, so
keeping a model fresh as Δm new interactions arrive costs O(Δm·(n+k))
supervised tokens instead of re-deriving (and re-training) the full
corpus.

Group geometry is identical to ``repro.core.dti.build_streaming_prompts``:
target interactions (absolute index ≥ n_ctx) partition into stride-k
groups; group g starts at ``n_ctx + g·k`` and its prompt is

    [BOS] ctx(n_ctx items)  t_gs [SUM]  t_gs+1 [SUM]  ...

Crucially the group boundaries depend only on (n_ctx, k) — never on the
current history length — so a group's prompt converges to exactly the row
a full rebuild would produce. When new events land, the builder re-emits
each *affected* group with every target present (old targets keep their
[SUM] tokens, labels and geometry: under causal attention they are context
for the new ones) but supervises only the newly arrived targets via a
``target_mask`` field layered on the canonical batch schema. The loss
masks on ``target_mask`` while the forward still sees ``is_sum``, so each
supervised (target, context) pair — and, packed, each gradient — is
identical to rebuilding the full DTI corpus and keeping only the new
targets (tests/test_stream.py::TestIncrementalEquivalence).

Per-user state is trimmed to the suffix future groups can reference
(≤ n_ctx + k interactions), so memory is O(users·(n_ctx+k)), not O(m).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core.dti import PromptStats, SpecialTokens, _pack, _pad_to


@dataclasses.dataclass
class _UserState:
    base: int = 0                      # absolute index of items[0]
    items: List[List[int]] = dataclasses.field(default_factory=list)
    labels: List[int] = dataclasses.field(default_factory=list)
    supervised: int = 0                # targets with index < this are trained

    @property
    def m(self) -> int:
        return self.base + len(self.items)


class IncrementalDTI:
    """Per-user history state + ``extend_prompts``.

    ``extend_prompts(events)`` consumes interaction events (dicts with
    ``user``, ``item_tokens``, ``label`` — ``repro.data.requests.
    make_event_stream``'s schema) and returns canonical-schema rows (plus
    ``target_mask``) supervising exactly the targets that had not been
    supervised before. ``seed_history`` installs a warm corpus the model
    was already trained on (its targets are marked supervised and never
    re-emitted).
    """

    def __init__(self, *, n_ctx: int, k: int, max_len: int,
                 sp: SpecialTokens = SpecialTokens(),
                 stats: Optional[PromptStats] = None):
        assert n_ctx > 0 and k > 0
        self.n_ctx = n_ctx
        self.k = k
        self.max_len = max_len
        self.sp = sp
        self.stats = stats if stats is not None else PromptStats()
        self._users: Dict[int, _UserState] = {}

    # -- state ---------------------------------------------------------------

    def seed_history(self, user: int, item_tokens: List[List[int]],
                     labels: List[int], *, supervised: bool = True) -> None:
        assert user not in self._users, f"user {user} already seeded"
        st = _UserState(items=[list(t) for t in item_tokens],
                        labels=[int(l) for l in labels])
        if supervised:
            st.supervised = st.m
        self._users[user] = st
        self._trim(st)

    def user_count(self) -> int:
        return len(self._users)

    def buffered_interactions(self, user: int) -> int:
        """Interactions currently held for ``user`` (bounded by n_ctx+k)."""
        return len(self._users[user].items)

    # -- the streaming step --------------------------------------------------

    def extend_prompts(self, events: Iterable[Dict]
                       ) -> List[Dict[str, np.ndarray]]:
        """Append events to their users' histories and emit one row per
        affected group, supervising only the newly arrived targets."""
        touched: List[int] = []
        seen = set()
        for ev in events:
            u = int(ev["user"])
            st = self._users.get(u)
            if st is None:
                st = self._users[u] = _UserState()
            if "index" in ev:           # catch dropped/redelivered events
                assert int(ev["index"]) == st.m, (
                    f"user {u}: event index {ev['index']} != expected "
                    f"{st.m} — a gap here would silently shift every later "
                    f"target's context")
            st.items.append([int(t) for t in ev["item_tokens"]])
            st.labels.append(int(ev["label"]))
            if u not in seen:             # first-event order, each user once
                seen.add(u)
                touched.append(u)
        rows: List[Dict[str, np.ndarray]] = []
        for u in touched:
            rows.extend(self._emit(self._users[u]))
        return rows

    # -- internals -----------------------------------------------------------

    def _emit(self, st: _UserState) -> List[Dict[str, np.ndarray]]:
        n_ctx, k, sp = self.n_ctx, self.k, self.sp
        m = st.m
        s = max(st.supervised, n_ctx)     # first unsupervised target index
        if m <= n_ctx or s >= m:
            self._trim(st)
            return []
        rows = []
        g_lo = (s - n_ctx) // k
        g_hi = (m - 1 - n_ctx) // k
        for g in range(g_lo, g_hi + 1):
            gs = n_ctx + g * k
            toks: List[int] = [sp.bos]
            for j in range(gs - n_ctx, gs):
                toks.extend(st.items[j - st.base])
            is_sum = [False] * len(toks)
            lab = [0] * len(toks)
            tmask = [False] * len(toks)
            n_new = 0
            for t in range(gs, min(gs + k, m)):
                it = st.items[t - st.base]
                toks.extend(it)
                is_sum.extend([False] * len(it))
                lab.extend([0] * len(it))
                tmask.extend([False] * len(it))
                toks.append(sp.sum)
                is_sum.append(True)
                lab.append(int(st.labels[t - st.base]))
                new = t >= s
                tmask.append(new)
                n_new += int(new)
            row = _pack(toks, is_sum, lab, self.max_len, sp)
            row["target_mask"] = _pad_to(np.asarray(tmask, bool),
                                         self.max_len, False)
            self.stats.add(len(toks), n_new)
            rows.append(row)
        st.supervised = m
        self._trim(st)
        return rows

    def _trim(self, st: _UserState) -> None:
        # keep from the start of the group the next *unemitted* target
        # belongs to, minus its context — everything older is never
        # referenced again. The anchor is the first unsupervised target (a
        # supervised=False seed keeps its whole pending history until
        # emitted), or m when nothing is pending (the next future target).
        anchor = min(max(st.supervised, self.n_ctx), st.m)
        gs_next = self.n_ctx + self.k * max(0, (anchor - self.n_ctx)
                                            // self.k)
        keep_from = max(st.base, gs_next - self.n_ctx)
        drop = keep_from - st.base
        if drop > 0:
            del st.items[:drop]
            del st.labels[:drop]
            st.base = keep_from


__all__ = ["IncrementalDTI"]
