"""User-sharded streaming: one event stream fanned over N shard workers.

Scale-out for the stream half of the train->serve loop (docs/sharding.md):
``shard_events`` partitions an event stream *by user*, so each shard's
``IncrementalDTI``/``StreamPipeline``/``OnlineTrainer`` stack sees every
interaction of its users in order (incremental prompt construction needs
per-user chronology; user-disjoint shards preserve it by construction)
while the shards run independently — separate hosts in production, separate
objects in tests.

Aggregation is exact, not approximate: ``StreamingAUC`` (binned count
histograms) and ``StreamingLogLoss`` (a sum and a count) merge
associatively, so the merged value over any shard partition equals the
single-shard value on the unpartitioned stream — the property
tests/test_shard_merge.py pins under hypothesis. The serve side aggregates
the same way: every ``ServeScheduler`` keeps its counters in a mergeable
``MetricsRegistry``, and ``fleet_serve_snapshot`` folds per-shard
``serve.*`` snapshots into one fleet view (counters add, gauges keep the
newest, histograms add bin-wise).
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional, Sequence

from repro.core.metrics import StreamingAUC, StreamingLogLoss
from repro.obs.metrics import Snapshot, merge_snapshots


def shard_key(event: Dict, n_shards: int) -> int:
    """Shard index of one event: its user id mod ``n_shards`` (stable,
    stateless — any worker can route without a directory service)."""
    return int(event["user"]) % n_shards


def shard_events(ticks: Iterable[List[Dict]], n_shards: int, *,
                 key: Optional[Callable[[Dict], int]] = None
                 ) -> List[List[List[Dict]]]:
    """Partition an event stream (iterable of ticks, each a list of event
    dicts carrying ``"user"``) into ``n_shards`` per-shard streams.

    Every shard gets the *same number of ticks* (possibly empty ones), so
    shard workers stay tick-aligned with the global stream — publish
    cadences and drift windows line up across the fleet. Events within a
    tick keep their order; users never split across shards, so per-user
    chronology — the invariant ``IncrementalDTI`` builds on — holds per
    shard exactly as it did globally.

    ``key`` overrides the routing function (default: ``user % n_shards``);
    it must be stable across ticks or a user's history would tear across
    shards.
    """
    assert n_shards >= 1
    if key is None:
        key = lambda e: shard_key(e, n_shards)
    out: List[List[List[Dict]]] = [[] for _ in range(n_shards)]
    for tick in ticks:
        split: List[List[Dict]] = [[] for _ in range(n_shards)]
        for e in tick:
            s = key(e)
            assert 0 <= s < n_shards, f"shard key {s} out of range"
            split[s].append(e)
        for s in range(n_shards):
            out[s].append(split[s])
    return out


def merged_streaming_auc(accs: Sequence[StreamingAUC]) -> StreamingAUC:
    """Fold per-shard AUC accumulators into a fresh one (inputs are not
    mutated — shards keep accumulating). Exact: the merged bin histograms
    equal the single-shard histograms over the unpartitioned stream."""
    accs = list(accs)
    assert accs, "nothing to merge"
    out = StreamingAUC(n_bins=accs[0].n_bins, lo=accs[0].lo, hi=accs[0].hi)
    for a in accs:
        out.merge(a)
    return out


def merged_streaming_log_loss(accs: Sequence[StreamingLogLoss]
                              ) -> StreamingLogLoss:
    """Fold per-shard log-loss accumulators into a fresh one (inputs are
    not mutated)."""
    accs = list(accs)
    assert accs, "nothing to merge"
    out = StreamingLogLoss(eps=accs[0].eps)
    for a in accs:
        out.merge(a)
    return out


def fleet_eval(trainers: Sequence) -> Dict[str, float]:
    """Fleet-wide progressive-validation summary over per-shard
    ``OnlineTrainer``s: lifetime AUC / log loss / target count, merged from
    the shards' accumulators."""
    auc = merged_streaming_auc([t.lifetime_auc for t in trainers])
    ll = merged_streaming_log_loss([t.lifetime_log_loss for t in trainers])
    return {"auc": auc.value(), "log_loss": ll.value(), "n_targets": auc.n}


def fleet_serve_snapshot(schedulers: Sequence) -> Snapshot:
    """One fleet-wide ``serve.*`` metrics snapshot merged from per-shard
    ``ServeScheduler`` registries (associative + commutative — shard order
    does not matter; tests/test_shard_merge.py). Counter values are fleet
    totals; e.g. ``serve.steps`` is the total decode steps the fleet ran,
    ``serve.cross_row_hits`` the total radix-index admissions."""
    return merge_snapshots(*(s.metrics.snapshot(prefix="serve.")
                             for s in schedulers))


__all__ = ["shard_key", "shard_events", "merged_streaming_auc",
           "merged_streaming_log_loss", "fleet_eval",
           "fleet_serve_snapshot"]
