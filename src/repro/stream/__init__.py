"""Streaming continual training: incremental DTI over growing histories.

Closes the train->serve loop (docs/streaming.md): events -> incremental
prompt construction (``incremental``) -> async fixed-shape batching
(``pipeline``) -> online fine-tuning with streaming eval (``online``) ->
weight publication into the live serving fleet (``publish``) -> hot-user
prefix prewarming of the serving fleet's paged KV cache (``prewarm``).
"""
from repro.stream.incremental import IncrementalDTI
from repro.stream.online import EvalWindow, OnlineTrainer, make_stream_loss_fn
from repro.stream.pipeline import StreamPipeline
from repro.stream.prewarm import PrefixPrewarmer
from repro.stream.publish import (LocalDirStore, ObjectStore, ParamPublisher,
                                  ParamSubscriber, replicated_subscribers)
from repro.stream.shard import (fleet_eval, fleet_serve_snapshot,
                                merged_streaming_auc,
                                merged_streaming_log_loss, shard_events)

__all__ = ["IncrementalDTI", "StreamPipeline", "OnlineTrainer", "EvalWindow",
           "make_stream_loss_fn", "ParamPublisher", "ParamSubscriber",
           "ObjectStore", "LocalDirStore", "replicated_subscribers",
           "shard_events", "merged_streaming_auc", "merged_streaming_log_loss",
           "fleet_eval", "fleet_serve_snapshot",
           "PrefixPrewarmer"]
