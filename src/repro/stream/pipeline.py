"""Async host pipeline: event ticks -> incremental rows -> fixed-shape batches.

A background thread drains a data-layer event source (an iterable of event
ticks, e.g. ``repro.data.requests.make_event_stream``), runs incremental
prompt construction (``IncrementalDTI.extend_prompts``), FFD-packs the
resulting rows into shared segment-isolated rows (``core.dti.pack_prompts``)
and queues fixed-shape batches for the jitted train step — host work
overlaps device work, the steady state never recompiles.

Shape discipline: the batch dim is always ``batch_size`` (a partial final
batch is padded by repeating its first row with ``target_mask`` cleared —
zero CTR loss weight, zero CTR gradient; an MoE config's batch-global
load-balancing aux term still sees the padding row, exactly as the batch
trainer's wrap-around padding does) and the sequence dim is the smallest
``bucket`` covering the longest packed row in the batch, so the step
function compiles once per bucket, at most ``len(buckets)`` times.

``PromptStats.pad_fraction`` is tracked over the emitted batches (slots =
rows x bucket length); padding-by-duplication rows count as slots carrying
tokens — they are real compute — but contribute no targets.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.dti import PromptStats, pack_prompts, prompt_length
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.stream.incremental import IncrementalDTI

_DONE = object()


class StreamPipeline:
    """Iterate ``batches()`` on the trainer side; the worker thread keeps
    the queue fed. ``stats`` carries the packed-batch token accounting
    (``pad_fraction``); ``n_targets`` below equals the number of supervised
    [SUM] positions emitted, each exactly once."""

    def __init__(self, source: Iterable[List[Dict]], inc: IncrementalDTI, *,
                 batch_size: int, buckets: Optional[Sequence[int]] = None,
                 pack: bool = True, queue_size: int = 8,
                 tracer=None, metrics: Optional[MetricsRegistry] = None):
        assert batch_size > 0
        self.inc = inc
        self.batch_size = batch_size
        self.buckets = tuple(sorted(buckets)) if buckets else (inc.max_len,)
        assert self.buckets[-1] == inc.max_len, (
            f"largest bucket {self.buckets[-1]} must equal max_len "
            f"{inc.max_len}")
        self.pack = pack
        self.stats = PromptStats()
        # worker-thread safe: span emission is a clock read plus a
        # deque.append (atomic under the GIL), counters a single +=
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_ticks = self.metrics.counter("stream.ticks")
        self._c_rows = self.metrics.counter("stream.rows")
        self._c_batches = self.metrics.counter("stream.batches")
        self._source = source
        self._q: queue.Queue = queue.Queue(maxsize=queue_size)
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._started = False

    # -- worker side ----------------------------------------------------------

    def _put(self, item) -> bool:
        """Bounded put that aborts when ``stop`` is requested, so an
        abandoned consumer never leaves the worker blocked forever."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _work(self):
        try:
            for tick in self._source:
                if self._stop.is_set():
                    return
                with self.tracer.span("stream.tick", events=len(tick)):
                    rows = self.inc.extend_prompts(tick)
                    if self.pack and rows:
                        rows = pack_prompts(rows, self.inc.max_len,
                                            sp=self.inc.sp)
                self._c_ticks.inc()
                self._c_rows.inc(len(rows))
                for batch in self._batches_from(rows):
                    self._c_batches.inc()
                    if not self._put(batch):
                        return
        except BaseException as e:  # noqa: BLE001 — surfaced on consumer side
            self._err = e
        finally:
            self._put(_DONE)

    def _batches_from(self, rows: List[Dict[str, np.ndarray]]):
        for lo in range(0, len(rows), self.batch_size):
            group = rows[lo: lo + self.batch_size]
            while len(group) < self.batch_size:       # fixed batch dim
                blank = dict(group[0])
                blank["target_mask"] = np.zeros_like(blank["target_mask"])
                group.append(blank)
            need = max(prompt_length(r) for r in group)
            bucket = next(b for b in self.buckets if b >= need)
            batch = {key: np.stack([r[key][:bucket] for r in group])
                     for key in group[0]}
            for r in group:
                self.stats.add_packed_row(
                    prompt_length(r), int(r["segment_ids"].max()) + 1,
                    int(r["target_mask"].sum()), bucket)
            yield batch

    # -- trainer side ---------------------------------------------------------

    def start(self) -> "StreamPipeline":
        if not self._started:
            self._started = True
            self._thread.start()
        return self

    def batches(self):
        """Yield fixed-shape batches until the source is exhausted; re-raises
        any worker-thread exception. A consumer stopping early (e.g.
        ``OnlineTrainer.run(..., n_steps=N)``) can resume from the same
        generator later, or call ``stop()`` to release the worker."""
        self.start()
        while True:
            try:
                item = self._q.get(timeout=0.1)
            except queue.Empty:
                # normally the sentinel ends the loop; if the worker is
                # gone (stop(), or a prior iteration already consumed the
                # sentinel) an empty queue is final — never block forever
                if not self._thread.is_alive():
                    break
                continue
            if item is _DONE:
                break
            yield item
        if self._err is not None:
            raise self._err
        self._thread.join()

    def stop(self) -> None:
        """Abandon the stream: unblock and join the worker, drop queued
        batches. Targets already emitted into dropped batches were marked
        supervised by ``IncrementalDTI`` and will not be re-emitted — stop
        is for shutdown, not pause (pause = just stop consuming). A
        consumer still (or later) blocked in ``batches()`` terminates
        cleanly: a sentinel is re-enqueued after the worker dies."""
        self._stop.set()

        def drain():
            while True:
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    return

        if self._started:
            drain()                         # release a put-blocked worker
            self._thread.join()
            drain()                         # its in-flight put may have won
        self._q.put_nowait(_DONE)           # wake any (future) consumer

    def __iter__(self):
        return self.batches()


__all__ = ["StreamPipeline"]
