"""Embedding substrate: JAX has no nn.EmbeddingBag / CSR — we build it.

Lookup = ``jnp.take`` (row gather); reduction = masked sum / ``segment_sum``.
Tables are column-sharded over the "model" mesh axis in the distributed
setting (every device holds dim/TP of every row -> lookups are always local;
see repro.sharding.partition). The Pallas kernel in
``repro.kernels.embedding_bag`` implements the same op with explicit VMEM
tiling for the TPU hot path; ``ref.py`` there aliases these functions.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init


def init_table(rng, vocab: int, dim: int, *, scale: float = 0.01,
               dtype=jnp.float32) -> jax.Array:
    return normal_init(rng, (vocab, dim), scale, dtype)


def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Single-hot lookup: ids (...,) -> (..., dim)."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table: jax.Array, ids: jax.Array,
                  valid: Optional[jax.Array] = None, *,
                  mode: str = "sum",
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """Fixed-shape multi-hot bag: ids (..., H) -> (..., dim).

    valid (..., H) masks padding slots. This is the dense-padded
    EmbeddingBag — the layout TPUs want (no ragged gathers).
    """
    e = jnp.take(table, ids, axis=0)                       # (..., H, dim)
    if weights is not None:
        e = e * weights[..., None].astype(e.dtype)
    if valid is not None:
        e = e * valid[..., None].astype(e.dtype)
    s = jnp.sum(e, axis=-2)
    if mode == "sum":
        return s
    if mode == "mean":
        n = (jnp.sum(valid, axis=-1, keepdims=True).astype(s.dtype)
             if valid is not None else jnp.asarray(ids.shape[-1], s.dtype))
        return s / jnp.maximum(n, 1)
    if mode == "max":
        neg = jnp.finfo(e.dtype).min
        e = e if valid is None else jnp.where(valid[..., None], e, neg)
        return jnp.max(e, axis=-2)
    raise ValueError(mode)


def embedding_bag_ragged(table: jax.Array, flat_ids: jax.Array,
                         segment_ids: jax.Array, num_segments: int, *,
                         weights: Optional[jax.Array] = None) -> jax.Array:
    """Ragged bag: flat_ids (N,), segment_ids (N,) -> (num_segments, dim).

    The CSR-offsets EmbeddingBag expressed with segment_sum (TPU-friendly
    scatter-add)."""
    e = jnp.take(table, flat_ids, axis=0)
    if weights is not None:
        e = e * weights[:, None].astype(e.dtype)
    return jax.ops.segment_sum(e, segment_ids, num_segments=num_segments)


def hash_bucket(ids: jax.Array, vocab: int, *, salt: int = 0x9E3779B9) -> jax.Array:
    """Deterministic hash trick for open-vocabulary ids (QR-embed style)."""
    x = ids.astype(jnp.uint32) * jnp.uint32(salt)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    return (x % jnp.uint32(vocab)).astype(jnp.int32)


def init_field_tables(rng, vocab_sizes: Sequence[int], dim: int,
                      *, dtype=jnp.float32) -> Dict[str, jax.Array]:
    """One table per categorical field (recsys layout)."""
    keys = jax.random.split(rng, len(vocab_sizes))
    return {f"field{i}": init_table(keys[i], v, dim, dtype=dtype)
            for i, v in enumerate(vocab_sizes)}


def field_lookup(tables: Dict[str, jax.Array], ids: jax.Array) -> jax.Array:
    """ids (B, F) with per-field tables -> (B, F, dim)."""
    cols = [embedding_lookup(tables[f"field{i}"], ids[:, i])
            for i in range(ids.shape[1])]
    return jnp.stack(cols, axis=1)


__all__ = ["init_table", "embedding_lookup", "embedding_bag",
           "embedding_bag_ragged", "hash_bucket", "init_field_tables",
           "field_lookup"]
