"""repro.train — optimizer, trainer loop, checkpointing, fault tolerance."""
from repro.train.optimizer import (OptimizerConfig, OptState, adamw_update,
                                   init_opt_state, schedule_lr)
from repro.train.checkpoint import CheckpointManager
from repro.train.resilience import FailureSupervisor, StragglerMonitor
from repro.train.trainer import (TrainOptions, TrainState, Trainer,
                                 init_train_state, make_train_step)
