"""Checkpointing: atomic, async, keep-k, and elastic (mesh-agnostic restore).

Format: one ``arrays.npz`` (flat path->array) + ``meta.json`` per step dir.
Writes go to ``<dir>/tmp.<step>`` then os.replace -> ``<dir>/step_<n>`` so a
crash mid-write never corrupts the latest checkpoint (restart safety).

Elastic restore: arrays are saved as plain host arrays; ``restore`` takes the
*current* shardings (whatever mesh exists after a failure — e.g. one pod lost,
(2,16,16) -> (16,16)) and device_puts into them. Nothing about the saved file
binds it to the mesh it was trained on.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(re.sub(r"[\[\]'\.]", "", str(p)) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten(tree: Any, arrays: Dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        key = "/".join(re.sub(r"[\[\]'\.]", "", str(p)) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        a = arrays[key]
        if hasattr(leaf, "shape") and tuple(a.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"ckpt {a.shape} vs target {leaf.shape}")
        leaves.append(a)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 save_interval: int = 100, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.save_interval = save_interval
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- write ------------------------------------------------------------
    def save(self, step: int, state: Any, meta: Optional[dict] = None,
             block: bool = False):
        # snapshot to host before handing to the writer thread
        arrays = _flatten(jax.device_get(state))
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f"tmp.{step}")
            final = os.path.join(self.dir, f"step_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            # bf16 has no numpy dtype <-> npz support everywhere; view as u16
            view, dtypes = {}, {}
            for k, a in arrays.items():
                if a.dtype == jax.numpy.bfloat16:
                    view[k] = a.view(np.uint16)
                    dtypes[k] = "bfloat16"
                else:
                    view[k] = a
                    dtypes[k] = str(a.dtype)
            np.savez(os.path.join(tmp, "arrays.npz"), **view)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, "dtypes": dtypes,
                           "meta": meta or {}, "time": time.time()}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if self.async_write and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def maybe_save(self, step: int, state: Any, meta: Optional[dict] = None):
        if step > 0 and step % self.save_interval == 0:
            self.save(step, state, meta)
            return True
        return False

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- read -------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Any:
        """Restore into the structure of ``target``. ``shardings`` (a pytree
        of NamedSharding matching target) makes restore elastic: arrays land
        directly on the current mesh regardless of the saving mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        raw = np.load(os.path.join(d, "arrays.npz"))
        arrays = {}
        for k in raw.files:
            a = raw[k]
            if meta["dtypes"].get(k) == "bfloat16":
                a = a.view(jax.numpy.bfloat16)
            arrays[k] = a
        tree = _unflatten(target, arrays)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree

    def restore_meta(self, step: Optional[int] = None) -> dict:
        step = step if step is not None else self.latest_step()
        with open(os.path.join(self.dir, f"step_{step:010d}", "meta.json")) as f:
            return json.load(f)


__all__ = ["CheckpointManager"]
