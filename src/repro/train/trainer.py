"""Training loop: jitted step factory + orchestration (checkpoint, straggler
monitoring, failure recovery, grad accumulation, gradient compression).

``make_train_step`` builds one jitted function from any
``loss_fn(params, batch, rng) -> (loss, metrics)``; the same factory serves
the DTI LM, the sliding-window baseline, recsys and GNN archs (they differ
only in loss_fn), so every paradigm shares one runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.clock import monotonic
from repro.obs.trace import NULL_TRACER
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import (OptimizerConfig, OptState, adamw_update,
                                   ef_compress_grads, init_opt_state)
from repro.train.resilience import StragglerMonitor


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    ef_error: Optional[Any]      # error-feedback residual (compression on)


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    grad_accum: int = 1
    compress_grads: bool = False
    donate: bool = True


def init_train_state(params, opt_cfg: OptimizerConfig,
                     options: TrainOptions = TrainOptions()) -> TrainState:
    ef = None
    if options.compress_grads:
        ef = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)
    return TrainState(params, init_opt_state(opt_cfg, params), ef)


def make_train_step(loss_fn: Callable, opt_cfg: OptimizerConfig,
                    options: TrainOptions = TrainOptions(),
                    in_shardings=None, out_shardings=None, jit: bool = True):
    """loss_fn(params, batch, rng) -> (loss, metrics-dict)."""

    def step(state: TrainState, batch, rng):
        if options.grad_accum > 1:
            def micro(carry, mb):
                g_acc, l_acc, rng = carry
                rng, sub = jax.random.split(rng)
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb, sub)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss, rng), None

            mb = jax.tree_util.tree_map(
                lambda x: x.reshape(options.grad_accum,
                                    x.shape[0] // options.grad_accum,
                                    *x.shape[1:]), batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss, _), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32), rng), mb)
            n = float(options.grad_accum)
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
            loss = loss / n
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch, rng)

        ef_error = state.ef_error
        if options.compress_grads:
            grads, ef_error = ef_compress_grads(grads, ef_error)

        params, opt, stats = adamw_update(opt_cfg, grads, state.opt,
                                          state.params)
        metrics = dict(metrics or {})
        metrics.update(loss=loss, **stats)
        return TrainState(params, opt, ef_error), metrics

    if not jit:
        return step
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    return jax.jit(step, donate_argnums=(0,) if options.donate else (), **kw)


@dataclasses.dataclass
class Trainer:
    """Step-loop orchestration with checkpoint/restart + straggler signals.

    Timing discipline: the first executed step pays XLA compilation, so
    folding it into throughput makes tok/s lie on short runs. The loop
    records it separately (``compile_s``) from the steady-state
    accumulators (``steady_s`` / ``steady_steps``); ``timing()`` reports
    both, and ``launch.train`` derives steady tokens/s from the steady
    half only. Per-step ``sec`` entries in ``history`` are unchanged
    (the first record still carries its compile-inclusive duration).
    """
    step_fn: Callable
    state: TrainState
    ckpt: Optional[CheckpointManager] = None
    monitor: Optional[StragglerMonitor] = None
    log_every: int = 10
    log_fn: Callable[[str], None] = print
    tracer: Any = None                 # repro.obs.trace.SpanTracer or None

    step: int = 0
    history: list = dataclasses.field(default_factory=list)
    compile_s: Optional[float] = None  # first executed step (compile+run)
    steady_s: float = 0.0              # sum of post-compile step times
    steady_steps: int = 0

    def timing(self) -> Dict[str, float]:
        """Compile-vs-steady split of this trainer's executed steps:
        ``compile_s`` (first step, XLA compile included), ``step_s``
        (mean steady-state step) and ``steady_steps`` (how many steps
        back that mean)."""
        step_s = self.steady_s / self.steady_steps if self.steady_steps \
            else 0.0
        return {"compile_s": float(self.compile_s or 0.0),
                "step_s": step_s, "steady_steps": self.steady_steps}

    def resume_if_possible(self):
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            self.state = self.ckpt.restore(self.state)
            self.step = self.ckpt.restore_meta()["step"]
            self.log_fn(f"[trainer] resumed from step {self.step}")

    def run(self, batches: Iterator, *, n_steps: int, rng=None,
            host_time_fn: Optional[Callable[[int, float], Dict[int, float]]] = None):
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        tracer = self.tracer if self.tracer is not None else NULL_TRACER
        target = self.step + n_steps
        for batch in batches:
            if self.step >= target:
                break
            rng, sub = jax.random.split(rng)
            t0 = monotonic()
            with tracer.span("train.step", step=self.step + 1):
                self.state, metrics = self.step_fn(self.state, batch, sub)
                jax.block_until_ready(metrics["loss"])
            dt = monotonic() - t0
            if self.compile_s is None:
                self.compile_s = dt
            else:
                self.steady_s += dt
                self.steady_steps += 1
            self.step += 1
            rec = {k: float(v) for k, v in metrics.items()}
            rec.update(step=self.step, sec=dt)
            self.history.append(rec)
            if self.monitor is not None:
                times = (host_time_fn(self.step, dt) if host_time_fn
                         else {0: dt})
                report = self.monitor.update(self.step, times)
                if report.stragglers:
                    self.log_fn(f"[straggler] step {self.step}: "
                                f"hosts {report.stragglers} "
                                f"worst/median={report.worst_ratio:.2f}")
            if self.ckpt is not None:
                self.ckpt.maybe_save(self.step, self.state,
                                     meta={"step": self.step})
            if self.step % self.log_every == 0:
                self.log_fn(f"[step {self.step}] loss={rec['loss']:.4f} "
                            f"lr={rec.get('lr', 0):.2e} {dt*1e3:.0f}ms")
        if self.ckpt is not None:
            self.ckpt.save(self.step, self.state, meta={"step": self.step},
                           block=True)
        return self.history


__all__ = ["TrainState", "TrainOptions", "init_train_state",
           "make_train_step", "Trainer"]
