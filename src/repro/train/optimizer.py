"""AdamW + LR schedules (cosine and WSD) + grad clipping. No optax installed —
built from scratch, optax-compatible in spirit (init/update pair).

Supports fp32 master weights over bf16 params (``master_fp32``), trainable-
subset masking (LoRA fine-tuning trains only lora_a/lora_b leaves), and
ZeRO-1-style optimizer-state sharding hooks (state pytree mirrors the param
pytree, so ``repro.sharding.partition`` can lay it out over the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 1e-3
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.001
    grad_clip: float = 1.0
    schedule: str = "cosine"        # "cosine" | "wsd" | "const"
    warmup_steps: int = 100
    total_steps: int = 1000
    decay_frac: float = 0.1         # WSD: fraction of steps in decay phase
    min_lr_frac: float = 0.1
    master_fp32: bool = True
    trainable: Optional[str] = None  # None = all, "lora" = lora_* leaves only


class OptState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params
    master: Optional[Params]


def schedule_lr(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, s / jnp.maximum(cfg.warmup_steps, 1))
    if cfg.schedule == "const":
        return cfg.lr * warm
    if cfg.schedule == "cosine":
        t = jnp.clip((s - cfg.warmup_steps)
                     / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)
    if cfg.schedule == "wsd":
        # Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395)
        decay_start = cfg.total_steps * (1 - cfg.decay_frac)
        t = jnp.clip((s - decay_start)
                     / jnp.maximum(cfg.total_steps - decay_start, 1), 0, 1)
        stable = 1.0 - (1 - cfg.min_lr_frac) * t
        return cfg.lr * warm * stable
    raise ValueError(cfg.schedule)


def _trainable_mask(cfg: OptimizerConfig, params: Params) -> Params:
    if cfg.trainable is None:
        return jax.tree_util.tree_map(lambda _: True, params)
    assert cfg.trainable == "lora"
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    vals = [any("lora" in str(k) for k in path) for path, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, vals)


def _decay_mask(params: Params) -> Params:
    """No weight decay on norms / biases / scalars."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    vals = []
    for path, leaf in flat:
        name = str(path[-1]) if path else ""
        decay = (hasattr(leaf, "ndim") and leaf.ndim >= 2
                 and "scale" not in name and "bias" not in name)
        vals.append(decay)
    return jax.tree_util.tree_unflatten(treedef, vals)


def init_opt_state(cfg: OptimizerConfig, params: Params) -> OptState:
    mask = _trainable_mask(cfg, params)
    zeros = jax.tree_util.tree_map(
        lambda p, m: jnp.zeros_like(p, jnp.float32) if m else jnp.zeros((), jnp.float32),
        params, mask)
    master = None
    if cfg.master_fp32:
        # copy=True: fp32 params must not alias the master buffer (donation
        # of TrainState would otherwise donate the same buffer twice).
        master = jax.tree_util.tree_map(
            lambda p, m: (jnp.array(p, dtype=jnp.float32, copy=True)
                          if m else jnp.zeros((), jnp.float32)),
            params, mask)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree_util.tree_map(jnp.copy, zeros), master=master)


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: OptimizerConfig, grads: Params, state: OptState,
                 params: Params, *, shard_specs=None):
    """Returns (new_params, new_state, stats).

    ``shard_specs`` (pytree of NamedSharding mirroring params): pins the
    freshly-updated bf16 params to the optimizer-shard layout BEFORE they
    are gathered back to the param layout — without it XLA all-gathers the
    fp32 master first and converts after (2x gather traffic and +12 GiB of
    fp32 gather buffers on minicpm-2b/dp)."""
    mask = _trainable_mask(cfg, params)
    dmask = _decay_mask(params)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.betas
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    use_master = cfg.master_fp32
    specs = (shard_specs if shard_specs is not None
             else jax.tree_util.tree_map(lambda _: None, params))

    def upd(g, mu, nu, p, master, m, dm, spec):
        if not m:
            return p, mu, nu, master
        g = g.astype(jnp.float32) * clip
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        upd = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        base = master if use_master else p.astype(jnp.float32)
        if dm:
            upd = upd + cfg.weight_decay * base
        new_master = base - lr * upd
        new_p = new_master.astype(p.dtype)
        if spec is not None and getattr(p, "ndim", 0):
            new_p = jax.lax.with_sharding_constraint(new_p, spec)
        return new_p, mu, nu, new_master

    masters = state.master if state.master is not None else params
    out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params,
                                 masters, mask, dmask, specs,
                                 is_leaf=lambda x: x is None)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_master = None
    if cfg.master_fp32:
        new_master = jax.tree_util.tree_map(
            lambda t: t[3], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step, new_mu, new_nu, new_master), \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# gradient compression (int8 error feedback) — optional DP-collective saver
# ---------------------------------------------------------------------------

def compress_int8(g: jax.Array):
    """Per-tensor symmetric int8 quantisation. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads: Params, error: Params):
    """Error-feedback compression (1-bit-Adam style, arXiv:2102.02888):
    quantise (g + e), carry the residual e' = (g + e) - dq(q). Cuts DP
    all-reduce bytes 4x; the residual keeps it unbiased over time."""
    def one(g, e):
        t = g.astype(jnp.float32) + e
        q, s = compress_int8(t)
        deq = decompress_int8(q, s)
        return deq, t - deq
    pairs = jax.tree_util.tree_map(one, grads, error)
    deq = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err


__all__ = ["OptimizerConfig", "OptState", "init_opt_state", "adamw_update",
           "schedule_lr", "global_norm", "compress_int8", "decompress_int8",
           "ef_compress_grads"]
