"""Fault-tolerance runtime pieces: straggler monitor + failure supervisor.

On a real multi-pod deployment the supervisor wraps the step loop: step
timings stream into the StragglerMonitor (per-host EWMA; in a single-process
container host timings are simulated by the tests), and any step exception
triggers restore-from-checkpoint with a freshly built mesh — possibly smaller
(elastic), since CheckpointManager.restore is mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.obs.clock import monotonic


@dataclasses.dataclass
class StragglerReport:
    step: int
    host_times: Dict[int, float]
    stragglers: List[int]
    p50: float
    worst_ratio: float


class StragglerMonitor:
    """EWMA per-host step-time tracker.

    A host is flagged when its EWMA exceeds ``threshold`` x the fleet median
    for ``patience`` consecutive steps — the hook a scheduler uses to
    re-slice or evict (we surface the signal; acting on it is deployment
    policy)."""

    def __init__(self, n_hosts: int, *, alpha: float = 0.2,
                 threshold: float = 1.5, patience: int = 3):
        self.n_hosts = n_hosts
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.ewma = np.zeros(n_hosts)
        self.strikes = np.zeros(n_hosts, dtype=int)
        self.initialized = False

    def update(self, step: int, host_times: Dict[int, float]) -> StragglerReport:
        t = np.array([host_times[h] for h in range(self.n_hosts)])
        if not self.initialized:
            self.ewma = t.astype(float)
            self.initialized = True
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * t
        med = float(np.median(self.ewma))
        over = self.ewma > self.threshold * med
        self.strikes = np.where(over, self.strikes + 1, 0)
        flagged = np.flatnonzero(self.strikes >= self.patience).tolist()
        worst = float(self.ewma.max() / max(med, 1e-9))
        return StragglerReport(step, dict(enumerate(t)), flagged, med, worst)


class FailureSupervisor:
    """Wraps a step function with restore-on-failure semantics.

    run(state) executes steps; on exception (device loss, preemption), it
    calls ``recover`` (restore last checkpoint + rebuild mesh) and resumes.
    ``max_failures`` bounds the retry budget.
    """

    def __init__(self, recover: Callable[[], object], *, max_failures: int = 3):
        self.recover = recover
        self.max_failures = max_failures
        self.failures = 0
        self.events: List[dict] = []

    def attempt(self, fn: Callable[[], object]):
        while True:
            try:
                return fn()
            except Exception as e:  # noqa: BLE001 — deliberate catch-all
                self.failures += 1
                # monotonic timestamp: event spacing is what matters here,
                # and it must survive wall-clock jumps
                self.events.append({"time": monotonic(), "error": repr(e)})
                if self.failures > self.max_failures:
                    raise
                fn = self._resume_wrapper(fn)

    def _resume_wrapper(self, fn):
        state = self.recover()

        def rerun():
            return fn() if state is None else fn()
        return rerun


__all__ = ["StragglerMonitor", "StragglerReport", "FailureSupervisor"]
