"""repro.sharding — GSPMD partition rules per model family."""
from repro.sharding.partition import (batch_spec, data_axis, dp_size,
                                      leaf_path_str, make_param_specs,
                                      rules_for, spec_for_shape, zero1_specs)
