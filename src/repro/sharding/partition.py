"""Leaf-path -> PartitionSpec rules (GSPMD layout policy per model family).

The resolver walks a params (or optimizer-state) pytree, matches each leaf's
path against ordered regex rules, and emits a NamedSharding. Two safety
passes make the rules robust across all 10 assigned archs:

* **divisibility** — an axis entry is kept only if the corresponding dim is
  divisible by the mesh axis size (vocab 122753 is odd, DIN's embed_dim is
  18, minicpm has 36 heads ... rules stay generic, the resolver drops what
  does not fit instead of failing the compile).
* **zero1** — optionally re-shards optimizer-state leaves over the data axis
  on their largest still-unsharded dim (ZeRO-1: optimizer memory scales with
  1/(dp*tp) while params keep their TP-only layout).

Rules use axis aliases resolved against the actual mesh:
  "data"  -> ("pod", "data") on the multi-pod mesh, "data" on single-pod
  "model" -> "model"
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisEntry = Union[None, str, Tuple[str, ...]]
Rule = Tuple[str, Tuple[AxisEntry, ...]]


def leaf_path_str(path) -> str:
    """KeyPath -> 'stack/attn/q/w' style string."""
    parts = []
    for p in path:
        s = str(p)
        s = re.sub(r"[\[\]'\.]", "", s)
        parts.append(s)
    return "/".join(parts)


def _resolve_axis(axis: AxisEntry, mesh: Mesh) -> AxisEntry:
    """Map alias axes onto the actual mesh ('data' spans pod+data if present)."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        flat: list = []
        for a in axis:
            r = _resolve_axis(a, mesh)
            if isinstance(r, tuple):
                flat.extend(x for x in r if x not in flat)
            elif r is not None and r not in flat:
                flat.append(r)
        return tuple(flat) if flat else None
    if axis == "data" and "pod" in mesh.axis_names:
        return ("pod", "data")
    return axis if axis in mesh.axis_names else None


def _axis_size(axis: AxisEntry, mesh: Mesh) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def spec_for_shape(shape: Sequence[int], template: Tuple[AxisEntry, ...],
                   mesh: Mesh) -> P:
    """Right-align the template onto the shape (scan stacking prepends a
    layer dim) and drop entries whose dim is not divisible."""
    n = len(shape)
    tpl: List[AxisEntry] = list(template)
    if len(tpl) < n:                       # leading (layer) dims unsharded
        tpl = [None] * (n - len(tpl)) + tpl
    elif len(tpl) > n:
        tpl = tpl[len(tpl) - n:]
    out: List[AxisEntry] = []
    for dim, axis in zip(shape, tpl):
        axis = _resolve_axis(axis, mesh)
        if axis is not None and dim % _axis_size(axis, mesh) != 0:
            axis = None
        out.append(axis)
    return P(*out)


def make_param_specs(params_shape: Any, rules: List[Rule], mesh: Mesh,
                     *, default: Tuple[AxisEntry, ...] = ()) -> Any:
    """Pytree of ShapeDtypeStruct/arrays -> pytree of NamedSharding."""
    compiled = [(re.compile(pat), tpl) for pat, tpl in rules]

    def one(path, leaf):
        key = leaf_path_str(path)
        shape = getattr(leaf, "shape", ())
        if not shape:
            return NamedSharding(mesh, P())
        for pat, tpl in compiled:
            if pat.search(key):
                return NamedSharding(mesh, spec_for_shape(shape, tpl, mesh))
        return NamedSharding(mesh, spec_for_shape(shape, default, mesh))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def zero1_specs(params_shape: Any, param_specs: Any, mesh: Mesh,
                *, axis: Union[str, Tuple[str, ...]] = "data") -> Any:
    """Optimizer-state layout: param spec + the given axis on the largest
    unsharded dim (divisibility permitting). The AdamW mu/nu/master trees
    mirror the param tree, so the same specs apply leaf-for-leaf. Pass
    ``axis=("data", "model")`` (pure-DP profiles) to shard optimizer state
    over the whole mesh."""
    dp = _resolve_axis(axis, mesh)
    size = _axis_size(dp, mesh)

    def used(entry) -> bool:
        ax = entry if isinstance(entry, tuple) else (entry,)
        dps = dp if isinstance(dp, tuple) else (dp,)
        return any(a in dps for a in ax if a is not None)

    def one(leaf, ns):
        shape = getattr(leaf, "shape", ())
        spec = list(ns.spec) + [None] * (len(shape) - len(ns.spec))
        if any(used(e) for e in spec if e is not None):
            return ns                      # FSDP profile already uses data
        best, best_dim = -1, 0
        for i, (dim, ax) in enumerate(zip(shape, spec)):
            if ax is None and dim % size == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best >= 0:
            spec[best] = dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, params_shape, param_specs)


def batch_spec(mesh: Mesh, *entries: AxisEntry) -> NamedSharding:
    return NamedSharding(mesh, P(*[_resolve_axis(e, mesh) for e in entries]))


def data_axis(mesh: Mesh) -> AxisEntry:
    return _resolve_axis("data", mesh)


def dp_size(mesh: Mesh) -> int:
    return _axis_size(_resolve_axis("data", mesh), mesh)


# ===========================================================================
# Per-family rule tables
# ===========================================================================

# Dense / GQA / MLA decoder, TP-only (params replicated over data).
LM_TP_RULES: List[Rule] = [
    (r"embed$",                 (None, "model")),       # (V, d): d sharded
    (r"lm_head/w$",             ("model", None)),       # (d, V): row-parallel
    (r"attn/(q|k|v)(_up)?/w$",  (None, "model")),       # col-parallel heads
    (r"attn/(q|k|v)/b$",        ("model",)),
    (r"attn/o/w$",              ("model", None)),       # row-parallel
    (r"attn/(q|kv)_down/w$",    (None, None)),          # small latents: repl.
    (r"attn/kv_up/w$",          (None, "model")),
    (r"attn/k_rope/w$",         (None, None)),
    (r"ffn/(gate|up)/w$",       (None, "model")),
    (r"ffn/down/w$",            ("model", None)),
    (r"shared/(gate|up)/w$",    (None, "model")),
    (r"shared/down/w$",         ("model", None)),
    (r"w_gate$|w_up$",          (None, None, "model")), # experts (E, d, f)
    (r"w_down$",                (None, "model", None)), # (E, f, d)
    (r"router/w$",              (None, None)),
    (r"lora_a$",                (None, None)),
    (r"lora_b$",                (None, "model")),
]

# FSDP+TP 2D for big models (deepseek-v2): the second large dim of every
# weight shards over "data" — GSPMD resolves the token-vs-weight axis clash
# by feature-resharding activations, which is acceptable as long as the
# token count per pass is bounded (train microbatches 16-way; prefill
# chunks its batch — see _lm_prefill_cell). An alternative expert-only 2D
# layout (experts/model + d_ff/data, dense TP-only) was measured WORSE: the
# unsharded expert capacity dim replicated expert FLOPs ~80x (hypothesis
# log, EXPERIMENTS.md §Perf).
LM_FSDP_TP_RULES: List[Rule] = [
    (r"embed$",                 ("data", "model")),
    (r"lm_head/w$",             ("model", "data")),
    (r"attn/(q|k|v)(_up)?/w$",  ("data", "model")),
    (r"attn/(q|k|v)/b$",        ("model",)),
    (r"attn/o/w$",              ("model", "data")),
    (r"attn/(q|kv)_down/w$",    ("data", None)),
    (r"attn/kv_up/w$",          (None, "model")),
    (r"attn/k_rope/w$",         ("data", None)),
    (r"ffn/(gate|up)/w$",       ("data", "model")),
    (r"ffn/down/w$",            ("model", "data")),
    (r"shared/(gate|up)/w$",    ("data", "model")),
    (r"shared/down/w$",         ("model", "data")),
    (r"w_gate$|w_up$",          ("model", "data", None)),  # (E, d, f): EP+d/dp
    (r"w_down$",                ("model", None, "data")),  # (E, f, d)
    (r"router/w$",              (None, None)),
    (r"lora_a$",                ("data", None)),
    (r"lora_b$",                (None, "model")),
]

# RecSys: tables column-sharded over model when dim divides, else row-sharded.
RECSYS_RULES: List[Rule] = [
    (r"tables/|linear/",        ("model", None)),   # per-field tables: rows
    (r"items$",                 ("model", None)),   # item table: row-sharded
    (r"pos$",                   (None, None)),
    (r"(dnn|head|attn|ffn|cin_out|fc\d)/.*w$", (None, "model")),
    (r"cin/",                   (None, None, None)),
    (r"s_matrix$",              (None, "model")),
]

# GNN: small model, replicate params (edges carry the parallelism).
GNN_RULES: List[Rule] = []


# Pure data parallelism: params replicated everywhere (grads sync once per
# step), optimizer state ZeRO-1-sharded over the WHOLE mesh. For <=4B-param
# dense models at 1M-token batches this beats TP by >10x on collective
# bytes (hillclimb log, EXPERIMENTS.md §Perf): TP pays 4 activation
# all-reduces per layer per microbatch, DP pays one 2x|params| all-reduce
# per step.
LM_DP_RULES: List[Rule] = []


# Variant: dense layers TP-only, routed experts 2D (E over model, d_ff over
# data). Hurts prefill (expert capacity replication) but relieves the dense
# activation-resharding storm in training — measured per cell in §Perf.
LM_EP_TP_RULES: List[Rule] = [r for r in LM_TP_RULES
                              if not r[0].startswith(r"w_")] + [
    (r"w_gate$|w_up$",          ("model", None, "data")),
    (r"w_down$",                ("model", "data", None)),
]


# ===========================================================================
# Serving-cache layout (decode KV caches, repro.serve.cache)
# ===========================================================================

# Leaf-key -> axis template for the two cache layouts. Templates are matched
# by exact key (the cache is a flat dict, not a nested pytree) and resolved
# through ``spec_for_shape`` so the usual safety passes apply: an axis entry
# is dropped when the dim is not divisible (n_kv_heads=2 on a model=4 mesh
# serves replicated heads instead of failing the compile), and "data"
# resolves to ("pod", "data") on a multi-pod mesh.
#
# Paged caches carry one *global* slot axis shared by every row —
# ``k/v (L, n_tot, Hk, d)`` — which shards over "data": each data shard owns
# a contiguous range of the page pool, and the page-table gather crosses
# shards only when a row's pages actually land on another shard (GSPMD
# inserts the collective). Contiguous caches shard their row axis
# ``(L, B, cap, ...)`` over "data" instead. KV heads shard over "model" in
# both layouts; the int8 scale sidecars ride the same axes as their codes,
# so a page stays self-describing per shard. Bookkeeping (``pos``,
# ``cursor``, ``ref``, ``page_table``) is replicated: it is host-mirrored
# int32 state that every shard's decode step reads in full.
_CACHE_PAGED_TPL: Dict[str, Tuple[AxisEntry, ...]] = {
    "k":         (None, "data", "model", None),
    "v":         (None, "data", "model", None),
    "k_scale":   (None, "data", "model"),
    "v_scale":   (None, "data", "model"),
    "ckv":       (None, "data", None),
    "kpe":       (None, "data", None),
    "ckv_scale": (None, "data"),
    "kpe_scale": (None, "data"),
}
_CACHE_CONTIG_TPL: Dict[str, Tuple[AxisEntry, ...]] = {
    "k":         (None, "data", None, "model", None),
    "v":         (None, "data", None, "model", None),
    "k_scale":   (None, "data", None, "model"),
    "v_scale":   (None, "data", None, "model"),
    "ckv":       (None, "data", None, None),
    "kpe":       (None, "data", None, None),
    "ckv_scale": (None, "data", None),
    "kpe_scale": (None, "data", None),
}


def cache_specs(cache: Any, mesh: Mesh) -> Dict[str, NamedSharding]:
    """NamedSharding per cache-dict key (concrete cache or ``cache_shape``
    spec): the serving-side layout policy. KV codes and scale sidecars
    shard their slot axis over "data" and the kv-head axis over "model"
    (divisibility permitting); bookkeeping replicates. The donated decode
    chain keeps these shardings step over step, so committing the cache
    once at scheduler construction pins the whole serving run's layout."""
    paged = "page_table" in cache
    tpl = _CACHE_PAGED_TPL if paged else _CACHE_CONTIG_TPL
    out: Dict[str, NamedSharding] = {}
    for key, leaf in cache.items():
        t = tpl.get(key, ())
        out[key] = NamedSharding(mesh, spec_for_shape(leaf.shape, t, mesh))
    return out


def serve_param_specs(params: Any, cfg, mesh: Mesh) -> Any:
    """LM TP specs restricted to *whole-head* granularity on the attention
    projections — the serving-side param layout.

    The generic divisibility pass checks the fused ``heads * head_dim``
    projection axis (64 for 2 kv heads of 32), which a model=4 axis splits
    into *half heads*. RoPE then mixes elements across the shard boundary
    inside each head, and that rotate-half pattern on a sub-head shard
    miscompiles under GSPMD on CPU (jax 0.4.37): a forward pass with only
    ``attn.k.w`` sharded 4-ways drifts by ~1e-1 while whole-head shardings
    (q with 4 heads, or k on a model=2 axis) match the replicated run to
    float32 noise. So here an attention projection keeps its "model" axis
    only when the *head count* divides the axis size; everything else
    (embed, lm_head, ffn) keeps the plain TP layout. Non-GQA attention
    (MLA's low-rank stacks carry their own rope sub-blocks) replicates the
    whole attn subtree for the same reason."""
    specs = make_param_specs(params, rules_for("lm", "tp"), mesh)
    size = mesh.shape.get("model", 1)
    if size == 1:
        return specs

    def heads(proj: str) -> int:
        return cfg.n_heads if proj in ("q", "o") else cfg.n_kv_heads

    def fix(kp, sharding):
        keys = [getattr(k, "key", str(k)) for k in kp]
        if "attn" not in keys:
            return sharding
        if cfg.attn_type != "gqa":
            return NamedSharding(mesh, P())
        i = keys.index("attn")
        proj = keys[i + 1] if i + 1 < len(keys) else ""
        if proj in ("q", "k", "v", "o") and heads(proj) % size == 0:
            return sharding
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(fix, specs)


def rules_for(family: str, profile: str = "tp") -> List[Rule]:
    if family == "lm":
        return {"tp": LM_TP_RULES, "fsdp_tp": LM_FSDP_TP_RULES,
                "dp": LM_DP_RULES, "ep_tp": LM_EP_TP_RULES}[profile]
    if family == "recsys":
        return RECSYS_RULES
    if family == "gnn":
        return GNN_RULES
    raise ValueError(family)


__all__ = ["make_param_specs", "zero1_specs", "batch_spec", "data_axis",
           "dp_size", "spec_for_shape", "rules_for", "leaf_path_str",
           "cache_specs", "serve_param_specs",
           "LM_TP_RULES", "LM_FSDP_TP_RULES", "RECSYS_RULES", "GNN_RULES"]
