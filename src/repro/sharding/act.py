"""Activation sharding policy: trace-time ambient mesh + token pinning.

GSPMD left alone propagates *weight* shardings into the residual stream —
with TP rules the hidden state ends up feature-sharded and every layer pays
full-width activation all-gathers/all-reduces; with FSDP rules it ends up
token-UNsharded (8 GiB fp32 intermediates at 1M tokens). Pinning the layer
boundary to token-sharded (batch over the data axis, features replicated)
is the Megatron/MaxText discipline; XLA then moves the *weights* (small,
per-layer, loop-hoistable) instead of the activations.

``activation_mesh(mesh)`` is a trace-time context: cell builders wrap their
step fns so the constraint applies no matter where jit traces them. When no
mesh is active (unit tests, CPU training) ``constrain_tokens`` is identity.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding.partition import spec_for_shape

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def activation_mesh(mesh: Optional[Mesh], batch_axis="data",
                    tensor_axis: Optional[str] = None):
    prev = (current_mesh(), getattr(_STATE, "batch_axis", "data"),
            getattr(_STATE, "tensor_axis", None))
    _STATE.mesh = mesh
    _STATE.batch_axis = batch_axis
    _STATE.tensor_axis = tensor_axis
    try:
        yield
    finally:
        _STATE.mesh, _STATE.batch_axis, _STATE.tensor_axis = prev


def constrain_tokens(x: jax.Array, kind: str = "boundary") -> jax.Array:
    """Pin activations to the profile's layout; identity when no activation
    mesh is active or an axis does not divide.

    kinds (Megatron discipline — batch over data everywhere):
      boundary  (B, S, d)     features replicated (post-all-reduce state)
      heads     (B, S, H, hd) H over "model" under TP, replicated under DP
      ffn       (B, S, f)     f over "model" under TP, replicated under DP

    Without these pins GSPMD materialises *global* activations for weight-
    gradient contractions (a 22.5 GiB all-gather of (256, 4096, 5760) fp32
    in the minicpm-2b/dp cell) or feature-reshards the residual stream
    (§Perf log)."""
    mesh = current_mesh()
    if mesh is None or not hasattr(x, "ndim") or x.ndim < 2:
        return x
    batch = getattr(_STATE, "batch_axis", "data")
    tp = getattr(_STATE, "tensor_axis", None)
    if kind == "heads" and tp is not None and x.ndim >= 3:
        axes = (batch,) + (None,) * (x.ndim - 3) + (tp, None)
    elif kind == "ffn" and tp is not None:
        axes = (batch,) + (None,) * (x.ndim - 2) + (tp,)
    else:
        axes = (batch,) + (None,) * (x.ndim - 1)
    spec = spec_for_shape(x.shape, axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def with_activation_mesh(fn, mesh: Optional[Mesh], batch_axis="data",
                         tensor_axis: Optional[str] = None):
    """Wrap a step fn so the policy is active while it traces."""
    if mesh is None:
        return fn

    def wrapped(*args, **kwargs):
        with activation_mesh(mesh, batch_axis, tensor_axis):
            return fn(*args, **kwargs)

    return wrapped


__all__ = ["activation_mesh", "constrain_tokens", "current_mesh",
           "with_activation_mesh"]
