"""Quickstart: the DTI training paradigm in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Build a synthetic CTR corpus (MovieLens-like, learnable labels).
2. Pack user histories into STREAMING prompts (k targets + [SUM] tokens).
3. Train a small decoder with windowed causal attention + the DTI losses.
4. Score held-out interactions with the sliding-window serving path.
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.dti import batch_prompts, build_streaming_prompts
from repro.core.metrics import ctr_metrics
from repro.data.synthetic import make_ctr_dataset, split_users
from repro.launch.train import (build_prompt_sets, evaluate_lm,
                                make_lm_loss_fn)
from repro.models.transformer import init_params
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import init_train_state, make_train_step

K, N_CTX, STEPS = 8, 8, 150

# -- 1. data ----------------------------------------------------------------
cfg = get_arch("dti-llama").smoke          # the paper's arch, CPU width
ds = make_ctr_dataset(n_users=32, n_items=200, seq_len=50,
                      vocab_size=cfg.vocab_size, label_scale=5.0)
splits = split_users(ds)

# -- 2. streaming prompts (the paradigm) -------------------------------------
train_prompts, test_prompts, test_labels, stats = build_prompt_sets(
    ds, splits, paradigm="dti", n_ctx=N_CTX, k=K, max_len=192)
print(f"{stats.n_prompts} streaming prompts carry {stats.n_targets} targets "
      f"in {stats.n_tokens} tokens (sliding-window would cost "
      f"~{K}x more prompt tokens)")

# -- 3. train -----------------------------------------------------------------
params = init_params(jax.random.PRNGKey(0), cfg)
ocfg = OptimizerConfig(lr=1e-3, schedule="cosine", warmup_steps=15,
                       total_steps=STEPS)
step = make_train_step(make_lm_loss_fn(cfg, window=0), ocfg)
state = init_train_state(params, ocfg)
rng = np.random.default_rng(0)

def batches():
    while True:
        yield from batch_prompts(train_prompts, 8, rng=rng)

it = batches()
for i in range(STEPS):
    state, m = step(state, next(it), jax.random.PRNGKey(i))
    if i % 30 == 0:
        print(f"step {i:4d}  loss {float(m['loss']):.4f}")

# -- 4. serve (sliding-window prompts, [SUM] readout) -------------------------
metrics = evaluate_lm(state.params, cfg, 0, test_prompts, test_labels)
print(f"test: AUC={metrics['auc']:.4f}  LogLoss={metrics['log_loss']:.4f} "
      f"F1={metrics['f1']:.4f}")
assert metrics["auc"] > 0.6, "expected learnable signal"
print("quickstart OK")
