"""End-to-end driver: sliding-window vs DTI on one dataset, full runtime.

    PYTHONPATH=src python examples/train_dti_vs_sw.py [--k 10] [--epochs 2]

This is the deliverable (b) training driver at container scale: the same
``repro.launch.train`` stack the production launcher uses — checkpointing
(atomic keep-k, resume), straggler monitor, cosine schedule — applied to
both paradigms back to back, finishing with the wall-clock and quality
comparison that is the paper's headline result.
"""
import argparse
import shutil
import tempfile

from benchmarks.common import ReproSetup, run_paradigm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--epochs", type=float, default=1.0)
    ap.add_argument("--no-pack", action="store_true",
                    help="disable segment-aware prompt packing for DTI")
    ap.add_argument("--attn-impl", default=None, dest="attn_impl",
                    choices=["dense", "blocked", "pallas"],
                    help="attention path for both paradigms; 'pallas' "
                         "trains through the fused kernel's custom VJP "
                         "(interpret mode off-TPU, no blocked fallback)")
    args = ap.parse_args()
    pack = not args.no_pack

    setup = ReproSetup.default()
    # pack both paradigms (or neither) so the headline reduction compares
    # SW vs DTI like-for-like, not packing vs no-packing
    impl_note = f", attn={args.attn_impl}" if args.attn_impl else ""
    print(f"== sliding-window baseline ({args.epochs} epochs, "
          f"{'packed' if pack else 'unpacked'}{impl_note}) ==")
    sw = run_paradigm(setup, paradigm="sw", k=1, epochs=args.epochs,
                      pack=pack, attn_impl=args.attn_impl)
    print(f"   time {sw['train_time_s']:.1f}s  AUC {sw['auc']:.4f} "
          f"LogLoss {sw['log_loss']:.4f}  pad {sw['pad_fraction']:.1%}")

    print(f"== DTI k={args.k} ({args.epochs} epochs, "
          f"{'packed' if pack else 'unpacked'}{impl_note}) ==")
    dti = run_paradigm(setup, paradigm="dti", k=args.k, epochs=args.epochs,
                       pack=pack, attn_impl=args.attn_impl)
    print(f"   time {dti['train_time_s']:.1f}s  AUC {dti['auc']:.4f} "
          f"LogLoss {dti['log_loss']:.4f}  pad {dti['pad_fraction']:.1%}  "
          f"eff {dti['effective_tokens_per_s']:.0f} tok/s")

    red = (1 - dti["train_time_s"] / sw["train_time_s"]) * 100
    print(f"\nDTI trained in {dti['train_time_s']:.1f}s vs SW "
          f"{sw['train_time_s']:.1f}s  ->  {red:.1f}% reduction "
          f"(paper: ~80-92% for k=10..50), "
          f"dAUC = {dti['auc'] - sw['auc']:+.4f}")


if __name__ == "__main__":
    main()
