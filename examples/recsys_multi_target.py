"""The DTI idea transplanted to a conventional CTR model (DIN).

    PYTHONPATH=src python examples/recsys_multi_target.py

DIN recomputes target-attention over a user's history once per candidate —
the same redundancy the paper eliminates for LLM context. ``din_forward_multi``
shares one history-embedding pass across k targets (DESIGN.md
§Arch-applicability: "partial" DTI). This example measures the training-step
speedup and verifies the multi-target scores equal k single-target passes.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.recsys import (bce_loss, din_forward, din_forward_multi,
                                 init_din)

import dataclasses

# production-shaped table (the smoke config's 1k-row table hides the shared
# cost: what DTI shares in DIN is the history gather + its gradient scatter,
# which only dominates once the table is large)
cfg = dataclasses.replace(get_arch("din").smoke, n_items=1_000_000,
                          embed_dim=32, seq_len=100)
params = init_din(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
B, L, K = 256, cfg.seq_len, 8
hist = jnp.asarray(rng.integers(0, cfg.n_items, (B, L)), jnp.int32)
targets = jnp.asarray(rng.integers(0, cfg.n_items, (B, K)), jnp.int32)
labels = jnp.asarray(rng.integers(0, 2, (B, K)), jnp.float32)

# correctness: multi-target == K single-target passes
multi = din_forward_multi(params, cfg, hist, targets)
for j in range(K):
    single = din_forward(params, cfg, hist, targets[:, j])
    np.testing.assert_allclose(multi[:, j], single, atol=1e-5)
print(f"multi-target DIN == {K} single passes (max diff "
      f"{float(jnp.max(jnp.abs(multi[:, 0] - din_forward(params, cfg, hist, targets[:, 0])))):.1e})")


def time_fn(f, *a, iters=10):
    jax.block_until_ready(f(*a))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3


# The sliding-window protocol delivers each (user, target) pair in its own
# minibatch, so the history gather + gradient scatter repeat per step —
# K separate jitted invocations below. (Folding the K passes into ONE graph
# would let XLA CSE the shared gather, which is precisely the optimization
# DTI makes structural rather than accidental.)
@jax.jit
def grad_single_step(p, hist, target, label):
    def loss(p):
        return bce_loss(din_forward(p, cfg, hist, target), label)
    return jax.grad(loss)(p)


@jax.jit
def grad_multi(p, hist, targets, labels):
    def loss(p):
        return bce_loss(din_forward_multi(p, cfg, hist, targets).reshape(-1),
                        labels.reshape(-1))
    return jax.grad(loss)(p)


t_one = time_fn(grad_single_step, params, hist, targets[:, 0], labels[:, 0])
t1 = t_one * K
t2 = time_fn(grad_multi, params, hist, targets, labels)
print(f"train cost for {K} targets/user: SW protocol = {K} steps x "
      f"{t_one:.1f} ms = {t1:.1f} ms, multi-target (DTI) = {t2:.1f} ms "
      f"->  {t1 / t2:.2f}x speedup")
print("recsys multi-target example OK")
