"""Serving example: batched CTR scoring + the windowed ring-buffer decode.

    PYTHONPATH=src python examples/serve_ctr.py

Part 1 — the paper's inference procedure: sliding-window prompts scored in
batches through CTRServer (one [SUM] readout per request, bi-dimensional
softmax -> p(click)).

Part 2 — the beyond-paper corollary: because training used windowed causal
attention, a user's *stream* can be scored incrementally with a ring-buffer
KV cache whose size never grows — position 10,000 costs exactly as much as
position 100 (this is what makes the long_500k production shape feasible).

Part 3 — multi-target serving (docs/serving.md): one request = one user
context + k candidate items, scored with the context encoded once — the
continuous-batching scheduler prefills the context into a shared cache and
scores the slate as one segment-isolated burst, matching Part 1's scores.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.dti import SpecialTokens, build_sliding_prompts
from repro.data.synthetic import make_ctr_dataset
from repro.models.transformer import init_params
from repro.serve.cache import init_lm_cache
from repro.serve.engine import CTRServer, make_decode_fn
from repro.serve.scheduler import ServeScheduler

SP = SpecialTokens()
cfg = get_arch("dti-llama").smoke
params = init_params(jax.random.PRNGKey(0), cfg)
ds = make_ctr_dataset(n_users=4, n_items=100, seq_len=40,
                      vocab_size=cfg.vocab_size)

# -- Part 1: batched sliding-window scoring ----------------------------------
toks, labels = ds.user_prompt_material(0)
prompts = build_sliding_prompts(toks, labels, n_ctx=6, max_len=128)
server = CTRServer(params, cfg, max_len=128)
t0 = time.perf_counter()
scores = server.score(prompts[:16])
dt = time.perf_counter() - t0
print(f"scored {len(scores)} requests in {dt*1e3:.1f} ms "
      f"(p_click range {min(scores):.3f}..{max(scores):.3f})")

# -- Part 2: incremental stream scoring with a ring cache ---------------------
WINDOW, CAP = 48, 64
decode = jax.jit(make_decode_fn(cfg, window=WINDOW, ring=True),
                 donate_argnums=(1,))
cache = init_lm_cache(cfg, batch=1, capacity=CAP)
stream, stream_labels = [], []
for t, lab in zip(toks, labels):
    stream.extend(t + [SP.sum])
    stream_labels.extend([None] * len(t) + [int(lab)])

p_hist = []
t0 = time.perf_counter()
for pos, (tok, lab) in enumerate(zip(stream, stream_labels)):
    p, cache = decode(params, cache,
                      jnp.asarray([[tok]], jnp.int32),
                      jnp.asarray([[pos]], jnp.int32),
                      jnp.asarray([[tok == SP.sum]]))
    if lab is not None:
        p_hist.append((pos, float(p[0, 0]), lab))
dt = time.perf_counter() - t0
print(f"streamed {len(stream)} tokens through a {CAP}-slot ring cache in "
      f"{dt:.1f}s ({len(p_hist)} targets scored); cache bytes constant "
      f"regardless of stream length")
for pos, p, lab in p_hist[:5]:
    print(f"  pos {pos:4d}: p_click={p:.3f} label={lab}")

# -- Part 3: continuous batching with shared-context KV reuse -----------------
K = 6
context = toks[:8]                       # the user's recent interactions
candidates = [ds.item_tokens[i] for i in range(K)]    # a candidate slate
sched = ServeScheduler(params, cfg, n_slots=2, capacity=128,
                       buckets=(16, 32, 64))
rid = sched.submit(context, candidates)
res = sched.run()[rid]
tel = sched.telemetry()
print(f"scheduler: scored {K} candidates in {sched.n_steps} decode steps, "
      f"{res.cache_hit_fraction:.0%} of prompt tokens served from the "
      f"shared-context cache")
print(f"  latency {res.latency_s*1e3:.1f} ms = queue {res.queue_s*1e3:.1f}"
      f" + service {res.service_s*1e3:.1f}; bucket histogram "
      f"{tel['bucket_steps']} (bursts never inflate the jit shape)")

# same scores as one sliding-window prompt per candidate (part 1's path)
naive = CTRServer(params, cfg, max_len=128)
prompts = []
for cand in candidates:
    prompts += build_sliding_prompts(context + [cand], [0] * (len(context) + 1),
                                     n_ctx=len(context), max_len=128)
np.testing.assert_allclose(res.scores, naive.score(prompts), atol=1e-4)
print("  scores match per-candidate re-prefill")
print("serve example OK")
