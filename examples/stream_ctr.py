"""Continual-training example: close the train->serve loop on a live stream.

    PYTHONPATH=src python examples/stream_ctr.py

Part 1 — warm start: pretrain the repro model on the warm half of every
user's history (batch DTI, packed), and stand up a live ``CTRServer`` on
the resulting weights.

Part 2 — the replay: new interactions arrive in ticks. The incremental
builder (``repro.stream.IncrementalDTI``) emits prompts supervising ONLY
the newly arrived targets; the async ``StreamPipeline`` packs them into
fixed-shape batches; the ``OnlineTrainer`` fine-tunes in place and
publishes weights through a ``ParamPublisher``. For contrast, the same
ticks are costed as periodic full retrains (what the repo could do before
``repro.stream`` existed).

Part 3 — the hot swap: a ``ParamSubscriber`` polls the publisher directory
and swaps fresh weights into the live server between requests — no
restart, no dropped traffic (docs/streaming.md).
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.dti import (PromptStats, batch_prompts,
                            build_streaming_prompts, pack_prompts,
                            train_max_len)
from repro.data.requests import make_event_stream, warm_histories
from repro.data.synthetic import make_ctr_dataset
from repro.models.transformer import init_params
from repro.serve.engine import CTRServer
from repro.stream import (IncrementalDTI, OnlineTrainer, ParamPublisher,
                          ParamSubscriber, StreamPipeline,
                          make_stream_loss_fn)
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import init_train_state, make_train_step

N_CTX, K, BATCH, TICKS = 6, 4, 4, 4
cfg = get_arch("dti-llama").smoke
ds = make_ctr_dataset(n_users=6, n_items=120, seq_len=32,
                      vocab_size=cfg.vocab_size, label_scale=5.0)
max_len = train_max_len(N_CTX, K, ds.avg_item_tokens)
loss_fn = make_stream_loss_fn(cfg, window=0)

# -- Part 1: warm-corpus pretrain + live server -------------------------------
warm = warm_histories(ds, start_frac=0.5)
prompts, stats = [], PromptStats()
for toks, labels in warm:
    if len(toks) > N_CTX:
        prompts += build_streaming_prompts(toks, labels, n_ctx=N_CTX, k=K,
                                           max_len=max_len, stats=stats)
prompts = pack_prompts(prompts, max_len)
ocfg = OptimizerConfig(lr=1e-3, schedule="const", warmup_steps=1,
                       total_steps=10_000)
state = init_train_state(init_params(jax.random.PRNGKey(0), cfg), ocfg)
step_fn = make_train_step(loss_fn, ocfg)
warm_steps = 0
for _ in range(2):
    for b in batch_prompts(prompts, BATCH, rng=np.random.default_rng(0)):
        state, _ = step_fn(state, b, jax.random.PRNGKey(warm_steps))
        warm_steps += 1
base_params = jax.device_get(state.params)
server = CTRServer(base_params, cfg, max_len=max_len)
print(f"[warm] {stats.n_targets} targets, {warm_steps} steps -> live server")

# -- Part 2: replay the stream incrementally ----------------------------------
pub_dir = tempfile.mkdtemp(prefix="stream_pub_")
publisher = ParamPublisher(pub_dir)
inc = IncrementalDTI(n_ctx=N_CTX, k=K, max_len=max_len)
for u, (toks, labels) in enumerate(warm):
    inc.seed_history(u, toks, labels, supervised=True)

trainer = OnlineTrainer(loss_fn, base_params, ocfg, publisher=publisher,
                        publish_every=2, window_targets=32)
ticks = make_event_stream(ds, n_ticks=TICKS, start_frac=0.5, seed=0)
full_retrain_prompts = 0
visible = {u: len(toks) for u, (toks, _) in enumerate(warm)}
for t, tick in enumerate(ticks):
    pipe = StreamPipeline(iter([tick]), inc, batch_size=BATCH)
    trainer.run(pipe.batches())
    # what a periodic full retrain would have cost at this point: one DTI
    # prompt per stride-k group over every user's FULL visible history
    for ev in tick:
        visible[ev["user"]] = max(visible[ev["user"]], ev["index"] + 1)
    full_retrain_prompts += sum(
        max(0, -(-(m - N_CTX) // K)) for m in visible.values())
    print(f"[tick {t}] {len(tick)} events -> {pipe.stats.n_rows} rows, "
          f"{pipe.stats.n_targets} fresh targets "
          f"(pad {pipe.stats.pad_fraction:.2f}); online step {trainer.step}, "
          f"published v{trainer.published_version}")
print(f"[cost] incremental: {trainer.step} steps total; periodic full "
      f"retrain would have rebuilt ~{full_retrain_prompts} prompts over "
      f"{TICKS} retrains")
trainer.flush_windows()
if trainer.eval_windows:
    w = trainer.eval_windows[-1]
    print(f"[drift] last window: auc={w.auc:.3f} logloss={w.log_loss:.3f} "
          f"over {w.n_targets} targets; lifetime progressive "
          f"auc={trainer.lifetime_auc.value():.3f}")

# -- Part 3: hot-swap the live server -----------------------------------------
toks, _ = ds.user_prompt_material(0)
request = [(toks[:N_CTX], [list(ds.item_tokens[i]) for i in (3, 7, 11)])]
before = server.score_multi_target(request)[0]
sub = ParamSubscriber(pub_dir, server.params)
version, fresh = sub.poll()
server.update_params(fresh)
after = server.score_multi_target(request)[0]
print(f"[swap] server picked up v{version}; slate scores "
      f"{np.round(before, 3).tolist()} -> {np.round(after, 3).tolist()} "
      f"(no restart, same jit)")
shutil.rmtree(pub_dir)
