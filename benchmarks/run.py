"""Benchmark aggregator: one function per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default mode runs every benchmark at reduced epochs (fits a CPU budget of
~10-15 min); --full uses the EXPERIMENTS.md settings. Output: CSV rows
``name,us_per_call,derived`` (also echoed as they are produced).
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (eq3_flops_reduction, fig3_ablations, kernels_micro,
                        roofline, table1_ctr_quality, table3_training_time)
from benchmarks.common import ROWS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="EXPERIMENTS.md-scale settings (slow)")
    args = ap.parse_args()
    quick = not args.full

    print("name,us_per_call,derived")
    t0 = time.time()
    eq3_flops_reduction.main()
    kernels_micro.main()
    table3_training_time.main(quick=quick)
    table1_ctr_quality.main(quick=quick)
    fig3_ablations.main(quick=quick)
    roofline.main("16x16")
    print(f"\n# {len(ROWS)} rows in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
