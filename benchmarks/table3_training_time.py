"""Paper Table 3 — wall-clock training-time reduction, SW vs DTI over k.

Equal-epoch protocol (the paper's): each paradigm sees the same user
interactions per epoch; DTI packs them into m/k streaming prompts instead
of m-n sliding prompts. Reported: wall-clock, relative reduction, and the
Eq. 3 prediction for the same (N, K, k) so prediction vs measurement sit
side by side (paper finds they align well).
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import ReproSetup, emit, run_paradigm
from repro.core.flops import flops_reduction_approx

OUT = os.path.join(os.path.dirname(__file__), "artifacts",
                   "table3_training_time.json")


def main(ks=(10, 30, 50), epochs: float = 2.0, quick=False):
    setup = ReproSetup.default()
    if quick:
        ks, epochs = (10,), 1.0
    c = setup.ds.avg_item_tokens + 1
    rows = []
    sw = run_paradigm(setup, paradigm="sw", k=1, epochs=epochs)
    sw["variant"] = "SW"
    rows.append(sw)
    emit("table3_sw", sw["train_time_s"] * 1e6,
         f"auc={sw['auc']:.4f} time={sw['train_time_s']:.1f}s "
         f"pad={sw['pad_fraction']:.3f}")
    for k in ks:
        for pack in (False, True):
            r = run_paradigm(setup, paradigm="dti", k=k, epochs=epochs,
                             pack=pack)
            r["variant"] = f"DTI k={k}" + (" packed" if pack else "")
            red = (1 - r["train_time_s"] / sw["train_time_s"]) * 100
            pred = flops_reduction_approx(setup.n_ctx * c, k * c, k)
            r["reduction_pct"] = red
            r["eq3_predicted_x"] = pred
            r["measured_x"] = sw["train_time_s"] / r["train_time_s"]
            rows.append(r)
            tag = f"table3_dti_k{k}" + ("_packed" if pack else "")
            emit(tag, r["train_time_s"] * 1e6,
                 f"auc={r['auc']:.4f} time={r['train_time_s']:.1f}s "
                 f"red={red:.1f}% eq3_pred={pred:.2f}x "
                 f"measured={r['measured_x']:.2f}x "
                 f"pad={r['pad_fraction']:.3f} "
                 f"eff_tok_s={r['effective_tokens_per_s']:.0f}")
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--epochs", type=float, default=2.0)
    ap.add_argument("--ks", type=int, nargs="+", default=[10, 30, 50])
    a = ap.parse_args()
    main(ks=tuple(a.ks), epochs=a.epochs, quick=a.quick)
