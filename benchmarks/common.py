"""Shared benchmark helpers: timing, CSV emission, the reduced-scale
experiment harness (dataset + model + train/eval loop) used by the Table 1 /
Table 3 / Fig 3 reproductions.

Scale note (DESIGN.md §7): the container is one CPU core, so the repro
model is the paper's architecture at reduced width/depth (≈6M params) with
every DTI mechanism real — prompts, masks, [SUM] loss, reset, ALiBi — and
the synthetic MovieLens-like corpus carries a learnable latent-factor
signal. Ratios (time reduction, quality deltas across paradigms) are the
reproduction target, not absolute wall-clock.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.dti import (PromptStats, batch_prompts, effective_window,
                            pack_prompts, train_max_len)
from repro.data.synthetic import make_ctr_dataset, split_users
from repro.launch.train import (build_prompt_sets, evaluate_lm,
                                make_lm_loss_fn)
from repro.models.transformer import ModelConfig, init_params
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import init_train_state, make_train_step

ROWS: List[Dict] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append({"name": name, "us_per_call": us_per_call,
                 "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6      # us


@dataclasses.dataclass
class ReproSetup:
    cfg: ModelConfig
    ds: object
    splits: tuple
    n_ctx: int = 10
    window: int = 0          # 0 = dense full causal at repro scale

    @classmethod
    def default(cls, *, users=48, items=300, seq=60, min_seq=None, seed=0,
                n_ctx=10) -> "ReproSetup":
        """``min_seq``: long-tailed per-user history lengths (realistic CTR
        regime; makes prompt lengths heterogeneous, which is what segment
        packing reclaims). None keeps the historical all-equal corpus."""
        cfg = get_arch("dti-llama").smoke
        ds = make_ctr_dataset(n_users=users, n_items=items, seq_len=seq,
                              min_seq_len=min_seq,
                              vocab_size=cfg.vocab_size, seed=seed,
                              label_scale=5.0)
        return cls(cfg, ds, split_users(ds), n_ctx=n_ctx)


def run_paradigm(setup: ReproSetup, *, paradigm: str, k: int,
                 steps: Optional[int] = None, epochs: Optional[float] = None,
                 batch: int = 8, lr: float = 1e-3, seed: int = 0,
                 fixes: Optional[Dict[str, bool]] = None,
                 pack: bool = False,
                 attn_impl: Optional[str] = None) -> Dict:
    """Train one paradigm variant end-to-end, return metrics + wall clock.

    ``epochs``: full passes over the paradigm's own prompt set — the paper's
    protocol (SW sees (m-n) prompts/epoch, DTI m/k; the wall-clock ratio at
    equal epochs IS the Table 3 number). ``steps`` overrides for
    matched-update comparisons.
    fixes: {"reset": bool, "pos": bool} — the two bottleneck solutions;
    both True = DTI, both False = DTI-, ignored for paradigm='sw'.
    ``pack``: bin-pack prompts into shared segment-isolated rows; an epoch
    then takes fewer, denser rows (same supervised targets).
    ``attn_impl``: override the config's attention path ("pallas" trains
    through the fused kernel's custom VJP; banded paths get a finite
    window when the setup's is 0).
    """
    cfg = setup.cfg
    if attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    window = effective_window(cfg.attn_impl, setup.window, setup.n_ctx,
                              setup.ds.avg_item_tokens)
    fixes = fixes or {"reset": True, "pos": True}
    if paradigm == "sw":
        cfg = dataclasses.replace(cfg, dti_reset=False, dti_sum_alibi=False)
    else:
        cfg = dataclasses.replace(cfg, dti_reset=fixes["reset"],
                                  dti_sum_alibi=fixes["pos"])

    max_len = train_max_len(setup.n_ctx, 1 if paradigm == "sw" else k,
                            setup.ds.avg_item_tokens)
    train_prompts, test_prompts, test_labels, stats = build_prompt_sets(
        setup.ds, setup.splits, paradigm="sw" if paradigm == "sw" else "dti",
        n_ctx=setup.n_ctx, k=k, max_len=max_len)
    if pack:
        pstats = PromptStats()
        train_prompts = pack_prompts(train_prompts, max_len, stats=pstats)
        stats = pstats
    if steps is None:
        assert epochs is not None
        steps = max(2, int(round(epochs * len(train_prompts) / batch)))

    params = init_params(jax.random.PRNGKey(seed), cfg)
    ocfg = OptimizerConfig(lr=lr, schedule="cosine",
                           warmup_steps=max(5, steps // 10),
                           total_steps=steps)
    loss_fn = make_lm_loss_fn(cfg, window)
    state = init_train_state(params, ocfg)
    step_fn = make_train_step(loss_fn, ocfg)
    rng = np.random.default_rng(seed)

    def batches():
        while True:
            yield from batch_prompts(train_prompts, batch, rng=rng)

    it = batches()
    # separate compile from steady-state timing
    state, _ = step_fn(state, next(it), jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    losses = []
    for i in range(1, steps):
        state, m = step_fn(state, next(it), jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    jax.block_until_ready(state.params)
    train_time = time.perf_counter() - t0

    metrics = evaluate_lm(state.params, cfg, window, test_prompts,
                          test_labels)
    # effective throughput: non-pad tokens pushed through the timed steps
    eff_tok_s = ((steps - 1) * batch * max_len * (1.0 - stats.pad_fraction)
                 / max(train_time, 1e-9))
    return {"paradigm": paradigm, "k": k, "steps": steps,
            "attn_impl": cfg.attn_impl, "window": window,
            "train_time_s": train_time,
            "tokens": stats.n_tokens, "prompts": stats.n_prompts,
            "targets": stats.n_targets, "rows": len(train_prompts),
            "packed": bool(pack), "pad_fraction": stats.pad_fraction,
            "effective_tokens_per_s": eff_tok_s,
            "time_per_target_us": train_time / max(stats.n_targets, 1) * 1e6,
            "loss_last": float(np.mean(losses[-10:])) if losses else 0.0,
            **metrics}


__all__ = ["emit", "time_fn", "ReproSetup", "run_paradigm", "ROWS"]
