"""Serving throughput benchmark: per-candidate re-prefill vs shared context.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] \
        [--attn-impl {dense,pallas}] [--repeat-frac F] \
        [--json BENCH_serve.json]

Ways to score the same request stream (one user context, k candidate items
per request), all producing the same p(click) per candidate:

  * ``naive``         — the paper's inference procedure taken literally: one
    sliding-window prompt per candidate, k prefills per request (the context
    is re-encoded k times). Baseline.
  * ``multi_target``  — one prefill per request over a multi-target row:
    context segment + k isolated [SUM]-terminated candidate segments
    (``repro.serve.engine.make_multi_target_prefill_fn``).
  * ``scheduler``     — continuous batching with decode-side shared-context
    KV reuse and cross-request prefix sharing
    (``repro.serve.scheduler.ServeScheduler``): context prefilled once into
    the batched cache, candidates scored as non-committing bursts, contexts
    retained/refcounted so later requests reuse matching prefixes.
  * ``scheduler_pallas`` (with ``--attn-impl pallas``) — the same scheduler
    run through the fused Pallas decode-attention kernel
    (``repro.kernels.decode_attn``; interpret mode off-TPU) instead of the
    dense decode einsums, so the perf trajectory records dense vs kernel
    side by side.

``--repeat-frac`` makes that fraction of requests revisit an earlier
context with a fresh slate (``repro.data.requests.make_request_stream``),
the traffic shape prefix sharing exploits.

Reports requests/sec, candidates/sec, p50/p99 request latency, the
cache-hit token fraction (share of logical prompt tokens never recomputed)
and the share of prefix-shared admissions, plus the max |score delta| of
each shared mode vs naive. Every scheduler-mode entry carries a
``decode_impl`` field. JSON output feeds the CI artifact next to
BENCH_kernels.json.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.dti import build_sliding_prompts
from repro.data.requests import make_request_stream
from repro.data.synthetic import make_ctr_dataset
from repro.models.transformer import init_params
from repro.serve.engine import CTRServer
from repro.serve.scheduler import ServeScheduler


def _round64(n: int) -> int:
    return ((n + 63) // 64) * 64


def _summary(latencies, scores, t_total, n_requests, k, hit_fraction=0.0):
    lat = np.asarray(latencies) * 1e3
    return {
        "requests_per_s": n_requests / t_total,
        "candidates_per_s": n_requests * k / t_total,
        "latency_p50_ms": float(np.percentile(lat, 50)),
        "latency_p99_ms": float(np.percentile(lat, 99)),
        "cache_hit_token_fraction": hit_fraction,
        "total_s": t_total,
        "scores": scores,
    }


def run_naive(params, cfg, requests, max_len):
    """k sliding-window prefills per request (context re-encoded k times)."""
    server = CTRServer(params, cfg, max_len=max_len)

    def score_one(req):
        prompts = []
        for cand in req["candidates"]:
            prompts += build_sliding_prompts(
                req["context"] + [cand], [0] * (len(req["context"]) + 1),
                n_ctx=len(req["context"]), max_len=max_len)
        return server.score(prompts)

    score_one(requests[0])                               # compile
    lat, scores = [], []
    t0 = time.perf_counter()
    for req in requests:
        t1 = time.perf_counter()
        scores.append(score_one(req))
        lat.append(time.perf_counter() - t1)
    return _summary(lat, scores, time.perf_counter() - t0,
                    len(requests), len(requests[0]["candidates"]))


def run_multi_target(params, cfg, requests, max_len):
    """One prefill per request: shared context + k isolated segments."""
    server = CTRServer(params, cfg, max_len=max_len)

    def score_one(req):
        return server.score_multi_target(
            [(req["context"], req["candidates"])])[0]

    score_one(requests[0])                               # compile
    lat, scores = [], []
    t0 = time.perf_counter()
    for req in requests:
        t1 = time.perf_counter()
        scores.append(score_one(req))
        lat.append(time.perf_counter() - t1)
    k = len(requests[0]["candidates"])
    hits = logical = 0
    for req in requests:                     # stream-wide, like the scheduler
        ctx = 1 + sum(len(t) for t in req["context"])
        hits += (k - 1) * ctx
        logical += k * ctx + sum(len(c) + 1 for c in req["candidates"])
    return _summary(lat, scores, time.perf_counter() - t0, len(requests), k,
                    hit_fraction=hits / max(logical, 1))


def run_scheduler(params, cfg, requests, *, n_slots, capacity, buckets,
                  attn_impl="dense"):
    """Continuous batching: shared-context cache + non-committing bursts +
    cross-request prefix sharing, on the dense or Pallas decode path."""
    sched = ServeScheduler(params, cfg, n_slots=n_slots, capacity=capacity,
                           window=cfg.window, buckets=buckets,
                           attn_impl=attn_impl)
    sched.submit(requests[0]["context"], requests[0]["candidates"])
    sched.run()                                          # compile per bucket
    # drop the warmup's retained context block (a params "swap" to the same
    # params invalidates retained blocks) and reset the counters: otherwise
    # the timed re-submission of requests[0] scores against a pre-warmed
    # cache and inflates the shared-admission / cache-hit stats
    sched.update_params(sched.params)
    sched.shared_admissions = 0
    sched.n_steps = 0
    t0 = time.perf_counter()
    rids = [sched.submit(r["context"], r["candidates"]) for r in requests]
    results = sched.run()
    t_total = time.perf_counter() - t0
    lat = [results[r].latency_s for r in rids]
    scores = [results[r].scores for r in rids]
    hits = sum(results[r].cached_tokens for r in rids)
    logical = sum(results[r].logical_tokens for r in rids)
    out = _summary(lat, scores, t_total, len(requests),
                   len(requests[0]["candidates"]),
                   hit_fraction=hits / max(logical, 1))
    out["steps"] = sched.n_steps
    out["decode_impl"] = attn_impl
    out["shared_admission_fraction"] = sched.shared_admissions / len(rids)
    out["shared_prefix_tokens"] = sum(
        results[r].shared_prefix_tokens for r in rids)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small stream, same code path)")
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--n-ctx", type=int, default=8, dest="n_ctx")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--attn-impl", default="dense", dest="attn_impl",
                    choices=("dense", "pallas"),
                    help="decode path for the scheduler; 'pallas' also "
                         "runs a scheduler_pallas mode through the fused "
                         "decode-attention kernel")
    ap.add_argument("--repeat-frac", type=float, default=0.25,
                    dest="repeat_frac",
                    help="fraction of requests revisiting an earlier "
                         "context (exercises cross-request prefix sharing)")
    args = ap.parse_args()

    n_requests = args.requests or (8 if args.smoke else 32)
    cfg = get_arch("dti-llama").smoke
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    ds = make_ctr_dataset(n_users=16, n_items=120, seq_len=max(args.n_ctx, 12),
                          vocab_size=cfg.vocab_size, seed=args.seed)
    requests = make_request_stream(ds, n_requests=n_requests, k=args.k,
                                   n_ctx=args.n_ctx, seed=args.seed,
                                   repeat_frac=args.repeat_frac)

    ctx_len = max(1 + sum(len(t) for t in r["context"]) for r in requests)
    cand_max = max(len(c) + 1 for r in requests for c in r["candidates"])
    sw_len = _round64(ctx_len + cand_max)
    mt_len = _round64(ctx_len + args.k * cand_max)
    buckets = (16, 32, 64)
    capacity = ctx_len + max(buckets)

    print(f"[serve_bench] {n_requests} requests, k={args.k}, "
          f"ctx<={ctx_len} tok, candidate burst<={cand_max} tok, "
          f"repeat_frac={args.repeat_frac}")
    modes = {
        "naive": run_naive(params, cfg, requests, sw_len),
        "multi_target": run_multi_target(params, cfg, requests, mt_len),
        "scheduler": run_scheduler(params, cfg, requests, n_slots=args.slots,
                                   capacity=capacity, buckets=buckets),
    }
    shared_modes = ["multi_target", "scheduler"]
    if args.attn_impl == "pallas":
        modes["scheduler_pallas"] = run_scheduler(
            params, cfg, requests, n_slots=args.slots, capacity=capacity,
            buckets=buckets, attn_impl="pallas")
        shared_modes.append("scheduler_pallas")

    ref = np.asarray(modes["naive"].pop("scores"))
    deltas = {}
    for name in shared_modes:
        sc = np.asarray(modes[name].pop("scores"))
        deltas[name] = float(np.max(np.abs(sc - ref)))
    for name, m in modes.items():
        print(f"  {name:16s} {m['candidates_per_s']:8.1f} cand/s  "
              f"{m['requests_per_s']:6.1f} req/s  "
              f"p50 {m['latency_p50_ms']:7.1f} ms  "
              f"p99 {m['latency_p99_ms']:7.1f} ms  "
              f"cache-hit {m['cache_hit_token_fraction']:.2f}"
              + (f"  shared-adm {m['shared_admission_fraction']:.2f}"
                 if "shared_admission_fraction" in m else ""))
    print(f"  max |p - naive|: {deltas}")

    result = {
        "config": {"arch": cfg.name, "n_requests": n_requests, "k": args.k,
                   "n_ctx": args.n_ctx, "slots": args.slots,
                   "smoke": bool(args.smoke),
                   "decode_impl": args.attn_impl,
                   "repeat_frac": args.repeat_frac},
        "modes": modes,
        "score_max_abs_delta_vs_naive": deltas,
        "speedup_candidates_per_s": {
            name: modes[name]["candidates_per_s"]
            / modes["naive"]["candidates_per_s"]
            for name in shared_modes},
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"[serve_bench] wrote {args.json}")


if __name__ == "__main__":
    main()
