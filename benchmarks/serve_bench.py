"""Serving throughput benchmark: per-candidate re-prefill vs shared context.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] \
        [--attn-impl {dense,pallas}] [--repeat-frac F] \
        [--ctx-heavy-tail] [--dump-scores] [--json BENCH_serve.json] \
        [--trace trace_serve.json] [--jax-profile DIR]

Ways to score the same request stream (one user context, k candidate items
per request), all producing the same p(click) per candidate:

  * ``naive``         — the paper's inference procedure taken literally: one
    sliding-window prompt per candidate, k prefills per request (the context
    is re-encoded k times). Baseline.
  * ``multi_target``  — one prefill per request over a multi-target row:
    context segment + k isolated [SUM]-terminated candidate segments
    (``repro.serve.engine.make_multi_target_prefill_fn``).
  * ``scheduler``     — continuous batching with decode-side shared-context
    KV reuse and cross-request prefix sharing
    (``repro.serve.scheduler.ServeScheduler``): context prefilled once into
    the batched cache, candidates scored as non-committing bursts, contexts
    retained/refcounted so later requests reuse matching prefixes. Runs the
    current scheduling policy: token-budgeted chunked prefill +
    one-step-ahead overlap.
  * ``scheduler_per_slot`` — the same scheduler on the per-slot contiguous
    cache layout (``paged=False``): prefix reuse works only while the
    owning row survives, so its ``cross_row_hits`` are 0 by construction.
    The side-by-side baseline for the paged layout's radix page index
    (``paged_vs_per_slot`` in the artifact: cross-row hits, prefix hit
    rate, pages in use, evictions); on a revisit-heavy stream the run
    exits nonzero if the paged scheduler serves no cross-row hits.
  * ``scheduler_monolithic`` — the same scheduler with the pre-budget
    policy (``monolithic_prefill=True``, no overlap): prefill chunks cut at
    the largest bucket, inflating every co-batched burst's jit shape, and a
    device sync per step. Kept as the side-by-side reference for the
    chunked-prefill p99 win.
  * ``scheduler_pallas`` (with ``--attn-impl pallas``) — the budgeted +
    overlap scheduler run through the fused Pallas decode-attention kernel
    (``repro.kernels.decode_attn``; interpret mode off-TPU) instead of the
    dense decode einsums, so the perf trajectory records dense vs kernel
    side by side.

``--kv-dtype int8`` appends a ``quantized_vs_bf16`` block: the revisit
drain re-run twice — int8 KV pages vs bf16 — at an *equal pool byte*
budget (``--quant-pages`` bf16 pages; int8 gets the same bytes, ~1.8x the
pages). The run exits nonzero unless int8 retains >= 1.5x the cross-row
prefix tokens and a strictly higher prefix hit rate than bf16, and both
runs' scores stay within 0.05 of the fp32 naive oracle.

``--mesh DP,MP`` appends a ``sharded`` block: the same stream drained by a
fleet of 2 mesh-sharded schedulers (user-routed; KV page pool sharded over
the ``data`` axis, KV heads over ``model`` — docs/sharding.md) with the
per-shard ``serve.*`` registries merged into one fleet telemetry snapshot.
The run exits nonzero if the fleet's scores drift more than 1e-4 from the
unsharded scheduler drain. On fewer than DP*MP devices the block is still
emitted on a degenerate (1, 1) mesh (``mesh_fallback: true``); the
``tier1-multidevice`` CI lane forces 8 host devices for the real (2, 4)
placement.

``--trace PATH`` exports the scheduler mode's final drain as a
Chrome-trace-event JSON (``repro.obs.trace``): nested scheduler-step ->
prefill-chunk / burst / dispatch spans plus admission / hot-swap /
finish / watchdog instants — loadable in Perfetto or chrome://tracing,
summarized by ``python -m repro.launch.obs_report``. The run exits
nonzero if the trace fails schema validation or lost the expected span
shapes. ``--jax-profile DIR`` additionally captures a ``jax.profiler``
device trace of the same drain, with decode dispatches annotated per
jit bucket.

``--repeat-frac`` makes that fraction of requests revisit an earlier
context with a fresh slate (``repro.data.requests.make_request_stream``),
the traffic shape prefix sharing exploits. ``--ctx-heavy-tail`` switches
the stream to Pareto-tailed context lengths (clamped at ``--n-ctx-tail``,
default 4x ``--n-ctx``) — the mixed-length traffic where monolithic
prefill's tail inflation shows up in p99.

Reports requests/sec, candidates/sec, p50/p99 request latency with its
queue/service split, the cache-hit token fraction (share of logical prompt
tokens never recomputed) and the share of prefix-shared admissions, plus
the max |score delta| of each shared mode vs naive. Scheduler entries
carry ``decode_impl`` and the scheduler's ``telemetry()`` block (bucket
histogram, queue depth, budget utilization, watchdog). Raw scores are
embedded only under ``--dump-scores``; percentile fields always carry
``n_samples``. The process exits nonzero if any mode reports a non-finite
score or a scheduler watchdog fires, so CI catches a silently-wrong run.
JSON output feeds the CI artifact next to BENCH_kernels.json.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import math
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.dti import build_sliding_prompts
from repro.data.requests import make_request_stream
from repro.data.synthetic import make_ctr_dataset
from repro.launch.mesh import make_cpu_mesh, make_serve_mesh
from repro.models.transformer import init_params
from repro.obs import profile as obs_profile
from repro.obs.trace import SpanTracer, validate_chrome_trace
from repro.serve.engine import CTRServer
from repro.serve.scheduler import ServeScheduler
from repro.stream.shard import fleet_serve_snapshot, shard_key


def _round64(n: int) -> int:
    return ((n + 63) // 64) * 64


def _summary(latencies, scores, t_total, n_requests, k, hit_fraction=0.0,
             queue=None, service=None):
    lat = np.asarray(latencies) * 1e3
    out = {
        "requests_per_s": n_requests / t_total,
        "candidates_per_s": n_requests * k / t_total,
        "latency_p50_ms": float(np.percentile(lat, 50)),
        "latency_p99_ms": float(np.percentile(lat, 99)),
        "n_samples": int(len(lat)),
        "cache_hit_token_fraction": hit_fraction,
        "total_s": t_total,
        "scores": scores,
    }
    if queue is not None:
        q, s = np.asarray(queue) * 1e3, np.asarray(service) * 1e3
        out["queue_p50_ms"] = float(np.percentile(q, 50))
        out["queue_p99_ms"] = float(np.percentile(q, 99))
        out["service_p50_ms"] = float(np.percentile(s, 50))
        out["service_p99_ms"] = float(np.percentile(s, 99))
    return out


def run_naive(params, cfg, requests, max_len):
    """k sliding-window prefills per request (context re-encoded k times)."""
    server = CTRServer(params, cfg, max_len=max_len)

    def score_one(req):
        prompts = []
        for cand in req["candidates"]:
            prompts += build_sliding_prompts(
                req["context"] + [cand], [0] * (len(req["context"]) + 1),
                n_ctx=len(req["context"]), max_len=max_len)
        return server.score(prompts)

    score_one(requests[0])                               # compile
    lat, scores = [], []
    t0 = time.perf_counter()
    for req in requests:
        t1 = time.perf_counter()
        scores.append(score_one(req))
        lat.append(time.perf_counter() - t1)
    return _summary(lat, scores, time.perf_counter() - t0,
                    len(requests), len(requests[0]["candidates"]))


def run_multi_target(params, cfg, requests, max_len):
    """One prefill per request: shared context + k isolated segments."""
    server = CTRServer(params, cfg, max_len=max_len)

    def score_one(req):
        return server.score_multi_target(
            [(req["context"], req["candidates"])])[0]

    score_one(requests[0])                               # compile
    lat, scores = [], []
    t0 = time.perf_counter()
    for req in requests:
        t1 = time.perf_counter()
        scores.append(score_one(req))
        lat.append(time.perf_counter() - t1)
    k = len(requests[0]["candidates"])
    hits = logical = 0
    for req in requests:                     # stream-wide, like the scheduler
        ctx = 1 + sum(len(t) for t in req["context"])
        hits += (k - 1) * ctx
        logical += k * ctx + sum(len(c) + 1 for c in req["candidates"])
    return _summary(lat, scores, time.perf_counter() - t0, len(requests), k,
                    hit_fraction=hits / max(logical, 1))


def run_scheduler(params, cfg, requests, *, n_slots, capacity, buckets,
                  attn_impl="dense", monolithic=False, overlap=True,
                  arrival_s=0.0, reps=1, paged=True,
                  cache_dtype=None, kv_dtype=None, n_pages=None,
                  tracer=None):
    """Continuous batching: shared-context cache + non-committing bursts +
    cross-request prefix sharing, on the dense or Pallas decode path.
    ``monolithic=True`` runs the pre-budget chunking (+ per-step sync) as
    the reference policy. ``paged=False`` runs the per-slot contiguous
    cache layout (no page pool, no radix page index) — the baseline the
    paged layout's cross-row prefix hits are measured against; scores are
    identical either way. ``arrival_s`` > 0 paces submissions at that
    inter-arrival gap (open-loop traffic: per-request latency measures the
    requests actually in flight together, not the whole drain's makespan);
    0 submits everything up front (batch drain). ``reps`` repeats the
    measured drain on a fresh scheduler each time and keeps the rep with
    the lowest p99 — scores are deterministic across reps, only wall time
    moves, so best-of-N strips scheduler-external timing noise from the
    policy comparison. ``tracer`` (a ``repro.obs.trace.SpanTracer``) is
    cleared at the start of each rep, so it ends up holding the final
    rep's span stream — enough for the trace artifact, without the
    cross-rep interleaving a shared buffer would record."""
    best = None
    for _ in range(max(1, reps)):
        # fresh scheduler per rep: retained (refcounted) contexts from a
        # prior rep would hand later reps free prefix hits and collapse
        # the policy difference under test
        if tracer is not None:
            tracer.clear()
        sched = ServeScheduler(params, cfg, n_slots=n_slots,
                               capacity=capacity, window=cfg.window,
                               buckets=buckets, attn_impl=attn_impl,
                               monolithic_prefill=monolithic,
                               overlap=overlap, paged=paged,
                               cache_dtype=(cache_dtype if cache_dtype
                                            is not None else jnp.float32),
                               kv_dtype=kv_dtype, n_pages=n_pages,
                               tracer=tracer)
        sched.warmup()                       # compile every bucket shape
        sched.reset_stats()
        t0 = time.perf_counter()
        if arrival_s > 0.0:
            rids, i = [], 0
            while True:
                while (i < len(requests)
                       and time.perf_counter() >= t0 + i * arrival_s):
                    rids.append(sched.submit(requests[i]["context"],
                                             requests[i]["candidates"]))
                    i += 1
                if not sched.step():
                    if i >= len(requests):
                        break
                    time.sleep(max(0.0, t0 + i * arrival_s
                                   - time.perf_counter()))
            results = sched.run()            # no-op drain: collect results
        else:
            rids = [sched.submit(r["context"], r["candidates"])
                    for r in requests]
            results = sched.run()
        t_total = time.perf_counter() - t0
        lat = [results[r].latency_s for r in rids]
        scores = [results[r].scores for r in rids]
        hits = sum(results[r].cached_tokens for r in rids)
        logical = sum(results[r].logical_tokens for r in rids)
        out = _summary(lat, scores, t_total, len(requests),
                       len(requests[0]["candidates"]),
                       hit_fraction=hits / max(logical, 1),
                       queue=[results[r].queue_s for r in rids],
                       service=[results[r].service_s for r in rids])
        out["steps"] = sched.n_steps
        out["decode_impl"] = attn_impl
        out["reps"] = max(1, reps)
        out["shared_admission_fraction"] = (sched.shared_admissions
                                            / len(rids))
        out["shared_prefix_tokens"] = sum(
            results[r].shared_prefix_tokens for r in rids)
        out["telemetry"] = sched.telemetry()
        out["jit_stats"] = sched.jit_stats()
        if best is None or out["latency_p99_ms"] < best["latency_p99_ms"]:
            best = out
    return best


def run_sharded_fleet(params, cfg, requests, *, n_slots, capacity, buckets,
                      dp, mp, fleet=2):
    """The scale-out drain (docs/sharding.md): a fleet of mesh-sharded
    schedulers splitting the request stream by user, each with its KV page
    pool sharded over ``data`` and KV heads over ``model``
    (``ServeScheduler(mesh=...)``). Falls back to the degenerate (1, 1)
    mesh when the runtime has fewer than ``dp * mp`` devices — the
    single-device CI job still emits the block, the forced-8-device lane
    exercises the real (2, 4) placement. Scores come back in submission
    order so the caller can diff them against the unsharded drain;
    telemetry is the per-shard ``serve.*`` registries merged into one
    fleet snapshot (``fleet_serve_snapshot``)."""
    try:
        mesh = make_serve_mesh(dp, mp)
        fallback = False
    except ValueError:
        mesh = make_cpu_mesh()
        fallback = True
    scheds = [ServeScheduler(params, cfg, n_slots=n_slots,
                             capacity=capacity, window=cfg.window,
                             buckets=buckets, mesh=mesh)
              for _ in range(fleet)]
    for s in scheds:
        s.warmup()
        s.reset_stats()
    parts = [[] for _ in range(fleet)]
    for i, r in enumerate(requests):
        parts[shard_key(r, fleet)].append((i, r))
    k = len(requests[0]["candidates"])
    scores = [None] * len(requests)
    lat, hits, logical = [], 0, 0
    t0 = time.perf_counter()
    for s, part in zip(scheds, parts):
        rids = [s.submit(r["context"], r["candidates"]) for _, r in part]
        results = s.run()
        for (i, _), rid in zip(part, rids):
            scores[i] = results[rid].scores
            lat.append(results[rid].latency_s)
            hits += results[rid].cached_tokens
            logical += results[rid].logical_tokens
    t_total = time.perf_counter() - t0
    out = _summary(lat, scores, t_total, len(requests), k,
                   hit_fraction=hits / max(logical, 1))
    out["requested_mesh"] = [dp, mp]
    out["mesh"] = {str(a): int(n) for a, n in mesh.shape.items()}
    out["mesh_fallback"] = fallback
    out["devices"] = len(jax.devices())
    out["fleet"] = fleet
    out["requests_per_shard"] = [len(p) for p in parts]
    out["steps"] = sum(s.n_steps for s in scheds)
    out["decode_impl"] = "dense"
    out["merged_telemetry"] = fleet_serve_snapshot(scheds)
    return out


def run_quant_compare(params, cfg, requests, *, n_slots, capacity, buckets,
                      arrival_s=0.0, base_pages=16, page_size=16):
    """int8 vs bf16 KV on the revisit drain at an *equal pool byte* budget.

    The bf16 scheduler gets ``base_pages`` pages; the int8 scheduler gets
    however many pages the same HBM bytes buy (per-token cost from
    ``repro.serve.cache.kv_token_bytes``, scale sidecar included — with
    the smoke config int8 is ~1.8x denser). Same stream, same slots, same
    capacity: the only free variable is what the byte budget retains, so
    int8's extra pages should show up directly as cross-row prefix hits
    the bf16 pool had to evict.
    """
    from repro.serve.cache import cache_shape, kv_token_bytes

    cap_eff = -(-capacity // page_size) * page_size   # scheduler's rounding
    tb = {}
    for label, kvd in (("bf16", None), ("int8", "int8")):
        spec = cache_shape(cfg, n_slots, cap_eff, dtype=jnp.bfloat16,
                           kv_dtype=kvd, page_size=page_size,
                           n_pages=base_pages)
        tb[label] = kv_token_bytes(spec)
    # floor keeps the int8 pool at-or-under the bf16 byte budget
    int8_pages = max(base_pages, int(base_pages * tb["bf16"] / tb["int8"]))
    out = {}
    for label, kvd, n_pages in (("bf16", None, base_pages),
                                ("int8", "int8", int8_pages)):
        m = run_scheduler(params, cfg, requests, n_slots=n_slots,
                          capacity=capacity, buckets=buckets,
                          arrival_s=arrival_s, cache_dtype=jnp.bfloat16,
                          kv_dtype=kvd, n_pages=n_pages)
        m["n_pages"] = n_pages
        m["kv_token_bytes"] = tb[label]
        m["pool_bytes"] = int(n_pages * page_size * tb[label])
        out[label] = m
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small stream, same code path)")
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--k", type=int, default=None,
                    help="slate size (default 8; 2 under --ctx-heavy-tail, "
                         "whose point is long contexts vs small bursts)")
    ap.add_argument("--n-ctx", type=int, default=None, dest="n_ctx",
                    help="context interactions per request (default 8; "
                         "6 under --ctx-heavy-tail)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--attn-impl", default="dense", dest="attn_impl",
                    choices=("dense", "pallas"),
                    help="decode path for the scheduler; 'pallas' also "
                         "runs a scheduler_pallas mode through the fused "
                         "decode-attention kernel")
    ap.add_argument("--repeat-frac", type=float, default=0.25,
                    dest="repeat_frac",
                    help="fraction of requests revisiting an earlier "
                         "context (exercises cross-request prefix sharing)")
    ap.add_argument("--ctx-heavy-tail", action="store_true",
                    dest="ctx_heavy_tail",
                    help="Pareto-tailed per-request context lengths "
                         "(n_ctx .. n_ctx_tail interactions) — the "
                         "mixed-length workload the chunked-prefill "
                         "scheduler targets")
    ap.add_argument("--n-ctx-tail", type=int, default=None,
                    dest="n_ctx_tail",
                    help="context length clamp under --ctx-heavy-tail "
                         "(default 8x --n-ctx)")
    ap.add_argument("--arrival-ms", type=float, default=None,
                    dest="arrival_ms",
                    help="inter-arrival gap for the scheduler modes "
                         "(default 0 = submit all up front / batch "
                         "drain; set >0 for open-loop paced traffic)")
    ap.add_argument("--reps", type=int, default=None,
                    help="repeat each scheduler-mode drain N times on a "
                         "fresh scheduler and keep the best-p99 rep "
                         "(default 3 under --ctx-heavy-tail, else 1) — "
                         "container timing noise otherwise swamps the "
                         "policy delta")
    ap.add_argument("--kv-dtype", default="native", dest="kv_dtype",
                    choices=("native", "int8"),
                    help="'int8' adds a quantized_vs_bf16 block: the "
                         "revisit drain re-run with int8 KV pages vs bf16 "
                         "at an equal pool byte budget, gated on int8 "
                         "retaining strictly more cross-row prefix")
    ap.add_argument("--quant-pages", type=int, default=16,
                    dest="quant_pages",
                    help="bf16-page budget of the quantized_vs_bf16 "
                         "compare (int8 gets the same bytes; default 16, "
                         "raised automatically if one row's capacity "
                         "needs more)")
    ap.add_argument("--dump-scores", action="store_true", dest="dump_scores",
                    help="embed every mode's raw per-candidate scores in "
                         "the JSON artifact (large; off by default)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export the scheduler mode's final drain as a "
                         "Chrome-trace JSON (load in Perfetto / "
                         "chrome://tracing, or summarize with "
                         "python -m repro.launch.obs_report PATH); the "
                         "run exits nonzero if the trace fails schema "
                         "validation or misses the expected span shapes")
    ap.add_argument("--mesh", default=None, metavar="DP,MP",
                    help="also run the scale-out drain: a fleet of 2 "
                         "schedulers splitting the stream by user, each "
                         "mesh-sharded (KV page pool over 'data', KV heads "
                         "over 'model') on a (DP, MP) device mesh; emits a "
                         "'sharded' block with per-shard-merged serve.* "
                         "telemetry and the max |score delta| vs the "
                         "unsharded scheduler drain. Falls back to a (1,1) "
                         "mesh when the runtime has < DP*MP devices (set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=8 for the real placement)")
    ap.add_argument("--jax-profile", default=None, dest="jax_profile",
                    metavar="DIR",
                    help="also capture a jax.profiler device trace of the "
                         "scheduler-mode drain into DIR (spans annotate "
                         "decode dispatches; no-op if the profiler is "
                         "unavailable)")
    args = ap.parse_args()

    n_requests = args.requests or (8 if args.smoke else 32)
    k = args.k or 8
    n_ctx = args.n_ctx or 8
    n_ctx_tail = None
    arrival_s = (args.arrival_ms or 0.0) * 1e-3
    reps = args.reps or 1
    if args.ctx_heavy_tail:
        # the heavy-tail workload: long mixed-length contexts, small
        # slates (bursts fit the smallest bucket — what monolithic
        # prefill needlessly inflates), drained as a batch so the tail
        # measures how fast the backlog behind a long prefill clears
        k = args.k or 2
        n_ctx = args.n_ctx or 6
        n_ctx_tail = args.n_ctx_tail or 8 * n_ctx
        reps = args.reps or 3
        # heavy tails need enough requests for p99 to mean anything beyond
        # the max; keep smoke runs CI-sized but not degenerate
        n_requests = args.requests or (16 if args.smoke else 48)
    cfg = get_arch("dti-llama").smoke
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    seq_len = max(n_ctx_tail or n_ctx, 12)
    ds = make_ctr_dataset(n_users=16, n_items=120, seq_len=seq_len,
                          vocab_size=cfg.vocab_size, seed=args.seed)
    requests = make_request_stream(ds, n_requests=n_requests, k=k,
                                   n_ctx=n_ctx, seed=args.seed,
                                   repeat_frac=args.repeat_frac,
                                   n_ctx_tail=n_ctx_tail)

    ctx_len = max(1 + sum(len(t) for t in r["context"]) for r in requests)
    cand_max = max(len(c) + 1 for r in requests for c in r["candidates"])
    sw_len = _round64(ctx_len + cand_max)
    mt_len = _round64(ctx_len + k * cand_max)
    buckets = (16, 32, 64)
    capacity = ctx_len + max(buckets)

    print(f"[serve_bench] {n_requests} requests, k={k}, "
          f"ctx<={ctx_len} tok, candidate burst<={cand_max} tok, "
          f"repeat_frac={args.repeat_frac}"
          + (f", heavy-tail ctx (clamp {n_ctx_tail})"
             if args.ctx_heavy_tail else ""))
    # host-side span tracer for the headline scheduler mode only: the
    # other modes are references, and one mode's trace is what the
    # viewer/summarizer consumes
    tracer = (SpanTracer(jax_annotate=bool(args.jax_profile))
              if (args.trace or args.jax_profile) else None)
    prof = (obs_profile.trace(args.jax_profile) if args.jax_profile
            else contextlib.nullcontext())
    modes = {
        "naive": run_naive(params, cfg, requests, sw_len),
        "multi_target": run_multi_target(params, cfg, requests, mt_len),
    }
    with prof:
        modes["scheduler"] = run_scheduler(
            params, cfg, requests, n_slots=args.slots, capacity=capacity,
            buckets=buckets, arrival_s=arrival_s, reps=reps, tracer=tracer)
    modes.update({
        # the per-slot contiguous cache, recorded side by side: its
        # prefix reuse dies with the row (cross_row_hits == 0 by
        # construction), which is exactly what the paged radix index is
        # measured against on revisit-heavy streams
        "scheduler_per_slot": run_scheduler(
            params, cfg, requests, n_slots=args.slots, capacity=capacity,
            buckets=buckets, arrival_s=arrival_s, reps=reps, paged=False),
        # the pre-change policy, recorded side by side so the budgeted +
        # overlap p99 win is measured, not asserted
        "scheduler_monolithic": run_scheduler(
            params, cfg, requests, n_slots=args.slots, capacity=capacity,
            buckets=buckets, monolithic=True, overlap=False,
            arrival_s=arrival_s, reps=reps),
    })
    shared_modes = ["multi_target", "scheduler", "scheduler_per_slot",
                    "scheduler_monolithic"]
    if args.attn_impl == "pallas":
        # single rep: interpret-mode wall time tracks correctness, not the
        # policy comparison (excluded from p99_improvement below), so
        # best-of-N would only burn CI minutes
        modes["scheduler_pallas"] = run_scheduler(
            params, cfg, requests, n_slots=args.slots, capacity=capacity,
            buckets=buckets, attn_impl="pallas", arrival_s=arrival_s)
        shared_modes.append("scheduler_pallas")

    all_scores = {name: modes[name].pop("scores") for name in modes}
    ref = np.asarray(all_scores["naive"])
    deltas = {}
    for name in shared_modes:
        sc = np.asarray(all_scores[name])
        deltas[name] = float(np.max(np.abs(sc - ref)))
    if args.dump_scores:
        for name in modes:
            modes[name]["scores"] = all_scores[name]
    for name, m in modes.items():
        print(f"  {name:20s} {m['candidates_per_s']:8.1f} cand/s  "
              f"{m['requests_per_s']:6.1f} req/s  "
              f"p50 {m['latency_p50_ms']:7.1f} ms  "
              f"p99 {m['latency_p99_ms']:7.1f} ms  "
              f"cache-hit {m['cache_hit_token_fraction']:.2f}"
              + (f"  shared-adm {m['shared_admission_fraction']:.2f}"
                 if "shared_admission_fraction" in m else ""))
    print(f"  max |p - naive|: {deltas}")

    result = {
        "config": {"arch": cfg.name, "n_requests": n_requests, "k": k,
                   "n_ctx": n_ctx, "n_ctx_tail": n_ctx_tail,
                   "arrival_ms": arrival_s * 1e3, "reps": reps,
                   "slots": args.slots,
                   "smoke": bool(args.smoke),
                   "decode_impl": args.attn_impl,
                   "repeat_frac": args.repeat_frac},
        "modes": modes,
        "score_max_abs_delta_vs_naive": deltas,
        "speedup_candidates_per_s": {
            name: modes[name]["candidates_per_s"]
            / modes["naive"]["candidates_per_s"]
            for name in shared_modes},
        # policy-vs-policy only: compare against the monolithic reference
        # on the same decode impl (pallas runs interpret-mode off-TPU, so
        # its wall time says nothing about the scheduling policy)
        "p99_improvement_vs_monolithic": {
            name: modes["scheduler_monolithic"]["latency_p99_ms"]
            / modes[name]["latency_p99_ms"]
            for name in shared_modes if name.startswith("scheduler")
            and name != "scheduler_monolithic"
            and modes[name]["decode_impl"]
            == modes["scheduler_monolithic"]["decode_impl"]},
        # the tentpole's headline: prefix reuse that survives row
        # eviction. per_slot's cross_row_hits are structurally 0 (its
        # prefixes die with the row); the paged radix index must serve
        # revisits that arrive after their source row was stolen.
        "paged_vs_per_slot": {
            "cross_row_hits": modes["scheduler"]["telemetry"]
                              ["cross_row_hits"],
            "cross_row_tokens": modes["scheduler"]["telemetry"]
                                ["cross_row_tokens"],
            "prefix_hit_rate_paged": modes["scheduler"]["telemetry"]
                                     ["prefix_hit_rate"],
            "prefix_hit_rate_per_slot": modes["scheduler_per_slot"]
                                        ["telemetry"]["prefix_hit_rate"],
            "pages_in_use": modes["scheduler"]["telemetry"]["pages_in_use"],
            "page_evictions": modes["scheduler"]["telemetry"]
                              ["page_evictions"],
        },
    }

    quant = None
    if args.kv_dtype == "int8":
        # a pool that can't hold one fully-occupied row deadlocks
        # admission: lift the page budget to row capacity + slack first
        page_size = 16
        base_pages = max(args.quant_pages,
                         -(-capacity // page_size) + 2)
        quant = run_quant_compare(
            params, cfg, requests, n_slots=args.slots, capacity=capacity,
            buckets=buckets, arrival_s=arrival_s, base_pages=base_pages,
            page_size=page_size)
        q_deltas = {}
        for label in quant:
            sc = np.asarray(quant[label].pop("scores"))
            q_deltas[label] = float(np.max(np.abs(sc - ref)))
        qi, qb = quant["int8"]["telemetry"], quant["bf16"]["telemetry"]
        result["quantized_vs_bf16"] = {
            "bf16": quant["bf16"], "int8": quant["int8"],
            "score_max_abs_delta_vs_naive": q_deltas,
            "pool_bytes_bf16": quant["bf16"]["pool_bytes"],
            "pool_bytes_int8": quant["int8"]["pool_bytes"],
            "pages_bf16": quant["bf16"]["n_pages"],
            "pages_int8": quant["int8"]["n_pages"],
            "cross_row_tokens_ratio": (qi["cross_row_tokens"]
                                       / max(qb["cross_row_tokens"], 1)),
        }
        for label in ("bf16", "int8"):
            t = quant[label]["telemetry"]
            print(f"  quant[{label}]: {quant[label]['n_pages']} pages "
                  f"({quant[label]['pool_bytes']} B)  cross-row tokens "
                  f"{t['cross_row_tokens']}  hit-rate "
                  f"{t['prefix_hit_rate']:.3f}  evictions "
                  f"{t['page_evictions']}  |dp| {q_deltas[label]:.2e}")

    sharded = None
    if args.mesh:
        try:
            dp, mp = (int(x) for x in args.mesh.split(","))
        except ValueError:
            ap.error(f"--mesh expects DP,MP (got {args.mesh!r})")
        sharded = run_sharded_fleet(
            params, cfg, requests, n_slots=args.slots, capacity=capacity,
            buckets=buckets, dp=dp, mp=mp)
        sh_scores = np.asarray(sharded.pop("scores"))
        sharded["score_max_abs_delta_vs_unsharded"] = float(np.max(np.abs(
            sh_scores - np.asarray(all_scores["scheduler"]))))
        sharded["score_max_abs_delta_vs_naive"] = float(
            np.max(np.abs(sh_scores - ref)))
        all_scores["sharded"] = sh_scores
        result["sharded"] = sharded
        mt = sharded["merged_telemetry"]
        print(f"  sharded mesh={sharded['mesh']}"
              + (" (FALLBACK — wanted "
                 f"{sharded['requested_mesh']}, "
                 f"{sharded['devices']} devices)"
                 if sharded["mesh_fallback"] else "")
              + f"  fleet={sharded['fleet']} "
              f"{sharded['candidates_per_s']:8.1f} cand/s  "
              f"fleet steps {mt['serve.steps']['value']}  "
              f"|dp vs unsharded| "
              f"{sharded['score_max_abs_delta_vs_unsharded']:.2e}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"[serve_bench] wrote {args.json}")

    # validity gate: a benchmark that silently scored garbage (NaN burst,
    # stalled row) must fail the CI job, not upload a green artifact
    bad = []
    if args.trace:
        # export first (a malformed trace should still land on disk for
        # inspection), then gate: schema-valid AND carrying the span
        # shapes the scheduler is supposed to emit — a drain whose trace
        # lost its step/prefill spans means the instrumentation regressed
        tracer.save(args.trace)
        doc = tracer.to_chrome_trace()
        problems = validate_chrome_trace(doc)
        names_x = {e["name"] for e in doc["traceEvents"]
                   if e.get("ph") == "X"}
        names_i = {e["name"] for e in doc["traceEvents"]
                   if e.get("ph") == "i"}
        if "scheduler.step" not in names_x:
            problems.append("no scheduler.step span")
        if not ({"prefill_chunk", "burst"} & names_x):
            problems.append("no prefill_chunk/burst span")
        if not ({"admission", "hot_swap", "finish"} & names_i):
            problems.append("no admission/hot_swap/finish instant")
        bad += [f"trace: {p}" for p in problems]
        print(f"[serve_bench] wrote {args.trace} "
              f"({len(tracer)} events, {len(problems)} problems)")
    for name, sc in all_scores.items():
        if not all(math.isfinite(float(s)) for req in sc for s in req):
            bad.append(f"{name}: non-finite score")
    for name in modes:
        tel = modes[name].get("telemetry")
        if tel and tel["watchdog_fired"]:
            bad.append(f"{name}: watchdog fired "
                       f"(stuck rids {tel['watchdog_stuck_rids']})")
    # cross-row regression gate: on a revisit-heavy stream with more
    # distinct contexts than rows, some revisits necessarily arrive after
    # their source row was reused — the paged radix index must serve them
    # (per-slot scores 0 here by design; a 0 on the paged path means the
    # index silently stopped working)
    if args.repeat_frac > 0 and n_requests >= 4 * args.slots:
        pvs = result["paged_vs_per_slot"]
        if pvs["cross_row_hits"] <= 0:
            bad.append(
                f"paged scheduler served 0 cross-row prefix hits on a "
                f"revisit-heavy stream (repeat_frac={args.repeat_frac}, "
                f"{n_requests} requests / {args.slots} slots) — per-slot "
                f"baseline hit rate "
                f"{pvs['prefix_hit_rate_per_slot']:.3f}, paged "
                f"{pvs['prefix_hit_rate_paged']:.3f}")
    if quant is not None:
        # int8 scores must stay near the fp32 naive oracle (quantization
        # error on p(click) is ~1e-3 at smoke scale; 0.05 catches a broken
        # dequant path, not noise), and both runs must be watchdog-clean
        qv = result["quantized_vs_bf16"]
        for label in ("bf16", "int8"):
            if qv["score_max_abs_delta_vs_naive"][label] > 0.05:
                bad.append(f"quant[{label}] scores diverged from naive by "
                           f"{qv['score_max_abs_delta_vs_naive'][label]:.3f}"
                           f" (> 0.05)")
            if quant[label]["telemetry"]["watchdog_fired"]:
                bad.append(f"quant[{label}]: watchdog fired")
        if args.repeat_frac > 0 and n_requests >= 4 * args.slots:
            # the tentpole's payoff gate: at equal pool bytes the denser
            # int8 pages must retain strictly more reusable prefix
            if qv["cross_row_tokens_ratio"] < 1.5:
                bad.append(
                    f"int8 cross-row prefix tokens only "
                    f"{qv['cross_row_tokens_ratio']:.2f}x bf16's at equal "
                    f"pool bytes (need >= 1.5x)")
            if (qi["prefix_hit_rate"] <= qb["prefix_hit_rate"]):
                bad.append(
                    f"int8 prefix hit rate {qi['prefix_hit_rate']:.3f} did "
                    f"not beat bf16's {qb['prefix_hit_rate']:.3f} at equal "
                    f"pool bytes")
    if sharded is not None:
        # the scale-out acceptance bound (docs/sharding.md): the sharded
        # fleet's scores must match the single-device scheduler drain —
        # GSPMD may reorder reductions across shards, nothing more
        if sharded["score_max_abs_delta_vs_unsharded"] > 1e-4:
            bad.append(
                f"sharded drain diverged from unsharded by "
                f"{sharded['score_max_abs_delta_vs_unsharded']:.2e} "
                f"(> 1e-4) on mesh {sharded['mesh']}")
        if sharded["merged_telemetry"]["serve.watchdog_fired"]["value"]:
            bad.append("sharded: watchdog fired on some shard")
    if bad:
        print(f"[serve_bench] INVALID RUN: {'; '.join(bad)}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
