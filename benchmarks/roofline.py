"""§Roofline — render the per-(arch x shape x mesh) roofline table from the
dry-run artifacts (benchmarks/artifacts/dryrun/**/*.json).

Per cell: the three terms in seconds, the dominant bottleneck, MODEL_FLOPS
(6*N*D-style analytic), the MODEL/HLO flops ratio (useful-compute fraction)
and the roofline fraction at the bound. Also emits the markdown table used
by EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load(mesh: str = "16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def render_markdown(recs, *, with_improvement=True) -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bound | "
        "GiB/dev | model/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        rf = r["roofline"]
        pd = r["per_device"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.2e} | "
            f"{rf['memory_s']:.2e} | {rf['collective_s']:.2e} | "
            f"{rf['bottleneck']} | {pd['peak_bytes_est'] / 2**30:.2f} | "
            f"{rf['model_flops_ratio']:.3f} | "
            f"{rf['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def main(mesh: str = "16x16"):
    recs = load(mesh)
    if not recs:
        emit("roofline", 0.0, "no dry-run artifacts; run "
             "`python -m repro.launch.dryrun` first")
        return []
    for r in recs:
        rf = r["roofline"]
        emit(f"roofline_{r['arch']}__{r['shape']}",
             rf["step_time_lb_s"] * 1e6,
             f"bound={rf['bottleneck']} frac={rf['roofline_fraction']:.4f} "
             f"model/hlo={rf['model_flops_ratio']:.3f}")
    worst = min((r for r in recs if r["roofline"]["roofline_fraction"] > 0),
                key=lambda r: r["roofline"]["roofline_fraction"],
                default=None)
    if worst:
        emit("roofline_worst_cell", 0.0,
             f"{worst['arch']}x{worst['shape']} "
             f"frac={worst['roofline']['roofline_fraction']:.5f}")
    return recs


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "16x16")
