"""Paper Figure 3 — ablation of the two bottleneck fixes at fixed k.

Variants (paper's naming):
  w/ both fixes   = DTI            (reset + SUM NoPE/ALiBi)
  w/ hs leak      = only positional fix (reset OFF)
  w/ pos bias     = only reset     (ALiBi fix OFF)
  w/ both issues  = DTI-           (neither)
Paper's finding: positional-bias overfitting dominates; both fixes matter.
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import ReproSetup, emit, run_paradigm

OUT = os.path.join(os.path.dirname(__file__), "artifacts",
                   "fig3_ablations.json")

VARIANTS = [
    ("dti_both_fixes", {"reset": True, "pos": True}),
    ("w_hs_leak", {"reset": False, "pos": True}),
    ("w_pos_bias", {"reset": True, "pos": False}),
    ("w_both_issues", {"reset": False, "pos": False}),
]


def main(k: int = 10, epochs: float = 3.0, seeds=(0,), quick=False):
    setup = ReproSetup.default()
    if quick:
        epochs, seeds = 1.0, (0,)
    rows = []
    for seed in seeds:
        for name, fixes in VARIANTS:
            r = run_paradigm(setup, paradigm="dti", k=k, epochs=epochs,
                             seed=seed, fixes=fixes)
            r["variant"] = name
            rows.append(r)
            emit(f"fig3_{name}_k{k}_seed{seed}", r["train_time_s"] * 1e6,
                 f"auc={r['auc']:.4f} logloss={r['log_loss']:.4f}")
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--epochs", type=float, default=3.0)
    ap.add_argument("--seeds", type=int, nargs="+", default=[0])
    a = ap.parse_args()
    main(k=a.k, epochs=a.epochs, seeds=tuple(a.seeds), quick=a.quick)
