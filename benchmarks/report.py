"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load(mesh):
    recs = {}
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"])] = r
    return recs


def dryrun_table() -> str:
    single, multi = load("16x16"), load("2x16x16")
    lines = [
        "| arch | shape | kind | compile 16x16 / 2x16x16 (s) | "
        "GiB/dev 16x16 / 2x16x16 | HLO GFLOPs/dev | collective GiB/dev |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in single:
        s, m = single[key], multi.get(key)
        lines.append(
            f"| {key[0]} | {key[1]} | {s['kind']} | "
            f"{s['compile_s']:.1f} / {m['compile_s']:.1f} | "
            f"{s['per_device']['peak_bytes_est']/2**30:.2f} / "
            f"{m['per_device']['peak_bytes_est']/2**30:.2f} | "
            f"{s['per_device']['hlo_flops']/1e9:.1f} | "
            f"{s['per_device']['collective_bytes']/2**30:.2f} |")
    return "\n".join(lines)


def roofline_table(mesh="16x16") -> str:
    recs = load(mesh)
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bound | "
        "model/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (a, sh), r in recs.items():
        rf = r["roofline"]
        lines.append(
            f"| {a} | {sh} | {rf['compute_s']:.2e} | {rf['memory_s']:.2e} | "
            f"{rf['collective_s']:.2e} | {rf['bottleneck']} | "
            f"{rf['model_flops_ratio']:.2f} | "
            f"{rf['roofline_fraction']:.5f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("## Dry-run\n")
    print(dryrun_table())
    print("\n## Roofline (16x16)\n")
    print(roofline_table("16x16"))
    print("\n## Roofline (2x16x16)\n")
    print(roofline_table("2x16x16"))
