"""Paper Table 1 / Figure 2 — CTR quality: SW vs DTI- vs DTI across k.

Reduced-scale reproduction (see benchmarks/common.py): one synthetic
dataset, the SW baseline, DTI without the bottleneck fixes (DTI-), and full
DTI, swept over k. The paper's claims being tested:

  1. DTI- degrades monotonically-ish as k grows (hidden-state leakage +
     positional-bias overfitting);
  2. DTI with both fixes holds SW-level AUC at every k;
  3. both at a fraction of SW's wall-clock.
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import ReproSetup, emit, run_paradigm

OUT = os.path.join(os.path.dirname(__file__), "artifacts",
                   "table1_ctr_quality.json")


def main(ks=(5, 10, 20), epochs: float = 3.0, seeds=(0,), quick=False):
    setup = ReproSetup.default()
    if quick:
        ks, epochs, seeds = (5,), 1.0, (0,)
    rows = []
    for seed in seeds:
        sw = run_paradigm(setup, paradigm="sw", k=1, epochs=epochs,
                          seed=seed)
        sw["variant"] = "SW"
        rows.append(sw)
        emit(f"table1_sw_seed{seed}", sw["train_time_s"] * 1e6,
             f"auc={sw['auc']:.4f} logloss={sw['log_loss']:.4f} "
             f"f1={sw['f1']:.4f}")
        for k in ks:
            for variant, fixes in [("DTI-", {"reset": False, "pos": False}),
                                   ("DTI", {"reset": True, "pos": True})]:
                r = run_paradigm(setup, paradigm="dti", k=k, epochs=epochs,
                                 seed=seed, fixes=fixes)
                r["variant"] = variant
                rows.append(r)
                rel = (r["auc"] - sw["auc"]) / sw["auc"] * 100
                emit(f"table1_{variant.lower()}_k{k}_seed{seed}",
                     r["train_time_s"] * 1e6,
                     f"auc={r['auc']:.4f} logloss={r['log_loss']:.4f} "
                     f"f1={r['f1']:.4f} rel_imp={rel:+.2f}%")
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--epochs", type=float, default=3.0)
    ap.add_argument("--ks", type=int, nargs="+", default=[5, 10, 20])
    ap.add_argument("--seeds", type=int, nargs="+", default=[0])
    a = ap.parse_args()
    main(ks=tuple(a.ks), epochs=a.epochs, seeds=tuple(a.seeds),
         quick=a.quick)
