"""Kernel microbenchmarks (CPU): fwd AND fwd+bwd timings for the three
attention paths, plus an eq3-style FLOPs/bytes account of the fused
windowed kernel vs the dense counterfactual.

On CPU the Pallas kernels run in interpret mode (correctness harness, not a
perf surface), so the timing rows compare the *jnp execution shapes* the
kernels encode: blocked-local O(S*2W) attention vs dense O(S^2) is the
structural win the paper's windowed causal attention buys. The fwd+bwd rows
exercise the kernel's flash-style custom VJP end to end — the training
pass is where the paper's 92% reduction lives, so the trajectory tracks
both directions.

``--json`` additionally writes a ``BENCH_kernels.json`` artifact
(rows + the analytic account) for CI trend tracking.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from benchmarks.common import ROWS, emit, time_fn
from repro.core.windowed import attention_blocked, attention_dense
from repro.kernels.windowed_attn.ops import windowed_attention
from repro.sparse.embedding import embedding_bag

ACCOUNTS: Dict[str, Dict] = {}


def flash_account(B: int, H: int, S: int, D: int, W: int, *,
                  bytes_el: int = 4) -> Dict[str, float]:
    """Analytic FLOPs / HBM-bytes model of the fused windowed kernel
    (eq3-style: the ratio vs the dense counterfactual is the claim).

    Forward: 2 banded matmuls (qk, pv) over ctx=min(W,S) keys per query.
    Backward: 7 banded matmuls — the dq pass recomputes qk and forms
    dp = do.v^T and dq = ds.k; the dk/dv pass recomputes qk, dp and forms
    dv = p^T.do, dk = ds^T.q (probabilities are never stored, only the
    (B,H,S) logsumexp + delta rows move through HBM).
    Dense counterfactual: the same matmuls over all S keys, plus the
    (S, S) probability tensor materialised fwd and bwd.
    """
    ctx = min(W, S)
    mm = 2.0 * B * H * S * ctx * D          # one banded matmul
    mm_dense = 2.0 * B * H * S * S * D
    bhsd = B * H * S * D * bytes_el
    bhs = B * H * S * bytes_el
    acct = {
        "B": B, "H": H, "S": S, "D": D, "W": W,
        "flops_fwd": 2 * mm,
        "flops_bwd": 7 * mm,
        "flops_fwd_dense": 2 * mm_dense,
        "flops_bwd_dense": 7 * mm_dense,
        # fwd: read q,k,v, write o + lse residual
        "bytes_fwd": 4 * bhsd + bhs,
        # bwd: read q,k,v,o,do + lse,delta, write dq,dk,dv
        "bytes_bwd": 8 * bhsd + 2 * bhs,
        # dense materialises the (S,S) probs fwd and again in bwd
        "bytes_probs_dense": 2.0 * B * H * S * S * bytes_el,
        "flops_reduction": S / ctx,
    }
    acct["intensity_fwd"] = acct["flops_fwd"] / acct["bytes_fwd"]
    acct["intensity_bwd"] = acct["flops_bwd"] / acct["bytes_bwd"]
    return acct


def attention_scaling():
    B, H, D, W = 2, 4, 32, 128
    for S in (512, 1024, 2048):
        q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        dense = jax.jit(lambda q, k, v: attention_dense(
            q, k, v, pos_q=pos, pos_k=pos, window=W))
        blocked = jax.jit(lambda q, k, v: attention_blocked(
            q, k, v, pos_q=pos, pos_k=pos, window=W))
        td = time_fn(dense, q, k, v)
        tb = time_fn(blocked, q, k, v)
        emit(f"attn_dense_S{S}_W{W}", td, f"O(S^2) reference")
        emit(f"attn_blocked_S{S}_W{W}", tb,
             f"speedup={td / tb:.2f}x (O(S*2W))")
        ACCOUNTS[f"S{S}_W{W}"] = flash_account(B, H, S, D, W)


def attention_train_step():
    """fwd+bwd (the training pass) through each attention path; the Pallas
    rows run the real backward kernels via the custom VJP (interpret mode
    on CPU — a correctness/coverage surface, the TPU number is the
    roofline's job)."""
    B, H, D, W, S, blk = 1, 2, 32, 64, 256, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    kw = dict(pos_q=pos, pos_k=pos, window=W)
    paths = {
        "dense": lambda q, k, v: attention_dense(q, k, v, **kw),
        "blocked": lambda q, k, v: attention_blocked(q, k, v, **kw),
        "pallas_interp": lambda q, k, v: windowed_attention(
            q, k, v, **kw, block_size=blk),
    }
    acct = flash_account(B, H, S, D, W)
    for name, fn in paths.items():
        fwd = jax.jit(lambda q, k, v, fn=fn: fn(q, k, v).sum())
        bwd = jax.jit(jax.grad(lambda q, k, v, fn=fn: fn(q, k, v).sum(),
                               argnums=(0, 1, 2)))
        tf = time_fn(fwd, q, k, v, warmup=1, iters=3)
        tb = time_fn(bwd, q, k, v, warmup=1, iters=3)
        # jax.grad re-runs the forward, so tb covers fwd+bwd:
        # model ratio = (2 + 7) banded matmuls / 2 = 4.5x the fwd
        model_ratio = (acct["flops_fwd"] + acct["flops_bwd"]) \
            / acct["flops_fwd"]
        emit(f"attn_{name}_fwd_S{S}_W{W}", tf,
             f"{acct['flops_fwd'] / tf:.0f} flop/us (banded model)")
        emit(f"attn_{name}_fwdbwd_S{S}_W{W}", tb,
             f"fwdbwd/fwd={tb / tf:.2f}x (model {model_ratio:.1f}x)")
    ACCOUNTS[f"train_S{S}_W{W}"] = acct


def embedding_bag_bench():
    V, D, B, H = 100_000, 64, 4096, 20
    table = jax.random.normal(jax.random.PRNGKey(0), (V, D))
    ids = jax.random.randint(jax.random.PRNGKey(1), (B, H), 0, V)
    valid = jnp.ones((B, H), bool)
    bag = jax.jit(lambda t, i, v: embedding_bag(t, i, v, mode="sum"))
    t = time_fn(bag, table, ids, valid)
    emit(f"embedding_bag_V{V}_B{B}_H{H}", t,
         f"{B * H / t:.1f} lookups/us")


def autotune_sweep():
    """Report what the block-size autotuner resolves (and, on TPU,
    measures) for the shapes the serving/training paths actually run.
    Off-TPU the sweeps time nothing — the rows carry the table defaults so
    the artifact still records what each geometry resolves to.

    The sweep records into a ``repro.obs.metrics`` registry and the rows
    are read back out of its snapshot: the registry is the path of record
    (mergeable across per-shape processes, same discipline as the serve
    telemetry), not a side channel next to the artifact."""
    from repro.kernels.autotune import (measure_decode, measure_train,
                                        measured_table)
    from repro.obs.metrics import MetricsRegistry
    reg = MetricsRegistry()
    shapes = ([(f"autotune_decode_cap{c}", c, measure_decode)
               for c in (128, 256, 1024)]
              + [(f"autotune_train_S{s}", s, measure_train)
                 for s in (512, 2048)])
    for name, size, measure in shapes:
        r = measure(size)
        reg.gauge(f"{name}.best_us").set(
            min(r["timings_us"].values()) if r["measured"] else 0.0)
        reg.gauge(f"{name}.block").set(int(r["block"]))
        reg.counter(f"{name}.measured").set(int(bool(r["measured"])))
    snap = reg.snapshot(prefix="autotune_")
    for name, _, _ in shapes:
        measured = bool(snap[f"{name}.measured"]["value"])
        emit(name, snap[f"{name}.best_us"]["value"],
             f"block={int(snap[f'{name}.block']['value'])} "
             + ("(measured)" if measured else "(table default)"))
    ACCOUNTS["autotune_measured"] = measured_table()


def main(json_path: Optional[str] = None):
    n0 = len(ROWS)
    attention_scaling()
    attention_train_step()
    embedding_bag_bench()
    autotune_sweep()
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"rows": ROWS[n0:], "accounts": ACCOUNTS}, f,
                      indent=2)
        print(f"# wrote {json_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_kernels.json",
                    default=None, metavar="PATH",
                    help="also write rows + FLOPs/bytes accounts as JSON "
                         "(default path: BENCH_kernels.json)")
    main(ap.parse_args().json)
