"""Kernel microbenchmarks (CPU): blocked/windowed attention vs dense oracle
cost scaling, embedding-bag substrate vs naive gather+sum.

On CPU the Pallas kernels run in interpret mode (correctness harness, not a
perf surface), so the timing rows compare the *jnp execution shapes* the
kernels encode: blocked-local O(S*2W) attention vs dense O(S^2) is the
structural win the paper's windowed causal attention buys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.windowed import attention_blocked, attention_dense
from repro.sparse.embedding import embedding_bag


def attention_scaling():
    B, H, D, W = 2, 4, 32, 128
    for S in (512, 1024, 2048):
        q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        dense = jax.jit(lambda q, k, v: attention_dense(
            q, k, v, pos_q=pos, pos_k=pos, window=W))
        blocked = jax.jit(lambda q, k, v: attention_blocked(
            q, k, v, pos_q=pos, pos_k=pos, window=W))
        td = time_fn(dense, q, k, v)
        tb = time_fn(blocked, q, k, v)
        emit(f"attn_dense_S{S}_W{W}", td, f"O(S^2) reference")
        emit(f"attn_blocked_S{S}_W{W}", tb,
             f"speedup={td / tb:.2f}x (O(S*2W))")


def embedding_bag_bench():
    V, D, B, H = 100_000, 64, 4096, 20
    table = jax.random.normal(jax.random.PRNGKey(0), (V, D))
    ids = jax.random.randint(jax.random.PRNGKey(1), (B, H), 0, V)
    valid = jnp.ones((B, H), bool)
    bag = jax.jit(lambda t, i, v: embedding_bag(t, i, v, mode="sum"))
    t = time_fn(bag, table, ids, valid)
    emit(f"embedding_bag_V{V}_B{B}_H{H}", t,
         f"{B * H / t:.1f} lookups/us")


def main():
    attention_scaling()
    embedding_bag_bench()


if __name__ == "__main__":
    main()
